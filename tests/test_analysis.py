"""vscheck analyzer tests: IR walker, abstract contract proofs, lint.

The property tests sweep randomized conv geometries (kernel x stride x
dilation x groups x tiny maps) and assert the three claims the analyzer
makes hold together: the abstract interval proof accepts the layer, the
byte derivation matches the kernel cost contract exactly (a VSC202/203
error would surface as a report error), and a *real* sparsified encoding
stays inside the abstract bounds with a faithful DMA count no larger
than the contract's budget.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.analysis import Report, VSCheckError
from repro.analysis.contracts import (
    _bounds_violations, _contract_fetches, _faithful_fetches, _offsets,
    canonical_conv_idx, check_contracts,
)
from repro.analysis.ir import check_net
from repro.analysis.lint import IMPL_VOCAB, lint_source
from repro.kernels.plan import conv_plan
from repro.models.graph import (
    Conv, FC, Flatten, Pool, ResidualAdd, Save, SparseNet,
    sparse_conv_from_dense,
)


def _single_conv_net(cin, cout, kh, kw, stride, groups, dilation,
                     allow_fallback=False):
    return SparseNet("prop", (
        Conv("c0", cin, cout, kh, kw, stride=stride, groups=groups,
             dilation=dilation, allow_fallback=allow_fallback),
    ))


@st.composite
def conv_geometries(draw):
    kind = draw(st.sampled_from(["dense", "dense", "grouped", "depthwise"]))
    kh = draw(st.sampled_from([1, 3, 5]))
    kw = draw(st.sampled_from([1, 3]))
    stride = draw(st.sampled_from([1, 2]))
    dilation = draw(st.sampled_from([1, 2]))
    h = draw(st.integers(min_value=6, max_value=14))
    w = draw(st.integers(min_value=6, max_value=14))
    density = draw(st.sampled_from([0.125, 0.25, 0.5, 1.0]))
    if kind == "dense":
        cin, cout, groups = draw(st.sampled_from(
            [(16, 64), (32, 128), (24, 64)])) + (1,)
    elif kind == "grouped":
        cin, cout, groups = draw(st.sampled_from(
            [(32, 64, 2), (64, 128, 4)]))
        if kh == 1 and kw == 1:
            kh = 3  # 1x1 grouped still runs the direct kernels; keep taps
    else:
        c = draw(st.sampled_from([32, 64]))
        cin = cout = groups = c
        if kh == 1 and kw == 1:
            kh = 3
    return (cin, cout, kh, kw, stride, groups, dilation, h, w, density)


class TestIRWalker:
    @pytest.mark.parametrize("name", [
        "vgg16", "resnet18", "resnet34", "resnet50", "mobilenet_v1"])
    def test_registered_nets_clean(self, name):
        from repro.analysis.__main__ import NETS
        net = NETS[name](image_size=32)
        nc = check_net(net, (1, 32, 32, 3))
        assert not nc.report.errors, nc.report.render()
        assert nc.conv_sites and nc.fc_sites

    def test_channel_mismatch_vsc101(self):
        net = SparseNet("bad", (Conv("c0", 3, 64, 3, 3),
                                Conv("c1", 32, 64, 3, 3)))
        rep = check_net(net, (1, 16, 16, 3)).report
        assert any(d.rule == "VSC101" for d in rep.errors)

    def test_undefined_slot_vsc104(self):
        net = SparseNet("bad", (Conv("c0", 3, 64, 3, 3),
                                ResidualAdd("nowhere")))
        rep = check_net(net, (1, 16, 16, 3)).report
        assert any(d.rule == "VSC104" for d in rep.errors)

    def test_residual_shape_mismatch_vsc105(self):
        net = SparseNet("bad", (
            Save("skip"),
            Conv("c0", 3, 64, 3, 3, stride=2),
            ResidualAdd("skip"),
        ))
        rep = check_net(net, (1, 16, 16, 3)).report
        assert any(d.rule == "VSC105" for d in rep.errors)

    def test_fc_fanin_mismatch_vsc106(self):
        net = SparseNet("bad", (
            Conv("c0", 3, 64, 3, 3),
            Pool(kind="gap"), Flatten(),
            FC("fc", 128, 10),
        ))
        rep = check_net(net, (1, 16, 16, 3)).report
        assert any(d.rule == "VSC106" for d in rep.errors)

    def test_channel_multiplier_vsc109(self):
        # multiplier-2 depthwise without allow_fallback is refused…
        net = _single_conv_net(32, 64, 3, 3, 1, 32, 1)
        rep = check_net(net, (1, 16, 16, 32)).report
        assert any(d.rule == "VSC109" for d in rep.errors)
        # …and downgraded to a warning (with a usable geometry) with it
        net = _single_conv_net(32, 64, 3, 3, 1, 32, 1, allow_fallback=True)
        nc = check_net(net, (1, 16, 16, 32))
        assert not nc.report.errors, nc.report.render()
        assert any(d.rule == "VSC109" for d in nc.report.warnings)
        assert nc.conv_sites[0].geom is not None


class TestContracts:
    @given(conv_geometries())
    @settings(max_examples=40, deadline=None)
    def test_random_geometry_proves_clean(self, geo):
        cin, cout, kh, kw, stride, groups, dilation, h, w, density = geo
        net = _single_conv_net(cin, cout, kh, kw, stride, groups, dilation)
        nc = check_net(net, (1, h, w, cin), density=density)
        assert not nc.report.errors, nc.report.render()
        rep, rows = check_contracts(nc)
        # zero errors here asserts: in-bounds proof (VSC201), exact byte
        # equality with the kernel CostEstimate (VSC202), traffic-model
        # agreement (VSC203), elision soundness (VSC204), FLOPs (VSC205)
        assert not rep.errors, rep.render()
        # halo + stack variants, each proved under both dtype contracts
        assert len(rows) == 4
        assert sorted(r.path for r in rows) == sorted(
            f"{nc.conv_sites[0].path}[{impl}{tag}]"
            for impl in ("halo", "stack") for tag in ("", ":int8"))

    @given(conv_geometries())
    @settings(max_examples=15, deadline=None)
    def test_real_encoding_within_abstract_bounds(self, geo):
        cin, cout, kh, kw, stride, groups, dilation, h, w, density = geo
        net = _single_conv_net(cin, cout, kh, kw, stride, groups, dilation)
        nc = check_net(net, (1, h, w, cin), density=density)
        site = nc.conv_sites[0]
        g = site.geom
        rng = np.random.default_rng(abs(hash(geo)) % 2**32)
        wd = rng.standard_normal(
            (kh, kw, cin // groups, cout)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(
            wd, density, vk=g.vk if not g.depthwise else 32, vn=g.vn,
            stride=stride, groups=groups, dilation=dilation)
        real_idx = np.asarray(spec.vs.idx, np.int64)
        for impl in ("halo", "stack"):
            plan = conv_plan(
                site.x_shape, kh=kh, kw=kw, stride=stride, groups=groups,
                dilation=dilation, cout=cout, s_steps=real_idx.shape[1],
                vk=g.vk, vn=g.vn, impl=impl, has_bias=True,
                has_residual=False, itemsize=4)
            cbg = 1 if g.depthwise else (site.x_shape[3] // g.vk) // groups
            canon = canonical_conv_idx(plan.nb, plan.s_steps, cbg) \
                if plan.kind != "vsmm" else real_idx
            for buf in plan.buffers:
                # the interval proof is idx-independent: it must hold for
                # the real encoding because it held for AbstractIdx
                assert not _bounds_violations(plan, buf), (impl, buf.name)
                if buf.policy == "excluded":
                    continue
                offs = _offsets(plan, buf, real_idx)
                assert offs.min() >= 0
                budget = _contract_fetches(
                    plan, buf, _offsets(plan, buf, canon))
                if buf.name == "input":
                    # the cin-major store order keeps the faithful DMA
                    # count of ANY balanced encoding within the budget the
                    # canonical worst case sets
                    assert _faithful_fetches(offs) <= budget, \
                        (impl, buf.name)

    def test_canonical_idx_matches_real_full_density(self):
        # at density 1 the stored set is all kb tiles, so the real
        # cin-major order must equal canonical_conv_idx exactly
        kh, kw, cin, cout = 3, 3, 32, 128
        wd = np.random.default_rng(0).standard_normal(
            (kh, kw, cin, cout)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(wd, 1.0, vk=32, vn=128)
        real = np.asarray(spec.vs.idx, np.int64)
        canon = canonical_conv_idx(real.shape[0], real.shape[1], cin // 32)
        np.testing.assert_array_equal(real, canon)

    @given(conv_geometries())
    @settings(max_examples=6, deadline=None)
    def test_executed_kernel_matches_planned_shape(self, geo):
        # the plan's geometry must describe the kernel that actually runs:
        # execute the real sparsified conv (interpret mode) and check the
        # output extents the IR walker predicted
        import jax.numpy as jnp

        from repro.kernels import vsconv

        cin, cout, kh, kw, stride, groups, dilation, h, w, density = geo
        net = _single_conv_net(cin, cout, kh, kw, stride, groups, dilation)
        nc = check_net(net, (1, h, w, cin), density=density)
        g = nc.conv_sites[0].geom
        rng = np.random.default_rng(abs(hash(geo)) % 2**32)
        wd = rng.standard_normal(
            (kh, kw, cin // groups, cout)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(
            wd, density, vk=g.vk if not g.depthwise else 32, vn=g.vn,
            stride=stride, groups=groups, dilation=dilation)
        x = jnp.asarray(rng.standard_normal((1, h, w, cin)), jnp.float32)
        out = vsconv(x, spec.vs, kh=kh, kw=kw, stride=stride, groups=groups,
                     dilation=dilation, interpret=True)
        assert out.shape == (1, -(-h // stride), -(-w // stride), cout)

    def test_selftest_catches_every_seed(self, capsys):
        from repro.analysis.__main__ import run_selftest
        assert run_selftest(), capsys.readouterr().out


class TestLint:
    def test_impl_typo_vsc301(self):
        rep = Report()
        lint_source("y = vsconv(x, vs, impl='hallo')\n", "f.py", rep=rep)
        assert any(d.rule == "VSC301" for d in rep.errors)
        rep = Report()
        for good in sorted(IMPL_VOCAB):
            lint_source(f"y = vsconv(x, vs, impl='{good}')\n", "f.py",
                        rep=rep)
        assert not rep.errors

    def test_clock_in_scheduler_branch_vsc302(self):
        src = ("import time\n"
               "while time.monotonic() < deadline:\n    pass\n")
        rep = Report()
        lint_source(src, "replica_scheduler.py", rep=rep)
        assert any(d.rule == "VSC302" for d in rep.errors)
        rep = Report()  # same pattern outside scheduler files is fine
        lint_source(src, "bench.py", rep=rep)
        assert not rep.errors

    def test_env_mutation_vsc303_scoping(self):
        rep = Report()
        lint_source("import os\nos.environ['A'] = '1'\n", "f.py", rep=rep)
        assert any(d.rule == "VSC303" for d in rep.errors)
        # inside a function or the __main__ guard it's allowed
        rep = Report()
        lint_source(
            "import os\n"
            "def main():\n    os.environ['A'] = '1'\n"
            "if __name__ == '__main__':\n    os.environ['B'] = '2'\n",
            "f.py", rep=rep)
        assert not rep.errors
        # …but a module-scope try/if body still runs at import time
        rep = Report()
        lint_source(
            "import os\ntry:\n    os.environ['A'] = '1'\n"
            "except KeyError:\n    pass\n", "f.py", rep=rep)
        assert any(d.rule == "VSC303" for d in rep.errors)

    def test_blanket_except_in_launch_vsc304(self):
        src = ("try:\n    run.dispatch()\n"
               "except Exception:\n    pass\n")
        rep = Report()
        lint_source(src, "src/repro/launch/scheduler.py", rep=rep)
        assert any(d.rule == "VSC304" for d in rep.errors)
        # bare except and tuple-smuggled blankets are caught too
        for body in ("except:", "except (ValueError, BaseException):"):
            rep = Report()
            lint_source(f"try:\n    f()\n{body}\n    pass\n",
                        "src/repro/launch/serve.py", rep=rep)
            assert any(d.rule == "VSC304" for d in rep.errors), body
        # typed handlers in launch are fine
        rep = Report()
        lint_source("try:\n    f()\nexcept (ValueError, KeyError):\n"
                    "    pass\n", "src/repro/launch/serve.py", rep=rep)
        assert not rep.errors
        # the same blanket outside launch/ is out of scope
        rep = Report()
        lint_source(src, "src/repro/kernels/ops.py", rep=rep)
        assert not rep.errors
        # waivers work for VSC304 like the other lint rules
        rep = Report()
        lint_source("try:\n    f()\n"
                    "# vscheck: ignore[VSC304] - sweep driver\n"
                    "except Exception:\n    pass\n",
                    "src/repro/launch/dryrun.py", rep=rep)
        assert not rep.errors

    def test_inline_waiver_covers_next_line(self):
        rep = Report()
        lint_source(
            "import os\n"
            "# vscheck: ignore[VSC303] - must precede the jax import\n"
            "os.environ['XLA_FLAGS'] = '-x'\n", "f.py", rep=rep)
        assert not rep.errors


class TestServeGate:
    def test_validate_net_refuses_malformed(self):
        from repro.launch.serve import validate_net
        net = SparseNet("bad", (Conv("c0", 3, 64, 3, 3),
                                Conv("c1", 32, 64, 3, 3)))
        with pytest.raises(VSCheckError) as ei:
            validate_net(net, 32)
        assert any(d.rule == "VSC101" for d in ei.value.diagnostics)

    def test_validate_net_accepts_registered(self):
        from repro.analysis.__main__ import NETS
        from repro.launch.serve import validate_net
        validate_net(NETS["resnet18"](image_size=32), 32)
