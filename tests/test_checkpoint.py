"""Checkpoint integrity: per-array checksums catch corruption, torn
writes and missing data files, and the error names the bad array."""
import json
import os
import tempfile

import numpy as np
import pytest

from repro.checkpoint import CheckpointError, CheckpointManager


def _tree():
    return {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.ones((4,), np.float32),
    }


def _save(d, step=0):
    cm = CheckpointManager(d, async_save=False)
    cm.save(step, _tree(), metadata={"k": "v"})
    return cm


class TestIntegrity:
    def test_roundtrip_with_checksums(self):
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            with open(os.path.join(d, "step_0", "manifest.json")) as f:
                manifest = json.load(f)
            assert all(len(leaf["sha256"]) == 64
                       for leaf in manifest["leaves"])
            tree, step, meta = cm.restore(_tree())
            assert step == 0 and meta == {"k": "v"}
            np.testing.assert_array_equal(np.asarray(tree["w"]),
                                          _tree()["w"])

    def test_corrupted_leaf_named(self):
        """Flip bytes in one array's file: restore raises CheckpointError
        naming that array, not a garbage deserialization."""
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            with open(os.path.join(d, "step_0", "manifest.json")) as f:
                manifest = json.load(f)
            bad = next(leaf for leaf in manifest["leaves"]
                       if leaf["path"] == "['w']")
            fpath = os.path.join(d, "step_0", bad["file"])
            data = bytearray(open(fpath, "rb").read())
            data[-4] ^= 0xFF  # corrupt payload, header stays parseable
            open(fpath, "wb").write(bytes(data))
            with pytest.raises(CheckpointError, match=r"\['w'\]"):
                cm.restore(_tree())

    def test_truncated_leaf_named(self):
        """A torn write (short file) fails the checksum with the array
        named."""
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            with open(os.path.join(d, "step_0", "manifest.json")) as f:
                manifest = json.load(f)
            bad = next(leaf for leaf in manifest["leaves"]
                       if leaf["path"] == "['b']")
            fpath = os.path.join(d, "step_0", bad["file"])
            data = open(fpath, "rb").read()
            open(fpath, "wb").write(data[: len(data) // 2])
            with pytest.raises(CheckpointError, match=r"\['b'\]"):
                cm.restore(_tree())

    def test_missing_leaf_file_named(self):
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            with open(os.path.join(d, "step_0", "manifest.json")) as f:
                manifest = json.load(f)
            bad = next(leaf for leaf in manifest["leaves"]
                       if leaf["path"] == "['w']")
            os.remove(os.path.join(d, "step_0", bad["file"]))
            with pytest.raises(CheckpointError,
                               match=r"missing the data file.*\['w'\]"):
                cm.restore(_tree())

    def test_torn_manifest(self):
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            mpath = os.path.join(d, "step_0", "manifest.json")
            data = open(mpath).read()
            open(mpath, "w").write(data[: len(data) // 2])
            with pytest.raises(CheckpointError, match="manifest"):
                cm.restore(_tree())

    def test_legacy_manifest_without_checksums(self):
        """Manifests written before checksums existed still restore
        (shape-checked only)."""
        with tempfile.TemporaryDirectory() as d:
            cm = _save(d)
            mpath = os.path.join(d, "step_0", "manifest.json")
            with open(mpath) as f:
                manifest = json.load(f)
            for leaf in manifest["leaves"]:
                del leaf["sha256"]
            with open(mpath, "w") as f:
                json.dump(manifest, f)
            tree, step, _ = cm.restore(_tree())
            np.testing.assert_array_equal(np.asarray(tree["b"]),
                                          _tree()["b"])
