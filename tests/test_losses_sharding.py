"""Chunked vocab-sharded CE vs dense oracle; MeshRules spec derivation."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as PS

from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as shd
from repro.parallel.losses import chunked_cross_entropy, cross_entropy_dense


def _abstract_mesh(sizes, names):
    """AbstractMesh ctor compat: new jax takes (sizes, names), 0.4.37 takes
    a tuple of (name, size) pairs."""
    try:
        return jax.sharding.AbstractMesh(sizes, names)
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(names, sizes)))


class TestChunkedCE:
    @pytest.mark.parametrize("t,chunk", [(16, 4), (16, 16), (15, 4)])
    def test_matches_dense(self, t, chunk, rng):
        b, d, v = 3, 8, 32
        h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
        got = chunked_cross_entropy(h, labels, w, real_vocab=v, chunk=chunk)
        ref = cross_entropy_dense(jnp.einsum("btd,dv->btv", h, w), labels)
        assert abs(float(got) - float(ref)) < 1e-4

    def test_padded_vocab_masked(self, rng):
        b, t, d, v, vp = 2, 8, 8, 30, 32
        h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, vp)), jnp.float32)
        # put huge weight on padded columns; they must not affect the loss
        w = w.at[:, v:].set(100.0)
        labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
        got = chunked_cross_entropy(h, labels, w, real_vocab=v)
        ref = cross_entropy_dense(
            jnp.einsum("btd,dv->btv", h, w[:, :v]), labels)
        assert abs(float(got) - float(ref)) < 1e-4

    def test_z_loss_positive(self, rng):
        b, t, d, v = 2, 8, 8, 32
        h = jnp.asarray(10 * rng.standard_normal((b, t, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
        base = chunked_cross_entropy(h, labels, w, real_vocab=v)
        with_z = chunked_cross_entropy(h, labels, w, real_vocab=v,
                                       z_weight=1e-2)
        assert float(with_z) > float(base)

    def test_mask_excludes_positions(self, rng):
        b, t, d, v = 2, 8, 8, 32
        h = jnp.asarray(rng.standard_normal((b, t, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
        labels = jnp.asarray(rng.integers(0, v, (b, t)), jnp.int32)
        mask = jnp.zeros((b, t), bool).at[:, :4].set(True)
        got = chunked_cross_entropy(h, labels, w, real_vocab=v, mask=mask)
        ref = chunked_cross_entropy(h[:, :4], labels[:, :4], w, real_vocab=v)
        assert abs(float(got) - float(ref)) < 1e-4


class TestMeshRules:
    def _mesh(self):
        return make_local_mesh(data=1, model=1)

    def test_spec_demotes_non_divisible(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = shd.TRAIN_RULES
        # 8 kv heads over 16-way model axis would not divide on the real
        # mesh; emulate with a shape check against a fake axis size via the
        # real mesh (1 divides everything -> stays)
        spec = shd.spec_for(("batch", "kv_heads"), mesh=mesh, rules=rules,
                            shape=(4, 8))
        assert spec == PS("data", "model")

    def test_missing_axis_filtered(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = shd.spec_for(("batch",), mesh=mesh, rules=shd.TRAIN_RULES,
                            shape=(8,))
        # batch maps to ('pod','data'); 'pod' absent from this mesh
        assert spec == PS("data")

    def test_repeated_axis_demoted(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = shd.spec_for(("heads", "ff"), mesh=mesh, rules=shd.TRAIN_RULES,
                            shape=(4, 4))
        # both want 'model'; the second claim loses
        assert spec == PS("model", None)

    def test_divisibility_guard(self):
        # AbstractMesh: spec_for only consults mesh.shape (no devices needed)
        mesh = _abstract_mesh((1, 2), ("data", "model"))
        spec = shd.spec_for(("ff",), mesh=mesh, rules=shd.TRAIN_RULES,
                            shape=(7,))  # 7 % 2 != 0 -> replicate
        assert spec == PS(None)
        spec2 = shd.spec_for(("ff",), mesh=mesh, rules=shd.TRAIN_RULES,
                             shape=(8,))
        assert spec2 == PS("model")

    def test_kv_heads_demoted_on_16way_axis(self):
        mesh = _abstract_mesh((16, 16), ("data", "model"))
        spec = shd.spec_for(("batch", None, "kv_heads", "head_dim"),
                            mesh=mesh, rules=shd.TRAIN_RULES,
                            shape=(256, 4096, 8, 128))
        assert spec == PS("data", None, None, None)  # 8 % 16 != 0

    def test_logical_noop_outside_mesh(self, rng):
        x = jnp.ones((4, 4))
        assert shd.logical(x, ("batch", None)) is x

    def test_constraint_applies_in_mesh(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with shd.use_mesh(mesh, shd.TRAIN_RULES):
            y = shd.logical(jnp.ones((4, 4)), ("batch", "ff"))
            assert y.shape == (4, 4)
