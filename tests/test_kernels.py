"""Pallas kernels vs pure-jnp oracles: shape/dtype/density sweeps.

Kernels run interpret=True on CPU (the TPU lowering is exercised by the
BlockSpecs themselves — identical index maps either way).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import encode, prune_vectors_balanced
from repro.kernels import vsmm, vsconv
from repro.kernels.ref import vsmm_ref, vsconv_ref


def _sparse(rng, k, n, vk, vn, density, dtype):
    w = rng.standard_normal((k, n)).astype(np.float32)
    wp, _ = prune_vectors_balanced(w, density, vk, vn)
    return encode(jnp.asarray(wp, dtype), vk, vn)


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


class TestVsmm:
    @pytest.mark.parametrize("m,k,n,vk,vn,density", [
        (64, 256, 256, 32, 128, 0.25),
        (100, 256, 512, 16, 128, 0.5),      # M padding path
        (7, 128, 128, 128, 128, 1.0),       # dense special case, tiny M
        (256, 512, 128, 64, 128, 0.125),
        (32, 64, 128, 8, 128, 0.5),         # small vk
    ])
    def test_matches_ref_f32(self, m, k, n, vk, vn, density, rng):
        vs = _sparse(rng, k, n, vk, vn, density, jnp.float32)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        assert _rel_err(vsmm(x, vs), vsmm_ref(x, vs)) < 1e-5

    @pytest.mark.parametrize("dtype,tol", [(jnp.bfloat16, 3e-2), (jnp.float32, 1e-5)])
    def test_dtypes(self, dtype, tol, rng):
        vs = _sparse(rng, 256, 256, 32, 128, 0.5, dtype)
        x = jnp.asarray(rng.standard_normal((64, 256)), dtype)
        assert _rel_err(vsmm(x, vs), vsmm_ref(x, vs)) < tol

    def test_zero_input_rows_skip_is_exact(self, rng):
        """Runtime input skipping must not change results (zeros contribute
        nothing) — the paper's input-side skip is exact, not approximate."""
        vs = _sparse(rng, 256, 256, 32, 128, 0.5, jnp.float32)
        x = np.maximum(rng.standard_normal((64, 256)), 0).astype(np.float32)
        x[: 32] = 0.0  # a fully-zero activation block
        x = jnp.asarray(x)
        on = vsmm(x, vs, skip_zero_inputs=True)
        off = vsmm(x, vs, skip_zero_inputs=False)
        assert _rel_err(on, off) < 1e-6
        assert np.asarray(on)[:32].max() == 0.0

    @settings(max_examples=12, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32, 64]),
           st.sampled_from([1, 2, 4]))
    def test_property_random_shapes(self, seed, vk, sfrac):
        rng = np.random.default_rng(seed)
        kb = 4
        k, n = kb * vk, 256
        vs = _sparse(rng, k, n, vk, 128, sfrac / 4, jnp.float32)
        x = jnp.asarray(rng.standard_normal((48, k)), jnp.float32)
        assert _rel_err(vsmm(x, vs), vsmm_ref(x, vs)) < 1e-5


class TestVsconv:
    @pytest.mark.parametrize("n,h,w,c,co,vk,vn,density", [
        (2, 14, 14, 64, 128, 32, 128, 0.3),
        (1, 7, 9, 128, 256, 64, 128, 0.5),   # odd spatial + bh padding
        (1, 8, 8, 32, 128, 32, 128, 1.0),    # dense special case
        (1, 16, 16, 32, 64, 32, 64, 0.25),   # vn < 128
    ])
    def test_matches_ref(self, n, h, w, c, co, vk, vn, density, rng):
        wmat = rng.standard_normal((9 * c, co)).astype(np.float32)
        wp, _ = prune_vectors_balanced(wmat, density, vk, vn)
        vs = encode(jnp.asarray(wp), vk, vn)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((n, h, w, c)), 0), jnp.float32)
        assert _rel_err(vsconv(x, vs), vsconv_ref(x, vs)) < 1e-5

    def test_post_relu_zero_planes(self, rng):
        """Whole zero input row-blocks (the paper's dashed blocks)."""
        c, co = 32, 128
        wmat = rng.standard_normal((9 * c, co)).astype(np.float32)
        wp, _ = prune_vectors_balanced(wmat, 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = np.maximum(rng.standard_normal((1, 16, 8, c)), 0).astype(np.float32)
        x[:, 4:12] = 0.0
        x = jnp.asarray(x)
        assert _rel_err(vsconv(x, vs), vsconv_ref(x, vs)) < 1e-5

    def test_bf16(self, rng):
        c, co = 32, 128
        wmat = rng.standard_normal((9 * c, co)).astype(np.float32)
        wp, _ = prune_vectors_balanced(wmat, 0.5, 32, 128)
        vs = encode(jnp.asarray(wp, jnp.bfloat16), 32, 128)
        x = jnp.asarray(np.maximum(rng.standard_normal((1, 8, 8, c)), 0),
                        jnp.bfloat16)
        assert _rel_err(vsconv(x, vs), vsconv_ref(x, vs)) < 5e-2


class TestStructuralFlopSkip:
    def test_sparse_grid_smaller_than_dense(self, rng):
        """The kernel's grid (and its CostEstimate FLOPs) scale with density —
        the zero weight vectors are structurally absent, like vectors absent
        from the paper's SRAM."""
        k = n = 256
        x = jnp.asarray(rng.standard_normal((64, k)), jnp.float32)
        flops = {}
        for dens in (0.25, 1.0):
            vs = _sparse(rng, k, n, 32, 128, dens, jnp.float32)
            flops[dens] = 2 * 64 * vs.n_strips * vs.nnz_per_strip * vs.vk * vs.vn
        assert flops[0.25] == flops[1.0] * 0.25


class TestFlashKernel:
    """Pallas flash-attention fwd vs naive softmax oracle."""

    @staticmethod
    def _naive(q, k, v, causal=True, window=None, q_offset=0):
        import jax
        bh, tq, hd = q.shape
        tk = k.shape[1]
        s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * hd ** -0.5
        qp = q_offset + jnp.arange(tq)[:, None]
        kp = jnp.arange(tk)[None, :]
        m = jnp.ones((tq, tk), bool)
        if causal:
            m &= qp >= kp
        if window is not None:
            m &= qp - kp < window
        s = jnp.where(m[None], s, -1e30)
        return jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(s, -1),
                          v.astype(jnp.float32))

    @pytest.mark.parametrize("case", [
        dict(bh=4, tq=128, tk=128, hd=64, bq=32, bk=32, causal=True),
        dict(bh=2, tq=64, tk=128, hd=32, bq=32, bk=64, causal=False),
        dict(bh=2, tq=128, tk=128, hd=64, bq=64, bk=32, causal=True, window=16),
        dict(bh=1, tq=32, tk=256, hd=64, bq=32, bk=64, causal=True, q_offset=224),
    ])
    def test_matches_naive(self, case, rng):
        from repro.kernels.flash import flash_fwd_pallas
        bh, tq, tk, hd = case["bh"], case["tq"], case["tk"], case["hd"]
        q = jnp.asarray(rng.standard_normal((bh, tq, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((bh, tk, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((bh, tk, hd)), jnp.float32)
        kw = {k_: v_ for k_, v_ in case.items()
              if k_ in ("causal", "window", "q_offset", "bq", "bk")}
        out = flash_fwd_pallas(q, k, v, interpret=True, **kw)
        ref = self._naive(q, k, v, case.get("causal", True),
                          case.get("window"), case.get("q_offset", 0))
        assert _rel_err(out, ref) < 2e-5

    def test_bf16(self, rng):
        from repro.kernels.flash import flash_fwd_pallas
        q = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.bfloat16)
        k = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.bfloat16)
        v = jnp.asarray(rng.standard_normal((2, 64, 128)), jnp.bfloat16)
        out = flash_fwd_pallas(q, k, v, bq=32, bk=32, interpret=True)
        ref = self._naive(q, k, v)
        assert _rel_err(out, ref) < 3e-2

    def test_numerical_stability_large_logits(self, rng):
        from repro.kernels.flash import flash_fwd_pallas
        q = jnp.asarray(80 * rng.standard_normal((1, 32, 32)), jnp.float32)
        k = jnp.asarray(80 * rng.standard_normal((1, 32, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 32, 32)), jnp.float32)
        out = flash_fwd_pallas(q, k, v, bq=16, bk=16, interpret=True)
        assert np.isfinite(np.asarray(out)).all()
