"""Generalized vector-sparse conv: KxK / stride / 1x1 / fused-epilogue parity.

Pallas kernels run interpret=True on CPU against the pure-jnp `ref.py`
oracles; the structural jnp path is checked against the same oracle so all
three implementations agree across the kernel family.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    conv_weight_to_matrix, dense_conv2d, encode, im2col,
    prune_vectors_balanced, vs_conv2d,
)
from repro.kernels import vsmm, vsconv
from repro.kernels.ref import vsmm_ref, vsconv_ref


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


def _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density):
    wm = rng.standard_normal((kh * kw * c, co)).astype(np.float32)
    wp, _ = prune_vectors_balanced(wm, density, vk, vn)
    return encode(jnp.asarray(wp), vk, vn)


# (kh, kw, stride, h, w, c, co, vk, vn, density) — odd H/W and asymmetric
# SAME padding cases included; 1x1 exercises the vsmm-over-pixels route.
GEOMETRIES = [
    (1, 1, 1, 9, 11, 32, 128, 32, 128, 0.5),
    (1, 1, 2, 13, 7, 32, 128, 32, 128, 0.5),
    (3, 3, 1, 8, 8, 32, 128, 32, 128, 0.5),
    (3, 3, 2, 13, 15, 32, 128, 32, 128, 0.5),
    (5, 5, 1, 11, 9, 16, 128, 16, 128, 0.4),
    (5, 5, 2, 12, 10, 16, 64, 16, 64, 0.4),
    (7, 7, 1, 9, 9, 8, 64, 8, 64, 0.5),
    (7, 7, 2, 21, 17, 8, 64, 8, 64, 0.5),
]


class TestKernelGeometry:
    @pytest.mark.parametrize("kh,kw,stride,h,w,c,co,vk,vn,density", GEOMETRIES)
    def test_pallas_matches_ref(self, kh, kw, stride, h, w, c, co, vk, vn,
                                density, rng):
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, w, c)), 0), jnp.float32)
        out = vsconv(x, vs, kh=kh, kw=kw, stride=stride)
        ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
        assert out.shape == ref.shape
        assert out.shape[1:3] == (-(-h // stride), -(-w // stride))
        assert _rel(out, ref) < 1e-5

    @pytest.mark.parametrize("kh,kw,stride,h,w,c,co,vk,vn,density", GEOMETRIES)
    def test_jnp_matches_ref(self, kh, kw, stride, h, w, c, co, vk, vn,
                             density, rng):
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, w, c)), 0), jnp.float32)
        out = vs_conv2d(x, vs, kh=kh, kw=kw, stride=stride, impl="jnp")
        ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
        assert _rel(out, ref) < 1e-5

    @pytest.mark.parametrize("kh,kw,stride", [(3, 3, 2), (7, 7, 2), (1, 1, 1)])
    def test_fused_epilogue_matches_unfused(self, kh, kw, stride, rng):
        c, co, vk, vn = 16, 128, 16, 128
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((1, 10, 12, c)), 0), jnp.float32)
        b = jnp.asarray(rng.standard_normal((co,)), jnp.float32)
        fused = vsconv(x, vs, kh=kh, kw=kw, stride=stride, bias=b,
                       fuse_relu=True)
        unfused = jnp.maximum(
            vsconv(x, vs, kh=kh, kw=kw, stride=stride).astype(jnp.float32)
            + b, 0.0)
        assert _rel(fused, unfused) < 1e-5
        ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride, bias=b,
                         fuse_relu=True)
        assert _rel(fused, ref) < 1e-5

    def test_fused_relu_output_nonnegative(self, rng):
        vs = _sparse_conv_weight(rng, 3, 3, 32, 128, 32, 128, 0.5)
        x = jnp.asarray(rng.standard_normal((1, 8, 8, 32)), jnp.float32)
        out = vsconv(x, vs, fuse_relu=True)
        assert np.asarray(out).min() >= 0.0

    def test_dense_special_case_all_geometries(self, rng):
        """Density 1.0 = the dense conv in the same datapath."""
        for kh, kw, stride in [(5, 5, 2), (1, 1, 1)]:
            c, co = 8, 64
            wm = rng.standard_normal((kh * kw * c, co)).astype(np.float32)
            vs = encode(jnp.asarray(wm), 8, 64)
            x = jnp.asarray(rng.standard_normal((1, 10, 10, c)), jnp.float32)
            w4 = jnp.asarray(wm.reshape(kh, kw, c, co))
            ref = dense_conv2d(x, w4, stride=stride)
            assert _rel(vsconv(x, vs, kh=kh, kw=kw, stride=stride), ref) < 1e-5


class TestOneByOneRouting:
    """1x1 convs are the sparse matmul over flattened pixels."""

    def test_matches_vsmm_directly(self, rng):
        c, co = 32, 128
        wm = rng.standard_normal((c, co)).astype(np.float32)
        wp, _ = prune_vectors_balanced(wm, 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(rng.standard_normal((2, 6, 5, c)), jnp.float32)
        out = vsconv(x, vs, kh=1, kw=1)
        ref = vsmm(x.reshape(-1, c), vs).reshape(2, 6, 5, co)
        assert _rel(out, ref) < 1e-6

    def test_stride2_subsamples(self, rng):
        c, co = 32, 128
        wm = rng.standard_normal((c, co)).astype(np.float32)
        vs = encode(jnp.asarray(wm), 32, 128)
        x = jnp.asarray(rng.standard_normal((1, 9, 9, c)), jnp.float32)
        out = vsconv(x, vs, kh=1, kw=1, stride=2)
        ref = vsmm(x[:, ::2, ::2].reshape(-1, c), vs).reshape(1, 5, 5, co)
        assert _rel(out, ref) < 1e-6


class TestVsmmEpilogue:
    def test_bias_relu_fused(self, rng):
        wp, _ = prune_vectors_balanced(
            rng.standard_normal((256, 256)).astype(np.float32), 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(rng.standard_normal((100, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        out = vsmm(x, vs, bias=b, fuse_relu=True)
        ref = vsmm_ref(x, vs, bias=b, fuse_relu=True)
        assert _rel(out, ref) < 1e-5
        assert np.asarray(out).min() >= 0.0


class TestGeneralizedIm2col:
    @pytest.mark.parametrize("kh,kw,stride", [(5, 5, 1), (7, 7, 2), (3, 3, 2)])
    def test_matches_lax_conv(self, kh, kw, stride, rng):
        x = jnp.asarray(rng.standard_normal((2, 11, 13, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((kh, kw, 8, 16)), jnp.float32)
        patches = im2col(x, kh=kh, kw=kw, stride=stride)
        ref = dense_conv2d(x, w, stride=stride)
        out = patches @ conv_weight_to_matrix(w)
        assert _rel(out, ref) < 1e-4


class TestSparseConvFromDense:
    def test_nontileable_cout_shrinks_strip(self, rng):
        """Cout = 192 > vn = 128 and not a multiple: the strip must shrink
        to a divisor (here 96), not crash in a reshape."""
        from repro.models.cnn import sparse_conv_from_dense
        w = rng.standard_normal((3, 3, 32, 192)).astype(np.float32)
        spec, wp = sparse_conv_from_dense(w, 0.5, vk=32, vn=128)
        assert spec.vs.shape == (9 * 32, 192)
        assert 192 % spec.vs.vn == 0 and spec.vs.vn <= 128
        x = jnp.asarray(
            np.maximum(rng.standard_normal((1, 8, 8, 32)), 0), jnp.float32)
        ref = dense_conv2d(x, jnp.asarray(wp))
        assert _rel(vs_conv2d(x, spec.vs, impl="jnp"), ref) < 1e-5


class TestResNetStemEndToEnd:
    """7x7/s2 stem -> 1x1 projection -> 3x3/s2 downsample, sparse vs dense."""

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_parity(self, impl, rng):
        import jax
        from repro.models.cnn import (
            resnet_stem_schema, resnet_stem_apply, sparsify_resnet_stem,
        )
        from repro.models.layers import init_params

        params = init_params(resnet_stem_schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        sparse, pruned = sparsify_resnet_stem(params, 0.5)
        assert set(sparse) == {"stem7x7", "proj1x1", "down3x3"}
        x = jnp.asarray(rng.standard_normal((2, 28, 30, 3)), jnp.float32)
        dense = resnet_stem_apply(pruned, x)
        assert dense.shape == (2, 7, 8, 128)  # H/4 x W/4
        out = resnet_stem_apply(params, x, sparse=sparse, impl=impl)
        assert _rel(out, dense) < 1e-3


class TestAccelModelGeometry:
    def test_stride2_halves_column_pairings(self):
        from repro.core.accel_model import PEConfig, conv_layer_cycles
        x = np.ones((16, 16, 4))
        w = np.ones((7, 7, 4, 8))
        pe = PEConfig(blocks=4, rows=8, cols=7)
        r1 = conv_layer_cycles(x, w, pe, stride=1)
        r2 = conv_layer_cycles(x, w, pe, stride=2)
        assert r2.dense == r1.dense // 2
        assert r2.macs_dense < r1.macs_dense

    def test_pruned_kx_columns_skip_under_stride(self):
        from repro.core.accel_model import PEConfig, conv_layer_cycles
        x = np.ones((16, 16, 4))
        w = np.ones((7, 7, 4, 8))
        w_pruned = w.copy()
        w_pruned[:, ::2] = 0.0
        pe = PEConfig(blocks=4, rows=8, cols=7)
        full = conv_layer_cycles(x, w, pe, stride=2)
        pruned = conv_layer_cycles(x, w_pruned, pe, stride=2)
        assert pruned.vscnn < full.vscnn
        assert pruned.vscnn >= pruned.ideal_vector

    def test_1x1_geometry(self):
        from repro.core.accel_model import PEConfig, conv_layer_cycles
        x = np.ones((8, 8, 4))
        w = np.ones((1, 1, 4, 8))
        r = conv_layer_cycles(x, w, PEConfig(blocks=2, rows=8, cols=1))
        assert r.dense == 1 * 8 * 4 * 4  # hc * W * cin * ceil(cout/B)
        assert r.vscnn == r.dense
