"""Grouped / depthwise / dilated vector-sparse conv: parity + traffic.

The acceptance sweep for the grouped-geometry extension: every
(groups, dilation, stride) combination must agree across all four
implementations — halo kernel, row-tap-stack kernel, the structural jnp
path, and the densified `kernels/ref.py` oracle — and the DRAM traffic
model's per-group bytes must equal the kernels' own `pl.CostEstimate`
formulas (per-group fetch, not full-cin).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode, prune_vectors_balanced
from repro.core.accel_model import (
    PE_4_14_3, conv_layer_cycles, conv_layer_traffic,
)
from repro.core.sparse_ops import same_pads, vs_conv2d
from repro.kernels import vsconv
from repro.kernels.ref import conv_ref, vsconv_ref
from repro.models.graph import apply_sparse_conv, sparse_conv_from_dense


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


# (groups, dilation, stride) — the acceptance grid: groups in {2, 4, cin},
# dilation in {1, 2}, stride 1/2.  cin = 64 throughout, 3x3 taps.
ACCEPTANCE_GRID = [
    (g, d, s)
    for g in (2, 4, 64)
    for d in (1, 2)
    for s in (1, 2)
]


class TestGroupedParity:
    @pytest.mark.parametrize("groups,dilation,stride", ACCEPTANCE_GRID)
    def test_halo_stack_jnp_vs_ref(self, groups, dilation, stride, rng):
        kh = kw = 3
        c, co = 64, 64 if groups == 64 else 128
        cin_g = c // groups
        w = rng.standard_normal((kh, kw, cin_g, co)).astype(np.float32)
        spec, wp = sparse_conv_from_dense(
            w, 0.5, vk=16, vn=32, stride=stride, groups=groups,
            dilation=dilation)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, 11, 10, c)), 0), jnp.float32)
        ref = vsconv_ref(x, spec.vs, kh=kh, kw=kw, stride=stride,
                         groups=groups, dilation=dilation)
        # the densified sparse weight equals the pruned dense weight
        dense = conv_ref(x, jnp.asarray(wp), stride=stride, groups=groups,
                         dilation=dilation)
        assert _rel(ref, dense) < 1e-5
        for impl in ("pallas-halo", "pallas-stack", "jnp"):
            out = apply_sparse_conv(x, spec, fuse_relu=False, impl=impl)
            assert out.shape == ref.shape, (impl, out.shape, ref.shape)
            assert _rel(out, ref) < 1e-5, impl

    @pytest.mark.parametrize("stride,dilation", [(1, 1), (2, 1), (1, 2)])
    @pytest.mark.parametrize("bias,residual,relu", [
        (True, False, True), (True, True, True),
    ])
    def test_depthwise_fused_epilogue(self, stride, dilation, bias, residual,
                                      relu, rng):
        """Depthwise per-channel tap kernels run the same fused epilogue
        (bias + residual-before-ReLU) as the full kernels."""
        kh = kw = 3
        c, vc = 64, 32
        wm = prune_vectors_balanced(
            rng.standard_normal((kh * kw, c)).astype(np.float32),
            0.6, 1, vc)[0]
        vs = encode(jnp.asarray(wm), 1, vc)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((1, 9, 12, c)), 0), jnp.float32)
        b = (jnp.asarray(rng.standard_normal((c,)), jnp.float32)
             if bias else None)
        ho, _, _ = same_pads(9, kh, stride, dilation)
        wo, _, _ = same_pads(12, kw, stride, dilation)
        res = (jnp.asarray(rng.standard_normal((1, ho, wo, c)), jnp.float32)
               if residual else None)
        kw_args = dict(kh=kh, kw=kw, stride=stride, groups=c,
                       dilation=dilation, bias=b, residual=res,
                       fuse_relu=relu)
        ref = vsconv_ref(x, vs, **kw_args)
        for impl in ("halo", "stack"):
            out = vsconv(x, vs, impl=impl, **kw_args)
            assert _rel(out, ref) < 1e-5, impl
        outj = vs_conv2d(x, vs, impl="jnp", **kw_args)
        assert _rel(outj, ref) < 1e-5

    def test_grouped_1x1(self, rng):
        """Grouped pointwise convs (block-diagonal matmul) run through the
        general kernels, not the full-cin vsmm route."""
        c, co, groups = 64, 128, 4
        w = rng.standard_normal((1, 1, c // groups, co)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(w, 0.5, vk=16, vn=32, groups=groups)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, 8, 8, c)), 0), jnp.float32)
        ref = vsconv_ref(x, spec.vs, kh=1, kw=1, groups=groups)
        for impl in ("pallas-halo", "pallas-stack", "jnp"):
            out = apply_sparse_conv(x, spec, fuse_relu=False, impl=impl)
            assert _rel(out, ref) < 1e-5, impl


class TestGroupedEncoding:
    def test_grouped_strips_stay_in_group(self, rng):
        """No output strip straddles a group: vn shrinks to a divisor of
        Cout/groups, and the K axis is Cin/groups."""
        w = rng.standard_normal((3, 3, 16, 128)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(w, 0.5, vk=32, vn=128, groups=4)
        assert spec.groups == 4
        assert spec.vs.shape == (3 * 3 * 16, 128)
        assert spec.vs.vn <= 128 // 4
        assert (128 // 4) % spec.vs.vn == 0
        assert spec.vs.vk <= 16 and 16 % spec.vs.vk == 0
        assert spec.cin_pad == 0

    def test_depthwise_encoding_is_tap_matrix(self, rng):
        w = rng.standard_normal((3, 3, 1, 256)).astype(np.float32)
        spec, wp = sparse_conv_from_dense(w, 0.5, vk=32, vn=128, groups=256)
        assert spec.groups == 256
        assert spec.vs.vk == 1 and spec.vs.vn == 128
        assert spec.vs.shape == (9, 256)
        # balanced: ceil-rounded tap quota per channel tile
        assert spec.vs.nnz_per_strip == max(1, round(9 * 0.5))
        assert wp.shape == (3, 3, 1, 256)

    def test_grouped_cin_major_order(self, rng):
        """Grouped tile ids are group-relative; the cin-major reorder keys
        on the per-group tile count, so the per-strip cin-tile stream is
        still non-decreasing (the halo revisit contract)."""
        w = rng.standard_normal((3, 3, 32, 64)).astype(np.float32)
        spec, _ = sparse_conv_from_dense(w, 0.5, vk=16, vn=32, groups=2)
        cbg = 32 // spec.vs.vk
        idx = np.asarray(spec.vs.idx)
        assert (np.diff(idx % cbg, axis=1) >= 0).all()


class TestGroupedTraffic:
    def test_per_group_bytes_match_kernel_cost(self):
        """Acceptance: the traffic model's per-group input fetch equals the
        halo kernel's CostEstimate with cb = Cin/(groups*vk) — NOT the
        full-cin count."""
        from repro.kernels.vsconv import halo_kernel_cost

        n, h, c, co, vk, vn, groups, s = 1, 16, 64, 128, 16, 32, 4, 12
        tr = conv_layer_traffic((n, h, h, c), kh=3, kw=3, stride=1,
                                groups=groups, cout=co, s_steps=s, vk=vk,
                                vn=vn, impl="halo")
        cbg = (c // vk) // groups
        est = halo_kernel_cost(
            n=n, hop=16, w_out=16, kh=3, stride=1, bwp=24, bh=8,
            nb=co // vn, s_steps=s, cb=cbg, vk=vk, vn=vn)
        assert (tr.input_bytes + tr.weight_bytes + tr.output_bytes
                == est.bytes_accessed)
        # full-cin accounting would fetch 4x the tiles per strip
        est_full = halo_kernel_cost(
            n=n, hop=16, w_out=16, kh=3, stride=1, bwp=24, bh=8,
            nb=co // vn, s_steps=s, cb=c // vk, vk=vk, vn=vn)
        assert est.bytes_accessed < est_full.bytes_accessed

    def test_depthwise_bytes_match_dw_kernel_cost(self):
        from repro.kernels.vsconv import (
            dw_halo_kernel_cost, dw_stack_kernel_cost,
        )

        n, h, c, vc, s = 1, 16, 256, 128, 5
        tr_h = conv_layer_traffic((n, h, h, c), kh=3, kw=3, stride=2,
                                  groups=c, cout=c, s_steps=s, vk=1, vn=vc,
                                  impl="halo")
        est_h = dw_halo_kernel_cost(
            n=n, hop=8, w_out=8, kh=3, stride=2, bwp=24, bh=8, nb=c // vc,
            s_steps=s, vc=vc)
        assert (tr_h.input_bytes + tr_h.weight_bytes + tr_h.output_bytes
                == est_h.bytes_accessed)
        tr_s = conv_layer_traffic((n, h, h, c), kh=3, kw=3, stride=2,
                                  groups=c, cout=c, s_steps=s, vk=1, vn=vc,
                                  impl="stack")
        # stack bw = round_up(wo + (kw-1)//stride, 8) = round_up(9, 8)
        est_s = dw_stack_kernel_cost(
            n=n, hop=8, w_out=8, bw=16, bh=8, nb=c // vc, s_steps=s, vc=vc)
        assert (tr_s.input_bytes + tr_s.weight_bytes + tr_s.output_bytes
                == est_s.bytes_accessed)

    def test_depthwise_halo_below_stack(self):
        """The mobilenet dw 3x3/s2 gate geometry: halo fetches the block
        once per (strip, row-block); the stack re-fetches per stored tap."""
        for h in (14, 28):
            tr = {impl: conv_layer_traffic(
                      (1, h, h, 512), kh=3, kw=3, stride=2, groups=512,
                      cout=512, s_steps=5, vk=1, vn=128, impl=impl)
                  for impl in ("halo", "stack")}
            assert (tr["halo"].bytes_accessed
                    < tr["stack"].bytes_accessed), h


class TestGroupedCycles:
    def test_grouped_cycles_sum_of_group_slices(self, rng):
        """A grouped conv's cycle report is the per-group sum on the
        channel slices — dense cycles scale with Cout/groups per input
        vector, not full Cout."""
        x = np.maximum(rng.standard_normal((8, 8, 16)), 0)
        w = rng.standard_normal((3, 3, 8, 32))
        rep_g = conv_layer_cycles(x, w, PE_4_14_3, groups=2)
        rep_a = conv_layer_cycles(x[..., :8], w[..., :16], PE_4_14_3)
        rep_b = conv_layer_cycles(x[..., 8:], w[..., 16:], PE_4_14_3)
        assert rep_g.dense == rep_a.dense + rep_b.dense
        assert rep_g.vscnn == rep_a.vscnn + rep_b.vscnn
        assert rep_g.macs_nonzero == rep_a.macs_nonzero + rep_b.macs_nonzero

    def test_dilated_macs_match_dense_conv(self, rng):
        """`macs_dense` and the nonzero-MAC count stay consistent with the
        dilated SAME geometry (Hout = ceil(H/stride) regardless of
        dilation; boundary taps read zero padding, so even an all-ones
        input issues fewer nonzero MACs than the dense slot count)."""
        x = np.maximum(rng.standard_normal((9, 9, 4)), 0)
        w = rng.standard_normal((3, 3, 4, 8))
        rep = conv_layer_cycles(x, w, PE_4_14_3, stride=2, dilation=2)
        assert rep.macs_dense == 5 * 5 * 3 * 3 * 4 * 8
        dense_macs = conv_layer_cycles(
            np.ones_like(x), np.ones_like(w), PE_4_14_3, stride=2,
            dilation=2).macs_nonzero
        assert 0 < dense_macs <= rep.macs_dense
        assert rep.macs_nonzero <= dense_macs


class TestNewNetsEndToEnd:
    @pytest.mark.parametrize("builder", ["build_resnet34", "build_resnet50",
                                         "build_mobilenet_v1"])
    def test_sparse_apply_matches_pruned_dense(self, builder, rng):
        """Acceptance: ResNet-34/50 and MobileNetV1 run end-to-end sparse
        through `SparseNet.apply` and match the BN-folded pruned dense
        oracle."""
        from repro.models import graph as G
        from repro.models.layers import init_params

        net = getattr(G, builder)(16, image_size=32)
        params = init_params(net.schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)), jnp.float32)
        sparse, pruned = G.sparsify(net, params, 0.5)
        # every conv and FC runs sparse
        assert set(sparse) == {l.name for l in net.conv_layers()} \
            | {l.name for l in net.fc_layers()}
        out = net.apply(params, x, sparse=sparse, impl="jnp")
        oracle = net.apply(pruned, x)
        assert out.shape == (2, 16)
        assert _rel(out, oracle) < 1e-5

    def test_mobilenet_depthwise_layers_are_depthwise(self):
        from repro.models.graph import build_mobilenet_v1

        net = build_mobilenet_v1(10)
        dw = [l for l in net.conv_layers() if l.groups > 1]
        assert len(dw) == 13
        assert all(l.groups == l.cin == l.cout for l in dw)

    def test_resnet50_bottleneck_shapes(self):
        from repro.models.graph import build_resnet50

        net = build_resnet50(10)
        convs = net.conv_layers()
        assert len(convs) == 1 + 16 * 3 + 4  # stem + blocks + projections
        assert convs[-1].cout == 2048

    def test_resnet34_basic_block_shapes(self):
        from repro.models.graph import build_resnet34

        net = build_resnet34(10)
        convs = net.conv_layers()
        # stem + 2 convs per basic block (3+4+6+3 blocks) + 3 projections
        assert len(convs) == 1 + 16 * 2 + 3
        assert convs[-1].cout == 512  # basic blocks: no 4x expansion

    @pytest.mark.parametrize("arch", ["vscnn-resnet34", "vscnn-resnet50",
                                      "vscnn-mobilenet-v1"])
    def test_servable_configs(self, arch):
        from repro.configs import get_config, list_cnn_archs

        assert arch in list_cnn_archs()
        cfg = get_config(arch).reduce()
        net = cfg.build()
        assert net.conv_layers()
