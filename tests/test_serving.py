"""Serving backends end to end: LM continuous batching (EOS retirement,
cache-merge backfill, exact decode-step accounting) and the batched CNN
path through `SparseNet.apply`.

The LM server is module-scoped: prefill/decode/merge jits compile once and
every test reuses them (eos_id is restored after mutation).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import (
    CNNServer, ImageRequest, Request, Server, random_prompt_lengths,
)
from repro.models import graph as G


@pytest.fixture(scope="module")
def lm_server():
    cfg = get_config("rwkv6-3b").reduce()
    # len_bucket=1: no length rounding, so tests control padding exactly
    return Server(cfg, batch=2, capacity=32, len_bucket=1)


def _reqs(cfg, lens_max_new, prompt_len=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, prompt_len,
                                        dtype=np.int32),
                    max_new=mn)
            for i, mn in enumerate(lens_max_new)]


class TestLMServing:
    def test_exact_decode_steps(self, lm_server):
        """max_new tokens cost exactly max_new - 1 decodes (prefill emits
        the first) — the trailing-decode off-by-one regression pin."""
        reqs = _reqs(lm_server.cfg, [4, 4])
        stats = lm_server.serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["decode_steps"] == 3
        assert s["new_tokens"] == 8
        assert all(len(r.out) == 4 for r in reqs)

    def test_retirement_frees_slot_for_queued_request(self, lm_server):
        """The headline regression: a short sequence retires mid-run and a
        queued request is backfilled into its slot in the same lockstep
        run — the run is bounded by the longest request, not the sum."""
        reqs = _reqs(lm_server.cfg, [2, 6, 3])
        stats = lm_server.serve(reqs)
        assert len(stats) == 1           # one lockstep run serves all three
        s = stats[0]
        assert s["backfills"] == 1 and s["finished"] == 3
        assert [len(r.out) for r in reqs] == [2, 6, 3]
        assert s["decode_steps"] == 5    # max(max_new) - 1
        assert s["new_tokens"] == 11

    def test_eos_retirement(self, lm_server):
        """A sequence retires the moment it emits eos_id, not at max_new."""
        [probe] = _reqs(lm_server.cfg, [6], seed=3)
        lm_server.serve([probe])
        assert len(probe.out) == 6
        eos = probe.out[1]               # greedy decode is deterministic
        [req] = _reqs(lm_server.cfg, [6], seed=3)
        lm_server.backend.eos_id = eos
        try:
            stats = lm_server.serve([req])
        finally:
            lm_server.backend.eos_id = None
        assert req.out == probe.out[:2]  # eos recorded, then retired
        assert stats[0]["decode_steps"] == 1

    def test_backfill_cache_merge_parity(self, lm_server):
        """A backfilled request must compute exactly what the same request
        computes when served alone at that context length — pins the
        prefill-and-merge cache scatter."""
        cfg = lm_server.cfg
        a, b = _reqs(cfg, [2, 3], seed=5)
        one = Server(cfg, batch=1, capacity=32, len_bucket=1)
        stats = one.serve([a, b])
        assert stats[0]["backfills"] == 1
        # b backfilled into slot 0 at context length 6+1: serve it alone,
        # left-padded to the same length, on the same width-1 jits
        b2 = Request(rid=9,
                     prompt=np.concatenate([np.zeros(1, np.int32),
                                            np.asarray(b.prompt)]),
                     max_new=3)
        one.serve([b2])
        assert b2.out == b.out

    def test_run_batch_overflow_backfills(self, lm_server):
        """run_batch with more requests than slots serves them all via
        backfill instead of silently dropping."""
        reqs = _reqs(lm_server.cfg, [2, 2, 2])
        s = lm_server.run_batch(reqs)
        assert s["finished"] == 3 and s["backfills"] == 1
        assert all(len(r.out) == 2 for r in reqs)

    def test_run_batch_raises_on_unservable_request(self, lm_server):
        """A request that can never join the run (token budget would
        overflow capacity) surfaces as an error, not a silent drop."""
        # the third request only fits via backfill, but 30 new tokens would
        # overflow capacity 32 from any retirement point
        reqs = _reqs(lm_server.cfg, [2, 2, 30])
        with pytest.raises(ValueError, match="could not backfill"):
            lm_server.run_batch(reqs)

    def test_backfill_prefill_shape_bucketing(self):
        """Backfills at distinct retirement steps share one bucketed
        prefill executable (the recompile-storm fix): the context is
        right-padded to the len_bucket ladder and the first token read at
        the true position, so the jit cache holds one entry, not one per
        distinct context length."""
        cfg = get_config("qwen1.5-4b").reduce()   # attention KV caches
        srv = Server(cfg, batch=2, capacity=64, len_bucket=8)
        assert srv.backend.backfill_bucket == 8
        reqs = _reqs(cfg, [2, 8, 3, 4], seed=7)
        stats = srv.serve(reqs)
        assert len(stats) == 1
        assert stats[0]["backfills"] == 2        # at two distinct steps
        assert [len(r.out) for r in reqs] == [2, 8, 3, 4]
        # both backfill contexts (9 and 11) round to the same 16-bucket
        assert srv.backend._prefill_at._cache_size() == 1

    def test_bucketed_backfill_matches_exact(self):
        """Right-padding the backfill context to the bucket must not change
        a single emitted token vs the exact-length prefill (junk K/V rows
        are masked, then overwritten by the next decode steps)."""
        cfg = get_config("qwen1.5-4b").reduce()
        outs = []
        for bucket in (8, 1):                    # bucketed vs exact
            srv = Server(cfg, batch=2, capacity=64, len_bucket=8)
            srv.backend.backfill_bucket = bucket
            reqs = _reqs(cfg, [2, 8, 3, 4], seed=7)
            stats = srv.serve(reqs)
            assert stats[0]["backfills"] == 2
            outs.append([r.out for r in reqs])
        assert outs[0] == outs[1]

    def test_stateful_caches_keep_exact_backfill(self):
        """rwkv state caches fold in every processed token, and
        sliding-window K/V caches are circular (right-pad junk would wrap
        onto real in-window history): both keep the exact-length backfill
        (bucket 1) even when admission buckets lengths."""
        for arch in ("rwkv6-3b", "gemma3-12b"):   # recurrent / windowed
            cfg = get_config(arch).reduce()
            srv = Server(cfg, batch=1, capacity=32, len_bucket=16)
            assert srv.backend.backfill_bucket == 1, arch

    def test_modality_dispatch_fields(self):
        assert get_config("rwkv6-3b").modality == "lm"
        assert get_config("vscnn-vgg16").modality == "cnn"
        assert get_config("vscnn-resnet18").modality == "cnn"

    def test_prompt_len_validation(self):
        """--prompt-len 8 used to crash on rng.integers(8, 8)."""
        rng = np.random.default_rng(0)
        lens = random_prompt_lengths(rng, 20, 8)
        assert all(1 <= n < 8 for n in lens)
        lens = random_prompt_lengths(rng, 20, 2)
        assert all(n == 1 for n in lens)
        with pytest.raises(ValueError, match="prompt-len"):
            random_prompt_lengths(rng, 4, 1)


class TestCNNServing:
    def test_vgg_batched_serving_parity(self):
        """A mixed queue through SparseNet.apply with batch reuse: one
        lockstep run, a backfilled fifth image, outputs matching the
        direct batched apply."""
        cfg = get_config("vscnn-vgg16").reduce()
        srv = CNNServer(cfg, batch=4, seed=0)
        rng = np.random.default_rng(1)
        imgs = [rng.standard_normal((32, 32, 3)).astype(np.float32)
                for _ in range(5)]
        reqs = [ImageRequest(rid=i, image=im) for i, im in enumerate(imgs)]
        stats = srv.serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["steps"] == 2           # wave of 4, then the backfilled 1
        assert s["backfills"] == 1 and s["finished"] == 5
        # one executable for the full wave + one for the shrunk final wave
        # (width 1) — the zero-pad lanes are no longer computed
        assert s["compiles"] == 2
        ref = np.asarray(G.net_apply(
            srv.net, srv.params, jnp.asarray(np.stack(imgs)),
            sparse=srv.sparse, impl="jnp"))
        for i, r in enumerate(reqs):
            assert r.logits is not None and r.logits.shape == (16,)
            np.testing.assert_allclose(r.logits, ref[i], rtol=1e-3,
                                       atol=1e-3)
            assert r.out == [int(ref[i].argmax())]

    def test_resnet_shape_buckets(self):
        """A size-agnostic net serves mixed image sizes as separate shape
        buckets, padding within each."""
        cfg = get_config("vscnn-resnet18").reduce()
        srv = CNNServer(cfg, batch=2, density=0.5, seed=0)
        rng = np.random.default_rng(2)
        reqs = [ImageRequest(rid=i,
                             image=rng.standard_normal((s, s, 3))
                                      .astype(np.float32))
                for i, s in enumerate([16, 24, 16])]
        stats = srv.serve(reqs)
        assert len(stats) == 2           # buckets (16,16,3) and (24,24,3)
        assert sum(s["finished"] for s in stats) == 3
        assert all(len(r.out) == 1 for r in reqs)
        assert srv.backend.apply.compiles == 2

    def test_final_wave_shrinks_to_occupied_slots(self):
        """A partial wave computes on a batch shrunk to the occupied slots
        (pow2 ladder), not the full width padded with zero images."""
        cfg = get_config("vscnn-vgg16").reduce()
        srv = CNNServer(cfg, batch=4, seed=0)
        rng = np.random.default_rng(4)
        reqs = [ImageRequest(rid=i,
                             image=rng.standard_normal((32, 32, 3))
                                      .astype(np.float32))
                for i in range(7)]
        stats = srv.serve(reqs)
        assert sum(s["finished"] for s in stats) == 7
        # wave of 4, then 3 backfills -> a 3-occupied wave on a width-4
        # batch: the pow2 ladder reuses the full-width executable
        widths = {k[-1][0] for k in srv.backend.apply.cache}
        assert widths == {4}
        # a lone trailing image lands on a width-1 executable
        srv.serve([ImageRequest(
            rid=9, image=rng.standard_normal((32, 32, 3))
                            .astype(np.float32))])
        widths = {k[-1][0] for k in srv.backend.apply.cache}
        assert widths == {4, 1}

    def test_fixed_input_rejects_oversize(self):
        """An oversize image for a fixed-input net is refused at admission
        with a structured outcome — it never reaches a batch (and never
        takes down the serve)."""
        cfg = get_config("vscnn-vgg16").reduce()   # image_size 32
        srv = CNNServer(cfg, batch=2, seed=0)
        big = ImageRequest(rid=0, image=np.zeros((48, 48, 3), np.float32))
        stats = srv.serve([big])
        assert stats == []
        assert big.outcome.status == "refused"
        assert big.outcome.reason.startswith("invalid:oversize")
        assert srv.outcomes[0] is big.outcome

    def test_malformed_requests_refused(self):
        """Every malformed-input arm becomes a structured refusal, and
        valid neighbors in the same serve still get answers."""
        cfg = get_config("vscnn-vgg16").reduce()
        srv = CNNServer(cfg, batch=2, seed=0)
        s = cfg.image_size
        good = ImageRequest(
            rid=0, image=np.ones((s, s, 3), np.float32))
        bad = [
            ImageRequest(rid=1, image=[[1.0]]),                # not ndarray
            ImageRequest(rid=2, image=np.ones((s, s), np.float32)),
            ImageRequest(rid=3, image=np.ones((s, s, 3), np.int32)),
            ImageRequest(rid=4, image=np.full((s, s, 3), np.nan,
                                              np.float32)),
        ]
        srv.serve([good] + bad)
        assert good.outcome.status == "delivered"
        assert good.out  # got a class
        reasons = [r.outcome.reason for r in bad]
        assert reasons[0].startswith("invalid:not_an_array")
        assert reasons[1].startswith("invalid:bad_rank")
        assert reasons[2].startswith("invalid:bad_dtype")
        assert reasons[3] == "invalid:non_finite_input"

    def test_lm_malformed_requests_refused(self, lm_server):
        """LM arm: empty prompts, wrong dtype/rank, bad budgets and
        over-capacity prompts are refused at admission; the valid request
        in the same serve still completes."""
        srv = lm_server
        good = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new=2)
        bad = [
            Request(rid=1, prompt=np.zeros(0, np.int32), max_new=2),
            Request(rid=2, prompt=np.ones(4, np.float32), max_new=2),
            Request(rid=3, prompt=np.ones((2, 2), np.int32), max_new=2),
            Request(rid=4, prompt=np.arange(4, dtype=np.int32), max_new=0),
            Request(rid=5, prompt=np.arange(100, dtype=np.int32),
                    max_new=2),   # capacity 32
        ]
        srv.serve([good] + bad)
        assert good.outcome.status == "delivered"
        assert len(good.out) == 2
        reasons = {r.rid: r.outcome.reason for r in bad}
        assert reasons[1] == "invalid:empty_prompt"
        assert reasons[2].startswith("invalid:bad_dtype")
        assert reasons[3].startswith("invalid:bad_rank")
        assert reasons[4].startswith("invalid:bad_max_new")
        assert reasons[5].startswith("invalid:prompt_too_long")
        for r in bad:
            assert r.out == []

    def test_lockstep_max_queue_sheds(self):
        """Bounded admission: requests beyond the depth are shed with a
        queue_full refusal, the rest are served."""
        cfg = get_config("vscnn-vgg16").reduce()
        srv = CNNServer(cfg, batch=2, seed=0, max_queue=3)
        s = cfg.image_size
        reqs = [ImageRequest(rid=i, image=np.ones((s, s, 3), np.float32))
                for i in range(5)]
        srv.serve(reqs)
        statuses = [r.outcome.status for r in reqs]
        assert statuses == ["delivered"] * 3 + ["refused"] * 2
        assert all(r.outcome.reason == "queue_full" for r in reqs[3:])

    def test_dense_path_serves(self):
        """sparse=False routes the same scheduler through plain XLA convs —
        the bench_serving baseline."""
        cfg = get_config("vscnn-vgg16").reduce()
        srv = CNNServer(cfg, batch=2, sparse=False, seed=0)
        rng = np.random.default_rng(3)
        reqs = [ImageRequest(rid=i,
                             image=rng.standard_normal((32, 32, 3))
                                      .astype(np.float32))
                for i in range(2)]
        stats = srv.serve(reqs)
        assert stats[0]["finished"] == 2
        ref = np.asarray(G.net_apply(
            srv.net, srv.params,
            jnp.asarray(np.stack([r.image for r in reqs]))))
        np.testing.assert_allclose(
            np.stack([r.logits for r in reqs]), ref, rtol=1e-5, atol=1e-5)


def _cnn_queue(n, seed=1, size=32):
    rng = np.random.default_rng(seed)
    return [ImageRequest(rid=i,
                         image=rng.standard_normal((size, size, 3))
                                  .astype(np.float32))
            for i in range(n)]


class TestCNNFleet:
    """Replica fleet on a single device: data-parallel replicas are weight
    copies, so the bar is *bit-identical* logits to the legacy
    single-backend path — not allclose."""

    def test_three_replicas_bit_identical_to_one(self):
        cfg = get_config("vscnn-vgg16").reduce()
        solo = CNNServer(cfg, batch=2, seed=0)
        ref_reqs = _cnn_queue(10)
        solo.serve(ref_reqs)
        fleet = CNNServer(cfg, batch=2, seed=0, replicas=3)
        reqs = _cnn_queue(10)
        stats = fleet.serve(reqs)
        # every replica actually served work
        assert {s["replica"] for s in stats} == {0, 1, 2}
        for r, ref in zip(reqs, ref_reqs):
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(ref.logits))
            assert r.out == ref.out

    def test_shard_fc_single_device_parity(self):
        """shard_fc on one device degenerates to a replicated mesh; the
        sharded compile path must still match the legacy path bit-exactly."""
        cfg = get_config("vscnn-vgg16").reduce()
        solo = CNNServer(cfg, batch=2, seed=0)
        ref_reqs = _cnn_queue(4, seed=6)
        solo.serve(ref_reqs)
        srv = CNNServer(cfg, batch=2, seed=0, shard_fc=True)
        assert len(srv.group.meshes) == 1
        reqs = _cnn_queue(4, seed=6)
        srv.serve(reqs)
        for r, ref in zip(reqs, ref_reqs):
            np.testing.assert_array_equal(np.asarray(r.logits),
                                          np.asarray(ref.logits))

    def test_fleet_multi_device_subprocess(self):
        """8 forced host devices: 4 replicas land on 4 distinct devices,
        shard_fc cout-shards the big FC heads over each replica's model
        axis, and logits stay bit-identical to the 1-replica serve."""
        import os
        import subprocess
        import sys
        prog = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.launch.serve import CNNServer, ImageRequest

assert jax.device_count() == 8
cfg = get_config("vscnn-vgg16").reduce()
def queue():
    rng = np.random.default_rng(1)
    return [ImageRequest(rid=i,
                         image=rng.standard_normal((32, 32, 3))
                                  .astype(np.float32))
            for i in range(8)]
solo = CNNServer(cfg, batch=2, seed=0)
ref = queue()
solo.serve(ref)
srv = CNNServer(cfg, batch=2, seed=0, replicas=4, shard_fc=True)
devs = {m.devices.flat[0] for m in srv.group.meshes}
assert len(devs) == 4, devs                     # distinct replica devices
shards = {e.vs.vals.sharding.spec for e in srv.backend.apply.sparse.values()
          if type(e).__name__ == "SparseFC" and e.vs.vals.shape[0] > 1}
assert jax.sharding.PartitionSpec("model", None, None, None) in shards
reqs = queue()
stats = srv.serve(reqs)
assert {s["replica"] for s in stats} == {0, 1, 2, 3}
for r, x in zip(reqs, ref):
    np.testing.assert_array_equal(np.asarray(r.logits),
                                  np.asarray(x.logits))
print("FLEET-OK")
"""
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        r = subprocess.run(
            [sys.executable, "-c", prog], capture_output=True, text=True,
            timeout=600, cwd=root,
            env={**os.environ, "PYTHONPATH": os.path.join(root, "src")})
        assert r.returncode == 0, r.stderr[-4000:]
        assert "FLEET-OK" in r.stdout


class TestLMSampling:
    """Per-request temperature / top-k through the LM backend."""

    def _sreqs(self, cfg, specs, seed=11):
        rng = np.random.default_rng(seed)
        return [Request(rid=100 + i,
                        prompt=rng.integers(0, cfg.vocab, 6, dtype=np.int32),
                        max_new=mn, temperature=t, top_k=k)
                for i, (mn, t, k) in enumerate(specs)]

    def test_temperature_zero_is_greedy_bit_exact(self, lm_server):
        """temp=0 requests take the exact legacy greedy path — same tokens,
        whether top_k is set or not."""
        cfg = lm_server.cfg
        ref = self._sreqs(cfg, [(5, 0.0, 0), (5, 0.0, 0)])
        lm_server.serve(ref)
        got = self._sreqs(cfg, [(5, 0.0, 7), (5, 0.0, 3)])
        lm_server.serve(got)
        assert [r.out for r in got] == [r.out for r in ref]

    def test_top_k_one_matches_greedy(self, lm_server):
        """top_k=1 leaves only the argmax in the distribution, so any
        temperature still reproduces greedy decoding."""
        cfg = lm_server.cfg
        ref = self._sreqs(cfg, [(5, 0.0, 0)])
        lm_server.serve(ref)
        got = self._sreqs(cfg, [(5, 1.5, 1)])
        lm_server.serve(got)
        assert got[0].out == ref[0].out

    def test_sampling_reproducible_and_not_greedy(self, lm_server):
        """Sampled streams are keyed by (seed, rid, step): the same request
        re-served emits the same tokens, and a hot temperature actually
        leaves the greedy path."""
        cfg = lm_server.cfg
        a = self._sreqs(cfg, [(8, 5.0, 0)])
        lm_server.serve(a)
        b = self._sreqs(cfg, [(8, 5.0, 0)])
        lm_server.serve(b)
        assert a[0].out == b[0].out
        greedy = self._sreqs(cfg, [(8, 0.0, 0)])
        lm_server.serve(greedy)
        assert a[0].out != greedy[0].out

    def test_mixed_batch_keeps_greedy_lane_bit_exact(self, lm_server):
        """A sampled neighbour in the batch must not perturb a greedy
        lane's tokens (the `where(temp > 0, ...)` lane isolation)."""
        cfg = lm_server.cfg
        ref = self._sreqs(cfg, [(6, 0.0, 0), (6, 0.0, 0)])
        lm_server.serve(ref)
        mixed = self._sreqs(cfg, [(6, 0.0, 0), (6, 2.0, 20)])
        lm_server.serve(mixed)
        assert mixed[0].out == ref[0].out
