"""Lockstep scheduler unit tests (no jax): bucketing, retirement order,
backfill (including instant-finish chaining), can_backfill refusal — plus
the replica fleet: per-replica wave dispatch (a stalled replica never
blocks the others' retirement), least-loaded placement, work stealing,
and N-replica output parity with the single-replica scheduler.

A scripted pure-python backend stands in for the model: each request
carries the emission stream its slot will produce, so slot lifecycle logic
is pinned independently of prefill/decode numerics.  Fleet backends share
an event log, so cross-replica interleaving is asserted directly.
"""
import dataclasses

from repro.launch.scheduler import FleetScheduler, LockstepScheduler


@dataclasses.dataclass
class Req:
    rid: int
    script: list            # emissions this request's slot produces, in order
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class ScriptBackend:
    """Emits each request's scripted stream; finishes on eos or max_new."""

    def __init__(self, *, eos=None, len_bucket=4, admit=None):
        self.eos = eos
        self.len_bucket = len_bucket
        self.admit = admit or (lambda req: True)  # can_backfill predicate
        self.started = []                          # audit: admission waves

    def bucket_key(self, req):
        return -(-len(req.script) // self.len_bucket)

    def sort_key(self, req):
        return -len(req.script)

    def start(self, reqs, width):
        self.started.append([r.rid for r in reqs])
        state = {"cur": [None] * width}
        emis = [None] * width
        for j, r in enumerate(reqs):
            state["cur"][j] = iter(r.script)
            emis[j] = next(state["cur"][j])
        return state, emis

    def step(self, state, slots):
        return state, [next(state["cur"][j], 0) if r is not None else None
                       for j, r in enumerate(slots)]

    def can_backfill(self, state, req):
        return self.admit(req)

    def backfill(self, state, slot, req):
        state["cur"][slot] = iter(req.script)
        return state, next(state["cur"][slot])

    def append(self, req, e):
        req.out.append(e)
        if self.eos is not None and e == self.eos:
            return True
        return len(req.out) >= req.max_new

    def finish(self, state):
        return {"custom": 1}


def _sched(be, batch):
    return LockstepScheduler(be, batch=batch)


class TestLockstep:
    def test_exact_steps_no_trailing_step(self):
        """Uniform batch: start emits token 1, so max_new tokens need
        exactly max_new - 1 steps — the off-by-one regression pin."""
        be = ScriptBackend()
        reqs = [Req(i, list(range(10, 16)), 4) for i in range(2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["steps"] == 3
        assert s["emissions"] == 8 and s["finished"] == 2
        assert all(r.out == [10, 11, 12, 13] for r in reqs)
        assert s["custom"] == 1  # backend.finish merged in

    def test_retired_slot_backfilled_same_run(self):
        """A short sequence frees its slot for a queued request within the
        same lockstep run."""
        be = ScriptBackend()
        reqs = [Req(0, [1] * 8, 2), Req(1, [2] * 8, 6), Req(2, [3] * 8, 3)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["backfills"] == 1 and s["finished"] == 3
        assert [len(r.out) for r in reqs] == [2, 6, 3]
        # r0 retires after step 1; r2 rides its slot; the run is bounded by
        # the longest request: 6 tokens -> 5 steps
        assert s["steps"] == 5
        assert s["emissions"] == 11

    def test_eos_retires_early(self):
        be = ScriptBackend(eos=99)
        r = Req(0, [5, 99, 7, 7], 4)
        stats = _sched(be, 1).serve([r])
        assert r.out == [5, 99]          # eos recorded, then retired
        assert stats[0]["steps"] == 1    # no steps wasted past the eos

    def test_backfill_chain_instant_finish(self):
        """A backfilled max_new=1 request finishes on its admission emission
        and must chain straight into the next backfill."""
        be = ScriptBackend()
        reqs = [Req(0, [1, 1], 2), Req(1, [2], 1), Req(2, [3, 3], 2)]
        stats = _sched(be, 1).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["backfills"] == 2 and s["finished"] == 3
        assert reqs[1].out == [2] and reqs[2].out == [3, 3]
        assert s["steps"] == 2  # r0: 1 step; r2: 1 step; r1 rides admissions

    def test_bucketing_splits_and_sorts(self):
        """Different buckets never share a run; within a bucket the sort key
        (longest first) picks the admission order."""
        be = ScriptBackend(len_bucket=4)
        short = [Req(0, [1] * 3, 2), Req(1, [1] * 4, 2)]   # bucket 1
        long = [Req(2, [1] * 8, 2), Req(3, [1] * 7, 2)]    # bucket 2
        stats = _sched(be, 2).serve([short[0], long[0], short[1], long[1]])
        assert len(stats) == 2
        assert be.started == [[1, 0], [2, 3]]

    def test_can_backfill_refusal_spills_to_new_run(self):
        """A request the backend refuses mid-run gets a fresh lockstep run
        instead of being dropped."""
        be = ScriptBackend(admit=lambda req: req.rid != 2)
        reqs = [Req(0, [1] * 4, 2), Req(1, [2] * 4, 2), Req(2, [3] * 4, 2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 2
        assert stats[0]["backfills"] == 0
        assert [len(r.out) for r in reqs] == [2, 2, 2]
        assert be.started == [[0, 1], [2]]

    def test_first_fit_skips_refused_head(self):
        """If the queue head doesn't fit, a later request that does is
        backfilled (first-fit scan)."""
        be = ScriptBackend(admit=lambda req: req.rid != 2)
        # sort_key keeps scripted lengths equal so queue order is stable
        reqs = [Req(0, [1] * 4, 1), Req(1, [2] * 4, 4),
                Req(2, [3] * 4, 2), Req(3, [4] * 4, 2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 2
        assert stats[0]["backfills"] == 1
        assert be.started == [[0, 1], [2]]
        assert len(reqs[3].out) == 2     # rid 3 rode rid 0's slot


class FleetScript(ScriptBackend):
    """One fleet replica's scripted backend; all replicas share ``events``
    so cross-replica ordering is observable."""

    def __init__(self, replica, events, **kw):
        super().__init__(**kw)
        self.replica = replica
        self.events = events

    def start(self, reqs, width):
        self.events.append(("start", self.replica, [r.rid for r in reqs]))
        return super().start(reqs, width)

    def step(self, state, slots):
        self.events.append(("step", self.replica))
        return super().step(state, slots)

    def backfill(self, state, slot, req):
        self.events.append(("backfill", self.replica, req.rid))
        return super().backfill(state, slot, req)


def _fleet(n, batch, **kw):
    events = []
    bes = [FleetScript(i, events, **kw) for i in range(n)]
    return FleetScheduler(bes, batch=batch), bes, events


class TestFleet:
    def test_stalled_replica_never_blocks_retirement_and_steal(self):
        """The headline fleet property: replica 0 grinds a 10-step wave
        while replica 1 retires its own waves AND steals replica 0's
        queued straggler — nothing waits on the slow wave."""
        sched, bes, events = _fleet(2, 1)
        a = Req(0, [9] * 10, 10)                 # 10 emissions: 9 steps
        b, c, d = (Req(i, [i], 1) for i in (1, 2, 3))
        # chunk placement (batch=1, least-loaded): a->r0, b->r1, c->r0, d->r1
        stats = sched.serve([a, b, c, d])
        assert len(a.out) == 10
        assert all(len(r.out) == 1 for r in (b, c, d))
        # c was queued behind a on replica 0 and moved to idle replica 1
        assert sched.steals == 1
        assert ("start", 1, [2]) in events
        # every replica-1 event precedes replica 0's first step: the slow
        # wave never gated the fast replica's retirement
        first_r0_step = events.index(("step", 0))
        assert all(e[1] == 0 for e in events[first_r0_step:])
        # retirement order: both replica-1 runs retire before replica 0's
        assert [s["replica"] for s in stats] == [1, 1, 0]
        assert stats[-1]["steps"] == 9 and stats[-1]["finished"] == 1

    def test_per_replica_wave_dispatch_interleaves(self):
        """Two busy replicas advance one step per tick each — interleaved,
        not drained sequentially."""
        sched, bes, events = _fleet(2, 2)
        reqs = [Req(i, [i] * 4, 4) for i in range(4)]
        sched.serve(reqs)
        steps = [e[1] for e in events if e[0] == "step"]
        assert steps == [0, 1, 0, 1, 0, 1]       # 3 ticks, both replicas
        assert all(len(r.out) == 4 for r in reqs)

    def test_least_loaded_chunk_placement(self):
        """Wave-sized chunks land on the least-loaded replica, ties to the
        lowest index."""
        sched, bes, events = _fleet(3, 2)
        reqs = [Req(i, [i] * 2, 2) for i in range(10)]   # 5 chunks of 2
        sched.serve(reqs)
        waves = {e[1]: e[2] for e in events if e[0] == "start"}
        assert waves[0] == [0, 1] and waves[1] == [2, 3] and \
            waves[2] == [4, 5]
        # chunks 4 and 5 backfill replicas 0 and 1's runs (same bucket)
        assert all(len(r.out) == 2 for r in reqs)
        assert sched.steals == 0

    def test_fleet_of_one_matches_lockstep(self):
        """One replica reproduces `LockstepScheduler.serve` exactly:
        admission waves, stats counters, and emissions."""
        mk = lambda: [Req(0, [1] * 8, 2), Req(1, [2] * 8, 6),
                      Req(2, [3] * 8, 3)]
        solo_be = ScriptBackend()
        solo_reqs = mk()
        solo = LockstepScheduler(solo_be, batch=2).serve(solo_reqs)
        sched, bes, _ = _fleet(1, 2)
        fleet_reqs = mk()
        fleet = sched.serve(fleet_reqs)
        assert [r.out for r in fleet_reqs] == [r.out for r in solo_reqs]
        assert bes[0].started == solo_be.started
        keys = ("steps", "finished", "backfills", "emissions")
        assert [{k: s[k] for k in keys} for s in fleet] == \
            [{k: s[k] for k in keys} for s in solo]

    def test_n_replica_outputs_match_single(self):
        """Every request's emission stream is identical however many
        replicas serve the queue (the fleet analogue of the CNN
        bit-identity gate, scripted)."""
        def serve(n):
            reqs = [Req(i, [10 + i] * 6, 1 + i % 4) for i in range(12)]
            sched, _, _ = _fleet(n, 2)
            sched.serve(reqs)
            return [r.out for r in reqs]
        ref = serve(1)
        for n in (2, 3, 5):
            assert serve(n) == ref

    def test_leftover_queue_gets_fresh_run(self):
        """Requests a backend refuses to backfill are not lost on the
        fleet path: they get a fresh run on their replica."""
        sched, bes, events = _fleet(1, 2,
                                    admit=lambda req: req.rid != 2)
        reqs = [Req(0, [1] * 4, 2), Req(1, [2] * 4, 2), Req(2, [3] * 4, 2)]
        stats = sched.serve(reqs)
        assert [len(r.out) for r in reqs] == [2, 2, 2]
        assert len(stats) == 2
        assert bes[0].started == [[0, 1], [2]]
