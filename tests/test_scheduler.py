"""Lockstep scheduler unit tests (no jax): bucketing, retirement order,
backfill (including instant-finish chaining), can_backfill refusal.

A scripted pure-python backend stands in for the model: each request
carries the emission stream its slot will produce, so slot lifecycle logic
is pinned independently of prefill/decode numerics.
"""
import dataclasses

from repro.launch.scheduler import LockstepScheduler


@dataclasses.dataclass
class Req:
    rid: int
    script: list            # emissions this request's slot produces, in order
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class ScriptBackend:
    """Emits each request's scripted stream; finishes on eos or max_new."""

    def __init__(self, *, eos=None, len_bucket=4, admit=None):
        self.eos = eos
        self.len_bucket = len_bucket
        self.admit = admit or (lambda req: True)  # can_backfill predicate
        self.started = []                          # audit: admission waves

    def bucket_key(self, req):
        return -(-len(req.script) // self.len_bucket)

    def sort_key(self, req):
        return -len(req.script)

    def start(self, reqs, width):
        self.started.append([r.rid for r in reqs])
        state = {"cur": [None] * width}
        emis = [None] * width
        for j, r in enumerate(reqs):
            state["cur"][j] = iter(r.script)
            emis[j] = next(state["cur"][j])
        return state, emis

    def step(self, state, slots):
        return state, [next(state["cur"][j], 0) if r is not None else None
                       for j, r in enumerate(slots)]

    def can_backfill(self, state, req):
        return self.admit(req)

    def backfill(self, state, slot, req):
        state["cur"][slot] = iter(req.script)
        return state, next(state["cur"][slot])

    def append(self, req, e):
        req.out.append(e)
        if self.eos is not None and e == self.eos:
            return True
        return len(req.out) >= req.max_new

    def finish(self, state):
        return {"custom": 1}


def _sched(be, batch):
    return LockstepScheduler(be, batch=batch)


class TestLockstep:
    def test_exact_steps_no_trailing_step(self):
        """Uniform batch: start emits token 1, so max_new tokens need
        exactly max_new - 1 steps — the off-by-one regression pin."""
        be = ScriptBackend()
        reqs = [Req(i, list(range(10, 16)), 4) for i in range(2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["steps"] == 3
        assert s["emissions"] == 8 and s["finished"] == 2
        assert all(r.out == [10, 11, 12, 13] for r in reqs)
        assert s["custom"] == 1  # backend.finish merged in

    def test_retired_slot_backfilled_same_run(self):
        """A short sequence frees its slot for a queued request within the
        same lockstep run."""
        be = ScriptBackend()
        reqs = [Req(0, [1] * 8, 2), Req(1, [2] * 8, 6), Req(2, [3] * 8, 3)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["backfills"] == 1 and s["finished"] == 3
        assert [len(r.out) for r in reqs] == [2, 6, 3]
        # r0 retires after step 1; r2 rides its slot; the run is bounded by
        # the longest request: 6 tokens -> 5 steps
        assert s["steps"] == 5
        assert s["emissions"] == 11

    def test_eos_retires_early(self):
        be = ScriptBackend(eos=99)
        r = Req(0, [5, 99, 7, 7], 4)
        stats = _sched(be, 1).serve([r])
        assert r.out == [5, 99]          # eos recorded, then retired
        assert stats[0]["steps"] == 1    # no steps wasted past the eos

    def test_backfill_chain_instant_finish(self):
        """A backfilled max_new=1 request finishes on its admission emission
        and must chain straight into the next backfill."""
        be = ScriptBackend()
        reqs = [Req(0, [1, 1], 2), Req(1, [2], 1), Req(2, [3, 3], 2)]
        stats = _sched(be, 1).serve(reqs)
        assert len(stats) == 1
        s = stats[0]
        assert s["backfills"] == 2 and s["finished"] == 3
        assert reqs[1].out == [2] and reqs[2].out == [3, 3]
        assert s["steps"] == 2  # r0: 1 step; r2: 1 step; r1 rides admissions

    def test_bucketing_splits_and_sorts(self):
        """Different buckets never share a run; within a bucket the sort key
        (longest first) picks the admission order."""
        be = ScriptBackend(len_bucket=4)
        short = [Req(0, [1] * 3, 2), Req(1, [1] * 4, 2)]   # bucket 1
        long = [Req(2, [1] * 8, 2), Req(3, [1] * 7, 2)]    # bucket 2
        stats = _sched(be, 2).serve([short[0], long[0], short[1], long[1]])
        assert len(stats) == 2
        assert be.started == [[1, 0], [2, 3]]

    def test_can_backfill_refusal_spills_to_new_run(self):
        """A request the backend refuses mid-run gets a fresh lockstep run
        instead of being dropped."""
        be = ScriptBackend(admit=lambda req: req.rid != 2)
        reqs = [Req(0, [1] * 4, 2), Req(1, [2] * 4, 2), Req(2, [3] * 4, 2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 2
        assert stats[0]["backfills"] == 0
        assert [len(r.out) for r in reqs] == [2, 2, 2]
        assert be.started == [[0, 1], [2]]

    def test_first_fit_skips_refused_head(self):
        """If the queue head doesn't fit, a later request that does is
        backfilled (first-fit scan)."""
        be = ScriptBackend(admit=lambda req: req.rid != 2)
        # sort_key keeps scripted lengths equal so queue order is stable
        reqs = [Req(0, [1] * 4, 1), Req(1, [2] * 4, 4),
                Req(2, [3] * 4, 2), Req(3, [4] * 4, 2)]
        stats = _sched(be, 2).serve(reqs)
        assert len(stats) == 2
        assert stats[0]["backfills"] == 1
        assert be.started == [[0, 1], [2]]
        assert len(reqs[3].out) == 2     # rid 3 rode rid 0's slot
