"""Calibration subsystem: the committed artifact + the drift-gate mechanism.

Mirrors tests/test_bench_baseline.py for the calibration loop: the
committed ``CALIB_cpu.json`` must load and reproduce its own recorded
predictions bit-exactly, identical rows must pass `compare_calibration`,
and a synthetically perturbed fitted constant (or deterministic feature)
must fail — exactly what CI sees when the cost model drifts without a
refit.  The fitter itself is checked by round-trip: times synthesized from
known constants recover those constants.
"""
import copy
import importlib.util
import json
import math
import pathlib

import pytest

from repro.core.accel_model import load_calibration
from repro.core.calibration import (
    CalibConstants,
    compare_calibration,
    fit_constants,
    layer_features,
    predict_time_s,
)

REPO = pathlib.Path(__file__).resolve().parents[1]
CALIB = REPO / "benchmarks" / "baselines" / "CALIB_cpu.json"


@pytest.fixture(scope="module")
def calib():
    with open(CALIB) as f:
        return json.load(f)


def _gate_rows(calib):
    """The stored rows for the gated subset, replayed as 'fresh' rows."""
    gate = set(calib["gate_layers"])
    return [copy.deepcopy(r) for r in calib["rows"] if r["name"] in gate]


class TestCommittedArtifact:
    def test_shape(self, calib):
        assert calib["calib"] == "measured_vs_modeled"
        assert calib["gate_layers"]
        names = {r["name"] for r in calib["rows"]}
        assert set(calib["gate_layers"]) <= names
        for r in calib["rows"]:
            assert {"features", "predicted_us", "measured_us", "hlo_flops",
                    "hlo_bytes", "modeled_cycles", "modeled_bytes"} <= set(r)

    def test_constants_load_through_accel_model(self, calib):
        c = load_calibration("cpu")
        assert c.calibrated
        assert c.to_dict() == calib["constants"]

    def test_predictions_reproduce_bit_exactly(self, calib):
        """The committed constants + committed features regenerate every
        recorded ``predicted_us`` — the invariant check 1 of the gate
        enforces, asserted here directly against the artifact."""
        c = CalibConstants.from_dict(calib["constants"])
        for r in calib["rows"]:
            got = predict_time_s(r["features"], c) * 1e6
            assert math.isclose(got, r["predicted_us"], rel_tol=1e-9), \
                r["name"]

    def test_hlo_flops_match_model_on_every_layer(self, calib):
        """The design anchor: compiled-HLO FLOPs of the structural path
        equal the modeled structural FLOPs exactly on EVERY layer — the
        matmul path lowers to dots, the depthwise path to fused elementwise
        multiplies, and `utils.hlo.analyze` counts both (a fused f32
        multiply is one MAC pair), so no row is exempt anymore."""
        for r in calib["rows"]:
            assert r["hlo_flops"] > 0, r["name"]
            assert r["flops_model_ratio"] == 1.0, r["name"]


class TestDriftGate:
    def test_identical_rows_pass(self, calib):
        failures, lines = compare_calibration(_gate_rows(calib), calib)
        assert failures == []
        assert lines[0].startswith("| layer |")
        assert any("machine scale" in l for l in lines)

    def test_perturbed_constant_fails(self, calib):
        """Acceptance: nudging one fitted constant without refitting must
        fail the gate (bit-exact round-trip check), with no clock
        involved."""
        perturbed = copy.deepcopy(calib)
        perturbed["constants"]["cycle_time_ns"] *= 1.01
        failures, _ = compare_calibration(_gate_rows(calib), perturbed)
        assert any("reproduce recorded predicted_us" in f for f in failures)

    @pytest.mark.parametrize("const", ["per_tap_overhead",
                                       "fixed_overhead_us"])
    def test_every_constant_is_load_bearing(self, calib, const):
        perturbed = copy.deepcopy(calib)
        perturbed["constants"][const] += 1.0
        failures, _ = compare_calibration(_gate_rows(calib), perturbed)
        assert failures

    def test_perturbed_deterministic_feature_fails_tight(self, calib):
        """A 5% hlo_flops shift (compiled-program drift) breaks the 2%
        deterministic band even though wall clock is untouched."""
        fresh = _gate_rows(calib)
        fresh[0]["hlo_flops"] = int(fresh[0]["hlo_flops"] * 1.05) + 1
        failures, lines = compare_calibration(fresh, calib)
        assert any("hlo_flops" in f for f in failures)
        assert any("| FAIL |" in l for l in lines)

    def test_machine_speed_is_normalized_out(self, calib):
        """A uniformly 8x slower machine passes: one global scale absorbs
        runner speed; only per-layer *shape* drift can fail the band."""
        fresh = _gate_rows(calib)
        for r in fresh:
            r["measured_us"] *= 8.0
        failures, _ = compare_calibration(fresh, calib)
        assert failures == []

    def test_single_layer_wallclock_blowup_fails(self, calib):
        fresh = _gate_rows(calib)
        fresh[0]["measured_us"] *= 100.0
        failures, _ = compare_calibration(fresh, calib)
        assert any("wall clock" in f for f in failures)

    def test_absurd_global_scale_fails_rail(self, calib):
        fresh = _gate_rows(calib)
        for r in fresh:
            r["measured_us"] *= 1000.0
        failures, _ = compare_calibration(fresh, calib)
        assert any("sanity rail" in f for f in failures)

    def test_missing_gated_layer_fails(self, calib):
        failures, _ = compare_calibration(_gate_rows(calib)[1:], calib)
        assert any("missing from fresh records" in f for f in failures)


class TestFitRoundTrip:
    def test_synthetic_times_recover_constants(self):
        """Times generated from known constants are fit back exactly (the
        design matrix is full-rank, the true solution is non-negative, so
        NNLS == lstsq == exact)."""
        true = CalibConstants(
            backend="cpu", cycle_time_ns=7.0, per_tap_overhead=3.0,
            vsmm_flush_cycles=11.0, dma_overlap=0.25, fixed_overhead_us=5.0,
            hbm_gbps=20.0)
        feats = [
            layer_features(flops=2 * 32 * 128 * m, bytes_accessed=b, nb=nb,
                           s_steps=s, blocks=blk, vk=32, vn=128)
            for m, b, nb, s, blk in [
                (50_000, 1_000_000, 1, 4, 16),
                (900_000, 4_000_000, 2, 9, 64),
                (10_000, 16_000_000, 4, 2, 8),
                (300_000, 500_000, 1, 30, 128),
                (2_000_000, 9_000_000, 8, 5, 2),
                (120_000, 2_500_000, 3, 17, 32),
                (700, 300_000, 1, 1, 1),
            ]
        ]
        times = [predict_time_s(f, true) for f in feats]
        got = fit_constants(feats, times, backend="cpu", hbm_gbps=20.0)
        for name in ("cycle_time_ns", "per_tap_overhead",
                     "vsmm_flush_cycles", "dma_overlap",
                     "fixed_overhead_us"):
            assert math.isclose(getattr(got, name), getattr(true, name),
                                rel_tol=1e-6), name

    def test_uncalibrated_defaults_predict_zero(self):
        c = CalibConstants()
        assert not c.calibrated
        f = layer_features(flops=1 << 20, bytes_accessed=1 << 20, nb=1,
                           s_steps=1, blocks=1, vk=32, vn=128)
        assert predict_time_s(f, c) == 0.0

    def test_calib_path_env_override(self, monkeypatch, tmp_path):
        from repro.core.calibration import default_calib_path, load_constants
        monkeypatch.setenv("VSCNN_CALIB_PATH", str(tmp_path / "nope.json"))
        assert default_calib_path("cpu") == tmp_path / "nope.json"
        assert not load_constants("cpu").calibrated  # missing -> defaults


class TestCalibrateCLI:
    """The benchmarks/calibrate.py driver, loaded the bench-script way."""

    @pytest.fixture(scope="class")
    def cal(self):
        spec = importlib.util.spec_from_file_location(
            "calibrate", REPO / "benchmarks" / "calibrate.py")
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_gate_layers_cover_one_fast_net(self, cal, calib):
        assert all(n.startswith(f"{cal.GATE_NET}/")
                   for n in calib["gate_layers"])
        assert len(calib["gate_layers"]) >= 10

    def test_fit_settings_recorded(self, cal, calib):
        fit = calib["fit"]
        assert set(fit["nets"]) == set(cal.DEFAULT_NETS)
        assert fit["image_size"] == cal.IMAGE_SIZE
        assert fit["density"] == cal.DEFAULT_DENSITY

    def test_model_side_records_without_clock(self, cal):
        """collect_records(measure=False) is the deterministic half the
        gate compares: modeled columns + features, no wall clock."""
        rows = cal.collect_records(("resnet18",), layers=None, measure=False)
        assert len(rows) == 21  # 20 convs + fc head
        for r in rows:
            assert "measured_us" not in r
            assert r["features"]["flops"] == r["modeled_flops"]
