"""Per-arch smoke tests (assignment requirement): reduced same-family config,
one forward + one train step on CPU, asserting shapes and finiteness; serve
consistency (prefill + decode == full forward) where decoding exists."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.models.frontend import synthetic_embeddings, synthetic_tokens
from repro.optim import adamw

ARCHS = list_archs()


def _batch(cfg, key, b=2, t=16):
    if cfg.embed_inputs:
        return {"tokens": synthetic_tokens(key, b, t, cfg.vocab),
                "labels": synthetic_tokens(jax.random.fold_in(key, 1), b, t,
                                           cfg.vocab)}
    return {"embeds": synthetic_embeddings(key, b, t, cfg.d_model, cfg.dtype),
            "labels": synthetic_tokens(jax.random.fold_in(key, 1), b, t,
                                       cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch).reduce()
        key = jax.random.PRNGKey(0)
        params = init_params(tfm.lm_schema(cfg), key, cfg.dtype)
        batch = _batch(cfg, key)
        logits = tfm.lm_apply(params, batch, cfg)
        b, t = batch["labels"].shape
        assert logits.shape == (b, t, cfg.padded_vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_one_train_step_reduces_loss_sign(self, arch):
        cfg = get_config(arch).reduce()
        key = jax.random.PRNGKey(0)
        params = init_params(tfm.lm_schema(cfg), key, cfg.dtype)
        opt = adamw()
        state = opt.init(params)
        batch = _batch(cfg, key)

        @jax.jit
        def step(p, s):
            (loss, _), g = jax.value_and_grad(tfm.loss_fn, has_aux=True)(
                p, batch, cfg)
            upd, s = opt.update(g, s, p, jnp.float32(1e-2))
            return jax.tree.map(lambda a, u: a + u, p, upd), s, loss

        losses = []
        for _ in range(3):
            params, state, loss = step(params, state)
            assert np.isfinite(float(loss)), arch
            losses.append(float(loss))
        # same batch re-fit: loss must drop
        assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).encoder_only])
def test_serve_consistency(arch):
    """prefill(x[:T]) + decode steps == full forward, per position."""
    cfg = get_config(arch).reduce()
    if cfg.moe is not None:  # disable capacity dropping for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(3)
    params = init_params(tfm.lm_schema(cfg), key, cfg.dtype)
    B, T, extra = 2, 20, 3
    if cfg.embed_inputs:
        toks = synthetic_tokens(key, B, T + extra, cfg.vocab)
        full = tfm.lm_apply(params, {"tokens": toks}, cfg)
        logits, caches = tfm.prefill(params, {"tokens": toks[:, :T]}, cfg,
                                     capacity=T + extra)
        dec = [toks[:, T + i][:, None] for i in range(extra)]
    else:
        emb = synthetic_embeddings(key, B, T + extra, cfg.d_model, cfg.dtype)
        full = tfm.lm_apply(params, {"embeds": emb}, cfg)
        logits, caches = tfm.prefill(params, {"embeds": emb[:, :T]}, cfg,
                                     capacity=T + extra)
        dec = [emb[:, T + i][:, None] for i in range(extra)]
    errs = [np.abs(np.asarray(logits) - np.asarray(full[:, T - 1])).max()]
    for i in range(extra):
        logits, caches = tfm.decode_step(params, caches, dec[i],
                                         jnp.int32(T + i), cfg)
        errs.append(
            np.abs(np.asarray(logits) - np.asarray(full[:, T + i])).max())
    rel = max(errs) / np.abs(np.asarray(full)).max()
    assert rel < 2e-2, (arch, errs)


def test_encoder_only_has_no_decode_shapes():
    cfg = get_config("hubert-xlarge")
    sup = cfg.supported_shapes()
    assert sup["decode_32k"] and sup["long_500k"]
    assert not sup["train_4k"] and not sup["prefill_32k"]


def test_long_context_eligibility_rules():
    eligible = {a for a in ARCHS
                if not get_config(a).supported_shapes()["long_500k"]}
    assert eligible == {"gemma3-12b", "jamba-v0.1-52b", "rwkv6-3b"}


def test_full_configs_match_assignment():
    """The exact public dims from the assignment table."""
    spec = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "rwkv6-3b": (32, 2560, None, None, 8960, 65536),
    }
    for arch, (nl, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.total_layers == nl, arch
        assert cfg.d_model == d and cfg.vocab == v, arch
        if h is not None and arch != "kimi-k2-1t-a32b":
            assert cfg.n_heads == h and cfg.n_kv_heads == kv, arch
        ff_cfg = cfg.moe.d_ff if (cfg.moe and arch != "jamba-v0.1-52b") else cfg.d_ff
        if arch == "kimi-k2-1t-a32b":
            ff_cfg = cfg.moe.d_ff
        assert ff_cfg == ff, arch


def test_moe_param_counts():
    """kimi-k2 must be ~1T total / ~32B active."""
    cfg = get_config("kimi-k2-1t-a32b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 0.8e12 < total < 1.3e12, total
    assert 15e9 < active < 50e9, active
