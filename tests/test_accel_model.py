"""Cycle-accurate PE-array model vs the paper's own numbers (Table I, §IV)."""
import numpy as np
import pytest

from repro.core import (
    PEConfig, PE_4_14_3, PE_8_7_3, aggregate, conv_layer_cycles,
)
from repro.core.accel_model import table1_example


class TestTable1:
    def test_paper_table1_dense_15_cycles(self):
        assert table1_example().dense == 15

    def test_paper_table1_sparse_8_cycles(self):
        assert table1_example().vscnn == 8

    def test_paper_table1_saving_47pct(self):
        r = table1_example()
        assert (r.dense - r.vscnn) / r.dense == pytest.approx(0.4667, abs=0.01)


class TestDenseCycleFormula:
    def test_dense_cycles_5x5(self):
        x = np.ones((5, 5, 1))
        w = np.ones((3, 3, 1, 1))
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
        assert r.dense == 15  # ceil(5/5) * 5 * 3

    def test_dense_scales_with_cin_cout(self):
        x = np.ones((14, 14, 4))
        w = np.ones((3, 3, 4, 8))
        pe = PEConfig(blocks=4, rows=14, cols=3)
        r = conv_layer_cycles(x, w, pe)
        # ceil(14/14)=1 row grp * 14 cols * 3 kx * 4 cin * ceil(8/4)=2
        assert r.dense == 1 * 14 * 3 * 4 * 2

    def test_rows_padding(self):
        x = np.ones((15, 5, 1))  # 15 rows on 14-row PE -> 2 row groups
        w = np.ones((3, 3, 1, 1))
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=14, cols=3))
        assert r.dense == 2 * 5 * 3


class TestSparseSkipping:
    def test_zero_weight_column_skipped(self):
        x = np.ones((5, 5, 1))
        w = np.ones((3, 3, 1, 1))
        w[:, 2] = 0.0  # kernel column WC pruned
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
        assert r.vscnn == 10  # 5 input cols x 2 nonzero weight cols

    def test_zero_input_column_skipped(self):
        x = np.ones((5, 5, 1))
        x[:, 1] = 0.0  # input column B all zero
        w = np.ones((3, 3, 1, 1))
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
        assert r.vscnn == 12  # 4 nonzero input cols x 3 weight cols

    def test_dense_input_dense_weight_no_skip(self):
        x = np.ones((5, 5, 2))
        w = np.ones((3, 3, 2, 2))
        r = conv_layer_cycles(x, w, PEConfig(blocks=2, rows=5, cols=3))
        assert r.vscnn == r.dense

    def test_all_zero_weight(self):
        x = np.ones((5, 5, 1))
        w = np.zeros((3, 3, 1, 1))
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
        assert r.vscnn == 0

    def test_speedup_monotone_in_sparsity(self):
        rng = np.random.default_rng(0)
        x = np.abs(rng.standard_normal((14, 14, 8)))
        pe = PE_4_14_3
        speeds = []
        for keep in (1.0, 0.6, 0.3):
            w = rng.standard_normal((3, 3, 8, 16))
            mask = rng.random((3, 8, 16)) < keep  # prune whole ky-columns
            w = w * mask[None]
            speeds.append(conv_layer_cycles(x, w, pe).speedup)
        assert speeds[0] <= speeds[1] <= speeds[2]


class TestIdealBounds:
    def test_vscnn_never_beats_ideal_vector(self):
        rng = np.random.default_rng(1)
        for _ in range(5):
            x = np.maximum(rng.standard_normal((28, 28, 4)), 0)
            w = rng.standard_normal((3, 3, 4, 8))
            w[:, :, :, rng.random(8) < 0.4] = 0
            for pe in (PE_4_14_3, PE_8_7_3):
                r = conv_layer_cycles(x, w, pe)
                assert r.vscnn >= r.ideal_vector
                assert r.ideal_vector >= r.ideal_fine or r.ideal_fine <= r.dense

    def test_aggregate_sums(self):
        x = np.ones((5, 5, 1))
        w = np.ones((3, 3, 1, 1))
        r = conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
        agg = aggregate([r, r, r])
        assert agg.dense == 3 * r.dense and agg.vscnn == 3 * r.vscnn


class TestBlockMapWidth:
    def test_width_mapping(self):
        x = np.ones((5, 10, 1))
        w = np.ones((3, 3, 1, 1))
        pe = PEConfig(blocks=2, rows=5, cols=3, block_map="width")
        r = conv_layer_cycles(x, w, pe)
        assert r.dense == 1 * 5 * 3 * 1 * 1  # width 10 / 2 blocks = 5 groups


class TestModelInvariances:
    """Structural properties the calibrated model is trusted to keep."""

    def test_vscnn_cycles_monotonic_in_density(self):
        """Nested masks (rising magnitude threshold) can only remove
        (input vec, weight col) pairs — vscnn cycles never increase as
        weights get sparser, at any PE shape."""
        rng = np.random.default_rng(11)
        x = np.maximum(rng.standard_normal((14, 14, 16)), 0)
        w = rng.standard_normal((3, 3, 16, 64))
        for pe in (PE_4_14_3, PE_8_7_3):
            prev = None
            for thresh in (0.0, 0.5, 1.0, 1.5, 2.0, 3.0):
                wt = np.where(np.abs(w) > thresh, w, 0.0)
                r = conv_layer_cycles(x, wt, pe)
                if prev is not None:
                    assert r.vscnn <= prev.vscnn
                    assert r.macs_nonzero <= prev.macs_nonzero
                prev = r

    def test_grouped_dilated_slices_sum_to_whole(self):
        """A grouped (dilated) layer's additive counts equal the sum of
        its per-group ungrouped slices — the rearrangement in
        `conv_layer_cycles` is exact, not an approximation.  (The ideal
        bounds ceil over global packing, so only the additive fields.)"""
        rng = np.random.default_rng(12)
        groups, cin_g, cout_g = 4, 8, 16
        x = np.maximum(rng.standard_normal((14, 14, groups * cin_g)), 0)
        w = rng.standard_normal((3, 3, cin_g, groups * cout_g))
        w[np.abs(w) < 0.8] = 0
        for dilation in (1, 2):
            whole = conv_layer_cycles(x, w, PE_4_14_3, groups=groups,
                                      dilation=dilation)
            parts = [
                conv_layer_cycles(
                    x[:, :, g * cin_g:(g + 1) * cin_g],
                    w[:, :, :, g * cout_g:(g + 1) * cout_g],
                    PE_4_14_3, dilation=dilation)
                for g in range(groups)
            ]
            for field in ("dense", "vscnn", "macs_nonzero", "macs_dense"):
                assert getattr(whole, field) == \
                    sum(getattr(p, field) for p in parts), field

    def test_1x1_traffic_impl_invariant(self):
        """A pointwise ungrouped conv has no halo and no row-tap stack:
        both input layouts must model identical HBM bytes (and identical
        arithmetic intensity)."""
        from repro.core.accel_model import conv_layer_traffic

        for cin, cout, stride in [(64, 128, 1), (128, 128, 2), (32, 256, 1)]:
            halo, stack = (
                conv_layer_traffic(
                    (1, 14, 14, cin), kh=1, kw=1, stride=stride, cout=cout,
                    s_steps=2, vk=32, vn=128, impl=impl)
                for impl in ("halo", "stack"))
            assert halo.bytes_accessed == stack.bytes_accessed, (cin, stride)
            assert halo.arithmetic_intensity == stack.arithmetic_intensity
            assert halo.flops == stack.flops
