"""Beyond-paper perf features: sparse LM FFN, resident MoE dispatch,
bf16-flow, flash remat, seq-sharded residuals, microbatched training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.models.frontend import synthetic_tokens
from repro.models.moe import MoEConfig, moe_apply, moe_schema
from repro.models.sparse_lm import sparse_mlp_apply, sparse_mlp_schema
from repro.parallel import sharding as shd


def _densify(vals, idx, k):
    vals, idx = np.asarray(vals, np.float32), np.asarray(idx)
    nb, s, vk, vn = vals.shape
    w = np.zeros((k // vk, vk, nb, vn), np.float32)
    for j in range(nb):
        for t in range(s):
            w[idx[j, t], :, j, :] += vals[j, t]
    return w.reshape(k, nb * vn)


class TestSparseLM:
    @pytest.mark.parametrize("arch,act", [("nemotron-4-340b", "relu2"),
                                          ("qwen1.5-4b", "swiglu")])
    def test_matches_densified_oracle(self, arch, act):
        cfg = dataclasses.replace(get_config(arch).reduce(),
                                  tp_hint=2, d_ff=128, d_model=64)
        params = init_params(sparse_mlp_schema(cfg, cfg.sparsity),
                             jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64), jnp.float32)
        y = sparse_mlp_apply(params, x, cfg)
        gated = params["wi_vals"].ndim == 5
        if gated:
            g = _densify(params["wi_vals"][0], params["wi_idx"][0], 64)
            u = _densify(params["wi_vals"][1], params["wi_idx"][1], 64)
            xf = np.asarray(x).reshape(-1, 64)
            h = (xf @ g) * (1 / (1 + np.exp(-(xf @ g)))) * (xf @ u)
            h = np.asarray(jax.nn.silu(jnp.asarray(xf @ g))) * (xf @ u)
        else:
            wi = _densify(params["wi_vals"], params["wi_idx"], 64)
            h = np.maximum(np.asarray(x).reshape(-1, 64) @ wi, 0) ** 2
        f_loc = cfg.d_ff // cfg.tp_hint
        wo = np.concatenate(
            [_densify(params["wo_vals"][r], params["wo_idx"][r], f_loc)
             for r in range(cfg.tp_hint)], axis=0)
        ref = (h @ wo).reshape(2, 8, 64)
        rel = np.abs(np.asarray(y) - ref).max() / np.abs(ref).max()
        assert rel < 1e-4, rel

    def test_sparse_lm_full_forward(self):
        cfg = dataclasses.replace(get_config("nemotron-4-340b").reduce(),
                                  use_sparse_ffn=True, tp_hint=2)
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0),
                             cfg.dtype)
        toks = synthetic_tokens(jax.random.PRNGKey(1), 2, 16, cfg.vocab)
        logits = tfm.lm_apply(params, {"tokens": toks}, cfg)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    def test_param_count_scales_with_density(self):
        cfg = dataclasses.replace(get_config("nemotron-4-340b").reduce(),
                                  tp_hint=2, d_ff=256, d_model=128)
        dense = 2 * 128 * 256  # wi + wo elements
        for density in (0.25, 0.5):
            sp = dataclasses.replace(cfg.sparsity, density=density)
            params = init_params(sparse_mlp_schema(cfg, sp),
                                 jax.random.PRNGKey(0), jnp.float32)
            vals = params["wi_vals"].size + params["wo_vals"].size
            assert vals <= dense * density * 1.35, (density, vals, dense)


class TestResidentMoE:
    def test_matches_gather_mode(self):
        rng = np.random.default_rng(0)
        moe = MoEConfig(n_experts=8, top_k=2, d_ff=16, capacity_factor=64.0)
        params = init_params(moe_schema(32, moe, gated=True, tp_hint=1),
                             jax.random.PRNGKey(0), jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 12, 32)), jnp.float32)
        mesh = make_local_mesh(data=1, model=1)
        with shd.use_mesh(mesh, shd.TRAIN_RULES):
            y_g, _ = moe_apply(params, x, moe, gated=True,
                               dispatch="gather_weights")
            y_r, _ = moe_apply(params, x, moe, gated=True, dispatch="resident")
        assert np.abs(np.asarray(y_g) - np.asarray(y_r)).max() < 1e-5


class TestPrecisionKnobs:
    def test_bf16_flow_close_to_f32(self):
        cfg = get_config("gemma3-12b").reduce()
        cfg_bf = dataclasses.replace(cfg, bf16_flow=True)
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0),
                             cfg.dtype)
        batch = {"tokens": synthetic_tokens(jax.random.PRNGKey(1), 2, 32,
                                            cfg.vocab),
                 "labels": synthetic_tokens(jax.random.PRNGKey(2), 2, 32,
                                            cfg.vocab)}
        l0, _ = tfm.loss_fn(params, batch, cfg)
        l1, _ = tfm.loss_fn(params, batch, cfg_bf)
        assert abs(float(l0) - float(l1)) < 0.05

    def test_flash_remat_identical_forward_and_grads(self):
        cfg = get_config("qwen1.5-4b").reduce()
        cfg_r = dataclasses.replace(cfg, flash_remat=True)
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0),
                             cfg.dtype)
        batch = {"tokens": synthetic_tokens(jax.random.PRNGKey(1), 2, 32,
                                            cfg.vocab),
                 "labels": synthetic_tokens(jax.random.PRNGKey(2), 2, 32,
                                            cfg.vocab)}
        g0 = jax.grad(lambda p: tfm.loss_fn(p, batch, cfg)[0])(params)
        g1 = jax.grad(lambda p: tfm.loss_fn(p, batch, cfg_r)[0])(params)
        for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-3, atol=2e-5)

    def test_seq_shard_residual_same_loss(self):
        cfg = get_config("qwen1.5-4b").reduce()
        cfg_s = dataclasses.replace(cfg, seq_shard_residual=True)
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0),
                             cfg.dtype)
        batch = {"tokens": synthetic_tokens(jax.random.PRNGKey(1), 2, 32,
                                            cfg.vocab),
                 "labels": synthetic_tokens(jax.random.PRNGKey(2), 2, 32,
                                            cfg.vocab)}
        l0, _ = tfm.loss_fn(params, batch, cfg)
        l1, _ = tfm.loss_fn(params, batch, cfg_s)
        assert abs(float(l0) - float(l1)) < 1e-3


class TestMicrobatching:
    def test_same_update_as_full_batch(self):
        """mb=4 gradient accumulation == single-batch gradients (fp32 acc)."""
        from repro.configs.base import ShapeSpec
        from repro.launch import step_builders as sb
        cfg = get_config("qwen1.5-4b").reduce()
        mesh = make_local_mesh(data=1, model=1)
        shape = ShapeSpec("t", 32, 8, "train")
        outs = {}
        for mb in (1, 4):
            cfg_mb = dataclasses.replace(cfg, microbatches=mb)
            with shd.use_mesh(mesh, shd.TRAIN_RULES) as ctx:
                art = sb.build_train(cfg_mb, shape, ctx)
                params = init_params(tfm.lm_schema(cfg_mb),
                                     jax.random.PRNGKey(0), cfg_mb.dtype)
                opt_state = sb.make_optimizer(cfg_mb).init(params)
                batch = {
                    "tokens": synthetic_tokens(jax.random.PRNGKey(1), 8, 32,
                                               cfg.vocab),
                    "labels": synthetic_tokens(jax.random.PRNGKey(2), 8, 32,
                                               cfg.vocab),
                }
                fn = jax.jit(art.fn, in_shardings=art.in_shardings,
                             out_shardings=art.out_shardings)
                p2, _, metrics = fn(params, opt_state, batch, jnp.int32(0))
            outs[mb] = (p2, float(metrics["loss"]))
        assert abs(outs[1][1] - outs[4][1]) < 5e-3
        for a, b in zip(jax.tree.leaves(outs[1][0]), jax.tree.leaves(outs[4][0])):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=5e-2, atol=5e-4)
