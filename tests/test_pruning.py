"""Vector pruning (Mao-style) invariants."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import (
    element_density, prune_conv_columns, prune_vectors, prune_vectors_balanced,
)
from repro.core.pruning import vector_scores, prune_tree_balanced


class TestGlobalPruning:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 1.0))
    def test_density_hit(self, seed, density):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((64, 64)).astype(np.float32)
        wp = prune_vectors(w, density, 8, 8)
        kept = (vector_scores(wp, 8, 8) > 0).mean()
        assert abs(kept - density) < 0.15

    def test_keeps_largest_vectors(self):
        w = np.ones((16, 8), np.float32)
        w[:8] *= 10  # top half has much larger norm
        wp = prune_vectors(w, 0.5, 8, 8)
        assert (wp[:8] != 0).all() and (wp[8:] == 0).all()


class TestBalancedPruning:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.15, 0.9))
    def test_per_strip_quota_exact(self, seed, density):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((96, 32)).astype(np.float32)
        _, mask = prune_vectors_balanced(w, density, 8, 8)
        counts = mask.sum(axis=0)
        assert (counts == counts[0]).all()

    def test_balanced_close_to_global_mass(self):
        rng = np.random.default_rng(7)
        w = rng.standard_normal((128, 128)).astype(np.float32)
        g = prune_vectors(w, 0.25, 16, 16)
        b, _ = prune_vectors_balanced(w, 0.25, 16, 16)
        mass = lambda a: np.square(a).sum()
        # the DESIGN.md claim: balancing retains ~the same magnitude mass
        assert mass(b) > 0.9 * mass(g)


class TestConvColumnPruning:
    def test_column_granularity(self):
        rng = np.random.default_rng(8)
        w = rng.standard_normal((3, 3, 4, 8)).astype(np.float32)
        wp = prune_conv_columns(w, 0.5)
        col_nz = (wp != 0).any(axis=0)  # (kx, cin, cout)
        col_all = (wp != 0).all(axis=0)
        # each kernel column is either fully kept or fully zero
        assert (col_nz == col_all).all()

    def test_density(self):
        rng = np.random.default_rng(9)
        w = rng.standard_normal((3, 3, 16, 16)).astype(np.float32)
        wp = prune_conv_columns(w, 0.3)
        assert abs(element_density(wp) - 0.3) < 0.05


class TestTreePruning:
    def test_only_large_matrices_pruned(self):
        import jax.numpy as jnp
        params = {
            "big": jnp.ones((512, 512)),
            "small": jnp.ones((8, 8)),
            "vec": jnp.ones((512,)),
        }
        new, report = prune_tree_balanced(params, 0.5, 16, 128)
        assert element_density(np.asarray(new["big"])) < 0.75
        assert (np.asarray(new["small"]) == 1).all()
        assert (np.asarray(new["vec"]) == 1).all()
        assert len(report) == 1
