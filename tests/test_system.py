"""End-to-end system tests: training loop with resume, serving driver,
VGG-16 sparse pipeline, HLO analyzer fidelity."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.vscnn_vgg16 import CONFIG as VGGCFG
from repro.launch.train import TrainLoop
from repro.launch.serve import Request, Server
from repro.models.cnn import (
    collect_conv_traffic, sparsify_vgg16, vgg16_apply, vgg16_schema,
)
from repro.models.layers import init_params


class TestTrainLoop:
    def test_loss_decreases_and_resumes(self):
        cfg = get_config("qwen1.5-4b").reduce()
        with tempfile.TemporaryDirectory() as d:
            ck = os.path.join(d, "ckpt")
            loop = TrainLoop(cfg, batch=4, seq=32, ckpt_dir=ck, ckpt_every=5)
            _, _, hist = loop.run(8, log_every=100)
            # fresh batch per step + lr warmup: assert stability, not descent
            # (per-arch descent on a fixed batch is covered in models smoke)
            assert all(np.isfinite(hist))
            assert max(hist) - min(hist) < 1.0
            # resume: a new loop continues from the saved step
            loop2 = TrainLoop(cfg, batch=4, seq=32, ckpt_dir=ck, ckpt_every=5)
            params, opt_state, start = loop2.maybe_resume()
            assert start == 8
            _, _, hist2 = loop2.run(10, log_every=100)
            assert len(hist2) == 2  # steps 8..9 only

    def test_straggler_monitor(self):
        from repro.launch.train import StragglerMonitor
        mon = StragglerMonitor(window=8, factor=3.0)
        for _ in range(10):
            assert not mon.observe(0.1)
        assert mon.observe(1.0)
        assert mon.events == 1

    def test_moe_arch_trains(self):
        cfg = get_config("granite-moe-3b-a800m").reduce()
        loop = TrainLoop(cfg, batch=4, seq=32, ckpt_dir=None)
        _, _, hist = loop.run(4, log_every=100)
        assert all(np.isfinite(hist))
        assert max(hist) - min(hist) < 1.0


class TestServer:
    def test_batched_serving(self):
        cfg = get_config("rwkv6-3b").reduce()
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab, 12, dtype=np.int32),
                        max_new=6)
                for i in range(5)]
        srv = Server(cfg, batch=4, capacity=32)
        stats = srv.serve(reqs)
        # continuous batching: the fifth request backfills a retired slot,
        # so one lockstep run serves all five (was 2 runs pre-backfill)
        assert len(stats) == 1
        s = stats[0]
        assert s["backfills"] == 1 and s["finished"] == 5
        # first wave: prefill + 5 decodes; backfilled request: 5 more
        assert s["decode_steps"] == 10
        assert all(len(r.out) == 6 for r in reqs)
        assert s["new_tokens"] == 30


class TestVGGPipeline:
    def test_sparse_paths_agree_with_pruned_dense(self):
        cfg = VGGCFG.reduce()
        key = jax.random.PRNGKey(0)
        params = init_params(
            vgg16_schema(cfg.num_classes, image_size=cfg.image_size),
            key, jnp.float32)
        x = jax.random.normal(key, (2, cfg.image_size, cfg.image_size, 3))
        sparse, pruned = sparsify_vgg16(params, cfg.weight_density,
                                        vk=cfg.vk, vn=cfg.vn)
        ref = vgg16_apply(pruned, x)
        out = vgg16_apply(params, x, sparse=sparse, impl="jnp")
        rel = (np.abs(np.asarray(out) - np.asarray(ref)).max()
               / np.abs(np.asarray(ref)).max())
        assert rel < 1e-4

    def test_traffic_collection_layer_count(self):
        cfg = VGGCFG.reduce()
        params = init_params(
            vgg16_schema(cfg.num_classes, image_size=cfg.image_size),
            jax.random.PRNGKey(0), jnp.float32)
        x = jnp.ones((1, cfg.image_size, cfg.image_size, 3))
        rec = collect_conv_traffic(params, x)
        assert len(rec) == 13  # VGG-16 conv layers

    def test_activation_sparsity_exists_after_relu(self):
        """The paper's input-side skipping depends on post-ReLU zeros."""
        cfg = VGGCFG.reduce()
        params = init_params(
            vgg16_schema(cfg.num_classes, image_size=cfg.image_size),
            jax.random.PRNGKey(0), jnp.float32)
        from repro.data import SyntheticImages
        img = SyntheticImages(1, size=cfg.image_size).batch_at(0)["images"]
        rec = collect_conv_traffic(params, jnp.asarray(img))
        # deeper conv inputs are post-ReLU: a solid fraction must be zeros
        densities = [float((np.asarray(x) != 0).mean()) for _, x, _ in rec[1:]]
        assert min(densities) < 0.9


def _xla_flops(compiled) -> float:
    """cost_analysis() returned a one-element list in older jax (0.4.x),
    a plain dict in newer releases."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca["flops"]


class TestHloAnalyzer:
    def test_matches_xla_cost_analysis_loop_free(self, rng):
        """For a while-free program our FLOP count must match XLA's."""
        from repro.utils.hlo import analyze

        def f(a, b):
            return (a @ b).sum()

        a = jnp.asarray(rng.standard_normal((64, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 32)), jnp.float32)
        compiled = jax.jit(f).lower(a, b).compile()
        got = analyze(compiled.as_text()).flops
        want = _xla_flops(compiled)
        assert got == pytest.approx(want, rel=0.05)

    def test_while_trip_multiplication(self, rng):
        from repro.utils.hlo import analyze

        def f(x, w):
            def body(h, _):
                return h @ w, ()
            h, _ = jax.lax.scan(body, x, None, length=7)
            return h.sum()

        x = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
        compiled = jax.jit(f).lower(x, w).compile()
        got = analyze(compiled.as_text()).flops
        body_once = _xla_flops(compiled)
        assert got >= 6 * body_once  # trip count applied (XLA counts once)
        assert got == pytest.approx(7 * 2 * 32 * 32 * 32, rel=0.1)
