"""GPipe pipeline-over-pods: correctness + differentiability.

Needs >1 device for a real pipeline, so the multi-stage cases run in a
subprocess with forced host devices (the in-process test suite must keep
the single-CPU device count — see conftest)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import pipeline_apply

_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import pipeline_apply

    P, M, D = 4, 8, 16
    mesh = jax.make_mesh((P,), ("pod",))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((P, D, D)) / D**0.5, jnp.float32)
    x = jnp.asarray(rng.standard_normal((M, 3, D)), jnp.float32)

    def stage(w, h):
        return jnp.tanh(h @ w)

    out = pipeline_apply(mesh, stage, ws, x, pod_axis="pod")

    ref = x
    for s in range(P):
        ref = jnp.tanh(ref @ ws[s])
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, ("forward", err)

    # differentiability: grads of the pipelined loss match sequential
    def loss_pipe(ws_):
        return jnp.sum(pipeline_apply(mesh, stage, ws_, x, pod_axis="pod") ** 2)

    def loss_seq(ws_):
        h = x
        for s in range(P):
            h = jnp.tanh(h @ ws_[s])
        return jnp.sum(h ** 2)

    g1 = jax.grad(loss_pipe)(ws)
    g2 = jax.grad(loss_seq)(ws)
    gerr = float(jnp.abs(g1 - g2).max() / jnp.abs(g2).max())
    assert gerr < 1e-4, ("grad", gerr)
    print("PIPELINE-OK", err, gerr)
""")


def test_gpipe_multistage_subprocess():
    env = dict(os.environ, PYTHONPATH="src",
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    res = subprocess.run([sys.executable, "-c", _SUBPROC], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE-OK" in res.stdout, res.stdout + res.stderr


def test_gpipe_single_stage_degenerate():
    """P=1 pipeline == plain application (runs on the real single device)."""
    mesh = jax.make_mesh((1,), ("pod",))
    w = jnp.ones((1, 4, 4)) * 0.1
    x = jnp.ones((3, 2, 4))
    out = pipeline_apply(mesh, lambda w_, h: h @ w_, w, x, pod_axis="pod")
    ref = x @ w[0]
    assert np.allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
