"""Halo-blocked direct-input vsconv: parity and HBM-traffic contract.

The halo impl must be numerically identical (allclose) to the row-tap stack
impl (the oracle layout) and to `kernels/ref.py` across the kernel family —
kh in {1,3,5,7}, odd/even kw, stride 1/2, fused epilogue on/off, and the
non-multiple-Hout padding edge — and its modeled HBM bytes must sit below
the stack path's for every VGG-16 / ResNet-18 conv layer (>= 3x lower for
the 7x7/s2 stem).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    conv_cin_major, encode, prune_vectors_balanced,
)
from repro.core.accel_model import conv_layer_traffic, network_traffic_reports
from repro.kernels import vsconv
from repro.kernels.ref import vsconv_ref


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


def _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density):
    wm = rng.standard_normal((kh * kw * c, co)).astype(np.float32)
    wp, _ = prune_vectors_balanced(wm, density, vk, vn)
    vs = encode(jnp.asarray(wp), vk, vn)
    if kh * kw > 1:
        vs = conv_cin_major(vs, c // vk)  # the order sparsify emits
    return vs


# (kh, kw, stride, h, w, c, co, vk, vn, density): kh in {1,3,5,7}, odd and
# even kw, stride 1/2, odd H/W (asymmetric SAME pads), Hout not a multiple
# of the row block (h=13/s1 -> hop pads to 16; h=9/s2 -> bh shrinks to 5),
# and the 1x1 vsmm route.
SWEEP = [
    (1, 1, 1, 9, 11, 32, 128, 32, 128, 0.5),
    (1, 3, 1, 9, 9, 32, 128, 32, 128, 0.5),
    (3, 3, 1, 13, 15, 32, 128, 32, 128, 0.5),
    (3, 2, 2, 10, 10, 32, 128, 32, 128, 0.5),
    (3, 4, 1, 11, 12, 16, 64, 16, 64, 0.5),
    (5, 5, 2, 12, 10, 16, 64, 16, 64, 0.4),
    (7, 7, 2, 21, 17, 8, 64, 8, 64, 0.5),
    (7, 3, 1, 9, 9, 8, 64, 8, 64, 0.5),
]


class TestHaloParity:
    @pytest.mark.parametrize("kh,kw,stride,h,w,c,co,vk,vn,density", SWEEP)
    def test_halo_matches_stack_and_ref(self, kh, kw, stride, h, w, c, co,
                                        vk, vn, density, rng):
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, w, c)), 0), jnp.float32)
        halo = vsconv(x, vs, kh=kh, kw=kw, stride=stride, impl="halo")
        stack = vsconv(x, vs, kh=kh, kw=kw, stride=stride, impl="stack")
        ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
        assert halo.shape == ref.shape
        assert _rel(halo, stack) < 1e-5
        assert _rel(halo, ref) < 1e-5

    @pytest.mark.parametrize("kh,kw,stride", [(3, 3, 2), (7, 7, 2)])
    @pytest.mark.parametrize("bias,residual,relu", [
        (True, False, True), (True, True, True), (False, True, False),
    ])
    def test_fused_epilogue_parity(self, kh, kw, stride, bias, residual,
                                   relu, rng):
        c, co, vk, vn = 16, 64, 16, 64
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((1, 11, 12, c)), 0), jnp.float32)
        b = (jnp.asarray(rng.standard_normal((co,)), jnp.float32)
             if bias else None)
        out_shape = (1, -(-11 // stride), -(-12 // stride), co)
        res = (jnp.asarray(rng.standard_normal(out_shape), jnp.float32)
               if residual else None)
        kw_args = dict(kh=kh, kw=kw, stride=stride, bias=b, residual=res,
                       fuse_relu=relu)
        halo = vsconv(x, vs, impl="halo", **kw_args)
        stack = vsconv(x, vs, impl="stack", **kw_args)
        ref = vsconv_ref(x, vs, **kw_args)
        assert _rel(halo, stack) < 1e-5
        assert _rel(halo, ref) < 1e-5

    def test_hout_padding_edge(self, rng):
        """Hout = 13 pads to a 16-row grid: the pad rows read zero padding
        in the halo window and are sliced off — no garbage leaks."""
        vs = _sparse_conv_weight(rng, 3, 3, 32, 128, 32, 128, 0.5)
        x = jnp.asarray(rng.standard_normal((1, 13, 8, 32)), jnp.float32)
        halo = vsconv(x, vs, impl="halo")
        assert halo.shape == (1, 13, 8, 128)
        assert _rel(halo, vsconv_ref(x, vs)) < 1e-5

    def test_bad_impl_rejected(self, rng):
        vs = _sparse_conv_weight(rng, 3, 3, 32, 128, 32, 128, 0.5)
        x = jnp.zeros((1, 8, 8, 32), jnp.float32)
        with pytest.raises(ValueError, match="halo"):
            vsconv(x, vs, impl="im2col")


class TestTinyFeatureMap:
    """The degenerate Hout < 4 case (ResNet layer4 on 32px inputs).

    The ungrouped halo kernel switches to the resident whole-input layout
    there (`use_resident_halo`): one block of all cin tiles, fetched once
    per (image, row-block) with the row-block grid axis outermost, tap AND
    cin tile resolved in-kernel.  Parity must hold through the layout
    switch, and the traffic model's resident accounting must put the halo
    path back below the stack path — the two assertions that were strict
    xfail while the per-strip streaming layout over-fetched here.
    """

    @pytest.mark.parametrize("h,stride", [(1, 1), (2, 1), (2, 2), (4, 2),
                                          (3, 1)])
    def test_parity_holds_at_tiny_hout(self, h, stride, rng):
        c, co, vk, vn = 32, 64, 16, 64
        vs = _sparse_conv_weight(rng, 3, 3, c, co, vk, vn, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, h, c)), 0), jnp.float32)
        ref = vsconv_ref(x, vs, stride=stride)
        for impl in ("halo", "stack"):
            out = vsconv(x, vs, stride=stride, impl=impl)
            assert out.shape == ref.shape
            assert _rel(out, ref) < 1e-5, impl

    def test_resident_parity_with_epilogue(self, rng):
        """The resident kernel's fused bias+residual+ReLU epilogue against
        the reference at Hout == 2."""
        c, co, vk, vn = 32, 64, 16, 64
        vs = _sparse_conv_weight(rng, 3, 3, c, co, vk, vn, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, 4, 4, c)), 0), jnp.float32)
        b = jnp.asarray(rng.standard_normal((co,)), jnp.float32)
        res = jnp.asarray(rng.standard_normal((2, 2, 2, co)), jnp.float32)
        kw_args = dict(stride=2, bias=b, residual=res, fuse_relu=True)
        halo = vsconv(x, vs, impl="halo", **kw_args)
        ref = vsconv_ref(x, vs, **kw_args)
        assert _rel(halo, ref) < 1e-5

    def test_halo_kernel_input_bytes_below_stack_hout2(self):
        # ResNet-18 layer3/4-class geometry at 32px: 4x4 input, 3x3/s2
        tr = {impl: conv_layer_traffic(
                  (1, 4, 4, 256), kh=3, kw=3, stride=2, cout=512,
                  s_steps=18, vk=32, vn=128, impl=impl)
              for impl in ("halo", "stack")}
        assert tr["halo"].input_bytes < tr["stack"].input_bytes

    def test_halo_total_bytes_below_stack_hout1(self):
        tr = {impl: conv_layer_traffic(
                  (1, 1, 1, 512), kh=3, kw=3, stride=1, cout=512,
                  s_steps=36, vk=32, vn=128, impl=impl)
              for impl in ("halo", "stack")}
        assert tr["halo"].bytes_accessed < tr["stack"].bytes_accessed

    def test_resident_threshold_and_grouped_exclusion(self):
        from repro.kernels.vsconv import use_resident_halo
        assert use_resident_halo(2, 1) and use_resident_halo(3, 1)
        assert not use_resident_halo(4, 1)   # image-64 nets stay streaming
        assert not use_resident_halo(2, 4)   # grouped: per-group fetch wins


class TestCinMajorOrder:
    def test_reorder_is_a_permutation(self, rng):
        # a coherent 3x3-conv K axis: 9 taps x cb=2 cin tiles = 18 K-tiles
        # (cb must divide KB or the (cin, tap) sort key is meaningless)
        vs = encode(jnp.asarray(
            prune_vectors_balanced(
                rng.standard_normal((18 * 32, 128)).astype(np.float32),
                0.5, 32, 128)[0]), 32, 128)
        vs2 = conv_cin_major(vs, 2)
        idx, idx2 = np.asarray(vs.idx), np.asarray(vs2.idx)
        for j in range(idx.shape[0]):
            assert sorted(idx[j]) == sorted(idx2[j])
        # cin-major: the cin-tile stream is non-decreasing per strip, so the
        # halo block is fetched at most cb times per (strip, row-block)
        assert (np.diff(idx2 % 2, axis=1) >= 0).all()
        # same decoded matrix
        from repro.core import decode
        np.testing.assert_array_equal(np.asarray(decode(vs)),
                                      np.asarray(decode(vs2)))


class TestTrafficContract:
    def test_kernel_cost_halo_below_stack_stem(self):
        """The kernels' own CostEstimates (no layout-build bytes) already
        order halo < stack for the 7x7/s2 stem geometry."""
        from repro.kernels.vsconv import halo_kernel_cost, stack_kernel_cost
        halo = halo_kernel_cost(n=1, hop=112, w_out=112, kh=7, stride=2,
                                bwp=232, bh=8, nb=1, s_steps=49, cb=1,
                                vk=8, vn=64)
        stack = stack_kernel_cost(n=1, hop=112, w_out=112, bw=120, bh=8,
                                  nb=1, s_steps=49, vk=8, vn=64)
        assert halo.bytes_accessed < stack.bytes_accessed
        assert halo.flops == stack.flops

    @pytest.mark.parametrize("builder,density", [
        ("build_vgg16", 0.235), ("build_resnet18", 0.5),
    ])
    def test_network_halo_bytes_below_stack(self, builder, density):
        """Acceptance: modeled halo bytes below stack for every VGG-16 /
        ResNet-18 conv layer (equal only on the 1x1 vsmm route, which has
        no stack to build), >= 3x lower for the 7x7/s2 stem."""
        from repro.models import graph as G
        from repro.models.layers import init_params

        net = getattr(G, builder)(16, image_size=64)
        params = init_params(net.schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        sparse, pruned = G.sparsify(net, params, density)
        x = jnp.zeros((1, 64, 64, 3), jnp.float32)
        traffic = G.collect_conv_traffic(net, pruned, x)
        reports = network_traffic_reports(traffic, sparse)
        assert len(reports) == len(net.conv_layers())
        for name, tr in reports:
            layer = next(l for l in net.conv_layers() if l.name == name)
            halo, stack = tr["halo"].bytes_accessed, tr["stack"].bytes_accessed
            if layer.kh == layer.kw == 1:
                assert halo == stack, name
            else:
                assert halo < stack, (name, halo, stack)
                assert (tr["halo"].arithmetic_intensity
                        > tr["stack"].arithmetic_intensity), name
            if layer.kh == 7:  # the ResNet stem
                assert stack >= 3 * halo, (name, halo, stack)

    def test_traffic_1x1_impl_invariant(self):
        tr_h = conv_layer_traffic((1, 16, 16, 64), kh=1, kw=1, stride=2,
                                  cout=128, s_steps=1, vk=32, vn=128,
                                  impl="halo")
        tr_s = conv_layer_traffic((1, 16, 16, 64), kh=1, kw=1, stride=2,
                                  cout=128, s_steps=1, vk=32, vn=128,
                                  impl="stack")
        assert tr_h.bytes_accessed == tr_s.bytes_accessed
