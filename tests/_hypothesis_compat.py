"""Optional-`hypothesis` shim with a deterministic fallback runner.

Property-based test modules import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly.  With hypothesis installed this is
a pure re-export.  Without it, ``@given`` no longer turns the test into a
silent skip (the seed-era behavior that let property coverage vanish in
bare environments): a miniature deterministic runner draws a fixed number
of examples from the small strategy vocabulary these tests use
(``integers`` / ``floats`` / ``sampled_from`` / ``booleans`` /
``composite``) and runs the test body on each.  Fewer examples and no
shrinking — real hypothesis in CI remains the authority (the CI tier-1 job
sets ``REQUIRE_HYPOTHESIS=1`` so the fallback can never mask a missing
install there) — but a bare environment now *executes* every property test
instead of collecting-then-skipping it.
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools
    import inspect
    import random

    HAVE_HYPOTHESIS = False

    FALLBACK_MAX_EXAMPLES = 10  # per-test cap for the deterministic runner

    class _Strategy:
        """A strategy the fallback runner can draw from deterministically."""

        def __init__(self, draw_fn):
            self._draw = draw_fn

        def draw(self, rnd: random.Random):
            return self._draw(rnd)

        def map(self, f):
            return _Strategy(lambda rnd: f(self.draw(rnd)))

        def filter(self, pred, _tries: int = 100):
            def draw(rnd):
                for _ in range(_tries):
                    v = self.draw(rnd)
                    if pred(v):
                        return v
                raise ValueError("filter predicate never satisfied")
            return _Strategy(draw)

    class _StrategyNamespace:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rnd: rnd.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(choices):
            seq = list(choices)
            return _Strategy(lambda rnd: seq[rnd.randrange(len(seq))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))

        @staticmethod
        def lists(elements, min_size=0, max_size=8):
            def draw(rnd):
                n = rnd.randint(min_size, max_size)
                return [elements.draw(rnd) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def composite(fn):
            # hypothesis passes ``draw`` as the first argument of the
            # decorated function; calling the decorated symbol returns a
            # strategy closed over the remaining args.
            @functools.wraps(fn)
            def build(*args, **kwargs):
                def draw_value(rnd):
                    return fn(lambda s: s.draw(rnd), *args, **kwargs)
                return _Strategy(draw_value)
            return build

    st = _StrategyNamespace()

    class HealthCheck:  # noqa: D401 - placeholder enum
        """Placeholder for ``hypothesis.HealthCheck`` attributes."""

        too_slow = data_too_large = filter_too_much = None

    def given(*gargs, **gkwargs):
        def decorate(fn):
            @functools.wraps(fn)
            def runner(*args, **kwargs):
                n = min(getattr(runner, "_fallback_max_examples",
                                FALLBACK_MAX_EXAMPLES),
                        FALLBACK_MAX_EXAMPLES)
                for i in range(n):
                    rnd = random.Random(0xC0FFEE + i)
                    drawn = tuple(s.draw(rnd) for s in gargs)
                    kdrawn = {k: s.draw(rnd) for k, s in gkwargs.items()}
                    fn(*args, *drawn, **kwargs, **kdrawn)
            # pytest must not see the drawn parameters as fixtures: expose a
            # signature with the strategy-filled ones removed (hypothesis
            # does the same; the drawn args right-fill the parameter list)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            if gargs:
                params = params[:-len(gargs)]
            params = [p for p in params if p.name not in gkwargs]
            runner.__signature__ = sig.replace(parameters=params)
            del runner.__wrapped__
            runner.is_fallback_property_test = True
            return runner
        return decorate

    def settings(max_examples: int | None = None, **_kw):
        def decorate(fn):
            if max_examples is not None:
                fn._fallback_max_examples = max_examples
            return fn
        return decorate


__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
