"""Optional-`hypothesis` shim.

Property-based test modules import ``given``/``settings``/``st`` from here
instead of from ``hypothesis`` directly.  With hypothesis installed this is a
pure re-export; without it the ``@given`` decorator turns each property test
into a pytest skip, so a bare environment *collects* every module cleanly
instead of erroring at import time (the tier-1 regression this file guards).
"""
from __future__ import annotations

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Stands in for any strategy object/combinator at collection time.

        Every attribute access and call returns another ``_Strategy``, so
        module-level strategy definitions (``st.integers(...)``,
        ``@st.composite``, nested ``draw`` helpers) all evaluate without
        touching hypothesis.  Nothing is ever drawn: ``@given`` skips first.
        """

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Strategy()
    HealthCheck = _Strategy()

    def given(*args, **kwargs):
        def decorate(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property-based test)"
            )(fn)

        return decorate

    def settings(*args, **kwargs):
        def decorate(fn):
            return fn

        return decorate


__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "given", "settings", "st"]
