"""Optimizers, schedules, data pipeline, checkpointing, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import LMBatchSpec, SyntheticImages, SyntheticLM
from repro.optim import adafactor, adamw, clip_by_global_norm, global_norm
from repro.optim.schedules import constant, warmup_cosine, warmup_linear
from repro.parallel.compression import (
    compressed_psum, dequantize_fp8_block, quantize_fp8_block,
)


class TestOptimizers:
    @pytest.mark.parametrize("make_opt", [adamw, adafactor])
    def test_minimizes_quadratic(self, make_opt):
        opt = make_opt()
        params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
        state = opt.init(params)

        def loss(p):
            return jnp.sum(p["w"] ** 2)

        for step in range(200):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params, jnp.float32(0.05))
            params = jax.tree.map(lambda a, u: a + u, params, upd)
        assert float(loss(params)) < 1e-2

    def test_adafactor_memory_factored(self):
        opt = adafactor(min_dim_factored=128)
        params = {"w": jnp.ones((256, 512)), "b": jnp.ones((4,))}
        st = opt.init(params)
        n = sum(x.size for x in jax.tree.leaves(st["moments"]))
        assert n == 256 + 512 + 4  # rows + cols for w, full for b

    def test_adamw_weight_decay_shrinks(self):
        opt = adamw(weight_decay=0.5)
        params = {"w": jnp.full((4,), 10.0)}
        st = opt.init(params)
        zero_g = {"w": jnp.zeros((4,))}
        upd, _ = opt.update(zero_g, st, params, jnp.float32(0.1))
        assert float(upd["w"].max()) < 0  # pure decay pulls toward zero

    def test_global_norm_clip(self):
        g = {"a": jnp.full((4,), 3.0), "b": jnp.full((3,), 4.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
        assert float(norm) == pytest.approx((9 * 4 + 16 * 3) ** 0.5, rel=1e-5)

    def test_schedules(self):
        lr = warmup_cosine(1.0, 10, 100)
        assert float(lr(0)) == 0.0
        assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
        assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
        lin = warmup_linear(1.0, 10, 110)
        assert float(lin(60)) == pytest.approx(0.5, rel=1e-2)
        assert float(constant(0.3)(999)) == pytest.approx(0.3)


class TestData:
    def test_deterministic_skip_to_step(self):
        spec = LMBatchSpec(global_batch=4, seq_len=64, vocab=1000)
        a = SyntheticLM(spec, seed=1).batch_at(17)
        b = SyntheticLM(spec, seed=1).batch_at(17)
        assert np.array_equal(a["tokens"], b["tokens"])

    def test_shards_differ(self):
        spec = lambda s: LMBatchSpec(global_batch=8, seq_len=64, vocab=1000,
                                     n_shards=2, shard=s)
        a = SyntheticLM(spec(0), seed=1).batch_at(3)
        b = SyntheticLM(spec(1), seed=1).batch_at(3)
        assert not np.array_equal(a["tokens"], b["tokens"])
        assert a["tokens"].shape == (4, 64)

    def test_labels_are_next_tokens(self):
        spec = LMBatchSpec(global_batch=2, seq_len=32, vocab=100)
        batch = SyntheticLM(spec, seed=0).batch_at(0)
        assert np.array_equal(batch["tokens"][:, 1:], batch["labels"][:, :-1])

    def test_images_have_relu_sparsity_structure(self):
        batch = SyntheticImages(2, size=64).batch_at(0)
        img = batch["images"]
        assert img.shape == (2, 64, 64, 3)
        assert abs(img.mean()) < 0.1 and 0.5 < img.std() < 2.0


class TestCheckpoint:
    def test_roundtrip_and_gc(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep=2, async_save=False)
            tree = {"w": jnp.arange(6.0).reshape(2, 3), "s": jnp.int32(7)}
            for s in (1, 2, 3):
                cm.save(s, tree)
            assert cm.all_steps() == [2, 3]  # keep=2 gc'd step 1
            target = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
            out, step, _ = cm.restore(target)
            assert step == 3
            assert np.array_equal(out["w"], np.arange(6.0).reshape(2, 3))

    def test_crash_safe_tmp_never_published(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False)
            cm.save(5, {"x": jnp.ones(3)})
            # stray tmp dir (simulated crash) must not be listed as a step
            os.makedirs(os.path.join(d, ".tmp_step_9"))
            assert cm.all_steps() == [5]

    def test_shape_mismatch_rejected(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=False)
            cm.save(1, {"x": jnp.ones(3)})
            with pytest.raises(ValueError):
                cm.restore({"x": jax.ShapeDtypeStruct((4,), jnp.float32)})

    def test_async_save_visible_after_wait(self):
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, async_save=True)
            cm.save(2, {"x": jnp.ones(3)})
            cm.wait()
            assert cm.all_steps() == [2]


class TestCompression:
    def test_fp8_roundtrip_error_bound(self, rng):
        x = jnp.asarray(rng.standard_normal(4096).astype(np.float32))
        q, s, pad = quantize_fp8_block(x, block=256)
        xr = dequantize_fp8_block(q, s, pad, x.shape)
        rel = float(jnp.abs(x - xr).max() / jnp.abs(x).max())
        assert rel < 0.1

    def test_outlier_blocks_isolated(self, rng):
        """Per-block scaling: an outlier ruins only its own block."""
        x = np.zeros(1024, np.float32)
        x[:512] = rng.standard_normal(512)
        x[600] = 1e4
        xq, s, pad = quantize_fp8_block(jnp.asarray(x), block=512)
        xr = np.asarray(dequantize_fp8_block(xq, s, pad, x.shape))
        assert np.abs(xr[:512] - x[:512]).max() < 0.05 * np.abs(x[:512]).max()

    def test_error_feedback_unbiased_over_steps(self, rng):
        """Repeated compression of the same gradient with EF: accumulated
        applied signal converges to the true signal (EF-SGD property)."""
        g = jnp.asarray(rng.standard_normal(512).astype(np.float32)) * 1e-3
        err = jnp.zeros_like(g)
        applied = jnp.zeros_like(g)
        for _ in range(20):
            target = g + err
            q, s, pad = quantize_fp8_block(target, block=128)
            deq = dequantize_fp8_block(q, s, pad, g.shape)
            err = target - deq
            applied = applied + deq
        # mean applied per step ~ g
        rel = float(jnp.abs(applied / 20 - g).max() / jnp.abs(g).max())
        assert rel < 0.05

    def test_compressed_psum_under_shard_map(self, rng):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as PS
        mesh = jax.make_mesh((1,), ("pod",))
        x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
        err = jnp.zeros_like(x)

        def body(xl, el):
            return compressed_psum(xl, "pod", el)

        y, new_err = shard_map(body, mesh=mesh, in_specs=(PS(), PS()),
                               out_specs=(PS(), PS()), check_rep=False)(x, err)
        rel = float(jnp.abs(y - x).max() / jnp.abs(x).max())
        assert rel < 0.1  # pod size 1: psum == dequantized identity


class TestAdamW8bit:
    def test_minimizes_quadratic(self):
        from repro.optim import adamw8bit
        opt = adamw8bit(weight_decay=0.0)
        params = {"w": jnp.asarray([3.0, -2.0, 1.0] * 100)}
        state = opt.init(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(250):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params, jnp.float32(0.05))
            params = jax.tree.map(lambda a, u: a + u, params, upd)
        assert float(loss(params)) < 1e-1

    def test_state_is_8bit(self):
        from repro.optim import adamw8bit
        opt = adamw8bit()
        params = {"w": jnp.ones((512, 512))}
        st = opt.init(params)
        mom = st["moments"]["w"]
        assert mom["mq"].dtype == jnp.int8 and mom["vq"].dtype == jnp.int8
        bits = (mom["mq"].size * 8 + mom["ms"].size * 32) / params["w"].size
        assert bits < 9  # ~8.125 bits/param/moment vs 32 for fp32

    def test_tracks_fp32_adamw(self):
        """A few steps of int8 AdamW stay close to exact AdamW."""
        from repro.optim import adamw, adamw8bit
        import numpy as np
        rng = np.random.default_rng(0)
        w0 = jnp.asarray(rng.standard_normal(1024), jnp.float32)
        paths = {}
        for name, opt in (("fp32", adamw(weight_decay=0.0)),
                          ("int8", adamw8bit(weight_decay=0.0))):
            p = {"w": w0}
            st = opt.init(p)
            for i in range(10):
                g = {"w": jnp.sin(p["w"] + i)}  # deterministic pseudo-grads
                upd, st = opt.update(g, st, p, jnp.float32(0.01))
                p = jax.tree.map(lambda a, u: a + u, p, upd)
            paths[name] = np.asarray(p["w"])
        drift = np.abs(paths["fp32"] - paths["int8"]).max()
        assert drift < 5e-3, drift
