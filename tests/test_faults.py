"""Fault-tolerant fleet serving: deterministic chaos injection, replica
health states (healthy -> suspect -> quarantined -> drained), re-placement
without loss or duplication, deadlines, retry budgets and admission
control.

Scripted-backend tests (no jax) pin the scheduler's fault handling
exactly; the real-CNN sweep at the bottom is the acceptance gate — a
replica death injected at every (replica, wave, kind) schedule position of
a 3-replica fleet still yields logits bit-identical to the fault-free
fleet.
"""
import dataclasses

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from test_scheduler import FleetScript, Req, ScriptBackend

from repro.launch.faults import (
    ChaosBackend, CompileFault, Fault, FaultPlan, ReplicaDead,
)
from repro.launch.scheduler import (
    DRAINED, HEALTHY, QUARANTINED, SUSPECT, FleetScheduler,
    LockstepScheduler,
)


class ResetScript(ScriptBackend):
    """ScriptBackend + the ``reset`` hook: a fault-displaced request's
    partial stream is cleared and regenerates identically (the script is
    re-iterated from the top)."""

    def reset(self, req):
        req.out.clear()


def _chaos_fleet(n, batch, plan, *, be_cls=FleetScript, sched_kw=None,
                 **kw):
    events = []
    bes = [ChaosBackend(be_cls(i, events, **kw), plan, replica=i)
           for i in range(n)]
    sched = FleetScheduler(bes, batch=batch, **(sched_kw or {}))
    return sched, bes, events


class ResetFleetScript(FleetScript):
    def reset(self, req):
        req.out.clear()


def _mk_reqs(n=6, script_len=4, max_new=2):
    return [Req(i, [(i + 1) * 10 + k for k in range(script_len)], max_new)
            for i in range(n)]


def _check_terminal(sched, reqs):
    """Every admitted request has exactly one terminal outcome, and
    delivered streams are never duplicated."""
    assert set(sched.outcomes) == {r.rid for r in reqs}
    for r in reqs:
        o = sched.outcomes[r.rid]
        assert o is r.outcome
        assert o.status in ("delivered", "refused")
        if o.status == "delivered":
            want = min(len(r.script), r.max_new)
            assert r.out == r.script[:want], (r.rid, r.out)
        else:
            assert isinstance(o.reason, str) and o.reason


class TestPlan:
    def test_seeded_plan_is_deterministic(self):
        a = FaultPlan.random(7, replicas=3)
        b = FaultPlan.random(7, replicas=3)
        assert a.faults == b.faults
        assert FaultPlan.random(8, replicas=3).faults != a.faults

    def test_plan_indexing_and_counts(self):
        plan = FaultPlan([Fault("nan", 0, 2), Fault("stall", 0, 2, ticks=3),
                          Fault("transient", 1, 0)])
        assert [f.kind for f in plan.at(0, 2)] == ["nan", "stall"]
        assert plan.at(2, 0) == []
        assert plan.counts() == {"nan": 1, "stall": 1, "transient": 1}
        assert len(plan) == 3

    def test_invalid_faults_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            Fault("explode", 0, 0)
        with pytest.raises(ValueError, match="invalid fault"):
            Fault("nan", 0, -1)


class TestChaosTransparency:
    def test_empty_plan_fleet_of_one_matches_lockstep(self):
        """The invariant everything else builds on: one chaos-wrapped
        replica with an empty plan is bit-identical to the plain
        `LockstepScheduler` — admission waves, stats, outputs, outcomes."""
        mk = lambda: [Req(0, [1] * 8, 2), Req(1, [2] * 8, 6),
                      Req(2, [3] * 8, 3)]
        solo_be = ScriptBackend()
        solo_reqs = mk()
        solo_sched = LockstepScheduler(solo_be, batch=2)
        solo = solo_sched.serve(solo_reqs)
        sched, bes, _ = _chaos_fleet(1, 2, FaultPlan())
        fleet_reqs = mk()
        fleet = sched.serve(fleet_reqs)
        assert [r.out for r in fleet_reqs] == [r.out for r in solo_reqs]
        assert bes[0].inner.started == solo_be.started
        keys = ("steps", "finished", "backfills", "emissions")
        assert [{k: s[k] for k in keys} for s in fleet] == \
            [{k: s[k] for k in keys} for s in solo]
        assert sched.health == [HEALTHY] and sched.fault_events == []
        assert {rid: o.status for rid, o in sched.outcomes.items()} == \
            {rid: o.status for rid, o in solo_sched.outcomes.items()}

    def test_empty_plan_never_fires(self):
        sched, bes, _ = _chaos_fleet(2, 2, FaultPlan())
        reqs = _mk_reqs(8)
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        assert all(be.injected == [] for be in bes)


class TestReplicaDeath:
    def test_die_dispatch_requeues_on_survivor(self):
        """Replica 0 dies dispatching its first wave: its in-flight slots
        and pending ladder move to replica 1; nothing is lost, nothing
        delivered twice."""
        plan = FaultPlan([Fault("die_dispatch", 0, 1)])
        sched, bes, _ = _chaos_fleet(2, 2, plan,
                                     be_cls=ResetFleetScript)
        reqs = _mk_reqs(8, script_len=4, max_new=3)
        sched.serve(reqs)
        assert sched.health == [DRAINED, HEALTHY]
        assert [e["fault"] for e in sched.fault_events] == ["ReplicaDead"]
        _check_terminal(sched, reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())
        # everything after the death ran on replica 1
        assert all(o.replica == 1 for o in sched.outcomes.values()
                   if o.wave > sched.fault_events[0]["wave"])

    def test_die_collect_loses_no_request(self):
        plan = FaultPlan([Fault("die_collect", 0, 1)])
        sched, bes, _ = _chaos_fleet(2, 2, plan,
                                     be_cls=ResetFleetScript)
        reqs = _mk_reqs(8, script_len=4, max_new=3)
        sched.serve(reqs)
        assert sched.health == [DRAINED, HEALTHY]
        _check_terminal(sched, reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())

    def test_partial_stream_lost_without_reset(self):
        """A request whose delivery already started can only be re-served
        if the backend can reset it; FleetScript (no reset) refuses with
        partial_stream_lost instead of emitting a duplicate stream."""
        plan = FaultPlan([Fault("die_dispatch", 0, 2)])
        sched, bes, _ = _chaos_fleet(2, 1, plan)  # no reset hook
        long = Req(0, [7] * 6, 6)
        short = Req(1, [8] * 2, 2)
        sched.serve([long, short])
        assert short.outcome.status == "delivered"
        assert long.outcome.status == "refused"
        assert long.outcome.reason == "partial_stream_lost"
        # the partial stream was not extended after the refusal
        assert 0 < len(long.out) < 6

    def test_all_replicas_dead_refuses_everything(self):
        plan = FaultPlan([Fault("die_dispatch", 0, 0)])
        sched, bes, _ = _chaos_fleet(1, 2, plan)
        reqs = _mk_reqs(4)
        stats = sched.serve(reqs)
        assert stats == []
        assert sched.health == [DRAINED]
        _check_terminal(sched, reqs)
        assert all(o.status == "refused" and
                   o.reason == "no_healthy_replicas"
                   for o in sched.outcomes.values())

    def test_dead_fleet_refuses_next_serve_at_admission(self):
        plan = FaultPlan([Fault("die_dispatch", 0, 0)])
        sched, bes, _ = _chaos_fleet(1, 2, plan)
        sched.serve(_mk_reqs(2))
        later = _mk_reqs(2)
        assert sched.serve(later) == []
        assert all(r.outcome.reason == "no_healthy_replicas"
                   for r in later)


class TestHealthStates:
    def test_transient_marks_suspect_then_quarantines(self):
        """One transient -> suspect (replica keeps serving); reaching
        suspect_limit quarantines and drains it."""
        plan = FaultPlan([Fault("transient", 0, 1)])
        sched, bes, _ = _chaos_fleet(2, 2, plan,
                                     be_cls=ResetFleetScript)
        reqs = _mk_reqs(8, max_new=3)
        sched.serve(reqs)
        assert sched.health[0] == SUSPECT
        assert sched.fault_counts[0] == 1
        _check_terminal(sched, reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())

        plan2 = FaultPlan([Fault("transient", 0, 1),
                           Fault("transient", 0, 2)])
        sched2, _, _ = _chaos_fleet(2, 2, plan2, be_cls=ResetFleetScript)
        reqs2 = _mk_reqs(8, max_new=3)
        sched2.serve(reqs2)
        assert sched2.health[0] == DRAINED   # quarantined, then drained
        _check_terminal(sched2, reqs2)
        assert all(o.status == "delivered"
                   for o in sched2.outcomes.values())

    def test_start_fail_quarantines_and_replaces(self):
        """A compile failure admitting a run is non-transient: quarantine;
        the admission wave is re-placed on the survivor."""
        plan = FaultPlan([Fault("start_fail", 0, 0)])
        sched, bes, _ = _chaos_fleet(2, 2, plan)
        reqs = _mk_reqs(4)
        sched.serve(reqs)
        assert sched.health == [DRAINED, HEALTHY]
        assert [e["fault"] for e in sched.fault_events] == ["CompileFault"]
        _check_terminal(sched, reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())

    def test_stall_lets_survivors_steal(self):
        """A stalled wave produces nothing for N ticks; the other replica
        keeps retiring and steals the stalled replica's queue — then the
        stalled wave completes normally."""
        plan = FaultPlan([Fault("stall", 0, 1, ticks=4)])
        sched, bes, events = _chaos_fleet(2, 1, plan)
        reqs = [Req(i, [i + 10] * 2, 2) for i in range(6)]
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())
        assert sched.health == [HEALTHY, HEALTHY]  # a stall is not a fault
        assert sched.steals >= 1
        assert ("stall" in [k for _, k in bes[0].injected])


class TestBudgets:
    def test_retry_budget_exhausted(self):
        """Endless transients on the only replica burn each displaced
        request's attempt budget down to a structured refusal — never an
        exception, never a hang."""
        plan = FaultPlan([Fault("transient", 0, w) for w in range(30)])
        sched, bes, _ = _chaos_fleet(
            1, 2, plan,
            sched_kw={"max_attempts": 2, "suspect_limit": 100})
        reqs = _mk_reqs(4)
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        assert all(o.status == "refused" and
                   o.reason == "retry_budget_exhausted"
                   for o in sched.outcomes.values())
        assert all(o.attempts == 3 for o in sched.outcomes.values())

    def test_deadline_refuses_queued_not_inflight(self):
        """deadline_waves counts fleet ticks: a request still queued past
        the budget is refused; the in-flight one always completes."""
        sched, bes, _ = _chaos_fleet(
            1, 1, FaultPlan(), sched_kw={"deadline_waves": 3})
        slow = Req(0, [5] * 10, 10)
        waiting = Req(1, [6] * 2, 2)
        sched.serve([slow, waiting])
        assert slow.outcome.status == "delivered"
        assert waiting.outcome.status == "refused"
        assert waiting.outcome.reason == "deadline_exceeded"
        assert waiting.outcome.wave == 3

    def test_per_request_deadline_overrides_default(self):
        sched, bes, _ = _chaos_fleet(
            1, 1, FaultPlan(), sched_kw={"deadline_waves": 100})
        slow = Req(0, [5] * 10, 10)
        waiting = Req(1, [6] * 2, 2)
        waiting.deadline_waves = 2
        sched.serve([slow, waiting])
        assert waiting.outcome.reason == "deadline_exceeded"
        assert slow.outcome.status == "delivered"

    def test_fleet_max_queue_sheds(self):
        sched, bes, _ = _chaos_fleet(
            2, 2, FaultPlan(), sched_kw={"max_queue": 3})
        reqs = _mk_reqs(5)
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        statuses = [r.outcome.status for r in reqs]
        assert statuses == ["delivered"] * 3 + ["refused"] * 2
        assert all(r.outcome.reason == "queue_full" for r in reqs[3:])


class TestReplay:
    def test_chaos_run_replays_identically(self):
        """Same plan + same queue on a fresh fleet: identical outcome
        trajectory (status/reason/replica/attempts/wave per request),
        fault events, health, waves and steals."""
        plan = FaultPlan.random(3, replicas=3, horizon=8, rate=0.3)

        def run():
            sched, _, _ = _chaos_fleet(
                3, 2, plan, be_cls=ResetFleetScript,
                sched_kw={"deadline_waves": 12, "max_attempts": 2})
            reqs = _mk_reqs(10, script_len=5, max_new=4)
            sched.serve(reqs)
            trace = {rid: dataclasses.astuple(o)
                     for rid, o in sched.outcomes.items()}
            return (trace, sched.fault_events, sched.health, sched.waves,
                    sched.steals, [r.out for r in reqs])
        assert run() == run()


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000), replicas=st.integers(1, 4),
           rate=st.floats(0.0, 0.5), nreq=st.integers(1, 12))
    def test_every_admitted_request_gets_one_terminal_outcome(
            self, seed, replicas, rate, nreq):
        """The tentpole invariant under randomized chaos: every admitted
        request ends in exactly one terminal outcome; delivered streams
        are exact (no loss, no duplication); the serve always returns."""
        plan = FaultPlan.random(seed, replicas=replicas, horizon=12,
                                rate=rate)
        sched, bes, _ = _chaos_fleet(
            replicas, 2, plan, be_cls=ResetFleetScript,
            sched_kw={"deadline_waves": 40, "max_attempts": 3})
        reqs = _mk_reqs(nreq, script_len=4, max_new=3)
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        # drained replicas stay drained; healthy ones have no fault count
        for h, c in zip(sched.health, sched.fault_counts):
            if h == HEALTHY:
                assert c == 0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000))
    def test_work_stealing_never_duplicates(self, seed):
        """Queues move between ladders and runs under chaos, but a request
        is only ever in one place: delivered exactly once with exactly its
        scripted stream."""
        plan = FaultPlan.random(seed, replicas=3, horizon=10, rate=0.25)
        sched, bes, _ = _chaos_fleet(
            3, 1, plan, be_cls=ResetFleetScript,
            sched_kw={"max_attempts": 4})
        reqs = _mk_reqs(9, script_len=3, max_new=3)
        sched.serve(reqs)
        _check_terminal(sched, reqs)
        delivered = [r for r in reqs
                     if sched.outcomes[r.rid].status == "delivered"]
        for r in delivered:
            assert r.out == r.script[:3]


# -- real-model acceptance gate ---------------------------------------------

from repro.configs import get_config            # noqa: E402
from repro.launch.serve import CNNServer, ImageRequest  # noqa: E402


@pytest.fixture(scope="module")
def cnn():
    """One shared CNNBackend (+ its jit cache) for the whole sweep: the
    backend is stateless across runs, so every chaos fleet can wrap the
    same instance and the 20+ serves below stay fast."""
    cfg = get_config("vscnn-vgg16").reduce()
    srv = CNNServer(cfg, batch=2, seed=0)
    return cfg, srv.backend


def _images(cfg, n):
    rng = np.random.default_rng(0)
    s = cfg.image_size
    return [ImageRequest(
                rid=i,
                image=rng.standard_normal((s, s, 3)).astype(np.float32))
            for i in range(n)]


def _cnn_fleet(be, plan, *, replicas=3, batch=2):
    bes = [ChaosBackend(be, plan, replica=i) for i in range(replicas)]
    return FleetScheduler(bes, batch=batch)


class TestCNNFaultSweep:
    def test_death_at_every_position_bit_identical(self, cnn):
        """The acceptance criterion: replica death (and NaN corruption)
        injected at every (replica, wave, kind) schedule position of a
        3-replica fleet still delivers every request with logits
        bit-identical to the fault-free fleet."""
        cfg, be = cnn
        ref_sched = _cnn_fleet(be, FaultPlan())
        ref = _images(cfg, 8)
        ref_sched.serve(ref)
        assert all(o.status == "delivered"
                   for o in ref_sched.outcomes.values())
        ref_logits = [r.logits.tobytes() for r in ref]
        for kind in ("die_dispatch", "die_collect", "nan"):
            for replica in range(3):
                for wave in range(3):
                    plan = FaultPlan([Fault(kind, replica, wave)])
                    sched = _cnn_fleet(be, plan)
                    reqs = _images(cfg, 8)
                    sched.serve(reqs)
                    pos = f"{kind}@r{replica}w{wave}"
                    assert all(o.status == "delivered" for o in
                               sched.outcomes.values()), pos
                    got = [r.logits.tobytes() for r in reqs]
                    assert got == ref_logits, pos
                    fired = [k for b in sched.backends
                             for _, k in b.injected]
                    if fired:  # the fault actually hit the schedule
                        assert sched.fault_events, pos
                        assert sched.health[replica] == DRAINED, pos

    def test_nan_guard_quarantines_producer(self, cnn):
        """The output guard catches the corrupted wave before any
        delivery: the producing replica is quarantined and the wave's
        requests are re-served elsewhere with finite logits."""
        cfg, be = cnn
        plan = FaultPlan([Fault("nan", 0, 1)])
        sched = _cnn_fleet(be, plan)
        reqs = _images(cfg, 8)
        sched.serve(reqs)
        assert all(o.status == "delivered"
                   for o in sched.outcomes.values())
        assert all(np.isfinite(r.logits).all() for r in reqs)
        assert sched.health[0] == DRAINED
        assert [e["fault"] for e in sched.fault_events] == \
            ["NonFiniteOutput"]

    def test_cnn_chaos_replay_identical(self, cnn):
        """Same seeded plan, fresh fleets: identical health, fault
        events, waves, steals, outcomes and logits bytes."""
        cfg, be = cnn
        plan = FaultPlan.random(11, replicas=3, horizon=6, rate=0.3)

        def run():
            sched = _cnn_fleet(be, plan)
            reqs = _images(cfg, 8)
            sched.serve(reqs)
            trace = {rid: dataclasses.astuple(o)
                     for rid, o in sched.outcomes.items()}
            return (trace, sched.fault_events, sched.health, sched.waves,
                    sched.steals,
                    [r.logits.tobytes() if r.logits is not None else None
                     for r in reqs])
        assert run() == run()

    def test_cnnserver_chaos_integration(self, cnn):
        """`CNNServer(fault_plan=...)` wires the chaos fleet end to end:
        structured outcomes on the server, no exception, health exposed."""
        cfg, _ = cnn
        plan = FaultPlan([Fault("die_dispatch", 0, 1)])
        srv = CNNServer(cfg, batch=2, seed=0, replicas=2,
                        fault_plan=plan, validate=False)
        reqs = _images(cfg, 6)
        srv.serve(reqs)
        assert all(o.status == "delivered"
                   for o in srv.outcomes.values())
        assert srv.scheduler.health == [DRAINED, HEALTHY]
