"""Unified sparse-graph executor: residual epilogue, BN folding, ResNet-18.

Covers the network IR (`models.graph`): the fused residual epilogue in the
kernels (vsmm/vsconv, jnp + pallas-interpret), BN folding exactness, ResNet
basic-block parity sweeps (stride 1/2, with/without projection), ResNet-18
end-to-end with every conv and FC on the vector-sparse path, the FC
remainder strip for non-tileable heads, delegation of the PR-1 entry
points, and the shared per-layer cycle-report walk.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode, prune_vectors_balanced, vs_conv2d, vs_matmul
from repro.kernels import vsmm, vsconv
from repro.kernels.ref import vsmm_ref, vsconv_ref
from repro.models import graph as G
from repro.models.layers import init_params


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


def _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, density):
    wm = rng.standard_normal((kh * kw * c, co)).astype(np.float32)
    wp, _ = prune_vectors_balanced(wm, density, vk, vn)
    return encode(jnp.asarray(wp), vk, vn)


def _randomize_bn(params, rng):
    """Non-identity BN stats so folding is actually exercised."""
    out = {}
    for name, p in params.items():
        p = dict(p)
        if "scale" in p:
            c = p["scale"].shape[0]
            p["scale"] = jnp.asarray(
                1 + 0.3 * rng.standard_normal(c), jnp.float32)
            p["offset"] = jnp.asarray(
                0.2 * rng.standard_normal(c), jnp.float32)
            p["mean"] = jnp.asarray(0.1 * rng.standard_normal(c), jnp.float32)
            p["var"] = jnp.asarray(
                np.abs(1 + 0.3 * rng.standard_normal(c)) + 0.1, jnp.float32)
        out[name] = p
    return out


class TestResidualEpilogue:
    """The fused residual add (before ReLU, at flush) in both kernels."""

    def test_vsmm_residual_matches_ref(self, rng):
        wp, _ = prune_vectors_balanced(
            rng.standard_normal((256, 256)).astype(np.float32), 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(rng.standard_normal((100, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        res = jnp.asarray(rng.standard_normal((100, 256)), jnp.float32)
        out = vsmm(x, vs, bias=b, residual=res, fuse_relu=True)
        ref = vsmm_ref(x, vs, bias=b, residual=res, fuse_relu=True)
        assert _rel(out, ref) < 1e-5
        assert np.asarray(out).min() >= 0.0

    @pytest.mark.parametrize("kh,kw,stride,h,w",
                             [(3, 3, 1, 8, 8), (3, 3, 2, 13, 15),
                              (1, 1, 2, 13, 7), (7, 7, 2, 11, 9)])
    def test_vsconv_residual_matches_ref(self, kh, kw, stride, h, w, rng):
        c, co, vk, vn = 16, 128, 16, 128
        vs = _sparse_conv_weight(rng, kh, kw, c, co, vk, vn, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, w, c)), 0), jnp.float32)
        ho, wo = -(-h // stride), -(-w // stride)
        res = jnp.asarray(rng.standard_normal((2, ho, wo, co)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((co,)), jnp.float32)
        out = vsconv(x, vs, kh=kh, kw=kw, stride=stride, bias=b,
                     residual=res, fuse_relu=True)
        ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride, bias=b,
                         residual=res, fuse_relu=True)
        assert _rel(out, ref) < 1e-5

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_residual_added_before_relu(self, impl, rng):
        """relu(conv + res) != relu(conv) + res — the order must be fused."""
        c, co, vk, vn = 32, 128, 32, 128
        vs = _sparse_conv_weight(rng, 3, 3, c, co, vk, vn, 0.5)
        x = jnp.asarray(rng.standard_normal((1, 8, 8, c)), jnp.float32)
        res = jnp.asarray(-1e4 * np.ones((1, 8, 8, co)), jnp.float32)
        out = vs_conv2d(x, vs, residual=res, fuse_relu=True, impl=impl)
        # a large negative shortcut drives everything through the ReLU to 0
        assert float(np.abs(np.asarray(out)).max()) == 0.0

    def test_vs_matmul_epilogue_jnp_matches_pallas(self, rng):
        wp, _ = prune_vectors_balanced(
            rng.standard_normal((128, 256)).astype(np.float32), 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(rng.standard_normal((10, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256,)), jnp.float32)
        res = jnp.asarray(rng.standard_normal((10, 256)), jnp.float32)
        a = vs_matmul(x, vs, bias=b, residual=res, fuse_relu=True, impl="jnp")
        p = vs_matmul(x, vs, bias=b, residual=res, fuse_relu=True,
                      impl="pallas")
        assert _rel(a, p) < 1e-5


class TestBNFolding:
    def test_fold_matches_explicit_bn(self, rng):
        """Folded conv(w*g)+b == BN(conv(w)) for one layer, within fp32."""
        net = G.SparseNet("one", (G.Conv("c", 32, 64, 3, 3, 1, bn=True),))
        params = _randomize_bn(
            init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32),
            np.random.default_rng(3))
        x = jnp.asarray(rng.standard_normal((2, 9, 9, 32)), jnp.float32)
        ref = G.net_apply(net, params, x)  # explicit BN
        sparse, pruned = G.sparsify(net, params, 1.0)  # fold, keep all tiles
        folded_dense = G.net_apply(net, pruned, x)
        folded_sparse = G.net_apply(net, params, x, sparse=sparse)
        assert _rel(folded_dense, ref) < 1e-4   # folding exact up to rounding
        assert _rel(folded_sparse, ref) < 1e-4
        assert "b" in pruned["c"] and "scale" not in pruned["c"]

    def test_bare_entry_for_bn_conv_rejected(self, rng):
        """A raw-encoded entry can't carry the folded BN scale/bias: running
        it would silently drop batch-norm, so the walker must refuse."""
        net = G.SparseNet("one", (G.Conv("c", 32, 64, 3, 3, 1, bn=True),))
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        bare = _sparse_conv_weight(rng, 3, 3, 32, 64, 32, 64, 1.0)
        x = jnp.asarray(rng.standard_normal((1, 8, 8, 32)), jnp.float32)
        with pytest.raises(ValueError, match="folded"):
            G.net_apply(net, params, x, sparse={"c": bare})

    def test_pruning_scores_see_folded_magnitudes(self, rng):
        """A huge BN scale on one channel must protect its vectors."""
        net = G.SparseNet("one", (G.Conv("c", 32, 64, 3, 3, 1, bn=True),))
        params = init_params(net.schema(), jax.random.PRNGKey(1), jnp.float32)
        p = dict(params["c"])
        scale = np.ones(64, np.float32)
        scale[:32] = 100.0  # first strip-half channels hugely amplified
        p["scale"] = jnp.asarray(scale)
        params = {"c": p}
        sparse, _ = G.sparsify(net, params, 0.25, vk=32, vn=32)
        vs = sparse["c"].vs
        # strips covering the amplified channels keep the same quota but the
        # *weights stored* are the folded (scaled) ones
        assert float(jnp.abs(vs.vals[0]).max()) > 10.0


def _block_net(cin, cout, stride):
    """A single ResNet basic block as a SparseNet (the IR doc example)."""
    layers = []
    G._basic_block(layers, "b", cin, cout, stride)
    return G.SparseNet("block", tuple(layers))


class TestBasicBlockParity:
    """Stride 1/2, with/without projection, jnp + pallas-interpret."""

    CASES = [
        (64, 64, 1),    # identity shortcut
        (64, 128, 2),   # stride-2 projection downsample
        (64, 128, 1),   # channel-change projection at stride 1
    ]

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    @pytest.mark.parametrize("cin,cout,stride", CASES)
    def test_sparse_matches_folded_dense(self, cin, cout, stride, impl, rng):
        net = _block_net(cin, cout, stride)
        params = _randomize_bn(
            init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32),
            np.random.default_rng(5))
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, 9, 11, cin)), 0), jnp.float32)
        sparse, pruned = G.sparsify(net, params, 0.5)
        ref = G.net_apply(net, pruned, x)
        out = G.net_apply(net, params, x, sparse=sparse, impl=impl)
        assert out.shape == (2, -(-9 // stride), -(-11 // stride), cout)
        assert _rel(out, ref) < 1e-4

    @pytest.mark.parametrize("cin,cout,stride", CASES)
    def test_sparse_matches_unfolded_dense(self, cin, cout, stride, rng):
        """vs the original (explicit-BN) dense net at density 1."""
        net = _block_net(cin, cout, stride)
        params = _randomize_bn(
            init_params(net.schema(), jax.random.PRNGKey(2), jnp.float32),
            np.random.default_rng(6))
        x = jnp.asarray(
            np.maximum(rng.standard_normal((1, 8, 8, cin)), 0), jnp.float32)
        sparse, _ = G.sparsify(net, params, 1.0)
        ref = G.net_apply(net, params, x)
        out = G.net_apply(net, params, x, sparse=sparse, impl="jnp")
        assert _rel(out, ref) < 1e-3

    def test_projection_only_when_needed(self):
        assert not any(l.name.endswith("_down")
                       for l in _block_net(64, 64, 1).conv_layers())
        assert any(l.name.endswith("_down")
                   for l in _block_net(64, 128, 2).conv_layers())


class TestResidualAddSpec:
    def test_explicit_residual_add_layer(self, rng):
        """The unfused ResidualAdd spec == the fused Conv(residual=...)."""
        cin = 32
        fused = G.SparseNet("f", (
            G.Save("in"),
            G.Conv("c1", cin, cin, 3, 3, 1),
            G.Conv("c2", cin, cin, 3, 3, 1, relu=False, residual="in"),
        ))
        # same convs, shortcut applied by an explicit layer + final relu off
        unfused = G.SparseNet("u", (
            G.Save("in"),
            G.Conv("c1", cin, cin, 3, 3, 1),
            G.Conv("c2", cin, cin, 3, 3, 1, relu=False),
            G.ResidualAdd("in", relu=False),
        ))
        params = init_params(fused.schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 8, 8, cin)), jnp.float32)
        a = G.net_apply(fused, params, x)
        b = G.net_apply(unfused, params, x)
        assert _rel(a, b) < 1e-6


class TestResNet18EndToEnd:
    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_every_layer_sparse_matches_dense(self, impl, rng):
        """The acceptance bar: all 20 convs + the FC head on the sparse
        path, residuals fused, BN folded, vs the folded-pruned oracle."""
        net = G.build_resnet18(num_classes=200, image_size=32)
        params = _randomize_bn(
            init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32),
            np.random.default_rng(9))
        x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
        sparse, pruned = G.sparsify(net, params, 0.5)
        # every conv AND the non-tileable 200-class head runs sparse
        assert set(sparse) == (
            {l.name for l in net.conv_layers()}
            | {l.name for l in net.fc_layers()})
        assert len(net.conv_layers()) == 20  # stem + 16 block + 3 downsample
        ref = G.net_apply(net, pruned, x)
        out = G.net_apply(net, params, x, sparse=sparse, impl=impl)
        assert out.shape == (1, 200)
        assert np.isfinite(np.asarray(out)).all()
        assert _rel(out, ref) < 1e-3

    def test_structure(self):
        net = G.build_resnet18()
        convs = net.conv_layers()
        assert [l.name for l in convs][:6] == [
            "conv1", "layer1_0_conv1", "layer1_0_conv2",
            "layer1_1_conv1", "layer1_1_conv2", "layer2_0_down"]
        # stride-2 downsamples exactly at stages 2-4
        downs = [l for l in convs if l.name.endswith("_down")]
        assert [(l.kh, l.kw, l.stride) for l in downs] == [(1, 1, 2)] * 3
        # all residual shortcuts fuse into the second conv of each block
        fused = [l for l in convs if l.residual]
        assert len(fused) == 8 and all(l.name.endswith("conv2")
                                       for l in fused)
        assert all(l.bn for l in convs)


class TestFCRemainderStrip:
    def test_1000_class_head_runs_sparse(self, rng):
        """Cout=1000 doesn't tile by vn=128: pad to 1024, slice back."""
        net = G.SparseNet("head", (G.Classifier("fc", 512, 1000),))
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        sparse, pruned = G.sparsify(net, params, 0.5)
        assert "fc" in sparse
        assert sparse["fc"].vs.shape == (512, 1024)
        assert sparse["fc"].dout == 1000
        x = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
        ref = G.net_apply(net, pruned, x)
        for impl in ("jnp", "pallas"):
            out = G.net_apply(net, params, x, sparse=sparse, impl=impl)
            assert out.shape == (4, 1000)
            assert _rel(out, ref) < 1e-5

    def test_vgg16_fc3_no_longer_skipped(self):
        """The PR-1 gap: sparsify_vgg16 must now cover the 1000-class head."""
        from repro.models.cnn import sparsify_vgg16, vgg16_schema
        params = init_params(vgg16_schema(1000, image_size=32),
                             jax.random.PRNGKey(0), jnp.float32)
        sparse, _ = sparsify_vgg16(params, 0.25)
        assert "fc3" in sparse
        assert sparse["fc3"].dout == 1000


class TestLegacyDelegation:
    """PR-1 entry points must reproduce through the graph executor."""

    def test_vgg16_sparse_parity(self, rng):
        from repro.models.cnn import sparsify_vgg16, vgg16_apply, vgg16_schema
        params = init_params(vgg16_schema(16, image_size=32),
                             jax.random.PRNGKey(0), jnp.float32)
        x = jnp.asarray(rng.standard_normal((2, 32, 32, 3)), jnp.float32)
        sparse, pruned = sparsify_vgg16(params, 0.25)
        ref = vgg16_apply(pruned, x)
        out = vgg16_apply(params, x, sparse=sparse, impl="jnp")
        assert out.shape == (2, 16)
        assert _rel(out, ref) < 1e-4

    def test_collect_traffic_triples(self):
        from repro.models.cnn import collect_conv_traffic, vgg16_schema
        params = init_params(vgg16_schema(16, image_size=32),
                             jax.random.PRNGKey(0), jnp.float32)
        rec = collect_conv_traffic(params, jnp.ones((1, 32, 32, 3)))
        assert len(rec) == 13
        assert all(len(t) == 3 for t in rec)

    def test_resnet_stem_parity(self, rng):
        from repro.models.cnn import (
            RESNET_STEM_LAYERS, resnet_stem_apply, resnet_stem_schema,
            sparsify_resnet_stem,
        )
        assert [n for n, *_ in RESNET_STEM_LAYERS] == [
            "stem7x7", "proj1x1", "down3x3"]
        params = init_params(resnet_stem_schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        sparse, pruned = sparsify_resnet_stem(params, 0.5)
        x = jnp.asarray(rng.standard_normal((2, 28, 30, 3)), jnp.float32)
        dense = resnet_stem_apply(pruned, x)
        assert dense.shape == (2, 7, 8, 128)
        out = resnet_stem_apply(params, x, sparse=sparse, impl="jnp")
        assert _rel(out, dense) < 1e-3


class TestBatchedApply:
    """Batch-N parity (the serving path) and the jit compile cache."""

    @staticmethod
    def _vgg_slice():
        """First VGG stage + head: conv-conv-pool-flatten-fc, image 8."""
        return G.SparseNet("vgg_slice", (
            G.Conv("c1", 3, 32), G.Conv("c2", 32, 32), G.Pool("max", 2),
            G.Flatten(), G.Classifier("fc", 32 * 4 * 4, 16),
        ))

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_vgg_slice_batch_matches_per_sample(self, impl, rng):
        net = self._vgg_slice()
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        sparse, pruned = G.sparsify(net, params, 0.5)
        x = jnp.asarray(rng.standard_normal((3, 8, 8, 3)), jnp.float32)
        out = G.net_apply(net, params, x, sparse=sparse, impl=impl)
        assert out.shape == (3, 16)
        ref = G.net_apply(net, pruned, x)
        assert _rel(out, ref) < 1e-4
        for i in range(3):  # batching must not couple samples
            one = G.net_apply(net, params, x[i:i + 1], sparse=sparse,
                              impl=impl)
            assert _rel(out[i], one[0]) < 1e-4

    @pytest.mark.parametrize("impl", ["jnp", "pallas"])
    def test_resnet_block_batch_matches_per_sample(self, impl, rng):
        net = _block_net(32, 64, 2)
        params = _randomize_bn(
            init_params(net.schema(), jax.random.PRNGKey(1), jnp.float32),
            np.random.default_rng(7))
        sparse, pruned = G.sparsify(net, params, 0.5)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((3, 8, 8, 32)), 0), jnp.float32)
        out = G.net_apply(net, params, x, sparse=sparse, impl=impl)
        ref = G.net_apply(net, pruned, x)
        assert _rel(out, ref) < 1e-4
        one = G.net_apply(net, params, x[1:2], sparse=sparse, impl=impl)
        assert _rel(out[1], one[0]) < 1e-4

    def test_jit_cache_one_compile_per_bucket(self, rng):
        net = self._vgg_slice()
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        sparse, _ = G.sparsify(net, params, 0.5)
        ap = net.batched_apply(params, sparse=sparse, key=(0.5,))
        x4 = jnp.asarray(rng.standard_normal((4, 8, 8, 3)), jnp.float32)
        a = ap(x4)
        b = ap(x4)                       # same bucket: cache hit
        assert ap.compiles == 1
        assert _rel(a, b) == 0.0
        ap(jnp.asarray(rng.standard_normal((2, 8, 8, 3)), jnp.float32))
        assert ap.compiles == 2          # new batch bucket, new executable
        ref = G.net_apply(net, params, x4, sparse=sparse)
        assert _rel(a, ref) < 1e-5

    def test_shared_cache_keys_disjoint_by_density(self, rng):
        """One shared cache dict holds several sparsified variants."""
        net = self._vgg_slice()
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        cache: dict = {}
        x = jnp.asarray(rng.standard_normal((2, 8, 8, 3)), jnp.float32)
        for d in (0.5, 0.25):
            sparse, _ = G.sparsify(net, params, d)
            net.batched_apply(params, sparse=sparse, key=(d,),
                              cache=cache)(x)
        assert len(cache) == 2


class TestGraphCycleReports:
    def test_resnet18_per_layer_walk(self, rng):
        """VGG and ResNet share one analysis path: traffic -> per-layer
        reports, residual-branch convs included."""
        from repro.core.accel_model import (
            PE_4_14_3, aggregate, network_cycle_reports,
        )
        net = G.build_resnet18(num_classes=16, image_size=32)
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        x = jnp.asarray(rng.standard_normal((1, 32, 32, 3)), jnp.float32)
        traffic = G.collect_conv_traffic(net, params, x)
        assert len(traffic) == 20
        reports = network_cycle_reports(traffic, PE_4_14_3)
        names = [n for n, _ in reports]
        assert "layer2_0_down" in names  # the projection branch is counted
        agg = aggregate([r for _, r in reports])
        assert agg.dense > 0 and agg.vscnn <= agg.dense
        # pruning must reduce cycles through the same walk
        _, pruned = G.sparsify(net, params, 0.25)
        rep_p = network_cycle_reports(
            G.collect_conv_traffic(net, pruned, x), PE_4_14_3)
        agg_p = aggregate([r for _, r in rep_p])
        assert agg_p.vscnn < agg.vscnn

    def test_vgg16_same_walk(self, rng):
        from repro.core.accel_model import PE_8_7_3, network_cycle_reports
        net = G.build_vgg16(16, image_size=32)
        params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
        traffic = G.collect_conv_traffic(
            net, params, jnp.ones((1, 32, 32, 3)))
        reports = network_cycle_reports(traffic, PE_8_7_3)
        assert len(reports) == 13
