"""MoE: local-dispatch correctness vs a dense-gather oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import init_params
from repro.models.moe import MoEConfig, moe_apply, moe_schema


def _setup(rng, d=32, e=8, k=2, f=16, gated=True, cf=64.0):
    moe = MoEConfig(n_experts=e, top_k=k, d_ff=f, capacity_factor=cf)
    schema = moe_schema(d, moe, gated=gated, tp_hint=1)
    params = init_params(schema, jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 12, d)), jnp.float32)
    return moe, params, x


def moe_oracle(params, x, moe, *, gated):
    """Dense per-token gather reference: every token through its top-k."""
    b, t, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    ep = logits.shape[1]
    if ep != moe.n_experts:
        logits = jnp.where(jnp.arange(ep)[None] < moe.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, moe.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for j in range(moe.top_k):
        e_id = topi[:, j]
        if gated:
            w1 = params["wi"][0][e_id]      # (N, d, f)
            w2 = params["wi"][1][e_id]
            h = jax.nn.silu(jnp.einsum("nd,ndf->nf", xf, w1)) * jnp.einsum(
                "nd,ndf->nf", xf, w2)
        else:
            h = jax.nn.silu(jnp.einsum("nd,ndf->nf", xf, params["wi"][e_id]))
        y = jnp.einsum("nf,nfd->nd", h, params["wo"][e_id])
        out = out + topw[:, j:j+1] * y
    return out.reshape(b, t, d)


class TestMoECorrectness:
    @pytest.mark.parametrize("gated", [True, False])
    def test_matches_oracle_no_drop(self, gated, rng):
        moe, params, x = _setup(rng, gated=gated)
        y, aux = moe_apply(params, x, moe, gated=gated)
        ref = moe_oracle(params, x, moe, gated=gated)
        err = np.abs(np.asarray(y) - np.asarray(ref)).max()
        assert err / np.abs(np.asarray(ref)).max() < 1e-4

    def test_padded_experts_never_selected(self, rng):
        # tp_hint=4 pads 6 experts -> 8; dead experts must get zero tokens
        moe = MoEConfig(n_experts=6, top_k=2, d_ff=16, capacity_factor=64.0)
        schema = moe_schema(32, moe, gated=True, tp_hint=4)
        params = init_params(schema, jax.random.PRNGKey(1), jnp.float32)
        assert params["router"].shape[1] == 8
        x = jnp.asarray(rng.standard_normal((2, 16, 32)), jnp.float32)
        y, _ = moe_apply(params, x, moe, gated=True)
        ref = moe_oracle(params, x, moe, gated=True)
        assert np.abs(np.asarray(y) - np.asarray(ref)).max() < 1e-4

    def test_capacity_drop_reduces_output_norm(self):
        rng = np.random.default_rng(1234)
        moe, params, x = _setup(rng, cf=64.0)
        y_full, _ = moe_apply(params, x, moe, gated=True)
        tight = dataclasses.replace(moe, capacity_factor=0.25)
        y_drop, _ = moe_apply(params, x, tight, gated=True)
        # dropped tokens contribute zero -> strictly less (or equal) energy
        assert (np.linalg.norm(np.asarray(y_drop)) <=
                np.linalg.norm(np.asarray(y_full)) + 1e-5)

    def test_aux_loss_uniform_router_is_one(self, rng):
        moe, params, x = _setup(rng)
        params = dict(params, router=jnp.zeros_like(params["router"]))
        _, aux = moe_apply(params, x, moe, gated=True)
        # perfectly uniform probs: E * sum(f_e * 1/E) = sum(f_e) = 1
        assert abs(float(aux) - 1.0) < 0.05

    def test_grads_flow_to_router(self, rng):
        moe, params, x = _setup(rng)

        def loss(p):
            y, aux = moe_apply(p, x, moe, gated=True)
            return jnp.sum(y ** 2) + aux

        g = jax.grad(loss)(params)
        assert float(jnp.abs(g["router"]).max()) > 0
        assert float(jnp.abs(g["wi"]).max()) > 0


class TestMoESharded:
    def test_shard_map_path_matches_local(self, rng):
        """On a 1x1 mesh the shard_map path must equal the local path."""
        from repro.parallel import sharding as shd
        from repro.launch.mesh import make_local_mesh
        moe, params, x = _setup(rng)
        y_local, aux_local = moe_apply(params, x, moe, gated=True)
        mesh = make_local_mesh(data=1, model=1)
        with shd.use_mesh(mesh, shd.TRAIN_RULES):
            y_mesh, aux_mesh = moe_apply(params, x, moe, gated=True)
        assert np.abs(np.asarray(y_local) - np.asarray(y_mesh)).max() < 1e-5
        assert abs(float(aux_local) - float(aux_mesh)) < 1e-5
