"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (single-CPU) device count; only launch/dryrun.py forces 512."""
import os

import jax
import numpy as np
import pytest


def pytest_configure(config):
    # CI's gating path sets REQUIRE_HYPOTHESIS=1: the property-based tests
    # must run under the real hypothesis there, never the deterministic
    # fallback runner (tests/_hypothesis_compat.py) — and never skip.
    if os.environ.get("REQUIRE_HYPOTHESIS"):
        from _hypothesis_compat import HAVE_HYPOTHESIS
        if not HAVE_HYPOTHESIS:
            raise pytest.UsageError(
                "REQUIRE_HYPOTHESIS is set but the real hypothesis package "
                "is not installed — property tests would run under the "
                "reduced fallback strategy runner")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield
