"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real (single-CPU) device count; only launch/dryrun.py forces 512."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)
    yield
