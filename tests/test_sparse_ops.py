"""Structural sparse ops (jnp path) vs dense oracles."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    conv_weight_to_matrix, dense_conv2d_3x3, encode, im2col_3x3,
    prune_vectors_balanced, vs_conv2d_3x3, vs_matmul,
)


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


class TestVsMatmul:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.sampled_from([0.25, 0.5, 1.0]))
    def test_vs_dense(self, seed, density):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((128, 256)).astype(np.float32)
        wp, _ = prune_vectors_balanced(w, density, 16, 128)
        vs = encode(jnp.asarray(wp), 16, 128)
        x = jnp.asarray(rng.standard_normal((4, 9, 128)), np.float32)
        assert _rel(vs_matmul(x, vs), x @ wp) < 1e-5

    def test_batched_shapes_preserved(self):
        rng = np.random.default_rng(2)
        w = rng.standard_normal((64, 128)).astype(np.float32)
        wp, _ = prune_vectors_balanced(w, 0.5, 16, 128)
        vs = encode(jnp.asarray(wp), 16, 128)
        x = jnp.ones((3, 5, 7, 64))
        assert vs_matmul(x, vs).shape == (3, 5, 7, 128)


class TestIm2col:
    def test_matches_lax_conv(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.standard_normal((2, 9, 11, 8)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((3, 3, 8, 16)), jnp.float32)
        patches = im2col_3x3(x)
        ref = dense_conv2d_3x3(x, w)
        out = patches @ conv_weight_to_matrix(w)
        assert _rel(out, ref) < 1e-4


class TestVsConv:
    @pytest.mark.parametrize("density", [0.25, 0.5, 1.0])
    def test_vs_dense_conv(self, density):
        rng = np.random.default_rng(4)
        cin, cout = 32, 128
        w = rng.standard_normal((3, 3, cin, cout)).astype(np.float32)
        wm = conv_weight_to_matrix(jnp.asarray(w))
        wp, _ = prune_vectors_balanced(np.asarray(wm), density, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(rng.standard_normal((2, 8, 8, cin)), jnp.float32)
        ref = dense_conv2d_3x3(x, jnp.asarray(wp.reshape(3, 3, cin, cout)))
        assert _rel(vs_conv2d_3x3(x, vs), ref) < 1e-4

    def test_jnp_and_pallas_agree(self):
        rng = np.random.default_rng(5)
        cin, cout = 32, 128
        wm = rng.standard_normal((9 * cin, cout)).astype(np.float32)
        wp, _ = prune_vectors_balanced(wm, 0.5, 32, 128)
        vs = encode(jnp.asarray(wp), 32, 128)
        x = jnp.asarray(np.maximum(rng.standard_normal((1, 8, 8, cin)), 0),
                        jnp.float32)
        assert _rel(vs_conv2d_3x3(x, vs, impl="jnp"),
                    vs_conv2d_3x3(x, vs, impl="pallas")) < 1e-5
