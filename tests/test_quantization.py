"""INT8 vector-sparse quantization: round-trip properties, bit-exact
Pallas-vs-jnp parity, int8-vs-f32 output agreement, and the dtype axis of
the hillclimb byte budget.

The quantization scheme (see `models.graph`): per-cout symmetric weight
scales from the PRUNED folded weights, per-tensor symmetric activation
scales at apply time — both rounded UP to powers of two, so the fused
dequant multiply in the kernel epilogue is exact in f32 and parity
between the Pallas kernels and the structural jnp path is bit-for-bit
regardless of compiler FMA contraction.
"""
import importlib.util
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encode, prune_vectors_balanced, vs_conv2d, vs_matmul
from repro.models import graph as G
from repro.models.layers import init_params

REPO = pathlib.Path(__file__).resolve().parents[1]


def _load_bench(name):
    spec = importlib.util.spec_from_file_location(
        name, REPO / "benchmarks" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules[name] = mod  # dataclass string-annotation resolution
    spec.loader.exec_module(mod)
    return mod


def _quantized_vs(rng, k, n, vk, vn, density):
    """Mirror `sparse_conv_from_dense`'s int8 encode: prune f32, scale
    from the pruned matrix, quantize, encode the int8 tiles."""
    wm = rng.standard_normal((k, n)).astype(np.float32)
    wp, _ = prune_vectors_balanced(wm, density, vk, vn)
    s = G.weight_scales(wp)
    wq = G.quantize_weights_int8(wp, s)
    return encode(jnp.asarray(wq), vk, vn), s, wp


class TestRoundTrip:
    def test_weight_scales_are_pow2(self, rng):
        wm = rng.standard_normal((96, 64)).astype(np.float32)
        wm[:, 7] = 0.0  # pad-like all-zero column
        s = G.weight_scales(wm)
        assert s.shape == (64,) and s.dtype == np.float32
        assert np.all(np.exp2(np.round(np.log2(s))) == s)  # exact po2
        assert s[7] == 1.0
        # po2 round-up never shrinks below the symmetric-range scale
        assert np.all(s[:7] * 127.0 >= np.abs(wm[:, :7]).max(axis=0))

    def test_weight_roundtrip_within_half_scale(self, rng):
        wm = rng.standard_normal((128, 256)).astype(np.float32) * 3.0
        s = G.weight_scales(wm)
        wq = G.quantize_weights_int8(wm, s)
        assert wq.dtype == np.int8
        err = np.abs(wm - wq.astype(np.float32) * s)
        assert np.all(err <= s / 2 + 1e-7)

    def test_activation_quant_pow2_and_bounds(self, rng):
        x = jnp.asarray(rng.standard_normal((4, 8, 8, 16)), jnp.float32)
        xq, sx = G.quantize_activations_int8(x)
        assert xq.dtype == jnp.int8
        sxv = float(sx)
        assert np.exp2(np.round(np.log2(sxv))) == sxv
        assert sxv * 127.0 >= float(jnp.abs(x).max())
        err = np.abs(np.asarray(x) - np.asarray(xq, np.float32) * sxv)
        assert np.all(err <= sxv / 2 + 1e-7)
        # all-zero tensor: scale guard, encode is a no-op
        zq, zs = G.quantize_activations_int8(jnp.zeros_like(x))
        assert float(zs) == 1.0 and not np.any(np.asarray(zq))

    def test_sparsify_int8_structure(self):
        net = G.build_resnet18(16, image_size=16)
        params = init_params(net.schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        sparse, pruned = net.sparsify(params, 0.5, dtype="int8")
        for name, entry in sparse.items():
            assert entry.vs.vals.dtype == jnp.int8, name
            assert entry.scale is not None, name
            s = np.asarray(entry.scale)
            assert np.all(np.exp2(np.round(np.log2(s))) == s), name


class TestKernelParity:
    """Pallas kernels vs the structural jnp path must agree BIT-FOR-BIT
    on int8 inputs: int32 step MACs are exact, the shared f32 accumulator
    sees identical addends in identical order, and the po2 dequant scale
    makes the epilogue immune to FMA contraction."""

    def test_vsmm_full_epilogue_bit_exact(self, rng):
        m, k, n, vk, vn = 32, 128, 256, 32, 128
        vs, s_w, _ = _quantized_vs(rng, k, n, vk, vn, 0.5)
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        xq, sx = G.quantize_activations_int8(x)
        scale = jnp.asarray(s_w) * sx
        bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
        res = jnp.asarray(rng.standard_normal((m, n)), jnp.float32)
        kw = dict(scale=scale, bias=bias, residual=res, fuse_relu=True)
        ref = vs_matmul(xq, vs, impl="jnp", **kw)
        out = vs_matmul(xq, vs, impl="pallas", **kw)
        assert ref.dtype == jnp.float32
        assert np.array_equal(np.asarray(ref), np.asarray(out))

    @pytest.mark.parametrize("geom", [
        dict(kh=3, kw=3, stride=1, groups=1, h=8, w=8, cin=32, cout=128,
             vk=32, vn=128, residual=True),
        dict(kh=3, kw=3, stride=2, groups=64, h=8, w=8, cin=64, cout=64,
             vk=1, vn=64, residual=False),          # depthwise
        dict(kh=3, kw=3, stride=1, groups=2, h=8, w=8, cin=64, cout=64,
             vk=16, vn=32, residual=False),         # grouped
        dict(kh=1, kw=1, stride=1, groups=1, h=8, w=8, cin=64, cout=128,
             vk=32, vn=128, residual=False),        # pointwise -> vsmm
    ], ids=["3x3_res", "dw3x3_s2", "grouped_g2", "1x1"])
    @pytest.mark.parametrize("impl", ["pallas", "pallas-stack"])
    def test_vsconv_bit_exact(self, rng, geom, impl):
        kh, kw, stride, groups = (geom["kh"], geom["kw"], geom["stride"],
                                  geom["groups"])
        h, w, cin, cout = geom["h"], geom["w"], geom["cin"], geom["cout"]
        vk, vn = geom["vk"], geom["vn"]
        depthwise = groups == cin
        k = kh * kw if depthwise else kh * kw * cin // groups
        vs, s_w, _ = _quantized_vs(rng, k, cout if not depthwise else cin,
                                   vk, vn, 0.5)
        if kh * kw > 1 and not depthwise:
            from repro.core import conv_cin_major
            vs = conv_cin_major(vs, (cin // groups) // vk)
        x = jnp.asarray(
            np.maximum(rng.standard_normal((2, h, w, cin)), 0), jnp.float32)
        xq, sx = G.quantize_activations_int8(x)
        scale = jnp.asarray(s_w) * sx
        bias = jnp.asarray(rng.standard_normal(vs.shape[1]), jnp.float32)
        res = None
        if geom["residual"]:
            ho = -(-h // stride)
            res = jnp.asarray(
                rng.standard_normal((2, ho, -(-w // stride), cout)),
                jnp.float32)
        kw_args = dict(kh=kh, kw=kw, stride=stride, groups=groups,
                       scale=scale, bias=bias, residual=res, fuse_relu=True)
        ref = vs_conv2d(xq, vs, impl="jnp", **kw_args)
        out = vs_conv2d(xq, vs, impl=impl, **kw_args)
        assert ref.dtype == jnp.float32
        assert np.array_equal(np.asarray(ref), np.asarray(out))


class TestNetworkAgreement:
    """int8 vs f32 forward on fixed seeded inputs: logits stay close and
    top-1 decisions mostly agree (random-init logit margins are tiny, so
    the match-rate bound is deliberately modest)."""

    @pytest.mark.parametrize("build", [G.build_resnet18,
                                       G.build_mobilenet_v1],
                             ids=["resnet18", "mobilenet_v1"])
    def test_int8_vs_f32_agreement(self, build):
        net = build(64, image_size=24)
        params = init_params(net.schema(), jax.random.PRNGKey(0),
                             jnp.float32)
        x = jnp.asarray(
            np.random.default_rng(7).standard_normal((8, 24, 24, 3)),
            jnp.float32)
        sp_f, _ = net.sparsify(params, 0.5)
        sp_q, _ = net.sparsify(params, 0.5, dtype="int8")
        lf = np.asarray(G.net_apply(net, params, x, sparse=sp_f,
                                    impl="jnp"))
        lq = np.asarray(G.net_apply(net, params, x, sparse=sp_q,
                                    impl="jnp"))
        assert lq.dtype == np.float32 and lq.shape == lf.shape
        assert float(np.abs(lq - lf).max()) <= 0.1
        match = float((lq.argmax(-1) == lf.argmax(-1)).mean())
        assert match >= 0.25


class TestHillclimbDtype:
    def test_int8_budget_keeps_more_vectors(self):
        """Regression for the modeled-bytes budget ignoring weight dtype:
        at the SAME absolute byte budget the int8 contract must afford
        strictly more stored vectors than f32."""
        hc = _load_bench("hillclimb")
        net = G.build_resnet18(200, image_size=32)
        f32 = hc.hillclimb(net, size=32, batch=1, budget=0.5,
                           verbose=False)
        i8 = hc.hillclimb(net, size=32, batch=1,
                          budget_bytes=f32["total_bytes"], dtype="int8",
                          verbose=False)
        assert i8["dtype"] == "int8"
        assert i8["total_bytes"] <= f32["total_bytes"]
        assert i8["kept_tiles"] > f32["kept_tiles"]
        assert i8["kept_weight_fraction"] > f32["kept_weight_fraction"]
