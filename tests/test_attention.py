"""Flash attention vs naive softmax oracle; decode cache semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention, repeat_kv


def naive_attention(q, k, v, *, causal=True, window=None):
    b, tq, h, hd = q.shape
    tk = k.shape[1]
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bshd->bqhd", p, v.astype(jnp.float32))


def _rel(a, b):
    a, b = np.asarray(a, np.float32), np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("tq,bq,bk", [(64, 16, 16), (64, 64, 32),
                                          (48, 16, 48), (33, 16, 16)])
    def test_causal_matches_naive(self, tq, bq, bk, rng):
        q = jnp.asarray(rng.standard_normal((2, tq, 4, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, tq, 4, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, tq, 4, 16)), jnp.float32)
        out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
        assert _rel(out, naive_attention(q, k, v)) < 1e-5

    def test_bidirectional(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
        k, v = q + 1, q - 1
        out = flash_attention(q, k, v, causal=False, bq=16, bk=16)
        assert _rel(out, naive_attention(q, k, v, causal=False)) < 1e-5

    @pytest.mark.parametrize("window", [1, 4, 16])
    def test_sliding_window(self, window, rng):
        q = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 32, 2, 8)), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=window, bq=8, bk=8)
        assert _rel(out, naive_attention(q, k, v, window=window)) < 1e-5

    def test_gqa_repeat(self, rng):
        q = jnp.asarray(rng.standard_normal((1, 16, 8, 8)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 16, 2, 8)), jnp.float32)
        kr, vr = repeat_kv(k, 8), repeat_kv(v, 8)
        out = flash_attention(q, kr, vr, bq=8, bk=8)
        ref = naive_attention(q, kr, vr)
        assert _rel(out, ref) < 1e-5
        # repeated heads share K/V: groups of 4 query heads attend identically
        assert kr.shape == (1, 16, 8, 8)
        assert np.allclose(np.asarray(kr[:, :, 0]), np.asarray(kr[:, :, 3]))

    def test_numerics_large_logits(self, rng):
        """Online softmax must be stable under large score magnitudes."""
        q = jnp.asarray(100 * rng.standard_normal((1, 16, 1, 8)), jnp.float32)
        k = jnp.asarray(100 * rng.standard_normal((1, 16, 1, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 16, 1, 8)), jnp.float32)
        out = flash_attention(q, k, v, bq=8, bk=8)
        assert np.isfinite(np.asarray(out)).all()


class TestCircularCache:
    def test_circular_decode_matches_window_attention(self, rng):
        """Sliding-window decode with capacity == window must equal full
        attention with the window mask at every step."""
        import dataclasses
        from repro.configs import get_config
        from repro.models import transformer as tfm
        from repro.models.layers import init_params
        from repro.models.frontend import synthetic_tokens

        cfg = get_config("gemma3-12b").reduce()  # has 16-window local layers
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0), cfg.dtype)
        T, extra = 20, 6  # T exceeds the reduced window (16) => wraparound
        toks = synthetic_tokens(jax.random.PRNGKey(1), 2, T + extra, cfg.vocab)
        full = tfm.lm_apply(params, {"tokens": toks}, cfg)
        logits, caches = tfm.prefill(params, {"tokens": toks[:, :T]}, cfg,
                                     capacity=T + extra)
        errs = [np.abs(np.asarray(logits) - np.asarray(full[:, T - 1])).max()]
        for i in range(extra):
            logits, caches = tfm.decode_step(
                params, caches, toks[:, T + i][:, None], jnp.int32(T + i), cfg)
            errs.append(np.abs(np.asarray(logits) - np.asarray(full[:, T + i])).max())
        rel = max(errs) / np.abs(np.asarray(full)).max()
        assert rel < 2e-2, errs


class TestPallasAttnImpl:
    def test_lm_forward_matches_xla_impl(self):
        import dataclasses
        from repro.configs import get_config
        from repro.models import transformer as tfm
        from repro.models.layers import init_params
        from repro.models.frontend import synthetic_tokens
        cfg = get_config("gemma3-12b").reduce()  # windows + globals
        cfgp = dataclasses.replace(cfg, attn_impl="pallas")
        params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(0),
                             cfg.dtype)
        toks = synthetic_tokens(jax.random.PRNGKey(1), 2, 32, cfg.vocab)
        l_x = tfm.lm_apply(params, {"tokens": toks}, cfg)
        l_p = tfm.lm_apply(params, {"tokens": toks}, cfgp)
        rel = (np.abs(np.asarray(l_x) - np.asarray(l_p)).max()
               / np.abs(np.asarray(l_x)).max())
        assert rel < 1e-4
