"""Benchmark-regression gate: the committed baseline + compare logic.

The CI step re-runs the ResNet-18 per-layer bench and fails on a >10%
per-layer regression of cycle speedup or modeled bytes.  These tests
verify the gate *mechanism* against the committed baseline artifact:
identical rows pass, a synthetically perturbed baseline (>10% better than
what the repo produces) fails, and the per-layer delta table renders.
"""
import copy
import importlib.util
import json
import pathlib

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
BASELINE = REPO / "benchmarks" / "baselines" / "BENCH_resnet18.json"


def _bench_kernels():
    spec = importlib.util.spec_from_file_location(
        "bench_kernels", REPO / "benchmarks" / "bench_kernels.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def bk():
    return _bench_kernels()


@pytest.fixture(scope="module")
def baseline():
    with open(BASELINE) as f:
        return json.load(f)


class TestCompareBaseline:
    def test_committed_baseline_shape(self, baseline):
        """The committed artifact carries everything the gate re-run
        needs (settings) and per-layer rows with the gated metrics."""
        assert baseline["net"] == "resnet18"
        assert {"image_size", "num_classes", "batch",
                "densities"} <= set(baseline)
        layer_rows = [r for r in baseline["rows"] if r["layer"] != "__net__"]
        assert layer_rows
        # conv rows carry the gated deterministic metrics; FC rows are the
        # (ungated) measured-vs-modeled ride-alongs
        conv_rows = [r for r in layer_rows if r.get("geometry") != "fc"]
        assert conv_rows
        for r in conv_rows:
            assert {"cycle_speedup", "bytes_halo", "bytes_stack"} <= set(r)

    def test_identical_rows_pass(self, bk, baseline):
        failures, lines = bk.compare_baseline(baseline["rows"], baseline)
        assert failures == []
        # delta table renders one markdown row per gated metric
        assert lines[0].startswith("| layer row |")
        assert len(lines) > 2 + len(baseline["rows"])
        assert all("| ok |" in l for l in lines[2:])

    def test_synthetic_regression_fails(self, bk, baseline):
        """Perturb the baseline >10% better than reality: the gate must
        fail — this is exactly what a real perf regression looks like to
        CI (current worse than committed)."""
        perturbed = copy.deepcopy(baseline)
        victim = next(r for r in perturbed["rows"]
                      if r["layer"] != "__net__")
        victim["cycle_speedup"] = victim["cycle_speedup"] * 1.25
        victim["bytes_halo"] = int(victim["bytes_halo"] * 0.8)
        failures, lines = bk.compare_baseline(baseline["rows"], perturbed)
        assert len(failures) == 2
        assert any("cycle_speedup" in f for f in failures)
        assert any("bytes_halo" in f for f in failures)
        assert sum("| FAIL |" in l for l in lines) == 2

    def test_small_regression_within_tolerance_passes(self, bk, baseline):
        perturbed = copy.deepcopy(baseline)
        for r in perturbed["rows"]:
            if "cycle_speedup" in r:
                r["cycle_speedup"] = r["cycle_speedup"] * 1.05  # < 10%
        failures, _ = bk.compare_baseline(baseline["rows"], perturbed)
        assert failures == []

    def test_missing_row_fails(self, bk, baseline):
        rows = [r for r in baseline["rows"]
                if r["name"] != baseline["rows"][0]["name"]]
        failures, _ = bk.compare_baseline(rows, baseline)
        assert any("missing" in f for f in failures)

    def test_new_rows_are_not_failures(self, bk, baseline):
        rows = baseline["rows"] + [{"name": "resnet99_conv1_density_1.0",
                                    "cycle_speedup": 1.0,
                                    "bytes_halo": 1, "bytes_stack": 1}]
        failures, _ = bk.compare_baseline(rows, baseline)
        assert failures == []


class TestRunNetworkSmoke:
    def test_mobilenet_rows_have_dw_geometry(self, bk):
        """The generalized per-network bench runs the depthwise net and
        tags dw layers in the geometry column (tiny config; model-only —
        the measured columns have their own test below)."""
        rows = bk.run_network("mobilenet_v1", densities=(0.5,),
                              image_size=16, num_classes=8, measure=False)
        dw = [r for r in rows if r.get("geometry", "").endswith("_dw")]
        assert len(dw) == 13
        net_row = next(r for r in rows if r["layer"] == "__net__")
        assert net_row["bytes_halo"] < net_row["bytes_stack"]

    def test_vgg16_rows_carry_measured_vs_modeled_columns(self, bk):
        """Every per-layer row (VGG-16 here; all registered nets in CI)
        carries the measured-vs-modeled columns next to the modeled ones,
        and the FC head rides along as its own row."""
        rows = bk.run_network("vgg16", densities=(0.5,),
                              image_size=32, num_classes=8)
        layer_rows = [r for r in rows if r["layer"] != "__net__"]
        for r in layer_rows:
            assert set(bk.MEASURED_COLS) <= set(r), r["name"]
            assert r["measured_us"] > 0
        assert any(r.get("geometry") == "fc" for r in layer_rows)
        # conv rows keep the gated deterministic metrics untouched
        conv = next(r for r in layer_rows if r.get("geometry") != "fc")
        assert {"cycle_speedup", "bytes_halo", "bytes_stack"} <= set(conv)
