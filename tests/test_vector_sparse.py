"""VectorSparse format invariants (property-based)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    decode, encode, from_mask, prune_vectors_balanced, tile_mask,
)


def _balanced_w(rng, kb, nb, vk, vn, s):
    w = rng.standard_normal((kb * vk, nb * vn)).astype(np.float32)
    wp, mask = prune_vectors_balanced(w, s / kb, vk, vn)
    return wp, mask


@st.composite
def sparse_case(draw):
    vk = draw(st.sampled_from([1, 2, 8, 16]))
    vn = draw(st.sampled_from([1, 4, 8]))
    kb = draw(st.integers(2, 6))
    nb = draw(st.integers(1, 4))
    s = draw(st.integers(1, kb))
    seed = draw(st.integers(0, 2**31 - 1))
    return vk, vn, kb, nb, s, seed


class TestEncodeDecode:
    @settings(max_examples=40, deadline=None)
    @given(sparse_case())
    def test_roundtrip(self, case):
        vk, vn, kb, nb, s, seed = case
        rng = np.random.default_rng(seed)
        wp, mask = _balanced_w(rng, kb, nb, vk, vn, s)
        vs = encode(jnp.asarray(wp), vk, vn)
        assert np.allclose(np.asarray(decode(vs)), wp)

    @settings(max_examples=40, deadline=None)
    @given(sparse_case())
    def test_density_invariant(self, case):
        vk, vn, kb, nb, s, seed = case
        rng = np.random.default_rng(seed)
        wp, mask = _balanced_w(rng, kb, nb, vk, vn, s)
        vs = encode(jnp.asarray(wp), vk, vn)
        # encode may keep more tiles than pruning if random zeros align, but
        # never fewer than the mask kept and never more than kb
        assert vs.nnz_per_strip <= kb
        assert 0 < vs.density <= 1.0

    def test_idx_sorted_and_in_range(self):
        rng = np.random.default_rng(3)
        wp, _ = _balanced_w(rng, 8, 4, 16, 8, 3)
        vs = encode(jnp.asarray(wp), 16, 8)
        idx = np.asarray(vs.idx)
        assert (np.diff(idx, axis=1) > 0).all()  # strictly increasing
        assert idx.min() >= 0 and idx.max() < 8

    def test_unbalanced_mask_rejected(self):
        w = np.ones((4, 4), np.float32)
        mask = np.array([[True, False], [False, False]])
        with pytest.raises(ValueError):
            from_mask(jnp.asarray(w), mask, 2, 2)

    def test_tile_mask_detects_any_nonzero(self):
        w = np.zeros((4, 4), np.float32)
        w[1, 3] = 7.0  # tile (0, 1) for vk=vn=2
        m = np.asarray(tile_mask(jnp.asarray(w), 2, 2))
        assert m.tolist() == [[False, True], [False, False]]

    def test_pytree_roundtrip(self):
        import jax
        rng = np.random.default_rng(4)
        wp, _ = _balanced_w(rng, 4, 2, 8, 8, 2)
        vs = encode(jnp.asarray(wp), 8, 8)
        leaves, treedef = jax.tree_util.tree_flatten(vs)
        vs2 = jax.tree_util.tree_unflatten(treedef, leaves)
        assert np.allclose(np.asarray(decode(vs2)), np.asarray(decode(vs)))

    def test_dense_special_case(self):
        # S == KB: the dense network as the same format (paper: one datapath)
        rng = np.random.default_rng(5)
        w = rng.standard_normal((64, 32)).astype(np.float32)
        vs = encode(jnp.asarray(w), 16, 8)
        assert vs.density == 1.0
        assert np.allclose(np.asarray(decode(vs)), w)


class TestEdgeCases:
    """Deterministic edge cases that must hold even without hypothesis."""

    @pytest.mark.parametrize("density", [0.125, 0.25, 0.5, 0.75, 1.0])
    def test_roundtrip_density_sweep(self, density):
        # encode -> decode is the identity on the pruned matrix for every
        # density 0 < d <= 1
        rng = np.random.default_rng(11)
        w = rng.standard_normal((128, 64)).astype(np.float32)
        wp, mask = prune_vectors_balanced(w, density, 16, 16)
        vs = encode(jnp.asarray(wp), 16, 16)
        assert np.allclose(np.asarray(decode(vs)), wp)
        assert vs.nnz_per_strip == int(mask.sum(axis=0)[0])

    def test_from_mask_unbalanced_counts_raise(self):
        w = jnp.asarray(np.arange(64, dtype=np.float32).reshape(8, 8))
        mask = np.zeros((4, 4), bool)
        mask[0, 0] = mask[1, 0] = True  # strip 0 keeps 2 tiles
        mask[2, 1] = True               # strip 1 keeps 1 tile
        mask[:, 2] = True               # strip 2 keeps 4
        mask[0, 3] = True               # strip 3 keeps 1
        with pytest.raises(ValueError, match="unbalanced"):
            from_mask(w, mask, 2, 2)

    def test_from_mask_wrong_mask_shape_rejected(self):
        w = jnp.ones((8, 8))
        with pytest.raises(AssertionError):
            from_mask(w, np.ones((2, 2), bool), 2, 2)  # should be (4, 4)

    @pytest.mark.parametrize("src,dst", [
        (jnp.float32, jnp.bfloat16),
        (jnp.bfloat16, jnp.float32),
        (jnp.float32, jnp.float16),
    ])
    def test_astype_preserves_structure(self, src, dst):
        rng = np.random.default_rng(12)
        wp, _ = _balanced_w(rng, 4, 2, 8, 8, 2)
        vs = encode(jnp.asarray(wp, src), 8, 8)
        vs2 = vs.astype(dst)
        assert vs2.dtype == dst
        assert vs2.vals.dtype == dst
        # structure (index system, shape, density) untouched by the cast
        assert vs2.shape == vs.shape
        assert vs2.idx is vs.idx
        assert vs2.density == vs.density
        assert np.allclose(
            np.asarray(decode(vs2), np.float32),
            np.asarray(decode(vs), np.float32),
            atol=1e-2,
        )

    def test_full_density_roundtrip_is_exact_per_dtype(self):
        rng = np.random.default_rng(13)
        w = rng.standard_normal((32, 16)).astype(np.float32)
        for dt in (jnp.float32, jnp.bfloat16):
            vs = encode(jnp.asarray(w, dt), 8, 8)
            assert vs.dtype == dt
            assert np.array_equal(
                np.asarray(decode(vs)), np.asarray(jnp.asarray(w, dt))
            )
