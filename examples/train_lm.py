"""Train a reduced-config assigned architecture end-to-end on CPU.

Exercises the full production loop: schema-driven init, jit'd train step
(microbatching if configured), deterministic data, async checkpoints,
auto-resume, straggler monitoring.  Any --arch from the registry works;
reduced configs are ~1M params so a few hundred steps run in minutes.

Run:  PYTHONPATH=src python examples/train_lm.py --arch jamba-v0.1-52b \
          --steps 200 --ckpt /tmp/jamba_ckpt
"""
import argparse

from repro.configs import get_config, list_archs
from repro.launch.train import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduce()
    print(f"== training reduced {cfg.name}: {cfg.total_layers} layers, "
          f"d_model {cfg.d_model} ==")
    loop = TrainLoop(cfg, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt, ckpt_every=50)
    _, _, hist = loop.run(args.steps, log_every=20)
    print(f"loss: {hist[0]:.3f} -> {hist[-1]:.3f} over {len(hist)} steps "
          f"({loop.monitor.events} straggler events)")


if __name__ == "__main__":
    main()
