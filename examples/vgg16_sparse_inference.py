"""End-to-end driver (the paper's kind: sparse CNN *inference*).

Pipeline: build VGG-16 -> vector-prune to the paper's 23.5% density ->
serve batched image requests through the vector-sparse path (structural op
or Pallas kernel) -> report agreement with the dense oracle and the
simulated accelerator cycle counts for the same traffic (Figs 12/13).

Run:  PYTHONPATH=src python examples/vgg16_sparse_inference.py [--size 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.vscnn_vgg16 import CONFIG
from repro.core.accel_model import PE_4_14_3, PE_8_7_3, aggregate
from repro.data import SyntheticImages
from repro.models.cnn import sparsify_vgg16, vgg16_apply, vgg16_schema
from repro.models.layers import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64,
                    help="image resolution (224 = paper scale)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--impl", choices=("jnp", "pallas"), default="jnp")
    args = ap.parse_args()

    print(f"== VGG-16 vector-sparse inference @ {args.size}px, "
          f"density {CONFIG.weight_density} ==")
    params = init_params(vgg16_schema(1000, image_size=args.size),
                         jax.random.PRNGKey(0), jnp.float32)
    sparse, pruned = sparsify_vgg16(params, CONFIG.weight_density,
                                    vk=CONFIG.vk, vn=CONFIG.vn)
    n_conv = sum(1 for k in sparse if k.startswith("conv"))
    print(f"sparsified {len(sparse)} layers — every conv ({n_conv}/13, stem "
          f"included via channel padding) + FC runs the vector-sparse path")

    data = SyntheticImages(args.batch, size=args.size)
    imgs = jnp.asarray(data.batch_at(0)["images"])

    dense_fn = jax.jit(lambda x: vgg16_apply(pruned, x))
    sparse_fn = jax.jit(lambda x: vgg16_apply(params, x, sparse=sparse,
                                              impl=args.impl))
    y_dense = dense_fn(imgs)
    t0 = time.time()
    y_sparse = sparse_fn(imgs)
    y_sparse.block_until_ready()
    dt = time.time() - t0
    rel = float(jnp.abs(y_sparse - y_dense).max() / jnp.abs(y_dense).max())
    print(f"sparse ({args.impl}) vs pruned-dense: rel err {rel:.2e}  "
          f"({dt*1e3:.0f} ms for batch {args.batch})")

    # accelerator cycle accounting for the same traffic — the per-layer
    # graph walk shared with ResNet-18 (see resnet18_sparse_inference.py)
    from repro.core.accel_model import network_cycle_reports
    from repro.models.graph import build_vgg16, collect_conv_traffic
    traffic = collect_conv_traffic(build_vgg16(), pruned, imgs[:1])
    for pe in (PE_4_14_3, PE_8_7_3):
        reports = network_cycle_reports(traffic, pe)
        agg = aggregate([r for _, r in reports])
        print(f"PE [{pe.blocks},{pe.rows},{pe.cols}]: "
              f"{agg.speedup:.2f}x speedup over dense "
              f"({agg.vscnn:,} vs {agg.dense:,} cycles; paper: 1.87-1.93x)")


if __name__ == "__main__":
    main()
