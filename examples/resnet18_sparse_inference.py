"""End-to-end ResNet-18 on the vector-sparse datapath.

Pipeline: build ResNet-18 from the graph IR -> fold BN into the conv
weights/bias and vector-prune to the paper's density -> run every conv and
FC layer (residual adds fused in the kernel epilogue) through the sparse
path -> report agreement with the folded-pruned dense oracle and the
simulated accelerator per-layer cycle counts, the same analysis walk VGG-16
uses.

Run:  PYTHONPATH=src python examples/resnet18_sparse_inference.py [--size 64]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.vscnn_resnet18 import CONFIG
from repro.core.accel_model import aggregate, network_cycle_reports
from repro.data import SyntheticImages
from repro.models.graph import (
    build_resnet18, collect_conv_traffic, net_apply, sparsify,
)
from repro.models.layers import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=64,
                    help="image resolution (224 = ImageNet scale)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--impl", choices=("jnp", "pallas"), default="jnp")
    ap.add_argument("--classes", type=int, default=CONFIG.num_classes)
    args = ap.parse_args()

    print(f"== ResNet-18 vector-sparse inference @ {args.size}px, "
          f"density {CONFIG.weight_density} ==")
    net = build_resnet18(args.classes, image_size=args.size)
    params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
    sparse, pruned = sparsify(net, params, CONFIG.weight_density,
                              vk=CONFIG.vk, vn=CONFIG.vn)
    n_conv = len(net.conv_layers())
    print(f"sparsified {len(sparse)} layers — every conv ({n_conv}/{n_conv}, "
          f"BN folded, residuals fused in-epilogue) + the {args.classes}-class "
          f"head (remainder strip) run the vector-sparse path")

    data = SyntheticImages(args.batch, size=args.size)
    imgs = jnp.asarray(data.batch_at(0)["images"])

    dense_fn = jax.jit(lambda x: net_apply(net, pruned, x))
    sparse_fn = jax.jit(lambda x: net_apply(net, params, x, sparse=sparse,
                                            impl=args.impl))
    y_dense = dense_fn(imgs)
    t0 = time.time()
    y_sparse = sparse_fn(imgs)
    y_sparse.block_until_ready()
    dt = time.time() - t0
    rel = float(jnp.abs(y_sparse - y_dense).max() / jnp.abs(y_dense).max())
    print(f"sparse ({args.impl}) vs folded-pruned dense: rel err {rel:.2e}  "
          f"({dt*1e3:.0f} ms for batch {args.batch})")

    # per-layer accelerator cycle accounting for the same traffic — the
    # graph walk VGG-16 shares
    traffic = collect_conv_traffic(net, pruned, imgs[:1])
    for pe in CONFIG.pe_configs:
        reports = network_cycle_reports(traffic, pe)
        agg = aggregate([r for _, r in reports])
        worst = min(reports, key=lambda nr: nr[1].speedup)
        best = max(reports, key=lambda nr: nr[1].speedup)
        print(f"PE [{pe.blocks},{pe.rows},{pe.cols}]: "
              f"{agg.speedup:.2f}x speedup over dense "
              f"({agg.vscnn:,} vs {agg.dense:,} cycles; "
              f"best layer {best[0]} {best[1].speedup:.2f}x, "
              f"worst {worst[0]} {worst[1].speedup:.2f}x)")


if __name__ == "__main__":
    main()
