"""Batched serving on CPU (reduced configs) through the lockstep scheduler.

LM mode (default): the production prefill/decode jits with continuous
batching — EOS/budget retirement and in-run slot backfill — the same step
functions the decode_32k / long_500k dry-run cells lower on the 512-chip
mesh.

CNN mode (--cnn): image requests through `SparseNet.apply` on the
vector-sparse datapath, batches padded/bucketed on image shape, freed slots
backfilled from the queue so the compiled batch shape is reused wave after
wave.

Multi-device: ``--replicas N`` serves a data-parallel replica fleet (one
device-placed weight copy per replica, per-replica wave dispatch, work
stealing); ``--shard-fc`` additionally cout-shards the FC heads over each
replica's leftover devices.  On a CPU-only box fake a mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b
      PYTHONPATH=src python examples/serve_batched.py --cnn vscnn-vgg16
      XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/serve_batched.py --cnn vscnn-vgg16 \\
          --replicas 4 --shard-fc
"""
import argparse

import numpy as np

from repro.configs import get_config, list_archs, list_cnn_archs
from repro.launch.serve import CNNServer, ImageRequest, Request, Server


def serve_cnn(args) -> None:
    cfg = get_config(args.cnn).reduce()
    rng = np.random.default_rng(0)
    s = cfg.image_size
    # mixed sizes exercise the shape bucketing; fixed-input nets (VGG) pad
    # everything up to image_size, size-agnostic nets (ResNet) get one
    # bucket per padded shape
    sizes = [s if i % 3 else max(8, s // 2) for i in range(args.requests)]
    reqs = [ImageRequest(rid=i,
                         image=rng.standard_normal((sz, sz, 3))
                                  .astype(np.float32))
            for i, sz in enumerate(sizes)]
    srv = CNNServer(cfg, batch=args.batch, replicas=args.replicas,
                    shard_fc=args.shard_fc)
    stats = srv.serve(reqs)
    total = sum(st["images"] for st in stats)
    run_s = sum(st["run_s"] for st in stats)
    backfills = sum(st["backfills"] for st in stats)
    used = sorted({st.get("replica", 0) for st in stats})
    print(f"served {total} images in {len(stats)} lockstep runs "
          f"({backfills} backfills), {total / max(run_s, 1e-9):.1f} img/s "
          f"(density {srv.density}, replicas used {used}, "
          f"shard_fc={args.shard_fc}; CPU, reduced config)")
    print("first request prediction:", reqs[0].out)


def serve_lm(args) -> None:
    cfg = get_config(args.arch).reduce()
    if not cfg.embed_inputs or cfg.encoder_only:
        raise SystemExit(f"{cfg.name}: choose a token-input decoder arch")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(8, 40)),
                                        dtype=np.int32),
                    max_new=args.tokens,
                    temperature=args.temperature, top_k=args.top_k)
            for i in range(args.requests)]
    srv = Server(cfg, batch=args.batch, capacity=80)
    stats = srv.serve(reqs)
    total = sum(s["new_tokens"] for s in stats)
    dec_s = sum(s["decode_s"] for s in stats)
    backfills = sum(s["backfills"] for s in stats)
    print(f"served {args.requests} requests in {len(stats)} lockstep runs "
          f"({backfills} backfills)")
    print(f"{total} tokens generated, decode throughput "
          f"{total / max(dec_s, 1e-9):.1f} tok/s (CPU, reduced config)")
    print("first request output:", reqs[0].out[:12], "...")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b", choices=list_archs())
    ap.add_argument("--cnn", default=None, choices=list_cnn_archs(),
                    help="serve a CNN arch through SparseNet.apply instead "
                         "of the LM stack")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--replicas", type=int, default=1,
                    help="CNN mode: data-parallel replica fleet size")
    ap.add_argument("--shard-fc", action="store_true",
                    help="CNN mode: cout-shard FC heads over each "
                         "replica's model devices")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="LM mode: sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="LM mode: top-k cutoff (0 = full vocab)")
    args = ap.parse_args()
    if args.cnn:
        serve_cnn(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
