"""Batched LM serving on CPU (reduced config): the production prefill/decode
jits with lockstep batching and slot retirement — the same step functions
the decode_32k / long_500k dry-run cells lower on the 512-chip mesh.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b
"""
import argparse

import numpy as np

from repro.configs import get_config, list_archs
from repro.launch.serve import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduce()
    if not cfg.embed_inputs or cfg.encoder_only:
        raise SystemExit(f"{cfg.name}: choose a token-input decoder arch")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        int(rng.integers(8, 40)),
                                        dtype=np.int32),
                    max_new=args.tokens)
            for i in range(args.requests)]
    srv = Server(cfg, batch=args.batch, capacity=80)
    stats = srv.serve(reqs)
    total = sum(s["new_tokens"] for s in stats)
    dec_s = sum(s["decode_s"] for s in stats)
    print(f"served {args.requests} requests in {len(stats)} lockstep batches")
    print(f"{total} tokens generated, decode throughput "
          f"{total / max(dec_s, 1e-9):.1f} tok/s (CPU, reduced config)")
    print("first request output:", reqs[0].out[:12], "...")


if __name__ == "__main__":
    main()
