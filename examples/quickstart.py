"""Quickstart: the paper's vector sparsity in five steps.

1. take a weight matrix, 2. vector-prune it (Mao-style, balanced),
3. encode to the VectorSparse block-CSR, 4. multiply through the structural
sparse op / Pallas kernel, 5. count accelerator cycles with the
cycle-accurate PE model (Table I).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    PE_4_14_3, conv_layer_cycles, encode, prune_vectors_balanced, vs_matmul,
)
from repro.core.accel_model import table1_example
from repro.kernels import vsmm


def main():
    rng = np.random.default_rng(0)

    # 1-2. prune a (K, N) matmul weight to 25% vector density
    w = rng.standard_normal((512, 1024)).astype(np.float32)
    w_pruned, mask = prune_vectors_balanced(w, density=0.25, vk=32, vn=128)
    print(f"kept {mask.mean():.1%} of (32x128) weight vectors")

    # 3. encode: only nonzero vectors are stored (the paper's SRAM rule)
    vs = encode(jnp.asarray(w_pruned), vk=32, vn=128)
    print(f"VectorSparse: {vs.n_strips} strips x {vs.nnz_per_strip} vectors, "
          f"density {vs.density:.2f}")

    # 4. multiply — structural path and Pallas TPU kernel agree with dense
    x = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
    y_dense = x @ jnp.asarray(w_pruned)
    y_jnp = vs_matmul(x, vs)                  # GSPMD-friendly structural op
    y_pallas = vsmm(x, vs)                    # scalar-prefetch TPU kernel
    for name, y in (("structural", y_jnp), ("pallas", y_pallas)):
        err = float(jnp.abs(y - y_dense).max() / jnp.abs(y_dense).max())
        print(f"{name:10s} matches dense: rel err {err:.2e}")

    # 5. the paper's cycle accounting (Table I micro example: 15 -> 8)
    r = table1_example()
    print(f"Table I:  dense {r.dense} cycles, VSCNN {r.vscnn} cycles "
          f"({1 - r.vscnn / r.dense:.0%} saved — paper says 47%)")

    # and a realistic conv layer on the [4,14,3] PE array (width mapping —
    # the block assignment that reproduces the paper's Figs 12-13)
    import dataclasses
    from repro.core import prune_conv_columns
    x_act = np.maximum(rng.standard_normal((28, 28, 64)), 0)  # post-ReLU
    w_conv = prune_conv_columns(rng.standard_normal((3, 3, 64, 128)), 0.4)
    pe = dataclasses.replace(PE_4_14_3, block_map="width")
    rep = conv_layer_cycles(x_act, w_conv, pe)
    print(f"conv 28x28x64->128 on [4,14,3]: {rep.speedup:.2f}x speedup over "
          f"dense ({rep.vscnn}/{rep.dense} cycles)")


if __name__ == "__main__":
    main()
