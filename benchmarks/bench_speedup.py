"""Paper Figs 12-13 + §IV: VGG-16 speedup of VSCNN over dense execution on
both 168-PE configurations, against ideal-vector and ideal-fine bounds.

Methodology (mirrors §IV): VGG-16 weights magnitude-pruned to 23.5% element
density (the paper's [18] operating point); input activations are the
network's real post-ReLU responses on natural-statistics images; the
cycle-accurate PE-array model (core.accel_model) executes every conv layer
on [4,14,3] and [8,7,3], skipping absent input/weight vectors.

Validation band: paper reports 1.871x / 1.93x overall speedup, exploiting
92% / 85% of ideal vector-sparse zeros and 46.6% / 47.1% of ideal
fine-grained zeros.
"""
from __future__ import annotations

from repro.core.accel_model import PEConfig, aggregate, conv_layer_cycles
from .bench_density import vgg_traffic


def run(image_size: int = 224) -> list[dict]:
    traffic = vgg_traffic(image_size=image_size)
    rows = []
    for pe, paper_speed, paper_fv, paper_ff in (
        (PEConfig(4, 14, 3, block_map="width"), 1.871, 0.92, 0.466),
        (PEConfig(8, 7, 3, block_map="width"), 1.93, 0.85, 0.471),
    ):
        reports = []
        for name, x, w in traffic:
            r = conv_layer_cycles(x[0], w, pe)
            reports.append(r)
            rows.append({
                "name": f"speedup_[{pe.blocks},{pe.rows},{pe.cols}]_{name}",
                "dense_cycles": r.dense,
                "vscnn_cycles": r.vscnn,
                "speedup": round(r.speedup, 3),
                "ideal_vector_speedup": round(r.dense / max(r.ideal_vector, 1), 3),
                "ideal_fine_speedup": round(r.dense / max(r.ideal_fine, 1), 3),
            })
        agg = aggregate(reports)
        rows.append({
            "name": f"speedup_[{pe.blocks},{pe.rows},{pe.cols}]_TOTAL",
            "dense_cycles": agg.dense,
            "vscnn_cycles": agg.vscnn,
            "speedup": round(agg.speedup, 3),
            "paper_speedup": paper_speed,
            "ideal_vector_speedup": round(agg.dense / agg.ideal_vector, 3),
            "ideal_fine_speedup": round(agg.dense / agg.ideal_fine, 3),
            "frac_ideal_vector_exploited":
                round(agg.frac_ideal_vector_exploited, 3),
            "paper_frac_ideal_vector": paper_fv,
            "frac_ideal_fine_exploited":
                round(agg.frac_ideal_fine_exploited, 3),
            "paper_frac_ideal_fine": paper_ff,
            "in_validation_band": bool(1.6 <= agg.speedup <= 2.3),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
