"""Per-layer density hillclimb against a modeled cycle/byte budget.

The ROADMAP "accuracy-vs-density frontier" item needs a search loop that
assigns each conv layer its own density instead of one uniform knob:
prune the layers whose modeled cost drops fastest per unit of weight
kept, until the whole net fits a budget.  This driver is that loop over
the *static* cost model (`core.accel_model.conv_layer_traffic` at the
geometry `repro.analysis.ir.check_net` derives) — no weights and no
execution, so it runs anywhere CI runs.  The accuracy term is a
placeholder (`kept_weight_fraction`) until the pretrained-checkpoint
importer lands; swap `score_fn` for a real eval then.

Usage:
  python benchmarks/hillclimb.py --net resnet18 --budget 0.5 \
      --out benchmarks/results/hillclimb.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.analysis.ir import ConvSite, check_net          # noqa: E402
from repro.core.accel_model import conv_layer_traffic      # noqa: E402
from repro.models.graph import SparseNet, strip_steps      # noqa: E402

DENSITY_STEPS = (1.0, 0.75, 0.5, 0.375, 0.25, 0.125)

# activation / weight / output itemsizes per dtype contract — the same
# axis `core.accel_model.conv_layer_traffic` and the kernel CostEstimates
# carry (int8 halves nothing by luck: in/weight streams drop to 1 byte,
# the f32 output stream stays 4)
DTYPES = {"f32": (4, 4, 4), "int8": (1, 1, 4)}


@dataclasses.dataclass
class LayerState:
    """One conv layer's knob position in the search."""

    site: ConvSite
    step: int  # index into DENSITY_STEPS
    itemsize: int = 4       # activation bytes/element
    w_itemsize: int = 4     # stored weight bytes/element
    out_itemsize: int = 4   # output bytes/element

    @property
    def density(self) -> float:
        return DENSITY_STEPS[self.step]

    def bytes_at(self, step: int, *, impl: str = "halo") -> int:
        s = strip_steps(self.site.geom.kb, DENSITY_STEPS[step],
                        prune=True)
        tr = conv_layer_traffic(
            self.site.x_shape, kh=self.site.kh, kw=self.site.kw,
            stride=self.site.stride, groups=self.site.groups,
            dilation=self.site.dilation, cout=self.site.cout, s_steps=s,
            vk=self.site.geom.vk, vn=self.site.geom.vn, impl=impl,
            itemsize=self.itemsize, w_itemsize=self.w_itemsize,
            out_itemsize=self.out_itemsize,
            residual=self.site.has_residual)
        return tr.bytes_accessed


def kept_tiles(layers: list[LayerState]) -> int:
    """Stored weight tiles (vectors) kept across the net at the current
    knob positions."""
    return sum(
        st.site.geom.nb * strip_steps(st.site.geom.kb, st.density,
                                      prune=True)
        for st in layers)


def kept_weight_fraction(layers: list[LayerState]) -> float:
    """Accuracy placeholder: the fraction of stored weight tiles kept,
    weighted by tile count.  Replace with a real eval once the
    checkpoint importer (ROADMAP) lands."""
    total = sum(st.site.geom.nb * st.site.geom.kb for st in layers)
    return kept_tiles(layers) / max(total, 1)


def hillclimb(net: SparseNet, *, size: int, batch: int, budget: float = 0.5,
              budget_bytes: int | None = None, dtype: str = "f32",
              impl: str = "halo", verbose: bool = True) -> dict:
    """Greedy coordinate descent: repeatedly prune the layer whose next
    density step buys the most modeled bytes per kept-weight point, until
    total modeled bytes <= ``budget`` x the dense-density total (or
    ``budget_bytes``, an absolute target that lets searches under
    different dtype contracts be compared at the same byte spend —
    an int8 search at the same absolute budget keeps more vectors).
    ``dtype`` picks the itemsize contract the modeled bytes use."""
    a_i, w_i, o_i = DTYPES[dtype]
    nc = check_net(net, (batch, size, size, 3), density=1.0)
    nc.report.raise_errors()
    layers = [LayerState(site=s, step=0, itemsize=a_i, w_itemsize=w_i,
                         out_itemsize=o_i) for s in nc.conv_sites]
    start = sum(st.bytes_at(st.step, impl=impl) for st in layers)
    target = int(start * budget) if budget_bytes is None else budget_bytes
    total = start
    while total > target:
        best, best_gain = None, 0.0
        for st in layers:
            if st.step + 1 >= len(DENSITY_STEPS):
                continue
            gain = st.bytes_at(st.step, impl=impl) \
                - st.bytes_at(st.step + 1, impl=impl)
            if gain > best_gain:
                best, best_gain = st, gain
        if best is None:  # every knob at the floor; budget unreachable
            break
        best.step += 1
        total -= int(best_gain)
        if verbose:
            print(f"  {best.site.path:<40} -> density {best.density:<6} "
                  f"total {total / start:.3f}x dense")
    return {
        "net": net.name,
        "impl": impl,
        "dtype": dtype,
        "budget": budget if budget_bytes is None else None,
        "budget_bytes": budget_bytes,
        "reached": total / start,
        "start_bytes": start,
        "total_bytes": total,
        "kept_tiles": kept_tiles(layers),
        "kept_weight_fraction": round(kept_weight_fraction(layers), 4),
        "densities": {st.site.name: st.density for st in layers},
    }


def main(argv: list[str] | None = None) -> int:
    from repro.analysis.__main__ import NETS

    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--net", choices=sorted(NETS), default="resnet18")
    p.add_argument("--size", type=int, default=32)
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--budget", type=float, default=0.5,
                   help="target modeled-bytes fraction of density-1.0")
    p.add_argument("--budget-bytes", type=int, default=None,
                   help="absolute modeled-bytes target (overrides "
                        "--budget; comparable across --dtype contracts)")
    p.add_argument("--dtype", choices=sorted(DTYPES), default="f32",
                   help="itemsize contract for the modeled bytes")
    p.add_argument("--impl", choices=("halo", "stack"), default="halo")
    p.add_argument("--out", default="")
    args = p.parse_args(argv)

    row = hillclimb(NETS[args.net](image_size=args.size), size=args.size,
                    batch=args.batch, budget=args.budget,
                    budget_bytes=args.budget_bytes, dtype=args.dtype,
                    impl=args.impl)
    print(json.dumps(row, indent=1))
    if args.out:
        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        rows = json.loads(out.read_text()) if out.exists() else []
        rows.append(row)
        out.write_text(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
