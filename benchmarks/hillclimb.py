import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""§Perf hillclimb driver: re-lower the three selected cells under config
deltas (hypothesis -> change -> re-analyse), appending tagged rows to
benchmarks/results/hillclimb.json.  Each row carries the full roofline terms
so EXPERIMENTS.md §Perf can show before/after per iteration.

Cells (selection rationale in EXPERIMENTS.md):
  A nemotron-4-340b x train_4k  — paper-representative (squared-ReLU input
    sparsity) + biggest absolute step time
  B kimi-k2-1t     x decode_32k — most collective-bound cell
  C granite-moe-3b x train_4k   — worst roofline fraction (large cells)
"""
import json
import traceback

from repro.launch.dryrun import run_cell

MATRIX = [
    # (arch, shape, tag, overrides)
    ("nemotron-4-340b", "train_4k", "A0_baseline", {"microbatches": 1}),
    ("nemotron-4-340b", "train_4k", "A1_mb64", {"microbatches": 64}),
    ("nemotron-4-340b", "train_4k", "A2_mb64_bf16flow",
     {"microbatches": 64, "bf16_flow": True}),
    ("nemotron-4-340b", "train_4k", "A3_mb64_bf16_fremat",
     {"microbatches": 64, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A4_mb16_bf16_fremat",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True}),
    ("kimi-k2-1t-a32b", "decode_32k", "B0_baseline", {}),
    ("kimi-k2-1t-a32b", "decode_32k", "B1_resident",
     {"moe_dispatch": "resident"}),
    ("kimi-k2-1t-a32b", "decode_32k", "B2_resident_bf16",
     {"moe_dispatch": "resident", "bf16_flow": True}),
    ("granite-moe-3b-a800m", "train_4k", "C0_baseline", {"microbatches": 1}),
    ("granite-moe-3b-a800m", "train_4k", "C1_bf16flow",
     {"microbatches": 1, "bf16_flow": True}),
    ("granite-moe-3b-a800m", "train_4k", "C2_bf16_fremat",
     {"microbatches": 1, "bf16_flow": True, "flash_remat": True}),
    ("granite-moe-3b-a800m", "train_4k", "C3_bf16_fremat_mb4",
     {"microbatches": 4, "bf16_flow": True, "flash_remat": True}),
    # iteration 2: pin projection-output sharding (gather AFTER the dot);
    # fixes GSPMD computing K/V projections replicated over the model axis
    ("granite-moe-3b-a800m", "train_4k", "C4_projpin_bf16",
     {"microbatches": 1, "bf16_flow": True}),
    ("granite-moe-3b-a800m", "train_4k", "C5_projpin_bf16_fremat_mb4",
     {"microbatches": 4, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A5_projpin_mb16_bf16_fremat",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A6_projpin_mb32_bf16_fremat",
     {"microbatches": 32, "bf16_flow": True, "flash_remat": True}),
    # iteration 3: cast-boundary fixes (bf16 cotangents before TP psums)
    ("granite-moe-3b-a800m", "train_4k", "C6_castfix_bf16_fremat",
     {"microbatches": 1, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A7_castfix_mb16_bf16_fremat",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A8_castfix_mb16_bf16acc",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True,
      "grad_accum_dtype": "bfloat16"}),
    ("kimi-k2-1t-a32b", "decode_32k", "B3_resident_castfix",
     {"moe_dispatch": "resident", "bf16_flow": True}),
    # iteration 4: grad-accumulator sharding pin + Megatron-SP residuals
    ("nemotron-4-340b", "train_4k", "A9_gpin_mb16_bf16_fremat",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True}),
    ("nemotron-4-340b", "train_4k", "A10_gpin_seqres_mb16",
     {"microbatches": 16, "bf16_flow": True, "flash_remat": True,
      "seq_shard_residual": True}),
    ("granite-moe-3b-a800m", "train_4k", "C7_seqres_bf16_fremat",
     {"microbatches": 1, "bf16_flow": True, "flash_remat": True,
      "seq_shard_residual": True}),
    # paper-representative: vector-sparse FFN in the serve path (23.5%)
    ("nemotron-4-340b", "prefill_32k", "P0_dense_prefill", {}),
    ("nemotron-4-340b", "prefill_32k", "P1_sparse_ffn_prefill",
     {"use_sparse_ffn": True}),
    ("nemotron-4-340b", "prefill_32k", "P2_sparse_ffn_bf16",
     {"use_sparse_ffn": True, "bf16_flow": True}),
]


def main():
    out = "benchmarks/results/hillclimb.json"
    rows = []
    if os.path.exists(out):
        rows = json.load(open(out))
    done = {r.get("tag") for r in rows}
    for arch, shape, tag, ov in MATRIX:
        if tag in done:
            print(f"skip {tag} (done)")
            continue
        print(f"=== {tag}: {arch} x {shape} {ov}", flush=True)
        try:
            row = run_cell(arch, shape, overrides=ov, tag=tag)
        except Exception as e:
            traceback.print_exc()
            row = {"arch": arch, "shape": shape, "tag": tag, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
        rows.append(row)
        with open(out, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    print("hillclimb matrix complete")


if __name__ == "__main__":
    main()
