"""Calibration CLI: measure wall-clock per layer, fit the cost model.

The modeled numbers in ``BENCH_<net>.json`` (cycles, bytes, arithmetic
intensity) come from the analytic accelerator model; this tool closes the
measured-vs-modeled loop (`repro.core.calibration`):

1. Default run: walk every conv/FC layer of the registered nets (VGG-16,
   ResNet-18/34/50, MobileNetV1 at the reduced CI geometry) through the
   structural sparse path as standalone jitted functions, recording
   median-of-k wall clock, compiled-HLO FLOPs/bytes (`utils.hlo.analyze`,
   trip-count aware) and the analytic model's numbers side by side.
2. ``--fit``: non-negative least squares over those measurements fits the
   time model's free constants (cycle time, per-tap overhead, vsmm flush
   cost, DMA overlap, dispatch floor) and writes the calibration artifact
   — constants + fit settings + every per-layer record with its
   ``predicted_us`` — to ``benchmarks/baselines/CALIB_<backend>.json``
   (committed; ``accel_model.load_calibration`` picks it up).
3. ``--gate-calibration``: the CI drift gate.  Re-measures the fast gated
   layer subset and fails the build when prediction error leaves the band:
   bit-exact round-trip of stored constants -> stored predictions, a tight
   band (default 2%) on deterministic HLO/model features, and a wide
   machine-normalized band (default 4x) on fresh wall clock.  Per-layer
   delta table goes to ``$GITHUB_STEP_SUMMARY`` when set.

Run with ``PYTHONPATH=src`` from the repo root, like the other benches.
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (
    attach_predictions,
    compare_calibration,
    default_calib_path,
    fit_constants,
    load_calibration_file,
    measured_vs_modeled_records,
    save_calibration,
)

# Reduced CI geometry — matches the BENCH_<net>.json baselines, so the
# calibration rows describe the same layers CI already tracks.
IMAGE_SIZE = 32
NUM_CLASSES = 200
DEFAULT_DENSITY = 0.5
DEFAULT_NETS = ("vgg16", "resnet18", "resnet34", "resnet50", "mobilenet_v1")
# The gate re-measures one small net: ~20 layers, a few seconds of CI time,
# but every feature family (7x7 stem, 3x3, 1x1 projection, stride-2
# downsample, FC head) appears in the subset.
GATE_NET = "resnet18"


def _builders() -> dict:
    from repro.models.graph import (
        build_mobilenet_v1, build_resnet18, build_resnet34, build_resnet50,
        build_vgg16,
    )
    return {
        "vgg16": build_vgg16,
        "resnet18": build_resnet18,
        "resnet34": build_resnet34,
        "resnet50": build_resnet50,
        "mobilenet_v1": build_mobilenet_v1,
    }


def collect_records(nets=DEFAULT_NETS, *, density: float = DEFAULT_DENSITY,
                    repeats: int = 5, warmup: int = 2,
                    layers: set[str] | None = None,
                    measure: bool = True) -> list[dict]:
    """Measured-vs-modeled rows for every conv/FC layer of ``nets``."""
    from repro.models.layers import init_params

    builders = _builders()
    rows: list[dict] = []
    for i, name in enumerate(nets):
        net = builders[name](NUM_CLASSES, image_size=IMAGE_SIZE)
        if layers is not None and not any(
                ln.startswith(f"{net.name}/") for ln in layers):
            continue
        params = init_params(net.schema(), jax.random.PRNGKey(i), jnp.float32)
        rng = np.random.default_rng(100 + i)
        x = jnp.asarray(
            rng.standard_normal((1, IMAGE_SIZE, IMAGE_SIZE, 3)), jnp.float32)
        rows += measured_vs_modeled_records(
            net, params, x, density=density, repeats=repeats, warmup=warmup,
            layers=layers, measure=measure)
    return rows


def run_fit(out_path: str | None, *, nets=DEFAULT_NETS,
            density: float = DEFAULT_DENSITY, repeats: int = 5,
            warmup: int = 2) -> int:
    """Measure everything, fit the constants, write the artifact."""
    backend = jax.default_backend()
    rows = collect_records(nets, density=density, repeats=repeats,
                           warmup=warmup)
    constants = fit_constants(
        [r["features"] for r in rows],
        [r["measured_us"] * 1e-6 for r in rows],
        backend=backend)
    attach_predictions(rows, constants)
    path = out_path or default_calib_path(backend)
    gate_layers = [r["name"] for r in rows if r["net"] == GATE_NET]
    save_calibration(
        path, constants, rows,
        fit_settings={
            "nets": list(nets),
            "image_size": IMAGE_SIZE,
            "num_classes": NUM_CLASSES,
            "density": density,
            "repeats": repeats,
            "warmup": warmup,
            "weighting": "relative",
            "jax": jax.__version__,
        },
        gate_layers=gate_layers)
    print(f"fitted {backend} constants over {len(rows)} layers "
          f"({len(nets)} nets):")
    for k, v in constants.to_dict().items():
        print(f"  {k:>18}: {v}")
    ratios = sorted(r["measured_us"] / max(r["predicted_us"], 1e-9)
                    for r in rows)
    print(f"measured/predicted ratio: min {ratios[0]:.2f} / median "
          f"{ratios[len(ratios) // 2]:.2f} / max {ratios[-1]:.2f}")
    print(f"wrote {path} (gate subset: {len(gate_layers)} {GATE_NET} layers)")
    return 0


def gate_calibration(baseline_path: str | None, *, band: float = 4.0,
                     feature_tol: float = 0.02, repeats: int = 5,
                     warmup: int = 2) -> int:
    """CI drift gate: re-measure the gated subset vs the committed calib."""
    backend = jax.default_backend()
    path = baseline_path or default_calib_path(backend)
    calib = load_calibration_file(path)
    fit = calib.get("fit", {})
    gate_layers = set(calib["gate_layers"])
    fresh = collect_records(
        tuple(fit.get("nets", DEFAULT_NETS)),
        density=fit.get("density", DEFAULT_DENSITY),
        repeats=repeats, warmup=warmup, layers=gate_layers)
    failures, lines = compare_calibration(
        fresh, calib, feature_tol=feature_tol, band=band)
    summary = "\n".join(
        [f"## Calibration drift gate — `{path}` "
         f"({'FAIL' if failures else 'PASS'})", ""]
        + lines + [""]
        + [f"- {f}" for f in failures])
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary + "\n")
    print(summary)
    if failures:
        print(f"calibration gate: FAIL ({len(failures)} drift(s))")
        return 1
    print("calibration gate: PASS")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    ap.add_argument("--fit", action="store_true",
                    help="fit the model constants to fresh measurements and "
                         "write benchmarks/baselines/CALIB_<backend>.json")
    ap.add_argument("--gate-calibration", action="store_true",
                    help="CI drift gate: re-measure the gated layer subset "
                         "and fail if prediction error leaves the band")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="calibration artifact to fit into / gate against "
                         "(default: benchmarks/baselines/CALIB_<backend>"
                         ".json)")
    ap.add_argument("--nets", default=",".join(DEFAULT_NETS),
                    help="comma-separated net list for measurement/fit")
    ap.add_argument("--density", type=float, default=DEFAULT_DENSITY)
    ap.add_argument("--repeats", type=int, default=5,
                    help="median-of-k wall-clock repeats per layer")
    ap.add_argument("--warmup", type=int, default=2)
    ap.add_argument("--band", type=float, default=4.0,
                    help="wall-clock band (x) for --gate-calibration")
    ap.add_argument("--feature-tol", type=float, default=0.02,
                    help="tight relative band for deterministic features")
    args = ap.parse_args()
    nets = tuple(n for n in args.nets.split(",") if n)
    if args.gate_calibration:
        raise SystemExit(gate_calibration(
            args.baseline, band=args.band, feature_tol=args.feature_tol,
            repeats=args.repeats, warmup=args.warmup))
    if args.fit:
        raise SystemExit(run_fit(
            args.baseline, nets=nets, density=args.density,
            repeats=args.repeats, warmup=args.warmup))
    for r in collect_records(nets, density=args.density,
                             repeats=args.repeats, warmup=args.warmup):
        print(json.dumps(r))
