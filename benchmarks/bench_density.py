"""Paper Figs 9-11: per-layer density of inputs / weights / work, at
fine-grained vs vector granularity, on VGG-16 with real post-ReLU traffic.

Weights: magnitude-pruned to the paper's 23.5% element density.  At the
accelerator's vector granularity (ky kernel columns for weights, R-row
activation columns for inputs) the observable density is higher — exactly
the fine-vs-vector gap Figs 9-11 plot.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.vscnn_vgg16 import CONFIG
from repro.data import SyntheticImages
from repro.models.cnn import collect_conv_traffic, vgg16_schema
from repro.models.layers import init_params


def magnitude_prune(w: np.ndarray, density: float) -> np.ndarray:
    flat = np.abs(w).ravel()
    keep = max(1, int(round(flat.size * density)))
    thresh = np.partition(flat, flat.size - keep)[flat.size - keep]
    return (w * (np.abs(w) >= thresh)).astype(w.dtype)


def vgg_traffic(image_size: int = 224, batch: int = 1, seed: int = 0,
                density: float | None = None):
    """(name, input acts NHWC, pruned weights) per conv layer."""
    density = density if density is not None else CONFIG.weight_density
    params = init_params(vgg16_schema(CONFIG.num_classes,
                                      image_size=image_size),
                         jax.random.PRNGKey(seed), jnp.float32)
    img = SyntheticImages(batch, size=image_size, seed=seed).batch_at(0)
    rec = collect_conv_traffic(params, jnp.asarray(img["images"]))
    out = []
    for name, x, w in rec:
        wp = magnitude_prune(np.asarray(w, np.float32), density)
        out.append((name, np.asarray(x, np.float32), wp))
    return out


def densities_for_layer(x: np.ndarray, w: np.ndarray, rows: int) -> dict:
    """x (N,H,W,Cin) post-ReLU inputs, w (3,3,Cin,Cout) pruned weights."""
    x_nz = x[0] != 0
    w_nz = w != 0
    h, wid, cin = x_nz.shape
    hc = math.ceil(h / rows)
    pad = hc * rows - h
    xp = np.concatenate([x_nz, np.zeros((pad, wid, cin), bool)]) if pad else x_nz
    iv = xp.reshape(hc, rows, wid, cin).any(axis=1)
    wv = w_nz.any(axis=0)  # ky-column occupancy
    return {
        "input_fine": float(x_nz.mean()),
        "input_vector": float(iv.mean()),
        "weight_fine": float(w_nz.mean()),
        "weight_vector": float(wv.mean()),
        "work_fine": float(x_nz.mean() * w_nz.mean()),
        "work_vector": float(iv.mean() * wv.mean()),
    }


def run(image_size: int = 224) -> list[dict]:
    rows = []
    traffic = vgg_traffic(image_size=image_size)
    for pe_rows, tag in ((14, "R14"), (7, "R7")):
        for name, x, w in traffic:
            d = densities_for_layer(x, w, pe_rows)
            rows.append({"name": f"density_{tag}_{name}", **{
                k: round(v, 4) for k, v in d.items()}})
        agg = {k: float(np.mean([r[k] for r in rows
                                 if r["name"].startswith(f"density_{tag}")]))
               for k in ("input_fine", "input_vector", "weight_fine",
                          "weight_vector", "work_fine", "work_vector")}
        rows.append({"name": f"density_{tag}_MEAN",
                     **{k: round(v, 4) for k, v in agg.items()}})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
