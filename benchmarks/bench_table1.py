"""Paper Table I: the 5x5 / 3x3 micro example — 15 dense cycles, 8 sparse."""
from __future__ import annotations

import time

from repro.core.accel_model import table1_example


def run() -> list[dict]:
    t0 = time.time()
    r = table1_example()
    us = (time.time() - t0) * 1e6
    rows = [{
        "name": "table1_micro_example",
        "us_per_call": round(us, 1),
        "dense_cycles": r.dense,
        "vscnn_cycles": r.vscnn,
        "paper_dense_cycles": 15,
        "paper_vscnn_cycles": 8,
        "saving": round(1 - r.vscnn / r.dense, 4),
        "paper_saving": 0.47,
        "match": r.dense == 15 and r.vscnn == 8,
    }]
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
