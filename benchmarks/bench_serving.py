"""CNN serving-path benchmark: images/s vs density vs batch size.

Drives the batched CNN backend (`launch.serve.CNNServer`) end to end —
queue, bucketing, slot retirement, backfill, jit-cached `SparseNet.apply` —
and reports steady-state throughput for the dense-jnp baseline (plain XLA
convs) and the vector-sparse structural path at several densities.  CPU
numbers demonstrate work ∝ density and batch amortization on a real
backend, not the TPU claim (same caveat as bench_kernels).

Each (path, density, batch) cell serves a warmup wave first so the compile
cost of the batch bucket is off the clock — the steady state is what a
serving deployment sees.

Writes a ``BENCH_serving.json`` artifact (--out) with per-cell rows plus a
summary checking that batched sparse throughput >= batch-1 throughput at
equal density.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --arch vscnn-vgg16
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.configs import get_config
from repro.launch.serve import CNNServer, ImageRequest


def _requests(rng, n: int, size: int) -> list[ImageRequest]:
    return [ImageRequest(rid=i,
                         image=rng.standard_normal((size, size, 3))
                                  .astype(np.float32))
            for i in range(n)]


def _throughput(srv: CNNServer, rng, n: int, size: int, batch: int) -> dict:
    srv.serve(_requests(rng, batch, size))          # warmup: compile bucket
    stats = srv.serve(_requests(rng, n, size))
    run_s = sum(s["run_s"] for s in stats)
    return {
        "images_per_s": round(n / max(run_s, 1e-9), 2),
        "run_s": round(run_s, 4),
        "runs": len(stats),
        "steps": sum(s["steps"] for s in stats),
        "backfills": sum(s["backfills"] for s in stats),
        "compiles": srv.backend.apply.compiles,
    }


def run(arch: str = "vscnn-vgg16", *, densities=(1.0, 0.5, 0.235),
        batches=(1, 4, 8), images: int = 24, size: int | None = None,
        out_path: str | None = None) -> dict:
    cfg = get_config(arch).reduce()
    size = size or cfg.image_size
    rng = np.random.default_rng(0)
    rows = []
    for batch in batches:
        srv = CNNServer(cfg, batch=batch, sparse=False)
        rows.append({"path": "dense-jnp", "density": 1.0, "batch": batch,
                     **_throughput(srv, rng, images, size, batch)})
        for density in densities:
            srv = CNNServer(cfg, batch=batch, density=density)
            rows.append({"path": "sparse-jnp", "density": density,
                         "batch": batch,
                         **_throughput(srv, rng, images, size, batch)})
    # batched throughput must beat (or match) batch-1 at equal density
    summary = {}
    max_batch = max(batches)
    for density in densities:
        cells = {r["batch"]: r["images_per_s"] for r in rows
                 if r["path"] == "sparse-jnp" and r["density"] == density}
        summary[str(density)] = {
            "batch1_images_per_s": cells.get(1),
            "batched_images_per_s": cells.get(max_batch),
            "batched_ge_batch1": (cells.get(max_batch, 0.0)
                                  >= cells.get(1, float("inf"))),
        }
    artifact = {
        "bench": "cnn_serving",
        "arch": arch,
        "image_size": size,
        "images": images,
        "batches": list(batches),
        "densities": list(densities),
        "rows": rows,
        "summary": summary,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return artifact


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vscnn-vgg16")
    ap.add_argument("--images", type=int, default=24)
    ap.add_argument("--size", type=int, default=None,
                    help="override the reduced config's image size")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--densities", type=float, nargs="+",
                    default=[1.0, 0.5, 0.235])
    ap.add_argument("--out", default=None,
                    help="write the artifact (e.g. BENCH_serving.json)")
    args = ap.parse_args()
    art = run(args.arch, densities=tuple(args.densities),
              batches=tuple(args.batches), images=args.images,
              size=args.size, out_path=args.out)
    for r in art["rows"]:
        print(r)
    print("summary:", art["summary"])
