"""CNN serving-path benchmark: images/s vs density vs batch size.

Drives the batched CNN backend (`launch.serve.CNNServer`) end to end —
queue, bucketing, slot retirement, backfill, jit-cached `SparseNet.apply` —
and reports steady-state throughput for the dense-jnp baseline (plain XLA
convs) and the vector-sparse structural path at several densities.  CPU
numbers demonstrate work ∝ density and batch amortization on a real
backend, not the TPU claim (same caveat as bench_kernels).

Each (path, density, batch) cell serves a warmup wave first so the compile
cost of the batch bucket is off the clock — the steady state is what a
serving deployment sees.

Each sparse cell also carries the *modeled* per-image HBM bytes of the two
conv input layouts (halo direct input vs materialized row-tap stack) and
their arithmetic intensity — `core.accel_model.conv_layer_traffic`, the
same formulas the Pallas kernels hand XLA as CostEstimate — so the
serving artifact captures the bandwidth win next to images/s.  ``--impl``
selects the executed path (jnp | pallas | pallas-stack; the pallas paths
run interpret-mode on CPU and are slow — bench them on TPU).

Writes a ``BENCH_serving.json`` artifact (--out) with per-cell rows plus a
summary checking that batched sparse throughput >= batch-1 throughput at
equal density.

Replica scaling (``--replicas R1 R2 ...``): serves the same request set
through the data-parallel replica fleet (`launch.serve.ReplicaGroup` +
`launch.scheduler.FleetScheduler`) at each fleet size and reports images/s
plus scaling efficiency against the *achievable* ideal — min(replicas,
cores), overridable with ``VSCNN_SCALING_IDEAL``.  On a forced-host CPU
mesh (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the replicas
share the physical cores XLA's intra-op parallelism already saturates, so
set ``VSCNN_SCALING_IDEAL=1`` there: the gate then bounds fleet-machinery
*overhead* (and pins scheduling determinism), not parallel speedup — real
replica speedup needs real devices (a TPU pod's data axis).  Scheduling
columns (waves/steps/steals/digest) are deterministic: the fleet loop is
synchronous and its control flow never reads the clock, so they gate
exactly against the committed ``BENCH_serving_replicas.json`` baseline
(``--compare-baseline``, modeled on bench_kernels).  ``--shard-fc``
additionally cout-shards FC heads over each replica's model-axis devices
and checks logits parity against the first fleet size.

Chaos / degraded mode (``--chaos``): serves the same request set under
seeded fault injection (`launch.faults.FaultPlan.random` over a
``--chaos-replicas`` fleet, one row per ``--chaos-seeds`` entry) and
reports planned vs fired faults, delivered/refused outcome counts by
reason, final replica health, degraded images/s vs the fault-free
reference, a delivered-bit-identical check, and a replay-determinism
check (the same plan must reproduce the exact outcome/fault/health
trajectory).  Exits non-zero if either check fails — the CI chaos smoke.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --arch vscnn-vgg16
(also: vscnn-resnet18 / vscnn-resnet50 / vscnn-mobilenet-v1 — any CNN
registry arch; MobileNet exercises the depthwise tap kernels' traffic
columns.)
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.faults import ChaosBackend, FaultPlan
from repro.launch.scheduler import FleetScheduler
from repro.launch.serve import CNNServer, ImageRequest


def _requests(rng, n: int, size: int) -> list[ImageRequest]:
    return [ImageRequest(rid=i,
                         image=rng.standard_normal((size, size, 3))
                                  .astype(np.float32))
            for i in range(n)]


def _model_bytes(srv: CNNServer, size: int) -> dict:
    """Modeled per-image conv HBM bytes + arithmetic intensity, both conv
    layouts, for this server's sparsified net at the served image size."""
    from repro.core.accel_model import network_traffic_reports
    from repro.models.graph import collect_conv_traffic

    if srv.sparse is None:
        return {}
    x = jnp.zeros((1, size, size, 3), jnp.float32)
    traffic = collect_conv_traffic(srv.net, srv.params, x)
    reps = network_traffic_reports(traffic, srv.sparse)
    out = {}
    for impl in ("halo", "stack"):
        total = sum(t[impl].bytes_accessed for _, t in reps)
        flops = sum(t[impl].flops for _, t in reps)
        out[f"model_bytes_per_image_{impl}"] = total
        out[f"model_ai_{impl}"] = round(flops / max(total, 1), 2)
    return out


def _throughput(srv: CNNServer, rng, n: int, size: int, batch: int) -> dict:
    srv.serve(_requests(rng, batch, size))          # warmup: compile bucket
    stats = srv.serve(_requests(rng, n, size))
    run_s = sum(s["run_s"] for s in stats)
    return {
        "images_per_s": round(n / max(run_s, 1e-9), 2),
        "run_s": round(run_s, 4),
        "runs": len(stats),
        "steps": sum(s["steps"] for s in stats),
        "backfills": sum(s["backfills"] for s in stats),
        "compiles": srv.backend.apply.compiles,
    }


def _int8_agreement(srv_f32: CNNServer, srv_int8: CNNServer, size: int,
                    batch: int) -> dict:
    """Serve one identical seeded request wave through both precision paths
    and compare logits: max |Δlogit| + top-1 match rate."""
    reqs_f = _requests(np.random.default_rng(42), batch, size)
    reqs_q = _requests(np.random.default_rng(42), batch, size)
    srv_f32.serve(reqs_f)
    srv_int8.serve(reqs_q)
    lf = np.stack([r.logits for r in sorted(reqs_f, key=lambda r: r.rid)])
    lq = np.stack([r.logits for r in sorted(reqs_q, key=lambda r: r.rid)])
    return {
        "max_abs_dlogit_vs_f32": round(float(np.abs(lq - lf).max()), 6),
        "top1_match_vs_f32": round(
            float((lq.argmax(-1) == lf.argmax(-1)).mean()), 4),
    }


def run(arch: str = "vscnn-vgg16", *, densities=(1.0, 0.5, 0.235),
        batches=(1, 4, 8), images: int = 24, size: int | None = None,
        impl: str = "jnp", dtype: str = "f32",
        out_path: str | None = None) -> dict:
    cfg = get_config(arch).reduce()
    size = size or cfg.image_size
    int8 = dtype == "int8"
    rng = np.random.default_rng(0)
    rows = []
    model_bytes: dict = {}  # per (density, dtype) — batch-size independent
    for batch in batches:
        srv = CNNServer(cfg, batch=batch, sparse=False)
        rows.append({"path": "dense-jnp", "density": 1.0, "batch": batch,
                     **_throughput(srv, rng, images, size, batch)})
        for density in densities:
            srv = CNNServer(cfg, batch=batch, density=density, impl=impl)
            if density not in model_bytes:
                model_bytes[density] = _model_bytes(srv, size)
            rows.append({"path": f"sparse-{impl}", "density": density,
                         "batch": batch,
                         **model_bytes[density],
                         **_throughput(srv, rng, images, size, batch)})
            if int8:
                # compound sparsity x precision cell: same density, int8
                # weights/activations, plus output-agreement columns vs
                # the sparse-f32 server on one identical seeded wave
                srv_q = CNNServer(cfg, batch=batch, density=density,
                                  impl=impl, dtype="int8")
                key = (density, "int8")
                if key not in model_bytes:
                    model_bytes[key] = _model_bytes(srv_q, size)
                rows.append({"path": f"sparse-{impl}-int8",
                             "density": density, "batch": batch,
                             **model_bytes[key],
                             **_throughput(srv_q, rng, images, size, batch),
                             **_int8_agreement(srv, srv_q, size, batch)})
    # batched throughput must beat (or match) batch-1 at equal density
    summary = {}
    max_batch = max(batches)
    for density in densities:
        cells = {r["batch"]: r["images_per_s"] for r in rows
                 if r["path"] == f"sparse-{impl}"
                 and r["density"] == density}
        summary[str(density)] = {
            "batch1_images_per_s": cells.get(1),
            "batched_images_per_s": cells.get(max_batch),
            "batched_ge_batch1": (cells.get(max_batch, 0.0)
                                  >= cells.get(1, float("inf"))),
        }
    artifact = {
        "bench": "cnn_serving",
        "arch": arch,
        "image_size": size,
        "images": images,
        "impl": impl,
        "dtype": dtype,
        "batches": list(batches),
        "densities": list(densities),
        "rows": rows,
        "summary": summary,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return artifact


# --------------------------------------------------------------------------
# Replica-fleet scaling (--replicas) + regression gate (--compare-baseline)
# --------------------------------------------------------------------------

# scheduling columns gated exactly against the committed baseline: the
# fleet loop is synchronous Python whose control flow (placement, stealing,
# wave/step counts) never reads the clock, and the class digest pins the
# served outputs — wall-clock columns are reported, never gated.
REPLICA_DET_COLS = ("waves", "steps", "backfills", "finished", "steals",
                    "bit_identical_to_first", "class_digest")


def _ideal_parallelism(replicas: int) -> int:
    """Achievable ideal speedup at this fleet size: min(replicas, cores),
    overridable with VSCNN_SCALING_IDEAL (set it to 1 on forced-host CPU
    meshes, where XLA intra-op parallelism already saturates the cores)."""
    cap = int(os.environ.get("VSCNN_SCALING_IDEAL", os.cpu_count() or 1))
    return max(1, min(replicas, cap))


def _class_digest(reqs) -> str:
    h = hashlib.sha256()
    for r in sorted(reqs, key=lambda r: r.rid):
        h.update(np.int64(r.out[0]).tobytes())
    return h.hexdigest()[:16]


def run_replicas(arch: str = "vscnn-vgg16", *, replicas=(1, 2, 4, 8),
                 images: int = 32, batch: int = 4, density: float = 0.5,
                 size: int | None = None, impl: str = "jnp",
                 shard_fc: bool = False,
                 out_path: str | None = None) -> dict:
    """Serve one request set at each fleet size; images/s + scaling
    efficiency + deterministic scheduling columns per row."""
    cfg = get_config(arch).reduce()
    size = size or cfg.image_size
    rows = []
    ref_logits = None
    base_ips = None
    for nrep in replicas:
        srv = CNNServer(cfg, batch=batch, density=density, impl=impl,
                        replicas=nrep, shard_fc=shard_fc)
        # warmup one wave per replica so every replica's executable is
        # compiled off the clock
        srv.serve(_requests(np.random.default_rng(0), batch * nrep, size))
        reqs = _requests(np.random.default_rng(1), images, size)
        t0 = time.time()
        stats = srv.serve(reqs)
        wall = time.time() - t0
        logits = np.stack([r.logits
                           for r in sorted(reqs, key=lambda r: r.rid)])
        if ref_logits is None:
            ref_logits = logits
        ips = images / max(wall, 1e-9)
        if base_ips is None:
            base_ips = ips
        ideal = _ideal_parallelism(nrep)
        speedup = ips / base_ips
        rows.append({
            "replicas": nrep,
            "images_per_s": round(ips, 2),
            "wall_s": round(wall, 4),
            "speedup_vs_first": round(speedup, 3),
            "ideal_parallelism": ideal,
            "scaling_efficiency": round(speedup / ideal, 3),
            "waves": len(stats),
            "steps": sum(s["steps"] for s in stats),
            "backfills": sum(s["backfills"] for s in stats),
            "finished": sum(s["finished"] for s in stats),
            "steals": getattr(srv.scheduler, "steals", 0),
            "replicas_used": sorted({s.get("replica", 0) for s in stats}),
            "bit_identical_to_first": bool(np.array_equal(ref_logits,
                                                          logits)),
            "parity_max_abs_diff": float(np.abs(ref_logits - logits).max()),
            "class_digest": _class_digest(reqs),
        })
    artifact = {
        "bench": "cnn_serving_replicas",
        "arch": arch,
        "image_size": size,
        "images": images,
        "batch": batch,
        "density": density,
        "impl": impl,
        "shard_fc": shard_fc,
        "replicas": list(replicas),
        "rows": rows,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return artifact


# --------------------------------------------------------------------------
# Degraded-mode chaos bench (--chaos): seeded fault injection over the fleet
# --------------------------------------------------------------------------

def _chaos_serve(backends, plan: FaultPlan, reqs, *, batch: int,
                 deadline_waves: int | None):
    """One chaos serve over fresh ChaosBackend wrappers of the shared
    (stateless) CNN backends; returns (scheduler, wall_s)."""
    bes = [ChaosBackend(b, plan, replica=i)
           for i, b in enumerate(backends)]
    sched = FleetScheduler(bes, batch=batch, deadline_waves=deadline_waves)
    t0 = time.time()
    sched.serve(reqs)
    return sched, time.time() - t0


def _outcome_trace(sched) -> dict:
    return {rid: (o.status, o.reason, o.replica, o.attempts, o.wave)
            for rid, o in sched.outcomes.items()}


def run_chaos(arch: str = "vscnn-vgg16", *, seeds=(0, 1, 2),
              replicas: int = 3, images: int = 24, batch: int = 4,
              density: float = 0.5, size: int | None = None,
              impl: str = "jnp", deadline_waves: int | None = None,
              out_path: str | None = None) -> dict:
    """Degraded-mode serving under seeded fault injection.

    One fault-free fleet serve pins the reference logits and throughput;
    each chaos seed then serves the same request set through the same
    (shared, stateless) backends wrapped in a fresh `ChaosBackend` fleet.
    Per-seed columns: planned/fired faults by kind, delivered/refused by
    reason, final health, deterministic scheduling counters, degraded
    images/s, a delivered-bit-identical check against the fault-free
    reference, and a replay check (the same plan served twice must
    reproduce the exact outcome/fault/health trajectory).
    """
    cfg = get_config(arch).reduce()
    size = size or cfg.image_size
    srv = CNNServer(cfg, batch=batch, density=density, impl=impl,
                    replicas=replicas)
    # warmup: compile every batch bucket off the clock
    srv.serve(_requests(np.random.default_rng(0), batch * replicas, size))
    reqs = _requests(np.random.default_rng(1), images, size)
    t0 = time.time()
    srv.serve(reqs)
    ref_wall = time.time() - t0
    ref_logits = {r.rid: r.logits.tobytes() for r in reqs}
    ref_ips = images / max(ref_wall, 1e-9)
    rows = []
    for seed in seeds:
        plan = FaultPlan.random(seed, replicas=replicas)
        reqs_c = _requests(np.random.default_rng(1), images, size)
        sched, wall = _chaos_serve(srv.backends, plan, reqs_c, batch=batch,
                                   deadline_waves=deadline_waves)
        outcomes = sched.outcomes
        delivered = [rid for rid, o in outcomes.items()
                     if o.status == "delivered"]
        refused: dict[str, int] = {}
        for o in outcomes.values():
            if o.status == "refused":
                refused[o.reason] = refused.get(o.reason, 0) + 1
        fired: dict[str, int] = {}
        for be in sched.backends:
            for _, kind in be.injected:
                fired[kind] = fired.get(kind, 0) + 1
        # delivered outputs must be bit-identical to the fault-free run
        bit_identical = all(
            r.logits is not None
            and r.logits.tobytes() == ref_logits[r.rid]
            for r in reqs_c
            if outcomes[r.rid].status == "delivered")
        # replay: the same plan on a fresh fleet reproduces the exact
        # outcome / fault-event / health / wave trajectory
        sched2, _ = _chaos_serve(
            srv.backends, plan, _requests(np.random.default_rng(1),
                                          images, size),
            batch=batch, deadline_waves=deadline_waves)
        replay_identical = (
            _outcome_trace(sched) == _outcome_trace(sched2)
            and sched.fault_events == sched2.fault_events
            and sched.health == sched2.health
            and sched.waves == sched2.waves)
        rows.append({
            "chaos_seed": seed,
            "faults_planned": plan.counts(),
            "faults_fired": fired,
            "fault_events": len(sched.fault_events),
            "delivered": len(delivered),
            "refused": refused,
            "health": list(sched.health),
            "waves": sched.waves,
            "steals": sched.steals,
            "images_per_s_degraded": round(
                len(delivered) / max(wall, 1e-9), 2),
            "throughput_vs_fault_free": round(
                (len(delivered) / max(wall, 1e-9)) / max(ref_ips, 1e-9), 3),
            "wall_s": round(wall, 4),
            "delivered_bit_identical": bool(bit_identical),
            "replay_identical": bool(replay_identical),
        })
    artifact = {
        "bench": "cnn_serving_chaos",
        "arch": arch,
        "image_size": size,
        "images": images,
        "batch": batch,
        "density": density,
        "impl": impl,
        "replicas": replicas,
        "deadline_waves": deadline_waves,
        "seeds": list(seeds),
        "reference": {"images_per_s": round(ref_ips, 2),
                      "wall_s": round(ref_wall, 4)},
        "rows": rows,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return artifact


def compare_replicas_baseline(rows: list[dict], baseline: dict
                              ) -> tuple[list[str], list[str]]:
    """Exact comparison of the deterministic scheduling columns against the
    committed baseline; wall-clock columns are shown, not gated."""
    cur = {r["replicas"]: r for r in rows}
    failures: list[str] = []
    lines = [
        "| replicas | metric | baseline | current | status |",
        "|---|---|---|---|---|",
    ]
    for b in baseline["rows"]:
        c = cur.get(b["replicas"])
        if c is None:
            failures.append(f"replicas={b['replicas']}: row missing")
            lines.append(f"| {b['replicas']} | — | — | MISSING | FAIL |")
            continue
        for metric in REPLICA_DET_COLS:
            if metric not in b:
                continue
            bad = c.get(metric) != b[metric]
            if bad:
                failures.append(
                    f"replicas={b['replicas']}: {metric} "
                    f"{b[metric]!r} -> {c.get(metric)!r}")
            lines.append(
                f"| {b['replicas']} | {metric} | {b[metric]} "
                f"| {c.get(metric)} | {'FAIL' if bad else 'ok'} |")
        lines.append(
            f"| {b['replicas']} | images_per_s (not gated) "
            f"| {b.get('images_per_s')} | {c.get('images_per_s')} | — |")
    return failures, lines


def gate_replicas(baseline_path: str, *, min_efficiency: float | None = None,
                  out_path: str | None = None) -> int:
    """CI gate: re-run the replica bench at the committed baseline's
    settings, fail on any deterministic-column drift, and (when
    ``min_efficiency`` is set) on scaling efficiency below the bound at any
    fleet size.  The fresh rows double as the run's trajectory artifact."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    art = run_replicas(
        baseline["arch"], replicas=tuple(baseline["replicas"]),
        images=baseline["images"], batch=baseline["batch"],
        density=baseline["density"], size=baseline["image_size"],
        impl=baseline["impl"], shard_fc=baseline.get("shard_fc", False),
        out_path=out_path)
    failures, lines = compare_replicas_baseline(art["rows"], baseline)
    if min_efficiency is not None:
        for r in art["rows"]:
            if r["scaling_efficiency"] < min_efficiency:
                failures.append(
                    f"replicas={r['replicas']}: scaling efficiency "
                    f"{r['scaling_efficiency']} < {min_efficiency} "
                    f"(ideal parallelism {r['ideal_parallelism']})")
    summary = "\n".join(
        [f"## Replica-scaling gate — `{baseline_path}` "
         f"({'FAIL' if failures else 'PASS'})", ""]
        + lines + [""]
        + [f"- {f}" for f in failures])
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary + "\n")
    print(summary)
    if failures:
        print(f"replica gate: FAIL ({len(failures)} failure(s))")
        return 1
    print("replica gate: PASS")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vscnn-vgg16")
    ap.add_argument("--images", type=int, default=24)
    ap.add_argument("--size", type=int, default=None,
                    help="override the reduced config's image size")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--densities", type=float, nargs="+",
                    default=[1.0, 0.5, 0.235])
    ap.add_argument("--impl", default="jnp",
                    choices=["jnp", "pallas", "pallas-halo", "pallas-stack"],
                    help="executed sparse path (pallas* = the TPU kernels; "
                         "interpret-mode and slow on CPU)")
    ap.add_argument("--dtype", default="f32", choices=["f32", "int8"],
                    help="int8 adds a sparse-<impl>-int8 row per cell "
                         "(compound sparsity x precision) with "
                         "output-agreement columns vs sparse-f32")
    ap.add_argument("--out", default=None,
                    help="write the artifact (e.g. BENCH_serving.json)")
    ap.add_argument("--replicas", type=int, nargs="+", default=None,
                    help="replica-fleet scaling mode: fleet sizes to bench")
    ap.add_argument("--batch", type=int, default=4,
                    help="wave width per replica (replica mode)")
    ap.add_argument("--density", type=float, default=0.5,
                    help="sparse density (replica mode)")
    ap.add_argument("--shard-fc", action="store_true",
                    help="cout-shard FC heads over each replica's model-"
                         "axis devices (replica mode)")
    ap.add_argument("--compare-baseline", default=None,
                    help="replica-gate mode: re-run at this committed "
                         "baseline's settings and fail on drift")
    ap.add_argument("--min-efficiency", type=float, default=None,
                    help="fail the gate below this scaling efficiency")
    ap.add_argument("--chaos", action="store_true",
                    help="degraded-mode bench: serve under seeded fault "
                         "injection and report refusals / degraded "
                         "throughput / replay determinism")
    ap.add_argument("--chaos-seeds", type=int, nargs="+", default=[0, 1, 2],
                    help="FaultPlan seeds for --chaos")
    ap.add_argument("--chaos-replicas", type=int, default=3,
                    help="fleet size for --chaos")
    ap.add_argument("--deadline-waves", type=int, default=None,
                    help="per-request deadline in fleet ticks (--chaos)")
    args = ap.parse_args()
    if args.chaos:
        art = run_chaos(args.arch, seeds=tuple(args.chaos_seeds),
                        replicas=args.chaos_replicas, images=args.images,
                        batch=args.batch, density=args.density,
                        size=args.size, impl=args.impl,
                        deadline_waves=args.deadline_waves,
                        out_path=args.out)
        print("reference:", art["reference"])
        bad = []
        for r in art["rows"]:
            print(r)
            if not r["delivered_bit_identical"]:
                bad.append(f"seed={r['chaos_seed']}: delivered logits "
                           f"diverge from the fault-free run")
            if not r["replay_identical"]:
                bad.append(f"seed={r['chaos_seed']}: chaos replay is not "
                           f"deterministic")
        for b in bad:
            print("FAIL:", b)
        sys.exit(1 if bad else 0)
    if args.compare_baseline:
        sys.exit(gate_replicas(args.compare_baseline,
                               min_efficiency=args.min_efficiency,
                               out_path=args.out))
    if args.replicas:
        art = run_replicas(args.arch, replicas=tuple(args.replicas),
                           images=args.images, batch=args.batch,
                           density=args.density, size=args.size,
                           impl=args.impl, shard_fc=args.shard_fc,
                           out_path=args.out)
        bad = []
        for r in art["rows"]:
            print(r)
            if args.shard_fc and r["parity_max_abs_diff"] > 1e-4:
                bad.append(f"replicas={r['replicas']}: sharded-FC logits "
                           f"diverge ({r['parity_max_abs_diff']:g})")
            if args.min_efficiency is not None \
                    and r["scaling_efficiency"] < args.min_efficiency:
                bad.append(f"replicas={r['replicas']}: efficiency "
                           f"{r['scaling_efficiency']} < "
                           f"{args.min_efficiency}")
        for b in bad:
            print("FAIL:", b)
        sys.exit(1 if bad else 0)
    art = run(args.arch, densities=tuple(args.densities),
              batches=tuple(args.batches), images=args.images,
              size=args.size, impl=args.impl, dtype=args.dtype,
              out_path=args.out)
    for r in art["rows"]:
        print(r)
    print("summary:", art["summary"])
