"""CNN serving-path benchmark: images/s vs density vs batch size.

Drives the batched CNN backend (`launch.serve.CNNServer`) end to end —
queue, bucketing, slot retirement, backfill, jit-cached `SparseNet.apply` —
and reports steady-state throughput for the dense-jnp baseline (plain XLA
convs) and the vector-sparse structural path at several densities.  CPU
numbers demonstrate work ∝ density and batch amortization on a real
backend, not the TPU claim (same caveat as bench_kernels).

Each (path, density, batch) cell serves a warmup wave first so the compile
cost of the batch bucket is off the clock — the steady state is what a
serving deployment sees.

Each sparse cell also carries the *modeled* per-image HBM bytes of the two
conv input layouts (halo direct input vs materialized row-tap stack) and
their arithmetic intensity — `core.accel_model.conv_layer_traffic`, the
same formulas the Pallas kernels hand XLA as CostEstimate — so the
serving artifact captures the bandwidth win next to images/s.  ``--impl``
selects the executed path (jnp | pallas | pallas-stack; the pallas paths
run interpret-mode on CPU and are slow — bench them on TPU).

Writes a ``BENCH_serving.json`` artifact (--out) with per-cell rows plus a
summary checking that batched sparse throughput >= batch-1 throughput at
equal density.

Run:  PYTHONPATH=src python benchmarks/bench_serving.py --arch vscnn-vgg16
(also: vscnn-resnet18 / vscnn-resnet50 / vscnn-mobilenet-v1 — any CNN
registry arch; MobileNet exercises the depthwise tap kernels' traffic
columns.)
"""
from __future__ import annotations

import argparse
import json

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.serve import CNNServer, ImageRequest


def _requests(rng, n: int, size: int) -> list[ImageRequest]:
    return [ImageRequest(rid=i,
                         image=rng.standard_normal((size, size, 3))
                                  .astype(np.float32))
            for i in range(n)]


def _model_bytes(srv: CNNServer, size: int) -> dict:
    """Modeled per-image conv HBM bytes + arithmetic intensity, both conv
    layouts, for this server's sparsified net at the served image size."""
    from repro.core.accel_model import network_traffic_reports
    from repro.models.graph import collect_conv_traffic

    if srv.sparse is None:
        return {}
    x = jnp.zeros((1, size, size, 3), jnp.float32)
    traffic = collect_conv_traffic(srv.net, srv.params, x)
    reps = network_traffic_reports(traffic, srv.sparse)
    out = {}
    for impl in ("halo", "stack"):
        total = sum(t[impl].bytes_accessed for _, t in reps)
        flops = sum(t[impl].flops for _, t in reps)
        out[f"model_bytes_per_image_{impl}"] = total
        out[f"model_ai_{impl}"] = round(flops / max(total, 1), 2)
    return out


def _throughput(srv: CNNServer, rng, n: int, size: int, batch: int) -> dict:
    srv.serve(_requests(rng, batch, size))          # warmup: compile bucket
    stats = srv.serve(_requests(rng, n, size))
    run_s = sum(s["run_s"] for s in stats)
    return {
        "images_per_s": round(n / max(run_s, 1e-9), 2),
        "run_s": round(run_s, 4),
        "runs": len(stats),
        "steps": sum(s["steps"] for s in stats),
        "backfills": sum(s["backfills"] for s in stats),
        "compiles": srv.backend.apply.compiles,
    }


def run(arch: str = "vscnn-vgg16", *, densities=(1.0, 0.5, 0.235),
        batches=(1, 4, 8), images: int = 24, size: int | None = None,
        impl: str = "jnp", out_path: str | None = None) -> dict:
    cfg = get_config(arch).reduce()
    size = size or cfg.image_size
    rng = np.random.default_rng(0)
    rows = []
    model_bytes: dict = {}  # per density — independent of the batch size
    for batch in batches:
        srv = CNNServer(cfg, batch=batch, sparse=False)
        rows.append({"path": "dense-jnp", "density": 1.0, "batch": batch,
                     **_throughput(srv, rng, images, size, batch)})
        for density in densities:
            srv = CNNServer(cfg, batch=batch, density=density, impl=impl)
            if density not in model_bytes:
                model_bytes[density] = _model_bytes(srv, size)
            rows.append({"path": f"sparse-{impl}", "density": density,
                         "batch": batch,
                         **model_bytes[density],
                         **_throughput(srv, rng, images, size, batch)})
    # batched throughput must beat (or match) batch-1 at equal density
    summary = {}
    max_batch = max(batches)
    for density in densities:
        cells = {r["batch"]: r["images_per_s"] for r in rows
                 if r["path"] == f"sparse-{impl}"
                 and r["density"] == density}
        summary[str(density)] = {
            "batch1_images_per_s": cells.get(1),
            "batched_images_per_s": cells.get(max_batch),
            "batched_ge_batch1": (cells.get(max_batch, 0.0)
                                  >= cells.get(1, float("inf"))),
        }
    artifact = {
        "bench": "cnn_serving",
        "arch": arch,
        "image_size": size,
        "images": images,
        "impl": impl,
        "batches": list(batches),
        "densities": list(densities),
        "rows": rows,
        "summary": summary,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return artifact


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vscnn-vgg16")
    ap.add_argument("--images", type=int, default=24)
    ap.add_argument("--size", type=int, default=None,
                    help="override the reduced config's image size")
    ap.add_argument("--batches", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--densities", type=float, nargs="+",
                    default=[1.0, 0.5, 0.235])
    ap.add_argument("--impl", default="jnp",
                    choices=["jnp", "pallas", "pallas-halo", "pallas-stack"],
                    help="executed sparse path (pallas* = the TPU kernels; "
                         "interpret-mode and slow on CPU)")
    ap.add_argument("--out", default=None,
                    help="write the artifact (e.g. BENCH_serving.json)")
    args = ap.parse_args()
    art = run(args.arch, densities=tuple(args.densities),
              batches=tuple(args.batches), images=args.images,
              size=args.size, impl=args.impl, out_path=args.out)
    for r in art["rows"]:
        print(r)
    print("summary:", art["summary"])
