"""Kernel-level benches: the TPU analogue of the paper's cycle savings.

1. Structural FLOP scaling: compiled HLO FLOPs of the vector-sparse matmul
   vs density — the zero weight vectors are absent from the compiled
   program exactly as they are absent from the paper's SRAM (compare with
   the dense baseline at density 1.0).
2. Wall-clock on CPU for the jnp structural path (CPU timing is NOT the TPU
   claim — it demonstrates the cycle model's work∝density on a real
   backend).
3. Pallas kernel allclose + grid-size-vs-density check (interpret mode).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode, prune_vectors_balanced, vs_matmul
from repro.kernels import vsmm
from repro.kernels.ref import vsmm_ref


def _sparse(rng, k, n, vk, vn, density, dtype=jnp.float32):
    w = rng.standard_normal((k, n)).astype(np.float32)
    wp, _ = prune_vectors_balanced(w, density, vk, vn)
    return encode(jnp.asarray(wp, dtype), vk, vn)


def hlo_flops(fn, *args) -> float:
    # the structural path is a scan over S steps: XLA's cost_analysis counts
    # the body once, so use the trip-multiplying analyzer (utils.hlo)
    from repro.utils.hlo import analyze
    return analyze(jax.jit(fn).lower(*args).compile().as_text()).flops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    m, k, n, vk, vn = 256, 2048, 2048, 32, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    dense_flops = None
    for density in (1.0, 0.5, 0.25, 0.125):
        vs = _sparse(rng, k, n, vk, vn, density)
        f = hlo_flops(lambda xx: vs_matmul(xx, vs), x)
        if dense_flops is None:
            dense_flops = f
        # wall time (CPU, jnp structural path)
        fn = jax.jit(lambda xx: vs_matmul(xx, vs))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(20):
            out = fn(x)
        out.block_until_ready()
        us = (time.time() - t0) / 20 * 1e6
        rows.append({
            "name": f"vsmm_structural_density_{density}",
            "us_per_call": round(us, 1),
            "hlo_flops": f,
            "flops_vs_dense": round(f / dense_flops, 4),
            "expected": density,
        })

    # Pallas kernel correctness + structural grid scaling
    for density in (1.0, 0.25):
        vs = _sparse(rng, 512, 512, 32, 128, density)
        xs = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
        t0 = time.time()
        out = vsmm(xs, vs)
        us = (time.time() - t0) * 1e6
        ref = vsmm_ref(xs, vs)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        rows.append({
            "name": f"vsmm_pallas_density_{density}",
            "us_per_call": round(us, 1),
            "rel_err_vs_ref": rel,
            "grid_sparse_steps": vs.nnz_per_strip,
            "grid_dense_steps": vs.kb,
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
