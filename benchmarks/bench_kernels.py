"""Kernel-level benches: the TPU analogue of the paper's cycle savings.

1. Structural FLOP scaling: compiled HLO FLOPs of the vector-sparse matmul
   vs density — the zero weight vectors are absent from the compiled
   program exactly as they are absent from the paper's SRAM (compare with
   the dense baseline at density 1.0).
2. Wall-clock on CPU for the jnp structural path (CPU timing is NOT the TPU
   claim — it demonstrates the cycle model's work∝density on a real
   backend).
3. Pallas kernel allclose + grid-size-vs-density check (interpret mode).
4. Generalized conv geometry sweep: per-(kernel, stride, groups, dilation)
   speedup-vs-density rows for the vsconv kernel family (1x1 / 3x3 / 5x5 /
   7x7, stride 1-2, grouped / depthwise / dilated taps), reporting the
   structural FLOP ratio, jnp-path wall clock, interpret-mode parity for
   *both* conv input layouts (halo direct input vs row-tap stack), and the
   modeled HBM bytes of each layout — the bandwidth story is part of the
   benchmarked contract, not just the MAC skips.
5. Per-network per-layer speedup-vs-density (``--net vgg16 | resnet18 |
   resnet34 | resnet50 | mobilenet_v1``, ``--resnet18`` kept as an alias):
   the graph executor + cycle model walked over every conv (residual
   blocks, BN folded, depthwise stages), emitting a ``BENCH_<net>.json``
   artifact so CI tracks the perf trajectory — with per-layer bytes /
   arithmetic-intensity columns for the halo and stack layouts, and the
   measured-vs-modeled columns (wall clock, compiled-HLO FLOPs/bytes,
   calibrated ``predicted_us`` — see `repro.core.calibration`) next to
   them.
6. ``--gate-traffic``: CI smoke gate — runs both impls on the ResNet
   7x7/s2 stem geometry and a MobileNet depthwise 3x3/s2 layer (interpret
   parity) and fails unless the halo path's modeled ``bytes_accessed`` is
   strictly below the stack path's on both.
7. ``--compare-baseline PATH``: CI regression gate — re-runs the
   per-network bench at the committed baseline's settings and fails on a
   >10% per-layer regression of cycle speedup or modeled bytes, writing a
   per-layer delta table to ``$GITHUB_STEP_SUMMARY`` when set.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode, prune_vectors_balanced, vs_conv2d, vs_matmul
from repro.kernels import vsconv, vsmm
from repro.kernels.ref import vsconv_ref, vsmm_ref


def _sparse(rng, k, n, vk, vn, density, dtype=jnp.float32):
    w = rng.standard_normal((k, n)).astype(np.float32)
    wp, _ = prune_vectors_balanced(w, density, vk, vn)
    return encode(jnp.asarray(wp, dtype), vk, vn)


def hlo_flops(fn, *args) -> float:
    # the structural path is a scan over S steps: XLA's cost_analysis counts
    # the body once, so use the trip-multiplying analyzer (utils.hlo)
    from repro.utils.hlo import analyze
    return analyze(jax.jit(fn).lower(*args).compile().as_text()).flops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    m, k, n, vk, vn = 256, 2048, 2048, 32, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    dense_flops = None
    for density in (1.0, 0.5, 0.25, 0.125):
        vs = _sparse(rng, k, n, vk, vn, density)
        f = hlo_flops(lambda xx: vs_matmul(xx, vs), x)
        if dense_flops is None:
            dense_flops = f
        # wall time (CPU, jnp structural path)
        fn = jax.jit(lambda xx: vs_matmul(xx, vs))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(20):
            out = fn(x)
        out.block_until_ready()
        us = (time.time() - t0) / 20 * 1e6
        rows.append({
            "name": f"vsmm_structural_density_{density}",
            "us_per_call": round(us, 1),
            "hlo_flops": f,
            "flops_vs_dense": round(f / dense_flops, 4),
            "expected": density,
        })

    # Pallas kernel correctness + structural grid scaling
    for density in (1.0, 0.25):
        vs = _sparse(rng, 512, 512, 32, 128, density)
        xs = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
        t0 = time.time()
        out = vsmm(xs, vs)
        us = (time.time() - t0) * 1e6
        ref = vsmm_ref(xs, vs)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        rows.append({
            "name": f"vsmm_pallas_density_{density}",
            "us_per_call": round(us, 1),
            "rel_err_vs_ref": rel,
            "grid_sparse_steps": vs.nnz_per_strip,
            "grid_dense_steps": vs.kb,
        })

    rows += run_conv_geometries()
    return rows


# (kh, kw, stride, groups, dilation, h, w, cin, cout, vk, vn) — the
# generalized kernel family: VGG's 3x3/s1 plus the ResNet vocabulary
# (7x7-s2 stem, 1x1 projection, stride-2 downsample), a 5x5 mid-size tap,
# grouped and depthwise (groups == cin) 3x3s, and dilated taps.
CONV_GEOMETRIES = [
    (1, 1, 1, 1, 1, 28, 28, 128, 128, 32, 128),
    (1, 1, 2, 1, 1, 28, 28, 128, 128, 32, 128),
    (3, 3, 1, 1, 1, 28, 28, 64, 128, 32, 128),
    (3, 3, 2, 1, 1, 28, 28, 64, 128, 32, 128),
    (5, 5, 1, 1, 1, 14, 14, 32, 128, 32, 128),
    (7, 7, 2, 1, 1, 28, 28, 8, 64, 8, 64),
    (3, 3, 1, 1, 2, 28, 28, 64, 128, 32, 128),   # dilated 3x3 d2
    (3, 3, 1, 4, 1, 28, 28, 64, 128, 16, 32),    # grouped 3x3 g4
    (3, 3, 2, 128, 1, 28, 28, 128, 128, 1, 128),  # depthwise 3x3/s2
]


def _geom_vs(rng, kh, kw, cin, cout, vk, vn, groups, density):
    """Encode one sweep geometry's sparse weight (grouped/dw aware)."""
    from repro.core import conv_cin_major

    cin_g = cin // groups
    wm = rng.standard_normal((kh * kw * cin_g, cout)).astype(np.float32)
    wp, _ = prune_vectors_balanced(wm, density, vk, vn)
    vs = encode(jnp.asarray(wp), vk, vn)
    if kh * kw > 1 and groups < cin:
        vs = conv_cin_major(vs, cin_g // vk)  # the serving tile order
    return vs


def _conv_bytes(kh, kw, stride, groups, dilation, h, w, cin, cout, vk, vn,
                s_steps, batch: int = 4) -> dict:
    """Modeled HBM bytes + arithmetic intensity for both conv layouts."""
    from repro.core.accel_model import conv_layer_traffic

    out = {}
    for impl in ("halo", "stack"):
        tr = conv_layer_traffic(
            (batch, h, w, cin), kh=kh, kw=kw, stride=stride, groups=groups,
            dilation=dilation, cout=cout,
            s_steps=s_steps, vk=vk, vn=vn, impl=impl)
        out[f"bytes_{impl}"] = tr.bytes_accessed
        out[f"ai_{impl}"] = round(tr.arithmetic_intensity, 2)
    return out


def run_conv_geometries(densities=(1.0, 0.5, 0.25)) -> list[dict]:
    """Per-geometry speedup-vs-density: structural FLOP ratio (the kernel's
    grid shrinks with density), jnp-path wall clock, modeled HBM bytes for
    the halo and stack layouts, and Pallas interpret parity of both impls
    vs the oracle — grouped, depthwise and dilated geometries included."""
    rng = np.random.default_rng(1)
    rows = []
    for (kh, kw, stride, groups, dilation, h, w, cin, cout, vk,
         vn) in CONV_GEOMETRIES:
        base_us = None
        for density in densities:
            vs = _geom_vs(rng, kh, kw, cin, cout, vk, vn, groups, density)
            x = jnp.asarray(
                np.maximum(rng.standard_normal((4, h, w, cin)), 0),
                jnp.float32)
            # structural work: sparse grid steps vs dense K-tiles
            flop_ratio = vs.density
            # jnp structural path wall clock (CPU; demonstrates work∝density)
            fn = jax.jit(lambda xx: vs_conv2d(
                xx, vs, kh=kh, kw=kw, stride=stride, groups=groups,
                dilation=dilation, impl="jnp"))
            fn(x).block_until_ready()
            t0 = time.time()
            for _ in range(5):
                out = fn(x)
            out.block_until_ready()
            us = (time.time() - t0) / 5 * 1e6
            if base_us is None:
                base_us = us  # density 1.0 reference
            tag = (f"vsconv_{kh}x{kw}_s{stride}"
                   + (f"_g{groups}" if groups > 1 else "")
                   + (f"_d{dilation}" if dilation > 1 else ""))
            row = {
                "name": f"{tag}_density_{density}",
                "us_per_call": round(us, 1),
                "speedup_vs_dense": round(base_us / us, 3),
                "structural_flops_vs_dense": round(flop_ratio, 4),
                "expected": density,
            }
            row.update(_conv_bytes(kh, kw, stride, groups, dilation, h, w,
                                   cin, cout, vk, vn, vs.nnz_per_strip))
            # Pallas interpret parity at the smallest density only (slow):
            # both input layouts against the oracle
            if density == densities[-1]:
                ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride,
                                 groups=groups, dilation=dilation)
                for impl in ("halo", "stack"):
                    out_p = vsconv(x, vs, kh=kh, kw=kw, stride=stride,
                                   groups=groups, dilation=dilation,
                                   impl=impl)
                    row[f"pallas_{impl}_rel_err_vs_ref"] = float(
                        np.abs(np.asarray(out_p) - np.asarray(ref)).max()
                        / np.abs(np.asarray(ref)).max())
            rows.append(row)
    return rows


def _net_builders() -> dict:
    from repro.models.graph import (
        build_mobilenet_v1, build_resnet18, build_resnet34, build_resnet50,
        build_vgg16,
    )
    return {"vgg16": build_vgg16, "resnet18": build_resnet18,
            "resnet34": build_resnet34, "resnet50": build_resnet50,
            "mobilenet_v1": build_mobilenet_v1}


MEASURED_COLS = ("measured_us", "hlo_flops", "hlo_bytes", "measured_ai",
                 "flops_model_ratio", "modeled_flops", "predicted_us")


def _measured_vs_modeled(net, params, x, density) -> dict:
    """Per-layer measured-vs-modeled columns (`repro.core.calibration`):
    median wall clock, compiled-HLO FLOPs/bytes, and the calibrated time
    model's ``predicted_us``.  Reported next to the modeled columns, never
    gated — only the deterministic metrics are stable enough for that."""
    from repro.core.accel_model import load_calibration
    from repro.core.calibration import (
        attach_predictions, measured_vs_modeled_records,
    )

    recs = measured_vs_modeled_records(net, params, x, density=density,
                                       repeats=3, warmup=1)
    attach_predictions(recs, load_calibration())
    keep = MEASURED_COLS + ("modeled_cycles", "modeled_bytes", "modeled_ai",
                            "kind")
    return {r["layer"]: {k: (round(r[k], 3) if k == "predicted_us" else r[k])
                         for k in keep if k in r} for r in recs}


def run_network(net_name: str = "resnet18", densities=(1.0, 0.5, 0.25), *,
                image_size: int = 32, num_classes: int = 200, batch: int = 1,
                out_path: str | None = None,
                measure: bool = True, dtype: str = "f32") -> list[dict]:
    """Per-network per-layer speedup-vs-density through the graph executor.

    For each density: sparsify the whole network (BN folded, residuals
    fused, depthwise stages on the per-channel tap path), time the jnp
    structural forward (whole-net wall clock; CPU demonstrates work ∝
    density, not the TPU claim), and walk the same graph through the
    accelerator cycle model for per-layer VSCNN-vs-dense cycle speedups
    plus the DRAM traffic model for per-layer bytes / arithmetic intensity
    under both conv input layouts (halo vs stack).  With ``measure`` (the
    default) each per-layer row also carries the measured-vs-modeled
    columns — standalone-jitted wall clock, compiled-HLO FLOPs/bytes, and
    the calibrated model's ``predicted_us`` — and the FC head gets its own
    (ungated) row.  ``out_path`` writes the rows as a JSON artifact
    (``BENCH_<net>.json`` in CI).

    ``dtype="int8"`` runs the compound sparsity x precision path: weights
    quantized per-cout at sparsify time (power-of-two scales), activations
    quantized per-tensor at apply time, int32 accumulation, dequant fused
    into the epilogue.  The traffic model keys itemsizes off the stored
    weight dtype (int8 activation/weight bytes, f32 output bytes), and
    every ``__net__`` row gains int8-vs-f32 output-agreement columns
    (``max_abs_dlogit_vs_f32``, ``top1_match_vs_f32``) against the
    sparse-f32 forward at the same density on the same seeded input.
    The calibrated measured-vs-modeled columns are f32-only and skipped.
    """
    from repro.core.accel_model import PE_4_14_3, aggregate, \
        network_cycle_reports, network_traffic_reports
    from repro.models.graph import collect_conv_traffic, net_apply, sparsify
    from repro.models.layers import init_params

    if dtype not in ("f32", "int8"):
        raise ValueError(f"dtype must be 'f32' or 'int8', got {dtype!r}")
    int8 = dtype == "int8"
    net = _net_builders()[net_name](num_classes, image_size=image_size)
    params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((batch, image_size, image_size, 3)),
                    jnp.float32)
    pe = PE_4_14_3
    rows = []
    base_us = None
    for density in densities:
        sparse, pruned = sparsify(net, params, density,
                                  dtype="int8" if int8 else None)
        fn = jax.jit(lambda xx: net_apply(net, params, xx, sparse=sparse,
                                          impl="jnp"))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(3):
            out = fn(x)
        out.block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        if base_us is None:
            base_us = us  # density 1.0 reference
        agreement = {}
        if int8:
            # output agreement vs the sparse-f32 forward at the same
            # density (the dense-f32 reference is the density-1.0 row)
            sparse_f, _ = sparsify(net, params, density)
            ref = np.asarray(net_apply(net, params, x, sparse=sparse_f,
                                       impl="jnp"))
            got = np.asarray(out)
            agreement = {
                "max_abs_dlogit_vs_f32": round(
                    float(np.abs(got - ref).max()), 6),
                "top1_match_vs_f32": round(
                    float((got.argmax(-1) == ref.argmax(-1)).mean()), 4),
            }
        # cycle model on the pruned weights + real forward-pass activations,
        # DRAM traffic model on the encoded geometry (itemsizes keyed off
        # the stored weight dtype — int8 in/weight bytes, f32 out bytes)
        traffic = collect_conv_traffic(net, pruned, x[:1])
        reports = network_cycle_reports(traffic, pe)
        byte_reports = dict(network_traffic_reports(traffic, sparse))
        measured = _measured_vs_modeled(net, params, x, density) \
            if measure and not int8 else {}
        for name, rep in reports:
            layer = next(l for l in net.conv_layers() if l.name == name)
            tr = byte_reports[name]
            geom = f"{layer.kh}x{layer.kw}_s{layer.stride}"
            if layer.groups > 1:
                geom += "_dw" if layer.groups == layer.cin \
                    else f"_g{layer.groups}"
            if layer.dilation > 1:
                geom += f"_d{layer.dilation}"
            row = {
                "name": f"{net_name}_{name}_density_{density}",
                "layer": name,
                "geometry": geom,
                "density": density,
                "cycle_speedup": round(rep.speedup, 3),
                "vscnn_cycles": rep.vscnn,
                "dense_cycles": rep.dense,
                "structural_flops_vs_dense": round(
                    sparse[name].vs.density, 4),
                "bytes_halo": tr["halo"].bytes_accessed,
                "bytes_stack": tr["stack"].bytes_accessed,
                "ai_halo": round(tr["halo"].arithmetic_intensity, 2),
                "ai_stack": round(tr["stack"].arithmetic_intensity, 2),
            }
            if name in measured:
                row.update({k: v for k, v in measured[name].items()
                            if k in MEASURED_COLS})
            rows.append(row)
        # FC layers have no cycle-model row; their measured-vs-modeled
        # record rides along as its own (ungated: no cycle/bytes metrics)
        conv_names = {name for name, _ in reports}
        for name, m in measured.items():
            if name not in conv_names:
                rows.append({
                    "name": f"{net_name}_{name}_density_{density}",
                    "layer": name,
                    "geometry": "fc",
                    "density": density,
                    **m,
                })
        agg = aggregate([r for _, r in reports])
        rows.append({
            "name": f"{net_name}_net_density_{density}",
            "layer": "__net__",
            "density": density,
            "cycle_speedup": round(agg.speedup, 3),
            "vscnn_cycles": agg.vscnn,
            "dense_cycles": agg.dense,
            "us_per_call": round(us, 1),
            "wallclock_speedup_vs_dense": round(base_us / us, 3),
            "bytes_halo": sum(t["halo"].bytes_accessed
                              for t in byte_reports.values()),
            "bytes_stack": sum(t["stack"].bytes_accessed
                               for t in byte_reports.values()),
            **agreement,
        })
    if out_path:
        artifact = {
            "bench": f"{net_name}_per_layer",
            "net": net_name,
            "dtype": dtype,
            "image_size": image_size,
            "num_classes": num_classes,
            "batch": batch,
            "pe": [pe.blocks, pe.rows, pe.cols],
            "densities": list(densities),
            "rows": rows,
        }
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return rows


def run_resnet18(densities=(1.0, 0.5, 0.25), *, image_size: int = 32,
                 num_classes: int = 200, batch: int = 1,
                 out_path: str | None = None) -> list[dict]:
    """Back-compat alias for `run_network("resnet18", ...)`."""
    return run_network("resnet18", densities, image_size=image_size,
                       num_classes=num_classes, batch=batch,
                       out_path=out_path)


# --------------------------------------------------------------------------
# Benchmark-regression gate (--compare-baseline)
# --------------------------------------------------------------------------

# per-layer metrics gated against the committed baseline.  Wall-clock
# columns are deliberately absent: only deterministic model outputs (cycle
# counts from seeded weights/activations, modeled bytes from the encoded
# geometry) are stable enough to gate at 10%.
COMPARE_HIGHER_IS_BETTER = ("cycle_speedup",)
COMPARE_LOWER_IS_BETTER = ("bytes_halo", "bytes_stack")


def compare_baseline(rows: list[dict], baseline: dict, *,
                     tol: float = 0.10) -> tuple[list[str], list[str]]:
    """Compare fresh bench rows against a committed baseline artifact.

    Returns ``(failures, table_lines)``: a failure for every per-layer
    metric that regressed by more than ``tol`` (speedup down >10%, or
    modeled bytes up >10%) and for every baseline row that vanished; the
    table is a GitHub-flavoured markdown per-layer delta table for
    ``$GITHUB_STEP_SUMMARY``.  Rows new in this run (new layers/nets) pass
    — they have no baseline to regress against.
    """
    cur = {r["name"]: r for r in rows}
    failures: list[str] = []
    lines = [
        "| layer row | metric | baseline | current | delta | status |",
        "|---|---|---|---|---|---|",
    ]
    for b in baseline["rows"]:
        name = b["name"]
        c = cur.get(name)
        if c is None:
            failures.append(f"{name}: row missing from current bench")
            lines.append(f"| {name} | — | — | MISSING | — | FAIL |")
            continue
        for metric, better in (
            [(m, "higher") for m in COMPARE_HIGHER_IS_BETTER]
            + [(m, "lower") for m in COMPARE_LOWER_IS_BETTER]
        ):
            if metric not in b or metric not in c:
                continue
            bv, cv = float(b[metric]), float(c[metric])
            delta = (cv - bv) / max(abs(bv), 1e-12)
            if better == "higher":
                bad = cv < bv * (1.0 - tol)
            else:
                bad = cv > bv * (1.0 + tol)
            status = "FAIL" if bad else "ok"
            if bad:
                failures.append(
                    f"{name}: {metric} {bv:g} -> {cv:g} "
                    f"({delta:+.1%}, tol ±{tol:.0%})")
            lines.append(
                f"| {name} | {metric} | {bv:g} | {cv:g} | {delta:+.1%} "
                f"| {status} |")
    return failures, lines


def gate_baseline(baseline_path: str, *, tol: float = 0.10,
                  out_path: str | None = None) -> int:
    """CI regression gate: re-run the per-network bench at the committed
    baseline's settings and fail on any >tol per-layer regression.  Writes
    the per-layer delta table to ``$GITHUB_STEP_SUMMARY`` when set;
    ``out_path`` writes the fresh rows as the run's bench artifact (so the
    gate run doubles as the trajectory artifact — no second bench pass)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    rows = run_network(
        baseline.get("net", "resnet18"),
        tuple(baseline["densities"]),
        image_size=baseline["image_size"],
        num_classes=baseline["num_classes"],
        batch=baseline.get("batch", 1),
        dtype=baseline.get("dtype", "f32"),
        out_path=out_path,
    )
    failures, lines = compare_baseline(rows, baseline, tol=tol)
    summary = "\n".join(
        [f"## Benchmark regression gate — `{baseline_path}` "
         f"({'FAIL' if failures else 'PASS'})", ""]
        + lines + [""]
        + [f"- {f}" for f in failures])
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(summary + "\n")
    print(summary)
    if failures:
        print(f"baseline gate: FAIL ({len(failures)} regression(s))")
        return 1
    print("baseline gate: PASS")
    return 0


def gate_int8_traffic(*, ratio_max: float = 0.55) -> bool:
    """Per-layer dtype half of the traffic gate: on every weight-carrying
    layer of resnet18 (every conv, both input layouts, plus the FC head)
    the int8 contract's modeled HBM bytes must be strictly below the f32
    contract's — and at most ``ratio_max`` of it (int8 activations+weights
    at 1 byte, the f32 output stream unchanged)."""
    from repro.core.accel_model import network_traffic_reports
    from repro.kernels.plan import fc_plan
    from repro.models.graph import collect_conv_traffic, sparsify
    from repro.models.layers import init_params

    net = _net_builders()["resnet18"](200, image_size=32)
    params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(
        np.random.default_rng(3).standard_normal((1, 32, 32, 3)),
        jnp.float32)
    ok = True
    worst = 0.0
    per_layer: dict[str, dict[str, int]] = {}
    for dt in ("f32", "int8"):
        sparse, pruned = sparsify(net, params, 0.5,
                                  dtype="int8" if dt == "int8" else None)
        traffic = collect_conv_traffic(net, pruned, x)
        for name, tr in network_traffic_reports(traffic, sparse):
            for impl in ("halo", "stack"):
                per_layer.setdefault(f"{name}[{impl}]", {})[dt] = \
                    tr[impl].bytes_accessed
        # FC head: quote the vsmm plan's cost under this dtype contract
        fc = sparse.get("fc")
        if fc is not None:
            a_i, w_i, o_i = (1, 1, 4) if dt == "int8" else (4, 4, 4)
            plan = fc_plan(
                m=1, k=fc.vs.shape[0], s_steps=fc.vs.nnz_per_strip,
                vk=fc.vs.vk, vn=fc.vs.vn, nb=fc.vs.vals.shape[0],
                has_bias=True, has_scale=dt == "int8", itemsize=a_i,
                w_itemsize=w_i, out_itemsize=o_i)
            per_layer.setdefault("fc[vsmm]", {})[dt] = \
                plan.cost.bytes_accessed
    for name, b in sorted(per_layer.items()):
        r = b["int8"] / b["f32"]
        worst = max(worst, r)
        bad = not (b["int8"] < b["f32"] and r <= ratio_max)
        if bad:
            print(f"FAIL: {name}: int8 {b['int8']:,} B vs f32 "
                  f"{b['f32']:,} B (ratio {r:.3f} > {ratio_max})")
            ok = False
    print(f"int8 traffic gate: {len(per_layer)} weight-carrying layer "
          f"rows, worst int8/f32 byte ratio {worst:.3f} "
          f"(bound {ratio_max})")
    return ok


def gate_traffic() -> int:
    """CI smoke gate for the halo layout's bandwidth claim.

    Runs both conv impls in interpret mode (allclose vs the oracle) and
    checks the modeled HBM bytes — the halo path must be *strictly below*
    the stack path — on two geometries: the ResNet 7x7/s2 stem and a
    MobileNetV1 depthwise 3x3/s2 layer (512 channels, the stage-4
    downsample), each at the ImageNet size and the reduced CI size.
    Also asserts the int8 dtype contract's modeled bytes are strictly
    below (and at most 0.55x) the f32 contract's on every weight-carrying
    resnet18 layer (`gate_int8_traffic`).  Returns a process exit code.
    """
    from repro.core import conv_cin_major
    from repro.core.accel_model import conv_layer_traffic

    rng = np.random.default_rng(7)
    ok = True

    # --- ResNet 7x7/s2 stem -------------------------------------------------
    kh, kw, stride, cin, cout, vk, vn = 7, 7, 2, 8, 64, 8, 64
    wm = rng.standard_normal((kh * kw * cin, cout)).astype(np.float32)
    vs = conv_cin_major(encode(jnp.asarray(wm), vk, vn), cin // vk)
    x = jnp.asarray(
        np.maximum(rng.standard_normal((1, 28, 28, cin)), 0), jnp.float32)
    ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
    for impl in ("halo", "stack"):
        out = vsconv(x, vs, kh=kh, kw=kw, stride=stride, impl=impl)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        print(f"stem 7x7/s2 {impl}: rel err vs ref {rel:.2e}")
        ok &= rel < 1e-5
    for h in (28, 224):
        tr = {impl: conv_layer_traffic(
                  (1, h, h, cin), kh=kh, kw=kw, stride=stride, cout=cout,
                  s_steps=vs.nnz_per_strip, vk=vk, vn=vn, impl=impl)
              for impl in ("halo", "stack")}
        ratio = tr["stack"].bytes_accessed / max(tr["halo"].bytes_accessed, 1)
        print(f"stem 7x7/s2 @{h}: halo {tr['halo'].bytes_accessed:,} B, "
              f"stack {tr['stack'].bytes_accessed:,} B "
              f"(stack/halo {ratio:.2f}x)")
        if not tr["halo"].bytes_accessed < tr["stack"].bytes_accessed:
            print("FAIL: halo modeled bytes not strictly below stack")
            ok = False

    # --- MobileNetV1 depthwise 3x3/s2 (512ch stage-4 downsample) ------------
    kh, kw, stride, c, vc = 3, 3, 2, 512, 128
    wm = rng.standard_normal((kh * kw, c)).astype(np.float32)
    dvs = encode(jnp.asarray(
        prune_vectors_balanced(wm, 0.5, 1, vc)[0]), 1, vc)
    x = jnp.asarray(
        np.maximum(rng.standard_normal((1, 14, 14, c)), 0), jnp.float32)
    ref = vsconv_ref(x, dvs, kh=kh, kw=kw, stride=stride, groups=c)
    for impl in ("halo", "stack"):
        out = vsconv(x, dvs, kh=kh, kw=kw, stride=stride, groups=c,
                     impl=impl)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        print(f"dw 3x3/s2 {impl}: rel err vs ref {rel:.2e}")
        ok &= rel < 1e-5
    for h in (14, 28):
        tr = {impl: conv_layer_traffic(
                  (1, h, h, c), kh=kh, kw=kw, stride=stride, groups=c,
                  cout=c, s_steps=dvs.nnz_per_strip, vk=1, vn=vc, impl=impl)
              for impl in ("halo", "stack")}
        ratio = tr["stack"].bytes_accessed / max(tr["halo"].bytes_accessed, 1)
        print(f"dw 3x3/s2 @{h}: halo {tr['halo'].bytes_accessed:,} B, "
              f"stack {tr['stack'].bytes_accessed:,} B "
              f"(stack/halo {ratio:.2f}x)")
        if not tr["halo"].bytes_accessed < tr["stack"].bytes_accessed:
            print("FAIL: halo modeled bytes not strictly below stack (dw)")
            ok = False

    ok &= gate_int8_traffic()

    print("traffic gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default=None,
                    choices=["vgg16", "resnet18", "resnet34", "resnet50",
                             "mobilenet_v1"],
                    help="run a per-layer network table instead of the "
                         "kernel micro-benches")
    ap.add_argument("--resnet18", action="store_true",
                    help="alias for --net resnet18")
    ap.add_argument("--gate-traffic", action="store_true",
                    help="CI gate: both conv impls on the 7x7/s2 stem and a "
                         "depthwise 3x3/s2 MobileNet layer; fail unless the "
                         "halo path's modeled bytes_accessed is strictly "
                         "below the stack path's")
    ap.add_argument("--compare-baseline", default=None, metavar="PATH",
                    help="CI gate: re-run the per-network bench at the "
                         "committed baseline's settings and fail on a >10%% "
                         "per-layer cycle-speedup or modeled-bytes "
                         "regression (delta table to $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--tol", type=float, default=0.10,
                    help="regression tolerance for --compare-baseline")
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--classes", type=int, default=200)
    ap.add_argument("--dtype", default="f32", choices=["f32", "int8"],
                    help="weight/activation precision for the per-network "
                         "bench: int8 runs the compound sparsity x "
                         "precision path with output-agreement columns "
                         "vs the sparse-f32 forward")
    ap.add_argument("--out", default=None,
                    help="write rows as a JSON artifact "
                         "(e.g. BENCH_resnet18.json)")
    args = ap.parse_args()
    if args.gate_traffic:
        raise SystemExit(gate_traffic())
    if args.compare_baseline:
        raise SystemExit(gate_baseline(args.compare_baseline, tol=args.tol,
                                       out_path=args.out))
    net = args.net or ("resnet18" if args.resnet18 else None)
    if net:
        for r in run_network(net, image_size=args.size,
                             num_classes=args.classes, dtype=args.dtype,
                             out_path=args.out):
            print(r)
    else:
        for r in run():
            print(r)
