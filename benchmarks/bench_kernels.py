"""Kernel-level benches: the TPU analogue of the paper's cycle savings.

1. Structural FLOP scaling: compiled HLO FLOPs of the vector-sparse matmul
   vs density — the zero weight vectors are absent from the compiled
   program exactly as they are absent from the paper's SRAM (compare with
   the dense baseline at density 1.0).
2. Wall-clock on CPU for the jnp structural path (CPU timing is NOT the TPU
   claim — it demonstrates the cycle model's work∝density on a real
   backend).
3. Pallas kernel allclose + grid-size-vs-density check (interpret mode).
4. Generalized conv geometry sweep: per-(kernel, stride) speedup-vs-density
   rows for the vsconv kernel family (1x1 / 3x3 / 5x5 / 7x7, stride 1-2),
   reporting the structural FLOP ratio, jnp-path wall clock, interpret-mode
   parity for *both* conv input layouts (halo direct input vs row-tap
   stack), and the modeled HBM bytes of each layout — the bandwidth story
   is part of the benchmarked contract, not just the MAC skips.
5. ResNet-18 per-layer speedup-vs-density (``--resnet18``): the graph
   executor + cycle model walked over every conv (residual blocks, BN
   folded), emitting a ``BENCH_resnet18.json`` artifact so CI tracks the
   perf trajectory — now with per-layer bytes / arithmetic-intensity
   columns for the halo and stack layouts.
6. ``--gate-traffic``: CI smoke gate — runs both impls on the ResNet
   7x7/s2 stem geometry (interpret parity) and fails unless the halo
   path's modeled ``bytes_accessed`` is strictly below the stack path's.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encode, prune_vectors_balanced, vs_conv2d, vs_matmul
from repro.kernels import vsconv, vsmm
from repro.kernels.ref import vsconv_ref, vsmm_ref


def _sparse(rng, k, n, vk, vn, density, dtype=jnp.float32):
    w = rng.standard_normal((k, n)).astype(np.float32)
    wp, _ = prune_vectors_balanced(w, density, vk, vn)
    return encode(jnp.asarray(wp, dtype), vk, vn)


def hlo_flops(fn, *args) -> float:
    # the structural path is a scan over S steps: XLA's cost_analysis counts
    # the body once, so use the trip-multiplying analyzer (utils.hlo)
    from repro.utils.hlo import analyze
    return analyze(jax.jit(fn).lower(*args).compile().as_text()).flops


def run() -> list[dict]:
    rng = np.random.default_rng(0)
    rows = []
    m, k, n, vk, vn = 256, 2048, 2048, 32, 128
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)

    dense_flops = None
    for density in (1.0, 0.5, 0.25, 0.125):
        vs = _sparse(rng, k, n, vk, vn, density)
        f = hlo_flops(lambda xx: vs_matmul(xx, vs), x)
        if dense_flops is None:
            dense_flops = f
        # wall time (CPU, jnp structural path)
        fn = jax.jit(lambda xx: vs_matmul(xx, vs))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(20):
            out = fn(x)
        out.block_until_ready()
        us = (time.time() - t0) / 20 * 1e6
        rows.append({
            "name": f"vsmm_structural_density_{density}",
            "us_per_call": round(us, 1),
            "hlo_flops": f,
            "flops_vs_dense": round(f / dense_flops, 4),
            "expected": density,
        })

    # Pallas kernel correctness + structural grid scaling
    for density in (1.0, 0.25):
        vs = _sparse(rng, 512, 512, 32, 128, density)
        xs = jnp.asarray(rng.standard_normal((64, 512)), jnp.float32)
        t0 = time.time()
        out = vsmm(xs, vs)
        us = (time.time() - t0) * 1e6
        ref = vsmm_ref(xs, vs)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        rows.append({
            "name": f"vsmm_pallas_density_{density}",
            "us_per_call": round(us, 1),
            "rel_err_vs_ref": rel,
            "grid_sparse_steps": vs.nnz_per_strip,
            "grid_dense_steps": vs.kb,
        })

    rows += run_conv_geometries()
    return rows


# (kh, kw, stride, h, w, cin, cout, vk, vn) — the generalized kernel family:
# VGG's 3x3/s1 plus the ResNet vocabulary (7x7-s2 stem, 1x1 projection,
# stride-2 downsample) and a 5x5 mid-size tap.
CONV_GEOMETRIES = [
    (1, 1, 1, 28, 28, 128, 128, 32, 128),
    (1, 1, 2, 28, 28, 128, 128, 32, 128),
    (3, 3, 1, 28, 28, 64, 128, 32, 128),
    (3, 3, 2, 28, 28, 64, 128, 32, 128),
    (5, 5, 1, 14, 14, 32, 128, 32, 128),
    (7, 7, 2, 28, 28, 8, 64, 8, 64),
]


def _conv_bytes(kh, kw, stride, h, w, cin, cout, vk, vn, s_steps,
                batch: int = 4) -> dict:
    """Modeled HBM bytes + arithmetic intensity for both conv layouts."""
    from repro.core.accel_model import conv_layer_traffic

    out = {}
    for impl in ("halo", "stack"):
        tr = conv_layer_traffic(
            (batch, h, w, cin), kh=kh, kw=kw, stride=stride, cout=cout,
            s_steps=s_steps, vk=vk, vn=vn, impl=impl)
        out[f"bytes_{impl}"] = tr.bytes_accessed
        out[f"ai_{impl}"] = round(tr.arithmetic_intensity, 2)
    return out


def run_conv_geometries(densities=(1.0, 0.5, 0.25)) -> list[dict]:
    """Per-geometry speedup-vs-density: structural FLOP ratio (the kernel's
    grid shrinks with density), jnp-path wall clock, modeled HBM bytes for
    the halo and stack layouts, and Pallas interpret parity of both impls
    vs the oracle."""
    from repro.core import conv_cin_major

    rng = np.random.default_rng(1)
    rows = []
    for kh, kw, stride, h, w, cin, cout, vk, vn in CONV_GEOMETRIES:
        base_us = None
        for density in densities:
            wm = rng.standard_normal((kh * kw * cin, cout)).astype(np.float32)
            wp, _ = prune_vectors_balanced(wm, density, vk, vn)
            vs = encode(jnp.asarray(wp), vk, vn)
            if kh * kw > 1:
                vs = conv_cin_major(vs, cin // vk)  # the serving tile order
            x = jnp.asarray(
                np.maximum(rng.standard_normal((4, h, w, cin)), 0),
                jnp.float32)
            # structural work: sparse grid steps vs dense K-tiles
            flop_ratio = vs.density
            # jnp structural path wall clock (CPU; demonstrates work∝density)
            fn = jax.jit(lambda xx: vs_conv2d(
                xx, vs, kh=kh, kw=kw, stride=stride, impl="jnp"))
            fn(x).block_until_ready()
            t0 = time.time()
            for _ in range(5):
                out = fn(x)
            out.block_until_ready()
            us = (time.time() - t0) / 5 * 1e6
            if base_us is None:
                base_us = us  # density 1.0 reference
            row = {
                "name": f"vsconv_{kh}x{kw}_s{stride}_density_{density}",
                "us_per_call": round(us, 1),
                "speedup_vs_dense": round(base_us / us, 3),
                "structural_flops_vs_dense": round(flop_ratio, 4),
                "expected": density,
            }
            row.update(_conv_bytes(kh, kw, stride, h, w, cin, cout, vk, vn,
                                   vs.nnz_per_strip))
            # Pallas interpret parity at the smallest density only (slow):
            # both input layouts against the oracle
            if density == densities[-1]:
                ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
                for impl in ("halo", "stack"):
                    out_p = vsconv(x, vs, kh=kh, kw=kw, stride=stride,
                                   impl=impl)
                    row[f"pallas_{impl}_rel_err_vs_ref"] = float(
                        np.abs(np.asarray(out_p) - np.asarray(ref)).max()
                        / np.abs(np.asarray(ref)).max())
            rows.append(row)
    return rows


def run_resnet18(densities=(1.0, 0.5, 0.25), *, image_size: int = 32,
                 num_classes: int = 200, batch: int = 1,
                 out_path: str | None = None) -> list[dict]:
    """ResNet-18 per-layer speedup-vs-density through the graph executor.

    For each density: sparsify the whole network (BN folded, residuals
    fused), time the jnp structural forward (whole-net wall clock; CPU
    demonstrates work ∝ density, not the TPU claim), and walk the same
    graph through the accelerator cycle model for per-layer VSCNN-vs-dense
    cycle speedups plus the DRAM traffic model for per-layer bytes /
    arithmetic intensity under both conv input layouts (halo vs stack).
    ``out_path`` writes the rows as a JSON artifact.
    """
    from repro.core.accel_model import PE_4_14_3, aggregate, \
        network_cycle_reports, network_traffic_reports
    from repro.models.graph import build_resnet18, collect_conv_traffic, \
        net_apply, sparsify
    from repro.models.layers import init_params

    net = build_resnet18(num_classes, image_size=image_size)
    params = init_params(net.schema(), jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((batch, image_size, image_size, 3)),
                    jnp.float32)
    pe = PE_4_14_3
    rows = []
    base_us = None
    for density in densities:
        sparse, pruned = sparsify(net, params, density)
        fn = jax.jit(lambda xx: net_apply(net, params, xx, sparse=sparse,
                                          impl="jnp"))
        fn(x).block_until_ready()
        t0 = time.time()
        for _ in range(3):
            out = fn(x)
        out.block_until_ready()
        us = (time.time() - t0) / 3 * 1e6
        if base_us is None:
            base_us = us  # density 1.0 reference
        # cycle model on the pruned weights + real forward-pass activations,
        # DRAM traffic model on the encoded geometry
        traffic = collect_conv_traffic(net, pruned, x[:1])
        reports = network_cycle_reports(traffic, pe)
        byte_reports = dict(network_traffic_reports(traffic, sparse))
        for name, rep in reports:
            layer = next(l for l in net.conv_layers() if l.name == name)
            tr = byte_reports[name]
            rows.append({
                "name": f"resnet18_{name}_density_{density}",
                "layer": name,
                "geometry": f"{layer.kh}x{layer.kw}_s{layer.stride}",
                "density": density,
                "cycle_speedup": round(rep.speedup, 3),
                "vscnn_cycles": rep.vscnn,
                "dense_cycles": rep.dense,
                "structural_flops_vs_dense": round(
                    sparse[name].vs.density, 4),
                "bytes_halo": tr["halo"].bytes_accessed,
                "bytes_stack": tr["stack"].bytes_accessed,
                "ai_halo": round(tr["halo"].arithmetic_intensity, 2),
                "ai_stack": round(tr["stack"].arithmetic_intensity, 2),
            })
        agg = aggregate([r for _, r in reports])
        rows.append({
            "name": f"resnet18_net_density_{density}",
            "layer": "__net__",
            "density": density,
            "cycle_speedup": round(agg.speedup, 3),
            "vscnn_cycles": agg.vscnn,
            "dense_cycles": agg.dense,
            "us_per_call": round(us, 1),
            "wallclock_speedup_vs_dense": round(base_us / us, 3),
            "bytes_halo": sum(t["halo"].bytes_accessed
                              for t in byte_reports.values()),
            "bytes_stack": sum(t["stack"].bytes_accessed
                               for t in byte_reports.values()),
        })
    if out_path:
        artifact = {
            "bench": "resnet18_per_layer",
            "image_size": image_size,
            "num_classes": num_classes,
            "pe": [pe.blocks, pe.rows, pe.cols],
            "densities": list(densities),
            "rows": rows,
        }
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1)
    return rows


def gate_traffic() -> int:
    """CI smoke gate for the halo layout's bandwidth claim.

    Runs both conv impls on the ResNet 7x7/s2 stem geometry in interpret
    mode (allclose vs the oracle) and checks the modeled HBM bytes: the
    halo path must be *strictly below* the stack path — at the ImageNet
    stem size and at the reduced CI size.  Returns a process exit code.
    """
    from repro.core import conv_cin_major
    from repro.core.accel_model import conv_layer_traffic

    kh, kw, stride, cin, cout, vk, vn = 7, 7, 2, 8, 64, 8, 64
    rng = np.random.default_rng(7)
    wm = rng.standard_normal((kh * kw * cin, cout)).astype(np.float32)
    vs = conv_cin_major(encode(jnp.asarray(wm), vk, vn), cin // vk)
    x = jnp.asarray(
        np.maximum(rng.standard_normal((1, 28, 28, cin)), 0), jnp.float32)
    ref = vsconv_ref(x, vs, kh=kh, kw=kw, stride=stride)
    ok = True
    for impl in ("halo", "stack"):
        out = vsconv(x, vs, kh=kh, kw=kw, stride=stride, impl=impl)
        rel = float(np.abs(np.asarray(out) - np.asarray(ref)).max()
                    / np.abs(np.asarray(ref)).max())
        print(f"stem 7x7/s2 {impl}: rel err vs ref {rel:.2e}")
        ok &= rel < 1e-5
    for h in (28, 224):
        tr = {impl: conv_layer_traffic(
                  (1, h, h, cin), kh=kh, kw=kw, stride=stride, cout=cout,
                  s_steps=vs.nnz_per_strip, vk=vk, vn=vn, impl=impl)
              for impl in ("halo", "stack")}
        ratio = tr["stack"].bytes_accessed / max(tr["halo"].bytes_accessed, 1)
        print(f"stem 7x7/s2 @{h}: halo {tr['halo'].bytes_accessed:,} B, "
              f"stack {tr['stack'].bytes_accessed:,} B "
              f"(stack/halo {ratio:.2f}x)")
        if not tr["halo"].bytes_accessed < tr["stack"].bytes_accessed:
            print("FAIL: halo modeled bytes not strictly below stack")
            ok = False
    print("traffic gate:", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--resnet18", action="store_true",
                    help="run the ResNet-18 per-layer table instead of the "
                         "kernel micro-benches")
    ap.add_argument("--gate-traffic", action="store_true",
                    help="CI gate: both conv impls on the 7x7/s2 stem; fail "
                         "unless the halo path's modeled bytes_accessed is "
                         "strictly below the stack path's")
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--classes", type=int, default=200)
    ap.add_argument("--out", default=None,
                    help="write rows as a JSON artifact "
                         "(e.g. BENCH_resnet18.json)")
    args = ap.parse_args()
    if args.gate_traffic:
        raise SystemExit(gate_traffic())
    if args.resnet18:
        for r in run_resnet18(image_size=args.size, num_classes=args.classes,
                              out_path=args.out):
            print(r)
    else:
        for r in run():
            print(r)
