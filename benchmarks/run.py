"""Benchmark harness: one module per paper table/figure + kernel benches.

  bench_table1   -- Table I (5x5 micro example cycle counts)
  bench_density  -- Figs 9-11 (input/weight/work density, fine vs vector)
  bench_speedup  -- Figs 12-13 + SIV (VGG-16 speedup on both PE configs)
  bench_kernels  -- TPU-analogue structural-FLOP scaling + Pallas allclose

Prints one CSV-ish line per result; exits nonzero if a paper-validation
check fails.  Roofline terms for the assigned architectures come from the
dry-run (benchmarks/results/dryrun*.json), not from this harness.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--image-size", type=int, default=224,
                    help="VGG input resolution (small for CI, 224 = paper)")
    ap.add_argument("--out", default="benchmarks/results/bench.json")
    ap.add_argument("--skip", nargs="*", default=[])
    args = ap.parse_args()

    from . import bench_table1, bench_density, bench_speedup, bench_kernels

    suites = [
        ("table1", lambda: bench_table1.run()),
        ("density", lambda: bench_density.run(image_size=args.image_size)),
        ("speedup", lambda: bench_speedup.run(image_size=args.image_size)),
        ("kernels", lambda: bench_kernels.run()),
    ]
    all_rows, failed = [], []
    for name, fn in suites:
        if name in args.skip:
            continue
        t0 = time.time()
        rows = fn()
        dt = time.time() - t0
        print(f"# suite {name}: {len(rows)} rows in {dt:.1f}s")
        for r in rows:
            all_rows.append(r)
            print(",".join(f"{k}={v}" for k, v in r.items()))
            if r.get("match") is False or r.get("in_validation_band") is False:
                failed.append(r["name"])

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# wrote {len(all_rows)} rows -> {args.out}")
    if failed:
        print(f"# VALIDATION FAILURES: {failed}")
        return 1
    print("# all paper validations passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
