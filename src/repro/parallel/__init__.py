"""Distribution: logical-axis sharding, sharded losses, grad compression."""
from .sharding import (
    MeshRules, use_mesh, current, logical, spec_for, named_sharding,
    sharding_tree, TRAIN_RULES, SERVE_RULES,
)
from .losses import chunked_cross_entropy, cross_entropy_dense
from . import compression, pipeline
