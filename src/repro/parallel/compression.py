"""Cross-pod gradient compression: fp8-block all-reduce with error feedback.

At multi-pod scale the 'pod' axis rides DCN (much lower bandwidth than
in-pod ICI), so the cross-pod leg of the gradient reduction is the one worth
compressing.  Wire format: per-block (fp8 values, fp32 amax scale) — an 8x
volume cut on the DCN hop vs fp32, ~2x vs bf16.  Error feedback accumulates
the quantization residual into the next step so the compression is unbiased
over time (Seide et al. / EF-SGD).

`compressed_psum(x, axis, err)` is the primitive (usable under shard_map
over the pod axis with `auto` in-pod axes); `apply_to_grads` wraps a whole
gradient pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["quantize_fp8_block", "dequantize_fp8_block", "compressed_psum",
           "apply_to_grads", "init_error_state"]

FP8 = jnp.float8_e4m3fn
FP8_MAX = 448.0
BLOCK = 512


def _pad_to(x: jax.Array, m: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % m
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, pad


def quantize_fp8_block(x: jax.Array, block: int = BLOCK):
    """x -> (fp8 values (Nb, block), fp32 scales (Nb,), pad)."""
    flat, pad = _pad_to(x.astype(jnp.float32), block)
    blocks = flat.reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax / FP8_MAX, 1e-12)
    q = (blocks / scale).astype(FP8)
    return q, scale[:, 0], pad


def dequantize_fp8_block(q, scale, pad, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_psum(x: jax.Array, axis: str, err: jax.Array,
                    block: int = BLOCK):
    """Sum x over `axis` with fp8 wire format + error feedback.

    Semantics: each peer quantizes (x + err); the quantized blocks are
    all-gathered (fp8 on the wire) and summed locally in fp32.  Returns
    (sum, new_err) where new_err is this peer's quantization residual.
    """
    target = x.astype(jnp.float32) + err
    q, scale, pad = quantize_fp8_block(target, block)
    local_deq = dequantize_fp8_block(q, scale, pad, x.shape)
    new_err = target - local_deq
    q_all = jax.lax.all_gather(q, axis)          # (P, Nb, block) fp8 wire
    s_all = jax.lax.all_gather(scale, axis)      # (P, Nb) fp32 (tiny)
    total = jnp.einsum(
        "pnb,pn->nb", q_all.astype(jnp.float32), s_all
    ).reshape(-1)
    if pad:
        total = total[:-pad]
    return total.reshape(x.shape).astype(x.dtype), new_err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def apply_to_grads(grads, err_state, axis: str, block: int = BLOCK):
    """Compressed-psum every leaf; returns (summed grads, new error state)."""
    out = jax.tree.map(
        lambda g, e: compressed_psum(g, axis, e, block), grads, err_state
    )
    summed = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return summed, errs
