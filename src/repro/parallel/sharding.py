"""Logical-axis sharding: one rules table maps model-code axis names to mesh axes.

Model code never mentions mesh axes.  It annotates arrays with *logical* axis
names (``('batch', 'seq', 'embed')``); the active `MeshRules` maps each name
to a physical mesh axis (or None = replicated).  A shape-divisibility guard
demotes any dim that does not divide evenly over its mesh axis to replicated,
so e.g. 8 KV heads on a 16-way model axis degrade gracefully instead of
failing to lower.

Used three ways:
  * activation constraints inside model code      -> `logical(x, axes)`
  * param / optimizer-state shardings for jit     -> `sharding_tree(axes_tree)`
  * input/output shardings for the dry-run        -> `named_sharding(axes)`
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "MeshRules",
    "MeshContext",
    "use_mesh",
    "current",
    "logical",
    "spec_for",
    "named_sharding",
    "sharding_tree",
    "TRAIN_RULES",
    "SERVE_RULES",
]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """logical axis name -> mesh axis name(s) or None (replicated).

    The default tables implement the posture in DESIGN.md §6:
      batch   -> ('pod', 'data')      DP across pods and in-pod data axis
      heads   -> 'model'              TP attention (when divisible)
      ff/vocab/expert -> 'model'      TP FFN / vocab-sharded logits / EP
      fsdp    -> 'data'               ZeRO-3 param+state sharding dim
      kv_seq  -> 'model'              sequence-sharded KV cache (decode)
      seq_sp  -> 'model'              sequence-parallel attention activations
    """

    rules: tuple[tuple[str, object], ...]

    def get(self, name: str):
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def replace(self, **updates) -> "MeshRules":
        d = dict(self.rules)
        d.update(updates)
        return MeshRules(tuple(d.items()))


def _mk(**kw) -> MeshRules:
    return MeshRules(tuple(kw.items()))


# Training posture: DP(+pod) x TP, FSDP over data.
TRAIN_RULES = _mk(
    batch=("pod", "data"),
    seq=None,
    seq_sp="model",
    embed=None,
    heads="model",
    kv_heads="model",
    head_dim=None,
    ff="model",
    vocab="model",
    expert="model",
    fsdp="data",
    kv_seq="model",
    stack=None,
    conv=None,
)

# Serving posture: params stay sharded (TP + fsdp dim over data so 1T fits),
# KV cache sequence-sharded over the model axis (flash-decoding layout).
SERVE_RULES = TRAIN_RULES

_local = threading.local()


@dataclasses.dataclass
class MeshContext:
    mesh: Mesh
    rules: MeshRules


def current() -> MeshContext | None:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: MeshRules = TRAIN_RULES):
    """Activate (mesh, rules) for `logical` constraints, and enter the mesh."""
    prev = current()
    _local.ctx = MeshContext(mesh, rules)
    try:
        with mesh:
            yield _local.ctx
    finally:
        _local.ctx = prev


def _axis_size(mesh: Mesh, phys) -> int:
    if phys is None:
        return 1
    if isinstance(phys, (tuple, list)):
        n = 1
        for p in phys:
            n *= mesh.shape[p]
        return n
    return mesh.shape[phys]


def spec_for(axes, *, mesh: Mesh, rules: MeshRules, shape=None) -> PartitionSpec:
    """Logical axes -> PartitionSpec, demoting non-divisible dims to None.

    ``axes`` may contain None entries (explicitly replicated dims).  If
    ``shape`` is given, any dim whose size does not divide over its mapped
    mesh axes is replicated instead (graceful GQA/odd-head degradation).
    Mesh axes must not repeat within one spec; later occurrences demote.
    """
    used: set[str] = set()
    out = []
    for i, name in enumerate(axes):
        phys = rules.get(name) if name is not None else None
        if phys is not None:
            flat = tuple(phys) if isinstance(phys, (tuple, list)) else (phys,)
            # drop axes absent from this mesh (e.g. 'pod' on the single-pod mesh)
            flat = tuple(p for p in flat if p in mesh.shape)
            if not flat or any(p in used for p in flat):
                phys = None
            elif shape is not None and shape[i] % _axis_size(mesh, flat) != 0:
                phys = None
            else:
                used.update(flat)
                phys = flat if len(flat) > 1 else flat[0]
        out.append(phys)
    return PartitionSpec(*out)


def named_sharding(axes, *, shape=None, ctx: MeshContext | None = None) -> NamedSharding:
    ctx = ctx or current()
    assert ctx is not None, "named_sharding requires an active use_mesh()"
    return NamedSharding(ctx.mesh, spec_for(axes, mesh=ctx.mesh, rules=ctx.rules, shape=shape))


def logical(x: jax.Array, axes) -> jax.Array:
    """Constrain activation sharding by logical axes; no-op outside use_mesh."""
    ctx = current()
    if ctx is None:
        return x
    spec = spec_for(axes, mesh=ctx.mesh, rules=ctx.rules, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def sharding_tree(axes_tree, shape_tree=None, *, ctx: MeshContext | None = None):
    """Tree of logical-axes tuples (+ optional matching shapes) -> NamedShardings."""
    ctx = ctx or current()
    assert ctx is not None

    def one(axes, shape=None):
        return named_sharding(axes, shape=shape, ctx=ctx)

    if shape_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=lambda t: isinstance(t, tuple))
    return jax.tree.map(
        lambda a, s: one(a, shape=s),
        axes_tree,
        shape_tree,
        is_leaf=lambda t: isinstance(t, tuple),
    )
