"""Vocab-sharded, chunked cross-entropy.

Never materializes the full (batch, seq, vocab) logits tensor: scans over
sequence chunks, projecting each chunk onto the (embed, vocab) output matrix
(vocab sharded over the model axis).  The log-sum-exp reduction over the
sharded vocab axis lowers to an all-reduce that GSPMD inserts automatically.
Padded vocab entries (vocab rounded up for even sharding) are masked out.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import logical

__all__ = ["chunked_cross_entropy", "cross_entropy_dense"]


def _chunk_ce(h, labels, w_out, *, real_vocab: int, z_weight: float):
    """h (B, C, D) f32/bf16, labels (B, C) int32, w_out (D, Vp)."""
    logits = jnp.einsum(
        "bcd,dv->bcv", h.astype(jnp.float32), w_out.astype(jnp.float32)
    )
    logits = logical(logits, ("batch", None, "vocab"))
    vp = w_out.shape[1]
    if real_vocab != vp:
        pad_mask = jnp.arange(vp) >= real_vocab
        logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_weight:
        nll = nll + z_weight * jnp.square(lse)  # z-loss (logit drift control)
    return nll


def chunked_cross_entropy(
    h: jax.Array,
    labels: jax.Array,
    w_out: jax.Array,
    *,
    real_vocab: int,
    chunk: int = 512,
    z_weight: float = 0.0,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Mean token NLL of h (B, T, D) against labels (B, T) via w_out (D, Vp).

    T is scanned in ``chunk``-sized slices so peak logits memory is
    (B, chunk, Vp / tp) per device.
    """
    b, t, d = h.shape
    chunk = min(chunk, t)
    if t % chunk:
        pad = chunk - t % chunk
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(
            mask if mask is not None else jnp.ones((b, t), bool),
            ((0, 0), (0, pad)),
        )
    tc = h.shape[1] // chunk
    hs = h.reshape(b, tc, chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(b, tc, chunk).transpose(1, 0, 2)
    if mask is not None:
        ms = mask.reshape(b, tc, chunk).transpose(1, 0, 2)
    else:
        ms = jnp.ones((tc, b, chunk), bool)

    def step(carry, xs):
        total, count = carry
        hc, lc, mc = xs
        nll = _chunk_ce(hc, lc, w_out, real_vocab=real_vocab, z_weight=z_weight)
        total = total + jnp.sum(nll * mc)
        count = count + jnp.sum(mc)
        return (total, count), None

    (total, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ls, ms)
    )
    return total / jnp.maximum(count, 1.0)


def cross_entropy_dense(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Plain CE for small-vocab models (CNN classifier, smoke tests)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(gold)
