"""Pipeline parallelism over the pod axis (GPipe schedule).

At 2+ pods the cross-pod DCN hop is the slowest link; instead of extending
data-parallelism across pods (gradient all-reduce over DCN every step), the
pod axis can act as a pipeline: each pod owns a contiguous block of layers,
microbatches stream through, and the only cross-pod traffic is one
activation tensor per microbatch per direction — O(B*T*D) instead of
O(params) per step.

`pipeline_apply` runs a GPipe forward over `pod_axis` inside shard_map:
stage s holds its own stage parameters (sliced by shard_map), microbatches
enter at stage 0, activations hop stage->stage+1 via `ppermute`, and the
last stage's outputs are summed back to all pods (masked psum).  The whole
schedule is differentiable — `ppermute`'s transpose is the reverse
permute, so jax.grad yields the standard GPipe backward (bubble included).

Bubble fraction = (P-1)/(M+P-1) for P stages and M microbatches — pick
M >= 4*(P-1) to keep it under ~20%.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

__all__ = ["gpipe_schedule", "pipeline_apply"]


def _axis_size(axis) -> int:
    """jax.lax.axis_size appeared after 0.4.37; psum(1, axis) is the
    long-standing equivalent (constant-folded to the static axis size)."""
    fn = getattr(jax.lax, "axis_size", None)
    return fn(axis) if fn is not None else jax.lax.psum(1, axis)


def gpipe_schedule(stage_fn, stage_params, x_mb, *, axis: str):
    """Run inside shard_map. stage_params: THIS stage's params; x_mb
    (M, ...) microbatch inputs (meaningful at stage 0).  Returns (M, ...)
    outputs (meaningful at the last stage; zeros elsewhere)."""
    p = _axis_size(axis)
    sid = jax.lax.axis_index(axis)
    m = x_mb.shape[0]
    fwd = [(i, (i + 1) % p) for i in range(p)]

    buf = jnp.zeros_like(x_mb[0])
    outs = jnp.zeros((m, *jax.eval_shape(stage_fn, stage_params,
                                         x_mb[0]).shape),
                     x_mb.dtype)
    is_first = sid == 0
    is_last = sid == p - 1
    for t in range(m + p - 1):
        feed = x_mb[min(t, m - 1)]
        x_in = jnp.where(is_first, feed, buf)
        y = stage_fn(stage_params, x_in)
        # retire a finished microbatch at the last stage
        oi = t - (p - 1)
        if oi >= 0:
            upd = outs.at[oi].set(y)
            outs = jnp.where(is_last, upd, outs)
        buf = jax.lax.ppermute(y, axis, fwd)
    return outs


def pipeline_apply(mesh, stage_fn, all_stage_params, x_mb, *,
                   pod_axis: str = "pod", params_spec=None):
    """GPipe over `pod_axis` of `mesh`.

    all_stage_params: pytree whose leaves have a leading stage dim == pod
    size (stage s gets slice s).  x_mb (M, ...) microbatches, replicated.
    Returns (M, ...) outputs replicated over the pod axis.
    """
    p = mesh.shape[pod_axis]

    def spec_of(leaf):
        return PS(pod_axis, *([None] * (leaf.ndim - 1)))

    in_specs = (
        jax.tree.map(spec_of, all_stage_params) if params_spec is None
        else params_spec,
        PS(),
    )

    def body(params_stage, x_local):
        # shard_map gives a leading stage dim of 1: drop it
        params = jax.tree.map(lambda a: a[0], params_stage)
        outs = gpipe_schedule(stage_fn, params, x_local, axis=pod_axis)
        # broadcast the last stage's outputs to every pod
        is_last = jax.lax.axis_index(pod_axis) == p - 1
        outs = jnp.where(is_last, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, pod_axis)

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=PS(),
        check_rep=False,
    )(all_stage_params, x_mb)
