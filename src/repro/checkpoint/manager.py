"""Fault-tolerant checkpointing: atomic, async, elastic-reshard restore.

Layout: <dir>/step_<k>/ contains one .npy per leaf plus manifest.json
(tree paths, shapes, dtypes, step, user metadata).  Writes go to a temp
directory and are renamed into place — a crash mid-save never corrupts the
latest checkpoint (restore scans for the newest *complete* step).

Restore is *elastic*: arrays are loaded host-side and re-placed with
whatever shardings the new mesh wants (`device_put` with NamedSharding), so
a run checkpointed on (16, 16) restores onto (2, 16, 16) or a single CPU
without conversion.  (Single-controller persistence; a multi-host deployment
would write per-shard files from each host — same manifest format.)
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["CheckpointManager"]

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, metadata: dict | None = None,
             block: bool = False):
        """Snapshot `tree` at `step`. Async by default; join with wait()."""
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
            for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
                fname = f"{i:05d}.npy"
                np.save(os.path.join(tmp, fname), leaf, allow_pickle=False)
                manifest["leaves"].append(
                    {"path": path, "file": fname,
                     "shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype)}
                )
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, _MANIFEST)
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: int | None = None, *, shardings=None):
        """Load into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching tree of
        NamedShardings for elastic re-placement on the current mesh.
        Returns (tree, step, metadata)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(flat)
        )
        out = []
        for (path, tgt), shd in zip(flat, shard_leaves):
            key = jax.tree_util.keystr(path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = np.load(os.path.join(d, by_path[key]["file"]))
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs target {tgt.shape}"
                )
            if shd is not None:
                out.append(jax.device_put(arr.astype(tgt.dtype), shd))
            else:
                out.append(jax.numpy.asarray(arr.astype(tgt.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), step, manifest["metadata"]
