"""Fault-tolerant checkpointing: atomic, async, elastic-reshard restore.

Layout: <dir>/step_<k>/ contains one .npy per leaf plus manifest.json
(tree paths, shapes, dtypes, per-leaf sha256 checksums, step, user
metadata).  Writes go to a temp directory and are renamed into place — a
crash mid-save never corrupts the latest checkpoint (restore scans for
the newest *complete* step).

Restore verifies integrity before deserializing anything into the model:
every leaf file's sha256 is checked against the manifest, so a corrupted,
truncated, or torn checkpoint raises `CheckpointError` *naming the bad
array* instead of silently loading garbage weights.  Manifests written
before checksums existed restore with a shape/dtype-only check
(back-compat).

Restore is *elastic*: arrays are loaded host-side and re-placed with
whatever shardings the new mesh wants (`device_put` with NamedSharding), so
a run checkpointed on (16, 16) restores onto (2, 16, 16) or a single CPU
without conversion.  (Single-controller persistence; a multi-host deployment
would write per-shard files from each host — same manifest format.)
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np

__all__ = ["CheckpointManager", "CheckpointError"]

_MANIFEST = "manifest.json"


class CheckpointError(Exception):
    """A checkpoint failed its integrity check (corrupted / torn / missing
    data); the message names the offending array."""


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


def _sha256(fname: str) -> str:
    h = hashlib.sha256()
    with open(fname, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, *, metadata: dict | None = None,
             block: bool = False):
        """Snapshot `tree` at `step`. Async by default; join with wait()."""
        host_tree = jax.tree.map(np.asarray, tree)  # device->host copy now

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            manifest = {"step": step, "metadata": metadata or {}, "leaves": []}
            for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
                fname = f"{i:05d}.npy"
                fpath = os.path.join(tmp, fname)
                np.save(fpath, leaf, allow_pickle=False)
                manifest["leaves"].append(
                    {"path": path, "file": fname,
                     "shape": list(np.shape(leaf)),
                     "dtype": str(np.asarray(leaf).dtype),
                     "sha256": _sha256(fpath)}
                )
            with open(os.path.join(tmp, _MANIFEST), "w") as f:
                json.dump(manifest, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                os.path.join(self.dir, name, _MANIFEST)
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_leaf(self, d: str, entry: dict) -> np.ndarray:
        """Load one leaf file with its integrity check: missing file,
        checksum mismatch (bit-rot / torn write) or an unparseable .npy all
        raise `CheckpointError` naming the array."""
        key = entry["path"]
        fpath = os.path.join(d, entry["file"])
        if not os.path.exists(fpath):
            raise CheckpointError(
                f"checkpoint {d} is missing the data file for array {key} "
                f"({entry['file']})")
        want = entry.get("sha256")
        if want is not None:
            got = _sha256(fpath)
            if got != want:
                raise CheckpointError(
                    f"checksum mismatch for array {key} in {d}: manifest "
                    f"sha256 {want[:12]}.. but file hashes {got[:12]}.. "
                    f"(corrupted or torn checkpoint)")
        try:
            arr = np.load(fpath, allow_pickle=False)
        except (ValueError, OSError, EOFError, zlib.error) as e:
            raise CheckpointError(
                f"array {key} in {d} failed to deserialize: {e}") from e
        if list(arr.shape) != list(entry["shape"]):
            raise CheckpointError(
                f"array {key} in {d} has shape {list(arr.shape)} but the "
                f"manifest recorded {entry['shape']}")
        return arr

    def restore(self, target, step: int | None = None, *, shardings=None):
        """Load into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching tree of
        NamedShardings for elastic re-placement on the current mesh.
        Every leaf is integrity-checked against the manifest (sha256)
        before use — see `CheckpointError`.
        Returns (tree, step, metadata)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(d, _MANIFEST)) as f:
                manifest = json.load(f)
        except json.JSONDecodeError as e:
            raise CheckpointError(
                f"manifest of {d} is not valid JSON (torn write?): {e}"
            ) from e
        by_path = {l["path"]: l for l in manifest["leaves"]}

        flat, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(flat)
        )
        out = []
        for (path, tgt), shd in zip(flat, shard_leaves):
            key = jax.tree_util.keystr(path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = self._load_leaf(d, by_path[key])
            if tuple(arr.shape) != tuple(tgt.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs target {tgt.shape}"
                )
            if shd is not None:
                out.append(jax.device_put(arr.astype(tgt.dtype), shd))
            else:
                out.append(jax.numpy.asarray(arr.astype(tgt.dtype)))
        return jax.tree_util.tree_unflatten(treedef, out), step, manifest["metadata"]
