"""Atomic async checkpointing with elastic-reshard restore."""
from .manager import CheckpointManager
