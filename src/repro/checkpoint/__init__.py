"""Atomic async checkpointing with elastic-reshard restore."""
from .manager import CheckpointError, CheckpointManager

__all__ = ["CheckpointError", "CheckpointManager"]
