"""The paper's own evaluation setup: VGG-16, vector-pruned to 23.5% density,
simulated on the two 168-PE configurations of §IV.
"""
from __future__ import annotations

import dataclasses

from repro.core.accel_model import PEConfig, PE_4_14_3, PE_8_7_3


@dataclasses.dataclass(frozen=True)
class VSCNNConfig:
    name: str = "vscnn-vgg16"
    modality: str = "cnn"           # servable arch: image requests, not tokens
    image_size: int = 224
    num_classes: int = 1000
    weight_density: float = 0.235   # paper: 23.5% after vector pruning
    vk: int = 32                    # TPU kernel vector length (K-tile)
    vn: int = 128                   # output strip width
    # the Flatten head ties fc1's fan-in to image_size: serving batches must
    # pad every image up to exactly (image_size, image_size)
    fixed_image_size: bool = True
    pe_configs: tuple[PEConfig, ...] = (PE_4_14_3, PE_8_7_3)
    # paper-reported reference points (Figs 12/13, §IV)
    paper_speedup: tuple[float, ...] = (1.871, 1.93)
    paper_frac_ideal_vector: tuple[float, ...] = (0.92, 0.85)
    paper_frac_ideal_fine: tuple[float, ...] = (0.466, 0.471)

    def reduce(self) -> "VSCNNConfig":
        return dataclasses.replace(self, image_size=32, num_classes=16)

    def build(self):
        """The servable network: `models.graph.SparseNet` for this config."""
        from repro.models.graph import build_vgg16
        return build_vgg16(self.num_classes, image_size=self.image_size)


CONFIG = VSCNNConfig()
