"""Phi-3-medium 14B: RoPE + SwiGLU + GQA (40H, kv=10) [arXiv:2404.14219].

40 heads don't divide tp=16 -> sequence-parallel attention.
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab=100352,
    segments=(Segment(40, (LayerSpec("attn", "mlp"),)),),
    activation="swiglu",
    microbatches=8,
    attn_sharding="sp",
)
