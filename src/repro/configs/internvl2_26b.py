"""InternVL2-26B backbone (InternViT frontend is a stub per assignment).

InternLM2-20B language backbone dims [arXiv:2404.16821]: the ViT patch
embeddings arrive precomputed via input_specs() (embed_inputs=False).
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    segments=(Segment(48, (LayerSpec("attn", "mlp"),)),),
    activation="swiglu",
    embed_inputs=False,
    microbatches=16,
    attn_sharding="heads",
    notes="vision frontend stubbed: inputs are precomputed patch embeddings",
)
