"""ResNet-34 on the vector-sparse datapath.

The mid-depth basic-block ResNet — ResNet-18's block type at ResNet-50's
stage depths, and a common accuracy/cost operating point in the sparse-
accelerator literature.  It introduces no conv geometry the kernel family
doesn't already run, so the whole config is plan + registry entry
(`models.graph.build_resnet34`); pruning recipe and PE configurations
match the paper's VGG-16 setup.
"""
from __future__ import annotations

import dataclasses

from repro.core.accel_model import PEConfig, PE_4_14_3, PE_8_7_3


@dataclasses.dataclass(frozen=True)
class VSCNNResNet34Config:
    name: str = "vscnn-resnet34"
    modality: str = "cnn"           # servable arch: image requests, not tokens
    image_size: int = 224
    num_classes: int = 1000
    weight_density: float = 0.235   # the paper's vector-pruning operating point
    vk: int = 32                    # TPU kernel vector length (K-tile)
    vn: int = 128                   # output strip width
    # GAP head: geometry is size-agnostic, so serving buckets pad images to
    # the nearest shape bucket instead of one fixed size
    fixed_image_size: bool = False
    pe_configs: tuple[PEConfig, ...] = (PE_4_14_3, PE_8_7_3)

    def reduce(self) -> "VSCNNResNet34Config":
        # num_classes=200 keeps a non-tileable head (200 % 128 != 0): the
        # FC remainder strip stays exercised even in the reduced config.
        return dataclasses.replace(self, image_size=32, num_classes=200)

    def build(self):
        """The servable network: `models.graph.SparseNet` for this config."""
        from repro.models.graph import build_resnet34
        return build_resnet34(self.num_classes, image_size=self.image_size)


CONFIG = VSCNNResNet34Config()
