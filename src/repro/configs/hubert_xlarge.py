"""HuBERT X-Large: encoder-only (bidirectional), masked-unit prediction over
504 cluster targets [arXiv:2106.07447].  The conv waveform frontend is a
stub: input_specs() provides precomputed frame embeddings.
No decode shapes (encoder-only skip rule).
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    segments=(Segment(48, (LayerSpec("attn", "mlp"),)),),
    activation="gelu",
    causal=False,
    encoder_only=True,
    embed_inputs=False,
    microbatches=4,
    attn_sharding="heads",
    notes="audio frontend stubbed: inputs are precomputed frame embeddings",
)
