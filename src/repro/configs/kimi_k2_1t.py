"""Kimi K2 — trillion-parameter MoE: 61 layers (first dense, 60 MoE),
384 experts top-8 + 1 shared expert, expert d_ff 2048 [paper-table].

Dense stem layer uses DeepSeek-V3-style d_ff 18432 (the assignment's
d_ff=2048 is the expert width).  Adafactor optimizer (1T AdamW state would
not fit 512 chips).
"""
from .base import ArchConfig, LayerSpec, Segment
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=18432,  # the single dense stem layer
    vocab=163840,
    segments=(
        Segment(1, (LayerSpec("attn", "mlp"),)),
        Segment(60, (LayerSpec("attn", "moe"),)),
    ),
    moe=MoEConfig(n_experts=384, top_k=8, d_ff=2048, n_shared=1),
    activation="swiglu",
    microbatches=8,
    grad_accum_dtype="bfloat16",  # f32 accumulator alone would be 15.6 GB/chip
    attn_sharding="heads",
    optimizer="adafactor",
)
