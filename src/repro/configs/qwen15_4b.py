"""Qwen1.5-4B: QKV bias, MHA (kv == heads == 20) [hf:Qwen/Qwen1.5-4B].

20 heads do not divide the 16-way model axis -> sequence-parallel attention
(attn_sharding='sp'), zero padding waste (DESIGN.md §6).
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab=151936,
    segments=(Segment(40, (LayerSpec("attn", "mlp"),)),),
    activation="swiglu",
    qkv_bias=True,
    microbatches=4,
    attn_sharding="sp",
)
