"""Config dataclasses: architectures, input shapes, sparsity, reduction.

Every assigned architecture is one `ArchConfig` (exact public dims) in its
own module; `reduce()` derives the CPU smoke-test config (same family
structure, tiny dims).  `ShapeSpec` enumerates the assignment's four input
shapes; `supported_shapes()` applies the assignment's skip rules
(sub-quadratic only for long_500k, no decode for encoder-only).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from repro.models.moe import MoEConfig

__all__ = [
    "LayerSpec", "Segment", "ShapeSpec", "SparsityConfig", "ArchConfig",
    "SHAPES", "uniform_segments",
]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # 'attn' | 'mamba' | 'rwkv_tm' | 'none'
    ffn: str = "mlp"             # 'mlp' | 'moe' | 'rwkv_cm' | 'none'
    window: int | None = None    # sliding-window size for local attention


@dataclasses.dataclass(frozen=True)
class Segment:
    repeat: int
    layers: tuple[LayerSpec, ...]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class SparsityConfig:
    """The paper's technique as a config knob (weights pruned at vector
    granularity; activation vectors skipped at runtime)."""

    density: float = 0.235   # paper's VGG-16 operating point
    vk: int = 32             # vector (K-tile) length
    vn: int = 128            # output strip width
    targets: tuple[str, ...] = ("ffn", "attn_proj")  # which matmuls


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    segments: tuple[Segment, ...]
    modality: str = "lm"              # serving dispatch; CNN configs say "cnn"
    moe: MoEConfig | None = None
    activation: str = "swiglu"
    head_dim_override: int | None = None
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    encoder_only: bool = False
    attn_free: bool = False
    subquadratic: bool = False        # eligible for long_500k
    embed_inputs: bool = True         # False => stub frontend (embeds input)
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    attn_sharding: str = "heads"      # 'heads' | 'sp'
    attn_impl: str = "xla"            # 'xla' | 'pallas' (single-device serve)
    sparsity: SparsityConfig | None = SparsityConfig()
    param_dtype: str = "bfloat16"
    cache_dtype_str: str = "bfloat16"
    vocab_pad_to: int = 2048
    scan_chunk: int = 256
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    ce_chunk: int = 512
    z_loss: float = 1e-4
    remat: bool = True
    tp_hint: int = 16                 # model-axis width configs pad against
    optimizer: str = "adamw"          # 'adamw' | 'adafactor'
    microbatches: int = 1             # gradient-accumulation splits per step
    moe_dispatch: str = "gather_weights"  # | 'resident' (serve/decode)
    bf16_flow: bool = False           # bf16 matmul outputs (perf knob)
    grad_accum_dtype: str = "float32" # microbatch gradient accumulator
    flash_remat: bool = False         # recompute flash scores in backward
    use_sparse_ffn: bool = False      # vector-sparse FFN (the paper's
                                      # technique in the LM serving path)
    seq_shard_residual: bool = False  # Megatron-SP residual stream: h is
                                      # sequence-sharded over the model axis
                                      # between blocks (bf16 gather/scatter
                                      # replaces f32 activation psums)
    notes: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.head_dim_override or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return -(-self.vocab // m) * m

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cache_dtype(self):
        return jnp.dtype(self.cache_dtype_str)

    @property
    def total_layers(self) -> int:
        return sum(s.repeat * len(s.layers) for s in self.segments)

    def supported_shapes(self) -> dict[str, str]:
        """shape name -> '' if runnable, else skip reason."""
        out = {}
        for name, sh in SHAPES.items():
            reason = ""
            if sh.kind == "decode" and self.encoder_only:
                reason = "encoder-only: no autoregressive decode step"
            elif name == "long_500k" and not self.subquadratic:
                reason = ("pure full-attention arch: 524k context requires "
                          "sub-quadratic attention (assignment skip rule)")
            out[name] = reason
        return out

    def param_count(self) -> int:
        """Total parameters (embedding included), from the schema."""
        from repro.models.transformer import lm_schema
        from repro.models.layers import is_param
        import jax
        return sum(
            math.prod(p.shape)
            for p in jax.tree.leaves(lm_schema(self), is_leaf=is_param)
        )

    def active_param_count(self) -> int:
        """MoE-aware active parameters per token (for 6*N*D roofline)."""
        if self.moe is None:
            return self.param_count()
        from repro.models.transformer import lm_schema
        from repro.models.layers import is_param
        import jax
        total = 0
        for path, p in jax.tree_util.tree_flatten_with_path(
            lm_schema(self), is_leaf=is_param
        )[0]:
            n = math.prod(p.shape)
            key = jax.tree_util.keystr(path)
            if "'ffn'" in key and "shared" not in key and "router" not in key:
                ep = self.moe.padded_experts(self.tp_hint)
                n = n * self.moe.top_k // ep
            total += n
        return total

    def reduce(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2), d_ff=64,
            )
        segs = tuple(
            Segment(repeat=min(s.repeat, 2),
                    layers=tuple(
                        dataclasses.replace(
                            sp, window=min(sp.window, 16) if sp.window else None
                        ) for sp in s.layers
                    ))
            for s in self.segments[:2]
        )
        return dataclasses.replace(
            self,
            d_model=64 * heads if self.attn_free else 32 * heads,
            n_heads=heads,
            n_kv_heads=kv,
            d_ff=128,
            vocab=512,
            vocab_pad_to=64,
            segments=segs,
            moe=moe,
            head_dim_override=None,
            scan_chunk=8,
            attn_block_q=32,
            attn_block_kv=32,
            ce_chunk=64,
            tp_hint=1,
            microbatches=1,
            param_dtype="float32",
            cache_dtype_str="float32",
        )


def uniform_segments(n_layers: int, spec: LayerSpec) -> tuple[Segment, ...]:
    return (Segment(repeat=n_layers, layers=(spec,)),)
