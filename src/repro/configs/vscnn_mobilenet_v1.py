"""MobileNetV1 on the vector-sparse datapath — the depthwise-separable
workload class the grouped/depthwise kernel extension exists for.

Every dw layer is a `Conv(groups=cin)` routed through the per-channel tap
kernels (vk == 1 tap vectors over vn-channel tiles) and every pointwise
conv is the 1x1 sparse matmul, so the efficient-CNN vocabulary serves off
the same datapath as VGG/ResNet (`models.graph.build_mobilenet_v1`).
"""
from __future__ import annotations

import dataclasses

from repro.core.accel_model import PEConfig, PE_4_14_3, PE_8_7_3


@dataclasses.dataclass(frozen=True)
class VSCNNMobileNetV1Config:
    name: str = "vscnn-mobilenet-v1"
    modality: str = "cnn"           # servable arch: image requests, not tokens
    image_size: int = 224
    num_classes: int = 1000
    # dw layers have only kh*kw tap vectors per channel tile, so the pruning
    # point is gentler than the paper's 0.235 VGG operating point: 0.5 keeps
    # ceil(9 * 0.5) of 9 taps — enough to stay a conv, still a 2x tap skip.
    weight_density: float = 0.5
    vk: int = 32                    # K-tile length (pointwise convs)
    vn: int = 128                   # output strip / dw channel-tile width
    # GAP head: geometry is size-agnostic, so serving buckets pad images to
    # the nearest shape bucket instead of one fixed size
    fixed_image_size: bool = False
    pe_configs: tuple[PEConfig, ...] = (PE_4_14_3, PE_8_7_3)

    def reduce(self) -> "VSCNNMobileNetV1Config":
        # num_classes=200 keeps a non-tileable head (200 % 128 != 0): the
        # FC remainder strip stays exercised even in the reduced config.
        return dataclasses.replace(self, image_size=32, num_classes=200)

    def build(self):
        """The servable network: `models.graph.SparseNet` for this config."""
        from repro.models.graph import build_mobilenet_v1
        return build_mobilenet_v1(self.num_classes,
                                  image_size=self.image_size)


CONFIG = VSCNNMobileNetV1Config()
