"""Jamba-v0.1 52B: Mamba+attention 1:7, MoE every other layer (16e top-2)
[arXiv:2403.19887].  One Jamba block = 8 layers (attention at offset 4, MoE
at odd offsets); 4 scanned blocks = 32 layers.
"""
from .base import ArchConfig, LayerSpec, Segment
from repro.models.moe import MoEConfig

_BLOCK = (
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("attn", "mlp"),
    LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "mlp"),
    LayerSpec("mamba", "moe"),
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    segments=(Segment(4, _BLOCK),),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=14336),
    activation="swiglu",
    subquadratic=True,
    microbatches=16,
    attn_sharding="heads",
)
