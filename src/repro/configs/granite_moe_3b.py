"""Granite-3.0 MoE 3B (800M active): 40 experts top-8, expert d_ff 512
[hf:ibm-granite/granite-3.0-3b-a800m-base].

40 experts pad to 48 on the 16-way model axis (dead experts, router-masked);
24 heads don't divide tp=16 -> sequence-parallel attention.
"""
from .base import ArchConfig, LayerSpec, Segment
from repro.models.moe import MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    segments=(Segment(32, (LayerSpec("attn", "moe"),)),),
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512),
    activation="swiglu",
    microbatches=4,
    attn_sharding="sp",
)
