"""Gemma-3 12B: 5 local (1024-window) : 1 global attention, GeGLU, qk-norm,
256k vocab, tied embeddings [hf:google/gemma-3-12b-pt].

subquadratic=True: only 8/48 layers are global attention; long_500k decode is
dominated by the windowed layers and the 8 global KVs shard over the
sequence axis (assignment long-context rule, DESIGN.md §5).
"""
from .base import ArchConfig, LayerSpec, Segment

_LOCAL = LayerSpec("attn", "mlp", window=1024)
_GLOBAL = LayerSpec("attn", "mlp")

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    segments=(Segment(8, (_LOCAL,) * 5 + (_GLOBAL,)),),
    activation="geglu",
    qk_norm=True,
    tie_embeddings=True,
    subquadratic=True,
    microbatches=8,
    attn_sharding="heads",
)
