"""Nemotron-4 340B: GQA, squared-ReLU MLP (real dynamic activation sparsity —
the closest LM analogue of the paper's post-ReLU input-vector skipping)
[arXiv:2402.16819].  Adafactor so optimizer state fits 512 chips.
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    segments=(Segment(96, (LayerSpec("attn", "mlp"),)),),
    activation="relu2",
    microbatches=16,
    grad_accum_dtype="bfloat16",
    attn_sharding="heads",
    optimizer="adafactor",
)
