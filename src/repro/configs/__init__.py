"""Architecture registry: --arch <id> resolution for launch/bench tooling.

Two registries share one `get_config` namespace: the LM stack's
`ArchConfig`s (trainable, token-input — what `list_archs` returns, and what
train/dryrun iterate) and the VSCNN CNN configs (`list_cnn_archs`) served
through the batched CNN backend.  Dispatch on ``cfg.modality`` ("lm" is the
default for ArchConfig) when a tool accepts both.
"""
from . import (
    internvl2_26b, gemma3_12b, nemotron_4_340b, qwen15_4b, phi3_medium_14b,
    jamba_v01_52b, granite_moe_3b, kimi_k2_1t, hubert_xlarge, rwkv6_3b,
    vscnn_vgg16, vscnn_resnet18, vscnn_resnet34, vscnn_resnet50,
    vscnn_mobilenet_v1,
)
from .base import ArchConfig, LayerSpec, Segment, ShapeSpec, SparsityConfig, SHAPES

_MODULES = [
    internvl2_26b, gemma3_12b, nemotron_4_340b, qwen15_4b, phi3_medium_14b,
    jamba_v01_52b, granite_moe_3b, kimi_k2_1t, hubert_xlarge, rwkv6_3b,
]

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}

# CNN serving archs (VSCNN): separate registry so LM-only iterators
# (train, dryrun, models smoke) keep seeing homogeneous ArchConfigs.
CNN_REGISTRY = {m.CONFIG.name: m.CONFIG
                for m in [vscnn_vgg16, vscnn_resnet18, vscnn_resnet34,
                          vscnn_resnet50, vscnn_mobilenet_v1]}


def get_config(name: str):
    if name in REGISTRY:
        return REGISTRY[name]
    if name in CNN_REGISTRY:
        return CNN_REGISTRY[name]
    raise KeyError(f"unknown arch {name!r}; have "
                   f"{sorted(REGISTRY) + sorted(CNN_REGISTRY)}")


def list_archs() -> list[str]:
    """LM (token-input) archs only — the train/dryrun iteration set."""
    return sorted(REGISTRY)


def list_cnn_archs() -> list[str]:
    """CNN serving archs (image-input, `CNNServer`-servable)."""
    return sorted(CNN_REGISTRY)
