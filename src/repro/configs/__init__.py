"""Architecture registry: --arch <id> resolution for launch/bench tooling."""
from . import (
    internvl2_26b, gemma3_12b, nemotron_4_340b, qwen15_4b, phi3_medium_14b,
    jamba_v01_52b, granite_moe_3b, kimi_k2_1t, hubert_xlarge, rwkv6_3b,
)
from .base import ArchConfig, LayerSpec, Segment, ShapeSpec, SparsityConfig, SHAPES

_MODULES = [
    internvl2_26b, gemma3_12b, nemotron_4_340b, qwen15_4b, phi3_medium_14b,
    jamba_v01_52b, granite_moe_3b, kimi_k2_1t, hubert_xlarge, rwkv6_3b,
]

REGISTRY = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(REGISTRY)
