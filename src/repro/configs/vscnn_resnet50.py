"""ResNet-50 on the vector-sparse datapath — the headline benchmark shared
with SCNN (Parashar et al.) and the structured-sparse FPGA accelerator
(Zhu et al.).

The bottleneck block (1x1 reduce -> 3x3 -> 1x1 expand, 4x expansion) was
already expressible in the kernel family; `models.graph.build_resnet50`
wires it.  Same pruning recipe and PE configurations as the paper's VGG-16
setup; BN folds into the conv weights/bias at sparsify time and residual
adds ride the kernels' fused epilogue, so every conv and FC layer runs the
single sparse datapath end-to-end.
"""
from __future__ import annotations

import dataclasses

from repro.core.accel_model import PEConfig, PE_4_14_3, PE_8_7_3


@dataclasses.dataclass(frozen=True)
class VSCNNResNet50Config:
    name: str = "vscnn-resnet50"
    modality: str = "cnn"           # servable arch: image requests, not tokens
    image_size: int = 224
    num_classes: int = 1000
    weight_density: float = 0.235   # the paper's vector-pruning operating point
    vk: int = 32                    # TPU kernel vector length (K-tile)
    vn: int = 128                   # output strip width
    # GAP head: geometry is size-agnostic, so serving buckets pad images to
    # the nearest shape bucket instead of one fixed size
    fixed_image_size: bool = False
    pe_configs: tuple[PEConfig, ...] = (PE_4_14_3, PE_8_7_3)

    def reduce(self) -> "VSCNNResNet50Config":
        # num_classes=200 keeps a non-tileable head (200 % 128 != 0): the
        # FC remainder strip stays exercised even in the reduced config.
        return dataclasses.replace(self, image_size=32, num_classes=200)

    def build(self):
        """The servable network: `models.graph.SparseNet` for this config."""
        from repro.models.graph import build_resnet50
        return build_resnet50(self.num_classes, image_size=self.image_size)


CONFIG = VSCNNResNet50Config()
