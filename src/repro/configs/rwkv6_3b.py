"""RWKV-6 "Finch" 3B: attention-free, data-dependent decay [arXiv:2404.05892].

TP adaptation (DESIGN.md §5): head_dim 160 -> 16 heads (Finch uses 64 -> 40
heads, which does not divide the 16-way model axis); the recurrence is
head-parallel with zero cross-device traffic.  subquadratic (state-based
decode) -> runs long_500k.
"""
from .base import ArchConfig, LayerSpec, Segment

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=16,          # head_dim 160 (TP adaptation; Finch: 64)
    n_kv_heads=16,
    d_ff=8960,
    vocab=65536,
    segments=(Segment(32, (LayerSpec("rwkv_tm", "rwkv_cm"),)),),
    activation="relu",   # unused: channel-mix is squared-ReLU internally
    attn_free=True,
    subquadratic=True,
    microbatches=8,
    attn_sharding="heads",
)
