"""Deterministic synthetic data pipeline.

Design goals that matter at cluster scale even for synthetic data:
  * deterministic per (seed, step, shard) — restarting at step k reproduces
    exactly the stream a non-failed run would have seen ("skip-to-step"),
  * shard-aware — each data shard materializes only its slice,
  * zero host I/O — everything derives from counter-based RNG.

Token streams get a Zipf marginal and short-range repetition structure so
losses and activation sparsity behave like text rather than white noise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMBatchSpec", "SyntheticLM", "SyntheticImages", "SyntheticEmbeds"]


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    global_batch: int
    seq_len: int
    vocab: int
    n_shards: int = 1
    shard: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class SyntheticLM:
    """Next-token LM batches: {'tokens', 'labels'} int32 (local_batch, seq)."""

    def __init__(self, spec: LMBatchSpec, seed: int = 0):
        self.spec = spec
        self.seed = seed

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.spec.shard])
        )

    def batch_at(self, step: int) -> dict:
        sp = self.spec
        rng = self._rng(step)
        # Zipf-ish marginals + repeated n-grams (compressible structure)
        u = rng.random((sp.local_batch, sp.seq_len + 1))
        stream = np.floor(np.exp(u * np.log(sp.vocab))).astype(np.int64) - 1
        # splice in repeats: copy a random earlier window forward
        for b in range(sp.local_batch):
            if sp.seq_len < 48:  # too short for the splice window math
                continue
            src = rng.integers(0, sp.seq_len // 2)
            dst = rng.integers(sp.seq_len // 2, sp.seq_len - 16)
            ln = rng.integers(8, 16)
            stream[b, dst : dst + ln] = stream[b, src : src + ln]
        stream = np.clip(stream, 0, sp.vocab - 1).astype(np.int32)
        return {"tokens": stream[:, :-1], "labels": stream[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticEmbeds:
    """Frontend-stub batches: {'embeds' (B, T, D) f32, 'labels' (B, T) i32}."""

    def __init__(self, spec: LMBatchSpec, d_model: int, seed: int = 0):
        self.spec = spec
        self.d_model = d_model
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        sp = self.spec
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, sp.shard, 1])
        )
        basis = np.random.default_rng(self.seed).standard_normal(
            (16, self.d_model), np.float32
        )
        coef = rng.standard_normal((sp.local_batch, sp.seq_len, 16), np.float32)
        noise = rng.standard_normal(
            (sp.local_batch, sp.seq_len, self.d_model), np.float32
        )
        embeds = (coef @ basis) / 4.0 + 0.5 * noise
        labels = rng.integers(
            0, sp.vocab, (sp.local_batch, sp.seq_len), dtype=np.int32
        )
        return {"embeds": embeds, "labels": labels}


class SyntheticImages:
    """Natural-image-statistics batches for the CNN path: 1/f spectrum images
    (so post-ReLU activation sparsity resembles real VGG traffic, which the
    paper's input-side skipping depends on)."""

    def __init__(self, batch: int, size: int = 224, classes: int = 1000,
                 seed: int = 0):
        self.batch, self.size, self.classes, self.seed = batch, size, classes, seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        n, s = self.batch, self.size
        freqs = np.fft.fftfreq(s)
        fx, fy = np.meshgrid(freqs, freqs)
        amp = 1.0 / np.maximum(np.sqrt(fx**2 + fy**2), 1.0 / s)
        spec = (
            rng.standard_normal((n, s, s, 3)) + 1j * rng.standard_normal((n, s, s, 3))
        ) * amp[None, :, :, None]
        img = np.fft.ifft2(spec, axes=(1, 2)).real
        img = (img - img.mean(axis=(1, 2, 3), keepdims=True)) / (
            img.std(axis=(1, 2, 3), keepdims=True) + 1e-6
        )
        labels = rng.integers(0, self.classes, (n,), dtype=np.int32)
        return {"images": img.astype(np.float32), "labels": labels}
