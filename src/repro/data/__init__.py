"""Deterministic shard-aware synthetic data pipelines."""
from .pipeline import LMBatchSpec, SyntheticLM, SyntheticImages, SyntheticEmbeds
