"""vscheck CLI — ``python -m repro.analysis``.

Runs the three static passes (IR validation, kernel contract checking,
repo lint) over the registered nets and the source tree, prints the
diagnostics, and exits non-zero on errors — the CI static-analysis gate.

Usage:
  python -m repro.analysis --all-nets [--size 32] [--batch 1]
  python -m repro.analysis --net resnet50 --density 0.25 -v
  python -m repro.analysis --lint-only
  python -m repro.analysis --selftest      # seeded-violation self-check
  python -m repro.analysis --rules         # print the rule catalog
"""
from __future__ import annotations

import argparse
import pathlib
import sys
from typing import Callable

from repro.models.graph import (
    Conv, SparseNet, build_mobilenet_v1, build_resnet18, build_resnet34,
    build_resnet50, build_vgg16,
)

from .contracts import check_contracts
from .diagnostics import RULES, Report
from .ir import check_net
from .lint import lint_paths

NETS: dict[str, Callable[..., SparseNet]] = {
    "vgg16": build_vgg16,
    "resnet18": build_resnet18,
    "resnet34": build_resnet34,
    "resnet50": build_resnet50,
    "mobilenet_v1": build_mobilenet_v1,
}

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]


def check_one_net(name: str, *, size: int, batch: int, density: float,
                  verbose: bool = False) -> Report:
    """IR + contract passes for one registered net at one input shape."""
    net = NETS[name](image_size=size)
    nc = check_net(net, (batch, size, size, 3), density=density)
    rep = Report()
    rep.extend(nc.report)
    if nc.report.ok():  # contract checks need well-formed sites
        crep, rows = check_contracts(nc)
        rep.extend(crep)
        if verbose:
            for r in rows:
                print(f"  {r.path:<44} {r.kind:<9} grid={r.grid} "
                      f"bytes={r.bytes_derived} flops={r.flops}")
    return rep


def run_selftest() -> bool:
    """Seeded-violation self-check: perturb the shared index-map/cost
    machinery in-process and assert the analyzer catches each seed.
    Guards against the nightmare failure mode of a verifier that silently
    verifies nothing."""
    import repro.kernels.plan as plan_mod

    from .diagnostics import Report as R
    from .lint import lint_source

    net = SparseNet("selftest", (
        Conv("c1", 32, 128, 3, 3),
        Conv("dw1", 128, 128, 3, 3, groups=128),
    ))
    shape = (1, 16, 16, 32)
    ok = True

    nc = check_net(net, shape)
    rep, _ = check_contracts(nc)
    if not (nc.report.ok() and rep.ok()):
        print("selftest: baseline net unexpectedly fails:")
        print(nc.report.render() or rep.render())
        return False

    def expect(label: str, rule: str, got: Report) -> None:
        nonlocal ok
        caught = any(d.rule == rule for d in got.errors)
        print(f"  seeded {label}: "
              f"{'caught ' + rule if caught else 'MISSED ' + rule}")
        ok = ok and caught

    # seed 1: shift the streaming halo window one row-block down — the
    # last row-block's reads escape the padded buffer (VSC201)
    orig_halo = plan_mod.halo_in_index_map

    def bad_halo(hb: int, stride: int, bh: int, cbg: int,
                 spg: int) -> Callable:
        inner = orig_halo(hb, stride, bh, cbg, spg)

        def index_map(j: object, m: object, s: object,
                      idx: object) -> tuple:
            o = inner(j, m, s, idx)
            return (o[0], o[1] + stride * bh, *o[2:])
        return index_map

    plan_mod.halo_in_index_map = bad_halo
    try:
        r, _ = check_contracts(check_net(net, shape))
    finally:
        plan_mod.halo_in_index_map = orig_halo
    expect("halo window shift", "VSC201", r)

    # seed 2: drop the sparse-step term from the weight stream — the
    # derived DMA count falls below the CostEstimate contract (VSC202)
    orig_w = plan_mod.conv_weight_index_map

    def bad_weights(resident: bool = False) -> Callable:
        inner = orig_w(resident)

        def index_map(g0: object, g1: object, s: object,
                      idx: object) -> tuple:
            o = inner(g0, g1, s, idx)
            return (o[0], 0 * o[1], *o[2:])
        return index_map

    plan_mod.conv_weight_index_map = bad_weights
    try:
        r, _ = check_contracts(check_net(net, shape))
    finally:
        plan_mod.conv_weight_index_map = orig_w
    expect("weight stream collapse", "VSC202", r)

    # seed 3: a depthwise channel-multiplier conv without allow_fallback
    # must be refused at the IR pass (VSC109)
    bad_net = SparseNet("selftest_vsc109",
                        (Conv("dwm", 32, 64, 3, 3, groups=32),))
    r = check_net(bad_net, shape).report
    expect("channel-multiplier depthwise", "VSC109", r)

    # seed 4: lint rules on a synthetic source
    lrep = R()
    lint_source(
        "import os, time\n"
        "os.environ['XLA_FLAGS'] = '-x'\n"
        "y = ops.vsconv(x, vs, impl='hallo')\n",
        "selftest_snippet.py", rep=lrep)
    expect("env mutation", "VSC303", lrep)
    expect("impl typo", "VSC301", lrep)
    lrep2 = R()
    lint_source(
        "import time\n"
        "while time.monotonic() < deadline:\n"
        "    pass\n",
        "scheduler.py", rep=lrep2)
    expect("clock in scheduler branch", "VSC302", lrep2)

    # seed 5: a blanket except in the launch layer must be flagged
    # (VSC304) — and the same source outside launch/ must stay clean
    blanket = ("try:\n"
               "    run.dispatch()\n"
               "except Exception:\n"
               "    pass\n")
    lrep3 = R()
    lint_source(blanket, "src/repro/launch/scheduler.py", rep=lrep3)
    expect("blanket except in launch", "VSC304", lrep3)
    lrep4 = R()
    lint_source(blanket, "src/repro/kernels/ops.py", rep=lrep4)
    clean = not any(d.rule == "VSC304" for d in lrep4.errors)
    print(f"  negative (non-launch blanket except): "
          f"{'clean' if clean else 'FALSE POSITIVE VSC304'}")
    ok = ok and clean
    return ok


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="vscheck: static IR/kernel contract verifier")
    p.add_argument("--net", choices=sorted(NETS), action="append",
                   default=None, help="net(s) to check (repeatable)")
    p.add_argument("--all-nets", action="store_true",
                   help="check every registered net")
    p.add_argument("--size", type=int, default=32,
                   help="input image size (default 32)")
    p.add_argument("--batch", type=int, default=1)
    p.add_argument("--density", type=float, default=0.25)
    p.add_argument("--lint-only", action="store_true",
                   help="run only the source lint pass")
    p.add_argument("--no-lint", action="store_true",
                   help="skip the source lint pass")
    p.add_argument("--selftest", action="store_true",
                   help="seeded-violation self-check (must catch each)")
    p.add_argument("--rules", action="store_true",
                   help="print the rule catalog and exit")
    p.add_argument("--suppress", action="append", default=[],
                   metavar="RULE", help="drop findings of this rule id")
    p.add_argument("--warnings-as-errors", action="store_true")
    p.add_argument("-v", "--verbose", action="store_true",
                   help="print every verified kernel plan")
    args = p.parse_args(argv)

    if args.rules:
        for rule, desc in RULES.items():
            print(f"{rule}  {desc}")
        return 0

    if args.selftest:
        ok = run_selftest()
        print("selftest:", "OK" if ok else "FAILED")
        return 0 if ok else 1

    rep = Report()
    names = sorted(NETS) if args.all_nets or args.net is None else args.net
    if not args.lint_only:
        for name in names:
            print(f"vscheck {name} @ {args.batch}x{args.size}x{args.size}x3 "
                  f"density={args.density}")
            rep.extend(check_one_net(
                name, size=args.size, batch=args.batch,
                density=args.density, verbose=args.verbose))
    if args.lint_only or not args.no_lint:
        n = lint_paths(_REPO_ROOT, rep=rep)
        print(f"lint: {n} files")

    rep = rep.suppress(set(args.suppress))
    if rep.diagnostics:
        print(rep.render())
    print(f"vscheck: {len(rep.errors)} error(s), "
          f"{len(rep.warnings)} warning(s)")
    return 0 if rep.ok(warnings_as_errors=args.warnings_as_errors) else 1


if __name__ == "__main__":
    sys.exit(main())
