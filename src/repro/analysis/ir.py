"""vscheck pass 1 — IR validation: shape/geometry inference over
`models.graph.SparseNet` layer graphs.

Walks a net's `LayerSpec`s propagating the NHWC stream shape (and every
saved slot) through Conv/FC/Pool/ResidualAdd/Save/Flatten, checking each
layer's geometry *before anything runs*: channel-count agreement, grouped
divisibility, residual-arm shape match at the fused add, slot liveness,
pool windows that collapse the map, and the tile-geometry rules
`sparsify` will apply (`graph.conv_tile_geometry` / `fc_tile_geometry` —
the same code, so the analyzer can't drift from the encoder).

The walk also emits one `ConvSite` / `FCSite` per sparse-encodable layer
— the static description pass 2 (`analysis.contracts`) turns into kernel
plans.  Rule ids are the VSC1xx block of `analysis.diagnostics.RULES`.
"""
from __future__ import annotations

import dataclasses

from repro.models.graph import (
    FC, Conv, ConvTileGeometry, FCTileGeometry, Flatten, Pool, ResidualAdd,
    Save, SparseNet, conv_tile_geometry, fc_tile_geometry, strip_steps,
)

from .diagnostics import Report, VSCheckError

__all__ = ["ConvSite", "FCSite", "NetCheck", "check_net"]


@dataclasses.dataclass(frozen=True)
class ConvSite:
    """Static description of one conv layer's sparse-kernel invocation."""

    name: str
    path: str                            # net/layer
    x_shape: tuple[int, int, int, int]   # encoded NHWC input (cin_pad incl.)
    kh: int
    kw: int
    stride: int
    groups: int
    dilation: int
    cout: int                            # encoded output width
    geom: ConvTileGeometry
    s_steps: int
    has_residual: bool


@dataclasses.dataclass(frozen=True)
class FCSite:
    """Static description of one FC layer's sparse-matmul invocation.
    ``geom`` is None when the layer stays dense (VSC116)."""

    name: str
    path: str
    m: int                               # batch rows
    din: int
    dout: int
    geom: FCTileGeometry | None
    s_steps: int


@dataclasses.dataclass
class NetCheck:
    """Result of one IR walk: diagnostics + the per-layer kernel sites."""

    report: Report
    conv_sites: list[ConvSite]
    fc_sites: list[FCSite]
    out_shape: tuple[int, ...] | None


def _pool_out(size_in: int, size: int, stride: int, padding: str) -> int:
    if padding == "SAME":
        return -(-size_in // stride)
    return (size_in - size) // stride + 1


def _conv_out(size_in: int, k: int, stride: int, dilation: int) -> int:
    # XLA "SAME" for the given stride: out = ceil(in / stride)
    del k, dilation
    return -(-size_in // stride)


def check_net(
    net: SparseNet,
    input_shape: tuple[int, int, int, int],
    *,
    density: float = 0.25,
    vk: int = 32,
    vn: int = 128,
) -> NetCheck:
    """Shape/geometry inference over ``net`` for a (N, H, W, C) input.

    Returns a `NetCheck`; errors in its report mean the net cannot run (or
    would compute garbage) at this input shape — `launch.serve.CNNServer`
    refuses placement on them.  Warnings flag wasteful-but-valid shapes.
    """
    rep = Report()
    sites: list[ConvSite] = []
    fcs: list[FCSite] = []
    shape: tuple[int, ...] | None = tuple(int(d) for d in input_shape)
    if len(shape) != 4 or any(d < 1 for d in shape):
        rep.error("VSC103", net.name,
                  f"input shape {shape} is not a positive NHWC shape")
        return NetCheck(rep, sites, fcs, None)
    saved: dict[str, tuple[int, ...]] = {}

    def read_slot(key: str, path: str, what: str) -> tuple[int, ...] | None:
        if key not in saved:
            rep.error("VSC104", path,
                      f"{what} reads slot {key!r} before any layer saved it",
                      hint="add Save / Conv(dst=...) producing the slot "
                           "earlier in the layer tuple")
            return None
        return saved[key]

    for l in net.layers:
        if shape is None:
            break  # a structural error already made downstream shapes moot
        if isinstance(l, Save):
            saved[l.key] = shape
        elif isinstance(l, Conv):
            path = f"{net.name}/{l.name}"
            if min(l.kh, l.kw, l.stride, l.dilation, l.groups, l.cin,
                   l.cout) < 1:
                rep.error("VSC103", path,
                          f"non-positive geometry parameter in {l}")
                shape = None
                break
            xin = read_slot(l.src, path, "Conv.src") if l.src else shape
            if xin is None:
                shape = None
                break
            if len(xin) != 4:
                rep.error("VSC107", path,
                          f"Conv on a rank-{len(xin)} stream {xin} "
                          f"(after Flatten?)")
                shape = None
                break
            n, h, w, c = xin
            if c != l.cin:
                rep.error("VSC101", path,
                          f"stream carries C={c} but Conv.cin={l.cin}")
                shape = None
                break
            if l.cin % l.groups or l.cout % l.groups:
                rep.error("VSC102", path,
                          f"cin={l.cin} / cout={l.cout} not divisible by "
                          f"groups={l.groups}")
                shape = None
                break
            if (l.kh - 1) * l.dilation + 1 > h or \
                    (l.kw - 1) * l.dilation + 1 > w:
                rep.warn("VSC112", path,
                         f"effective kernel extent "
                         f"({(l.kh - 1) * l.dilation + 1}x"
                         f"{(l.kw - 1) * l.dilation + 1}) exceeds the "
                         f"{h}x{w} input: some taps read padding only")
            cin_g = l.cin // l.groups
            try:
                geom = conv_tile_geometry(
                    l.kh, l.kw, cin_g, l.cout, vk=vk, vn=vn, groups=l.groups,
                    allow_fallback=l.allow_fallback, path=path)
            except VSCheckError as e:
                rep.diagnostics.extend(e.diagnostics)
                shape = None
                break
            if l.groups > 1 and cin_g == 1 and not geom.depthwise:
                # allow_fallback=True accepted the vk==1 grouped fallback;
                # still worth flagging
                rep.warn("VSC109", path,
                         f"channel-multiplier depthwise falls back to "
                         f"grouped kernels with vk={geom.vk} (MXU-wasteful)")
            if geom.cin_pad >= geom.vk:
                rep.error("VSC111", path,
                          f"cin padding {geom.cin_pad} >= K-tile {geom.vk}: "
                          f"a whole all-zero tile per tap")
            if geom.vn < 8 and geom.vn < min(vn, l.cout):
                rep.warn("VSC110", path,
                         f"output strip shrunk to vn={geom.vn} (cout="
                         f"{l.cout} has no divisor near {vn}): lane "
                         f"utilization {geom.vn}/{vn}",
                         hint="pick a cout with a larger power-of-two "
                              "divisor")
            ho = _conv_out(h, l.kh, l.stride, l.dilation)
            wo = _conv_out(w, l.kw, l.stride, l.dilation)
            if ho < 1 or wo < 1:
                rep.error("VSC108", path,
                          f"conv output {ho}x{wo} collapses the feature map")
                shape = None
                break
            out = (n, ho, wo, l.cout)
            if l.residual:
                rshape = read_slot(l.residual, path, "Conv.residual")
                if rshape is not None and rshape != out:
                    rep.error(
                        "VSC105", path,
                        f"residual arm {l.residual!r} is {rshape}, the conv "
                        f"produces {out}: the fused add cannot broadcast",
                        hint="insert a projection conv on the shortcut "
                             "(stride/channel match)")
            # the prune rule sparsify applies: grouped layers always prune
            # (per-strip == per-group quota); ungrouped small-cin stems
            # stay dense-in-format
            prune = True if l.groups > 1 else cin_g >= vk
            s_steps = strip_steps(geom.kb, density, prune=prune)
            c_enc = l.cin + (0 if geom.depthwise or l.groups > 1
                             else geom.cin_pad)
            sites.append(ConvSite(
                name=l.name, path=path, x_shape=(n, h, w, c_enc), kh=l.kh,
                kw=l.kw, stride=l.stride, groups=l.groups,
                dilation=l.dilation, cout=l.cout, geom=geom, s_steps=s_steps,
                has_residual=l.residual is not None,
            ))
            if l.dst:
                saved[l.dst] = out
            else:
                shape = out
        elif isinstance(l, ResidualAdd):
            path = f"{net.name}/residual_add[{l.key}]"
            rshape = read_slot(l.key, path, "ResidualAdd")
            if rshape is not None and rshape != shape:
                rep.error("VSC105", path,
                          f"shortcut {l.key!r} is {rshape}, the stream is "
                          f"{shape}")
        elif isinstance(l, Pool):
            path = f"{net.name}/pool[{l.kind}]"
            if len(shape) != 4:
                rep.error("VSC107", path,
                          f"Pool on a rank-{len(shape)} stream {shape}")
                shape = None
                break
            n, h, w, c = shape
            if l.kind == "gap":
                shape = (n, 1, 1, c)
            else:
                stride = l.stride or l.size
                ho = _pool_out(h, l.size, stride, l.padding)
                wo = _pool_out(w, l.size, stride, l.padding)
                if ho < 1 or wo < 1:
                    rep.error("VSC108", path,
                              f"{l.size}x{l.size}/s{stride} {l.padding} "
                              f"pool of a {h}x{w} map yields {ho}x{wo}")
                    shape = None
                    break
                shape = (n, ho, wo, c)
        elif isinstance(l, Flatten):
            if len(shape) != 4:
                rep.error("VSC107", f"{net.name}/flatten",
                          f"Flatten on a rank-{len(shape)} stream {shape}")
                shape = None
                break
            n, h, w, c = shape
            shape = (n, h * w * c)
        elif isinstance(l, FC):
            path = f"{net.name}/{l.name}"
            if min(l.din, l.dout) < 1:
                rep.error("VSC103", path, f"non-positive FC dims in {l}")
                shape = None
                break
            if len(shape) != 2:
                rep.error("VSC107", path,
                          f"FC on a rank-{len(shape)} stream {shape}",
                          hint="insert Flatten() before the FC head")
                shape = None
                break
            n, feats = shape
            if feats != l.din:
                rep.error("VSC106", path,
                          f"flattened features {feats} != FC.din {l.din}")
                shape = None
                break
            fgeom = fc_tile_geometry(l.din, l.dout, vk=vk, vn=vn)
            if fgeom is None:
                rep.warn("VSC116", path,
                         f"din={l.din} is not a multiple of vk={vk}: the "
                         f"layer stays dense at sparsify time")
                s_steps = 0
            else:
                s_steps = strip_steps(fgeom.kb, density, prune=True)
            fcs.append(FCSite(name=l.name, path=path, m=n, din=l.din,
                              dout=l.dout, geom=fgeom, s_steps=s_steps))
            shape = (n, l.dout)
        else:
            rep.error("VSC103", net.name, f"unknown layer spec {l!r}")
            shape = None
            break
    return NetCheck(rep, sites, fcs, shape)
