"""Structured diagnostics for vscheck (the static IR/kernel verifier).

Every finding the analyzer emits is a `Diagnostic`: a stable rule id (the
catalog below), a severity, the layer path it anchors to
(``net/layer``), a message, and a fix hint.  `Report` collects them per
run; `VSCheckError` carries error diagnostics across an API boundary
(e.g. `models.graph.sparse_conv_from_dense` refusing a wasteful
depthwise-multiplier encoding, or `launch.serve.CNNServer` rejecting an
invalid net before device placement).

This module is dependency-free on purpose: `models.graph` imports it to
*raise* diagnostics, while `analysis.ir` imports `models.graph` to *walk*
nets — keeping the error vocabulary here breaks that cycle.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Diagnostic", "Report", "VSCheckError", "RULES"]


# Rule catalog: id -> one-line description.  IR rules are VSC1xx, kernel
# contract rules VSC2xx, source lint rules VSC3xx.  README "Static
# analysis" documents the same table; `python -m repro.analysis --rules`
# prints it.
RULES: dict[str, str] = {
    # -- IR validation (shape/geometry inference over LayerSpec graphs) ----
    "VSC101": "Conv input channel mismatch (stream C != Conv.cin)",
    "VSC102": "invalid grouped geometry (cin or cout not divisible by groups)",
    "VSC103": "non-positive kernel/stride/dilation/channel parameter",
    "VSC104": "read of an undefined saved slot (src/residual/ResidualAdd)",
    "VSC105": "residual arm shape mismatch at the fused add",
    "VSC106": "FC fan-in mismatch (flattened features != FC.din)",
    "VSC107": "rank mismatch (FC on 4-D stream / Conv after Flatten)",
    "VSC108": "pool window collapses the feature map (output dim < 1)",
    "VSC109": "depthwise channel-multiplier > 1 without allow_fallback "
              "(vk==1 grouped fallback is MXU-wasteful)",
    "VSC110": "output strip shrunk far below vn (non-tileable Cout)",
    "VSC111": "cin zero-padding exceeds the real channel count",
    "VSC112": "kernel extent exceeds the input extent (taps read padding "
              "only)",
    "VSC116": "FC fan-in not a vk multiple: layer stays dense at sparsify",
    # -- kernel contract checking (abstract index-map evaluation) ----------
    "VSC201": "block read escapes the padded buffer bounds",
    "VSC202": "abstractly derived bytes != kernel CostEstimate bytes",
    "VSC203": "abstractly derived bytes != conv_layer_traffic model bytes",
    "VSC204": "faithful revisit simulation exceeds the contract bytes "
              "(cost formula is not a sound upper bound)",
    "VSC205": "abstractly derived FLOPs != kernel CostEstimate FLOPs",
    # -- repo lint (AST rules over src/ + benchmarks/) ---------------------
    "VSC301": "impl= string literal outside the dispatch vocabulary",
    "VSC302": "clock read feeding scheduler control flow",
    "VSC303": "module-scope environment mutation outside a main() guard",
    "VSC304": "bare/blanket except in the serving launch layer (swallows "
              "typed replica faults)",
}

_SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One analyzer finding.

    ``path`` anchors the finding: ``net/layer`` for IR and contract rules,
    ``file:line`` for lint rules.
    """

    rule: str
    severity: str
    path: str
    message: str
    hint: str = ""

    def __post_init__(self) -> None:
        if self.rule not in RULES:
            raise ValueError(f"unknown diagnostic rule {self.rule!r}")
        if self.severity not in _SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def render(self) -> str:
        hint = f"  [fix: {self.hint}]" if self.hint else ""
        return f"{self.severity}[{self.rule}] {self.path}: {self.message}{hint}"


class VSCheckError(Exception):
    """An operation refused because vscheck diagnostics rate it invalid."""

    def __init__(self,
                 diagnostics: list[Diagnostic] | Diagnostic) -> None:
        if isinstance(diagnostics, Diagnostic):
            diagnostics = [diagnostics]
        self.diagnostics = list(diagnostics)
        super().__init__(
            "\n".join(d.render() for d in self.diagnostics) or "vscheck failed")


@dataclasses.dataclass
class Report:
    """Collected diagnostics of one analyzer run."""

    diagnostics: list[Diagnostic] = dataclasses.field(default_factory=list)

    def add(self, rule: str, severity: str, path: str, message: str,
            hint: str = "") -> None:
        self.diagnostics.append(Diagnostic(rule, severity, path, message, hint))

    def error(self, rule: str, path: str, message: str, hint: str = "") -> None:
        self.add(rule, "error", path, message, hint)

    def warn(self, rule: str, path: str, message: str, hint: str = "") -> None:
        self.add(rule, "warning", path, message, hint)

    def extend(self, other: "Report") -> None:
        self.diagnostics.extend(other.diagnostics)

    def suppress(self, rules: set[str]) -> "Report":
        """A copy without diagnostics whose rule id is in ``rules``."""
        return Report([d for d in self.diagnostics if d.rule not in rules])

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "error"]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == "warning"]

    def ok(self, *, warnings_as_errors: bool = False) -> bool:
        if warnings_as_errors:
            return not self.diagnostics
        return not self.errors

    def render(self) -> str:
        return "\n".join(d.render() for d in self.diagnostics)

    def raise_errors(self) -> None:
        if self.errors:
            raise VSCheckError(self.errors)
