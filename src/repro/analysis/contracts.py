"""vscheck pass 2 — kernel contract checking by abstract index-map
evaluation.

For every `ConvSite`/`FCSite` the IR walk produced, build the
`kernels.plan.KernelPlan` each impl would dispatch ('halo' and 'stack'
for convs, vsmm for FC heads) and prove, without executing anything:

  VSC201  every block a grid step can read/write stays inside the padded
          buffer — the kernel's *own* index_map evaluated over
          `analysis.intervals.Interval` grid axes and the full stored-
          tile-id range (so the proof covers every balanced encoding of
          the layer, not one sampled mask);
  VSC202  the HBM bytes the kernel claims in its `pl.CostEstimate` equal
          the bytes re-derived from the abstract access set — the same
          index_map enumerated over the concrete grid with the canonical
          cin-major idx, block fetches counted under each buffer's
          declared DMA policy;
  VSC203  `core.accel_model.conv_layer_traffic`'s per-column model
          (input/weight/output/flops/build) equals the same derivation
          quoted at the logical (un-padded) extents;
  VSC204  a faithful simulation of Pallas's actual DMA-elision rule
          (skip when a step's offsets equal the immediately previous
          step's) never exceeds the contract's input-fetch count — the
          cost formulas are sound upper bounds.  Input buffer only: the
          weight/output terms are deliberate once-per-unique-tile
          idealizations shared with the traffic model (see
          `kernels.plan`);
  VSC205  claimed FLOPs == flops_per_step * grid size.

The canonical idx is the one `models.graph.sparse_conv_from_dense`
emits: ascending stored-tile ids re-sorted cin-major per strip — the
order the halo cost formula's min(S, CB) fetch floor relies on.

Every site is proven under *both* dtype contracts: f32 (activation /
weight / output all 4 bytes) and int8 (int8 activations+weights, f32
output, a per-cout dequant-scale operand whose tile rides the excluded
DMA policy like bias).  Int8 rows carry a ``:int8`` path tag.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.accel_model import conv_layer_traffic
from repro.kernels.plan import BufferAccess, KernelPlan, conv_plan, fc_plan

from .diagnostics import Report
from .intervals import AbstractIdx, Interval
from .ir import ConvSite, FCSite, NetCheck

__all__ = [
    "PlanSummary", "canonical_conv_idx", "canonical_tap_idx",
    "check_plan", "check_conv_site", "check_fc_site", "check_contracts",
]


@dataclasses.dataclass(frozen=True)
class PlanSummary:
    """One verified kernel invocation (a CLI/report row)."""

    path: str
    variant: str       # 'halo' | 'stack' | 'fc'
    kind: str          # plan kind actually dispatched
    grid: tuple[int, int, int]
    bytes_derived: int
    flops: int


def canonical_conv_idx(nb: int, s_steps: int, cbg: int) -> np.ndarray:
    """The idx table `sparse_conv_from_dense` would emit for the first
    ``s_steps`` stored tiles of every strip: ascending tile ids re-sorted
    cin-major (primary key tile % cbg, secondary tile // cbg) — the order
    `core.vector_sparse.conv_cin_major` produces."""
    r = np.arange(s_steps, dtype=np.int64)
    order = np.lexsort((r // cbg, r % cbg))
    return np.tile(r[order], (nb, 1))


def canonical_tap_idx(nb: int, s_steps: int) -> np.ndarray:
    """Depthwise / vsmm idx: bare ascending ids per strip."""
    return np.tile(np.arange(s_steps, dtype=np.int64), (nb, 1))


# --------------------------------------------------------------------------
# Abstract evaluation machinery
# --------------------------------------------------------------------------

def _grid_axes(grid: tuple[int, int, int]
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The full grid in lexicographic order, last axis fastest — the order
    Pallas iterates a row-major grid (the order VSC204's elision
    simulation depends on)."""
    a0, a1, a2 = np.meshgrid(
        np.arange(grid[0], dtype=np.int64),
        np.arange(grid[1], dtype=np.int64),
        np.arange(grid[2], dtype=np.int64), indexing="ij")
    return a0.ravel(), a1.ravel(), a2.ravel()


def _offsets(plan: KernelPlan, buf: BufferAccess, idx: np.ndarray
             ) -> np.ndarray:
    """(G, rank) element offsets of every grid step's block, lex order."""
    a0, a1, a2 = _grid_axes(plan.grid)
    out = buf.index_map(a0, a1, a2, idx)
    cols = [np.broadcast_to(np.asarray(o, dtype=np.int64), a0.shape)
            for o in out]
    offs = np.stack(cols, axis=1)
    if not buf.unblocked:
        offs = offs * np.asarray(buf.block, dtype=np.int64)
    return offs


def _contract_fetches(plan: KernelPlan, buf: BufferAccess,
                      offs: np.ndarray) -> int:
    """Block DMAs under the buffer's declared counting policy."""
    if buf.policy == "per_step":
        return int(offs.shape[0])
    if buf.policy == "distinct":
        return int(np.unique(offs, axis=0).shape[0])
    if buf.policy == "sweep_distinct":
        axes = _grid_axes(plan.grid)
        key = np.zeros_like(axes[0])
        for ax in buf.sweep_axes:
            key = key * plan.grid[ax] + axes[ax]
        rows = np.concatenate([key[:, None], offs], axis=1)
        return int(np.unique(rows, axis=0).shape[0])
    raise ValueError(f"policy {buf.policy!r} has no fetch count")


def _faithful_fetches(offs: np.ndarray) -> int:
    """Pallas's actual rule: a DMA is issued whenever a step's offsets
    differ from the immediately previous step's (plus the first)."""
    if offs.shape[0] == 0:
        return 0
    changed = np.any(offs[1:] != offs[:-1], axis=1)
    return 1 + int(changed.sum())


def _bounds_violations(plan: KernelPlan, buf: BufferAccess
                       ) -> list[tuple[int, Interval]]:
    """Interval-evaluate the index map over the whole grid and the whole
    stored-tile-id range; every axis whose block can escape the padded
    buffer is a violation."""
    axes = tuple(Interval(0, g - 1) for g in plan.grid)
    out = buf.index_map(*axes, AbstractIdx(plan.kb))
    bad: list[tuple[int, Interval]] = []
    for ax, o in enumerate(out):
        iv = Interval.of(o)
        if buf.unblocked:
            ok = iv.lo >= 0 and iv.hi + buf.block[ax] <= buf.dims[ax]
        else:
            ok = iv.lo >= 0 and (iv.hi + 1) * buf.block[ax] <= buf.dims[ax]
        if not ok:
            bad.append((ax, iv))
    return bad


def _prod(xs: tuple[int, ...]) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def _at_valid(v: int, buf: BufferAccess, path: str) -> int:
    """Re-quote a padded-extent total at the buffer's logical extents
    (exact by construction: wrappers pad whole axes)."""
    num, den = _prod(buf.valid), _prod(buf.dims)
    scaled = v * num
    assert scaled % den == 0, (path, buf.name, v, buf.valid, buf.dims)
    return scaled // den


def check_plan(plan: KernelPlan, *, path: str, rep: Report,
               idx: np.ndarray) -> dict[str, int]:
    """VSC201/202/204/205 for one kernel plan.

    Returns the per-buffer derived byte columns (padded extents) for the
    caller's model comparison; {} is still returned on failure.
    """
    g_total = _prod(plan.grid)
    cols: dict[str, int] = {}
    total = 0
    for buf in plan.buffers:
        for ax, iv in _bounds_violations(plan, buf):
            rep.error(
                "VSC201", path,
                f"{plan.kind}: {buf.name} axis {ax} offset {iv} + block "
                f"{buf.block[ax]} escapes dim {buf.dims[ax]}")
        if buf.policy == "excluded":
            continue
        offs = _offsets(plan, buf, idx)
        fetches = _contract_fetches(plan, buf, offs)
        nbytes = fetches * buf.block_elems * buf.itemsize
        cols[buf.name] = nbytes
        total += nbytes
        if buf.name == "input":
            faithful = _faithful_fetches(offs)
            if faithful > fetches:
                rep.error(
                    "VSC204", path,
                    f"{plan.kind}: faithful DMA-elision simulation issues "
                    f"{faithful} input fetches, the {buf.policy} contract "
                    f"only budgets {fetches}",
                    hint="the stored-tile order no longer matches the "
                         "cost formula's revisit assumption (cin-major)")
    if total != plan.cost.bytes_accessed:
        rep.error(
            "VSC202", path,
            f"{plan.kind}: abstract access set moves {total} bytes, the "
            f"kernel CostEstimate claims {plan.cost.bytes_accessed}")
    derived_flops = plan.flops_per_step * g_total
    if derived_flops != plan.cost.flops:
        rep.error(
            "VSC205", path,
            f"{plan.kind}: grid issues {derived_flops} FLOPs, the kernel "
            f"CostEstimate claims {plan.cost.flops}")
    return cols


def _plan_idx(plan: KernelPlan, *, cbg: int) -> np.ndarray:
    if plan.kind in ("halo", "resident", "stack"):
        return canonical_conv_idx(plan.nb, plan.s_steps, cbg)
    return canonical_tap_idx(plan.nb, plan.s_steps)


def check_conv_site(site: ConvSite, *, rep: Report, itemsize: int = 4,
                    w_itemsize: int | None = None,
                    out_itemsize: int | None = None) -> list[PlanSummary]:
    """Both conv impls of one site: plan + prove + compare to the traffic
    model column by column (VSC203).

    ``itemsize``/``w_itemsize``/``out_itemsize`` select the dtype
    contract — (4, 4, 4) is f32, (1, 1, 4) is the int8 path (int8
    activations+weights dequantized to f32 in the epilogue, so the plan
    additionally carries the excluded per-cout scale tile).
    """
    out: list[PlanSummary] = []
    g = site.geom
    n, h, w, c = site.x_shape
    w_itemsize = w_itemsize or itemsize
    out_itemsize = out_itemsize or itemsize
    int8 = w_itemsize == 1
    tag = ":int8" if int8 else ""
    for impl in ("halo", "stack"):
        plan = conv_plan(
            site.x_shape, kh=site.kh, kw=site.kw, stride=site.stride,
            groups=site.groups, dilation=site.dilation, cout=site.cout,
            s_steps=site.s_steps, vk=g.vk, vn=g.vn, impl=impl,
            has_bias=True, has_residual=site.has_residual,
            has_scale=int8, itemsize=itemsize, w_itemsize=w_itemsize,
            out_itemsize=out_itemsize,
        )
        assert plan.kb == g.kb, (site.path, plan.kb, g.kb)
        path = f"{site.path}[{impl}{tag}]"
        cbg = 1 if g.depthwise else (c // g.vk) // site.groups
        cols = check_plan(plan, path=path, rep=rep,
                          idx=_plan_idx(plan, cbg=cbg))
        model = conv_layer_traffic(
            site.x_shape, kh=site.kh, kw=site.kw, stride=site.stride,
            groups=site.groups, dilation=site.dilation, cout=site.cout,
            s_steps=site.s_steps, vk=g.vk, vn=g.vn, impl=impl,
            itemsize=itemsize, w_itemsize=w_itemsize,
            out_itemsize=out_itemsize, residual=site.has_residual,
        )
        # quote the derived columns at logical extents (the vsmm row axis
        # is the only padded one) and derive the layout-pass bytes from
        # the plan's input buffer dims
        if plan.kind == "vsmm":
            x_buf, o_buf = plan.buffer("input"), plan.buffer("output")
            m_valid, mp = o_buf.valid[0], o_buf.dims[0]
            derived = {
                "input": _at_valid(cols["input"], x_buf, path),
                "weights": cols["weights"],
                "output": _at_valid(cols["output"], o_buf, path)
                + (_at_valid(cols["residual"], plan.buffer("residual"), path)
                   if site.has_residual else 0),
                "flops": plan.flops_per_step * _prod(plan.grid)
                * m_valid // mp,
                "build": (2 * m_valid * c * itemsize
                          if site.stride != 1 else 0),
            }
        else:
            in_dims = plan.buffer("input").dims
            derived = {
                "input": cols["input"],
                "weights": cols["weights"],
                "output": cols["output"] + cols.get("residual", 0),
                "flops": plan.flops_per_step * _prod(plan.grid),
                "build": (n * h * w * c + _prod(in_dims)) * itemsize,
            }
        expect = {
            "input": model.input_bytes,
            "weights": model.weight_bytes,
            "output": model.output_bytes,
            "flops": model.flops,
            "build": model.build_bytes,
        }
        bad = [k for k in expect if derived[k] != expect[k]]
        if bad:
            detail = ", ".join(
                f"{k}: derived {derived[k]} != model {expect[k]}"
                for k in bad)
            rep.error("VSC203", path,
                      f"{plan.kind}: traffic model drift — {detail}")
        out.append(PlanSummary(
            path=path, variant=impl, kind=plan.kind, grid=plan.grid,
            bytes_derived=sum(cols.values()),
            flops=plan.flops_per_step * _prod(plan.grid)))
    return out


def check_fc_site(site: FCSite, *, rep: Report, itemsize: int = 4,
                  w_itemsize: int | None = None,
                  out_itemsize: int | None = None) -> list[PlanSummary]:
    """The vsmm plan of one FC head (dense VSC116 layers are skipped —
    no sparse kernel runs for them).  Dtype contract selection as in
    `check_conv_site`."""
    g = site.geom
    if g is None:
        return []
    w_itemsize = w_itemsize or itemsize
    out_itemsize = out_itemsize or itemsize
    int8 = w_itemsize == 1
    plan = fc_plan(
        m=site.m, k=site.din, s_steps=site.s_steps, vk=g.vk, vn=g.vn,
        nb=g.nb, has_bias=True, has_scale=int8, itemsize=itemsize,
        w_itemsize=w_itemsize, out_itemsize=out_itemsize,
    )
    path = f"{site.path}[fc:int8]" if int8 else f"{site.path}[fc]"
    cols = check_plan(plan, path=path, rep=rep,
                      idx=_plan_idx(plan, cbg=1))
    return [PlanSummary(
        path=path, variant="fc", kind=plan.kind, grid=plan.grid,
        bytes_derived=sum(cols.values()),
        flops=plan.flops_per_step * _prod(plan.grid))]


# activation / weight / output itemsizes of each verified dtype contract
DTYPE_CONTRACTS: dict[str, tuple[int, int, int]] = {
    "f32": (4, 4, 4),
    "int8": (1, 1, 4),
}


def check_contracts(nc: NetCheck, *, itemsize: int = 4,
                    dtypes: tuple[str, ...] = ("f32", "int8")
                    ) -> tuple[Report, list[PlanSummary]]:
    """Pass 2 over everything pass 1 surfaced, once per dtype contract.

    ``itemsize`` overrides the f32 contract's uniform itemsize (kept for
    callers probing odd widths); the int8 pass always runs (1, 1, 4).
    """
    rep = Report()
    rows: list[PlanSummary] = []
    for dt in dtypes:
        a_i, w_i, o_i = DTYPE_CONTRACTS[dt]
        if dt == "f32":
            a_i = w_i = o_i = itemsize
        for site in nc.conv_sites:
            rows.extend(check_conv_site(
                site, rep=rep, itemsize=a_i, w_itemsize=w_i,
                out_itemsize=o_i))
        for fsite in nc.fc_sites:
            rows.extend(check_fc_site(
                fsite, rep=rep, itemsize=a_i, w_itemsize=w_i,
                out_itemsize=o_i))
    return rep, rows
