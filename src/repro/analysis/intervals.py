"""Integer interval arithmetic for abstract index-map evaluation.

The Pallas kernels' ``BlockSpec.index_map`` functions are closed
arithmetic over grid indices and the prefetched ``idx`` table: only
``+ - * // %`` with non-negative operands (see `repro.kernels.vsconv`).
Evaluating them with `Interval` operands therefore yields sound bounds on
every block offset a kernel can ever issue — the in-bounds proof in
`analysis.contracts` needs nothing more than these five operators.

Soundness convention: every operation returns an interval containing all
pointwise results for operands in the input intervals.  ``//`` and ``%``
are only defined for positive *constant* divisors (the only form the
index maps use); ``%`` collapses to ``[0, c-1]`` when the dividend spans a
multiple of ``c`` (exact otherwise).
"""
from __future__ import annotations

import dataclasses

__all__ = ["Interval", "AbstractIdx"]


@dataclasses.dataclass(frozen=True)
class Interval:
    """A closed integer interval [lo, hi] (lo <= hi)."""

    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    @staticmethod
    def point(v: int) -> "Interval":
        return Interval(v, v)

    @staticmethod
    def of(v: "Interval | int") -> "Interval":
        return v if isinstance(v, Interval) else Interval.point(int(v))

    # -- arithmetic ---------------------------------------------------------
    def __add__(self, other: "Interval | int") -> "Interval":
        o = Interval.of(other)
        return Interval(self.lo + o.lo, self.hi + o.hi)

    __radd__ = __add__

    def __sub__(self, other: "Interval | int") -> "Interval":
        o = Interval.of(other)
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def __rsub__(self, other: int) -> "Interval":
        return Interval.of(other) - self

    def __mul__(self, other: "Interval | int") -> "Interval":
        o = Interval.of(other)
        corners = (self.lo * o.lo, self.lo * o.hi,
                   self.hi * o.lo, self.hi * o.hi)
        return Interval(min(corners), max(corners))

    __rmul__ = __mul__

    def __floordiv__(self, c: int) -> "Interval":
        if isinstance(c, Interval):
            if c.lo != c.hi:
                raise TypeError("interval // interval is not supported")
            c = c.lo
        if c <= 0:
            raise ValueError(f"// by non-positive constant {c}")
        return Interval(self.lo // c, self.hi // c)

    def __mod__(self, c: int) -> "Interval":
        if isinstance(c, Interval):
            if c.lo != c.hi:
                raise TypeError("interval % interval is not supported")
            c = c.lo
        if c <= 0:
            raise ValueError(f"% by non-positive constant {c}")
        if self.lo < 0:
            raise ValueError(f"% of a possibly-negative interval {self}")
        if self.lo // c != self.hi // c:
            # the dividend spans a multiple of c: the residue wraps
            return Interval(0, c - 1)
        return Interval(self.lo % c, self.hi % c)

    # -- queries ------------------------------------------------------------
    def within(self, lo: int, hi: int) -> bool:
        """True when the whole interval lies in [lo, hi]."""
        return lo <= self.lo and self.hi <= hi

    def __repr__(self) -> str:
        return f"[{self.lo},{self.hi}]"


class AbstractIdx:
    """Abstract stand-in for the prefetched ``idx`` table.

    ``idx[j, s]`` returns the full stored-tile-id range ``[0, kb - 1]``
    whatever the (abstract) strip and step — so a bounds proof over it
    holds for *every* balanced encoding of the layer, not one sample.
    """

    def __init__(self, kb: int) -> None:
        if kb < 1:
            raise ValueError(f"kb must be >= 1, got {kb}")
        self.kb = kb

    def __getitem__(self, key: object) -> Interval:
        return Interval(0, self.kb - 1)
