"""vscheck — static IR/kernel contract verification for the sparse stack.

Three passes, runnable standalone (``python -m repro.analysis``) and as
the CI static-analysis gate:

  1. `analysis.ir`         — shape/geometry inference over `SparseNet`
                             layer graphs (rules VSC1xx);
  2. `analysis.contracts`  — abstract index-map evaluation proving every
                             registered kernel invocation in-bounds and
                             its `pl.CostEstimate` byte/FLOP contract
                             exact (rules VSC2xx);
  3. `analysis.lint`       — repo-specific AST lint (rules VSC3xx).

Only `diagnostics`/`intervals` are imported eagerly: `models.graph`
imports `analysis.diagnostics` for its error vocabulary, while
`analysis.ir` imports `models.graph` — the submodules that close that
loop load lazily via ``__getattr__``.
"""
from __future__ import annotations

import importlib
from typing import Any

from .diagnostics import RULES, Diagnostic, Report, VSCheckError
from .intervals import AbstractIdx, Interval

__all__ = [
    "RULES", "Diagnostic", "Report", "VSCheckError",
    "AbstractIdx", "Interval",
    # lazy (see __getattr__): walker + contract + lint entry points
    "check_net", "check_contracts", "check_one_net", "lint_paths",
    "ConvSite", "FCSite", "NetCheck", "PlanSummary",
]

_LAZY = {
    "check_net": "ir", "ConvSite": "ir", "FCSite": "ir", "NetCheck": "ir",
    "check_contracts": "contracts", "PlanSummary": "contracts",
    "lint_paths": "lint",
    "check_one_net": "__main__",
}


def __getattr__(name: str) -> Any:
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    return getattr(importlib.import_module(f".{mod}", __name__), name)
