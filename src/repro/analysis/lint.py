"""vscheck pass 3 — repo-specific AST lint rules.

Stdlib-`ast` rules for invariants this codebase cares about and generic
linters can't know:

  VSC301  ``impl=`` keyword string literals must come from the dispatch
          vocabulary (`ops.vsconv` takes 'halo'/'stack',
          `core.sparse_ops` 'jnp'/'pallas'/'pallas-halo'/'pallas-stack',
          the walker adds 'auto') — a typo'd impl string otherwise
          surfaces as a runtime ValueError deep inside a sweep;
  VSC302  wall-clock reads (`time.time`/`monotonic`/`perf_counter`)
          must not appear in `if`/`while` conditions of the serving
          scheduler — timing-dependent control flow is what made the
          replica scheduler non-reproducible; clocks are fine in
          stats/telemetry straight-line code;
  VSC303  module scope must not mutate ``os.environ`` — import order
          then silently decides XLA/JAX flags; mutations belong inside
          ``main()`` / under ``if __name__ == "__main__":``;
  VSC304  no bare or blanket ``except`` (``except:``, ``except
          Exception`` / ``BaseException``) in the serving launch layer
          (`repro/launch/`) — the fleet scheduler's fault tolerance
          relies on replica faults being *typed*
          (`launch.faults.FAULT_TYPES`); an overbroad handler between
          the backend and the scheduler silently swallows the fault and
          defeats quarantine/requeue (and chaos testing with it).
"""
from __future__ import annotations

import ast
import pathlib
import re

from .diagnostics import Report

__all__ = ["IMPL_VOCAB", "lint_source", "lint_paths"]


# every impl= string the dispatch layers accept
IMPL_VOCAB = frozenset(
    {"halo", "stack", "jnp", "pallas", "pallas-halo", "pallas-stack", "auto"})

_CLOCK_ATTRS = frozenset({"time", "monotonic", "perf_counter"})

# VSC302 only applies where timing-dependent branches are a correctness
# hazard (the serving scheduler's placement/retry logic)
_SCHEDULER_HINTS = ("scheduler",)

# VSC304 applies to the serving launch layer, where fault handling must
# stay typed (FAULT_TYPES) for quarantine/requeue to see replica faults
_LAUNCH_HINTS = ("launch",)

_BLANKET_EXCEPTIONS = frozenset({"Exception", "BaseException"})


def _blanket_name(handler: ast.ExceptHandler) -> str | None:
    """The blanket type a handler catches, if any: None type (bare
    ``except:``), ``Exception``/``BaseException`` by name or attribute,
    including inside a tuple of types."""
    t = handler.type
    if t is None:
        return "bare except:"
    types = list(t.elts) if isinstance(t, ast.Tuple) else [t]
    for node in types:
        name = None
        if isinstance(node, ast.Name):
            name = node.id
        elif isinstance(node, ast.Attribute):
            name = node.attr
        if name in _BLANKET_EXCEPTIONS:
            return f"except {name}"
    return None


def _is_clock_call(node: ast.AST) -> bool:
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)):
        return False
    f = node.func
    return (f.attr in _CLOCK_ATTRS and isinstance(f.value, ast.Name)
            and f.value.id == "time")


def _is_environ(node: ast.AST) -> bool:
    """os.environ / environ attribute chains."""
    return ((isinstance(node, ast.Attribute) and node.attr == "environ")
            or (isinstance(node, ast.Name) and node.id == "environ"))


def _is_main_guard(node: ast.stmt) -> bool:
    return (isinstance(node, ast.If)
            and isinstance(node.test, ast.Compare)
            and isinstance(node.test.left, ast.Name)
            and node.test.left.id == "__name__")


_IGNORE_RE = re.compile(r"#\s*vscheck:\s*ignore\[([A-Z0-9, ]+)\]")


def _inline_ignores(src: str) -> dict[int, frozenset[str]]:
    """``# vscheck: ignore[VSC303]`` waivers, keyed by 1-based line.
    A waiver covers its own line and the one below it (so it can sit on
    a comment line above a statement too long to share)."""
    out: dict[int, frozenset[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if m:
            rules = frozenset(r.strip() for r in m.group(1).split(","))
            out[i] = out.get(i, frozenset()) | rules
            out[i + 1] = out.get(i + 1, frozenset()) | rules
    return out


def lint_source(src: str, filename: str, *, rep: Report) -> None:
    """All three rules over one file's source text.  A finding whose line
    carries ``# vscheck: ignore[RULE]`` is waived (for mutations that are
    genuinely load-bearing, e.g. XLA flags that must precede the jax
    import)."""
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        rep.error("VSC303", f"{filename}:{e.lineno or 0}",
                  f"file does not parse: {e.msg}")
        return
    ignores = _inline_ignores(src)

    def emit(rule: str, lineno: int, message: str, hint: str = "") -> None:
        if rule in ignores.get(lineno, ()):
            return
        rep.error(rule, f"{filename}:{lineno}", message, hint)

    parts = pathlib.PurePath(filename).parts
    is_scheduler = any(h in pathlib.PurePath(filename).name
                       for h in _SCHEDULER_HINTS)
    is_launch = any(h in parts for h in _LAUNCH_HINTS)

    # VSC301 — impl= literals
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg != "impl":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str) \
                    and v.value not in IMPL_VOCAB:
                emit(
                    "VSC301", v.lineno,
                    f"impl={v.value!r} is not in the dispatch vocabulary "
                    f"{sorted(IMPL_VOCAB)}",
                    hint="typo'd impl strings raise ValueError at run "
                         "time, deep inside a sweep")

    # VSC302 — clock reads in scheduler control flow
    if is_scheduler:
        for node in ast.walk(tree):
            if isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if _is_clock_call(sub):
                        emit(
                            "VSC302", sub.lineno,
                            "wall-clock read inside a scheduler branch "
                            "condition",
                            hint="read the clock into stats outside the "
                                 "branch; decide on counters/queue state")

    # VSC304 — blanket excepts in the launch layer
    if is_launch:
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            blanket = _blanket_name(node)
            if blanket is not None:
                emit(
                    "VSC304", node.lineno,
                    f"{blanket} in the serving launch layer swallows typed "
                    f"replica faults",
                    hint="catch the concrete exception types (e.g. "
                         "launch.faults.FAULT_TYPES) so the fleet "
                         "scheduler's quarantine/requeue sees the fault")

    # VSC303 — module-scope os.environ mutation
    def check_stmt(st: ast.stmt) -> None:
        for node in ast.walk(st):
            bad = False
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                bad = any(isinstance(t, ast.Subscript)
                          and _is_environ(t.value) for t in targets)
            elif (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("setdefault", "update", "pop",
                                           "clear")
                    and _is_environ(node.func.value)):
                bad = True
            if bad:
                emit(
                    "VSC303", node.lineno,
                    "os.environ mutated at module scope (import-order "
                    "dependent)",
                    hint="move it into main() / the "
                         "__name__ == '__main__' guard")

    def scan_stmts(stmts: list[ast.stmt]) -> None:
        for st in stmts:
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
                continue  # deferred bodies don't run at import time
            if _is_main_guard(st):
                continue
            if isinstance(st, (ast.If, ast.For, ast.While, ast.With,
                               ast.Try)):
                # compound statements' bodies still execute at import time
                scan_stmts(st.body)
                scan_stmts(getattr(st, "orelse", []) or [])
                scan_stmts(getattr(st, "finalbody", []) or [])
                for h in getattr(st, "handlers", []) or []:
                    scan_stmts(h.body)
            else:
                check_stmt(st)

    scan_stmts(tree.body)


def lint_paths(root: pathlib.Path, *, rep: Report,
               subdirs: tuple[str, ...] = ("src", "benchmarks")) -> int:
    """Lint every .py file under ``root``'s code subdirs; returns the
    file count."""
    n = 0
    for sub in subdirs:
        base = root / sub
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(root)
            lint_source(p.read_text(), str(rel), rep=rep)
            n += 1
    return n
