"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["warmup_cosine", "warmup_linear", "constant"]


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  floor: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return lr


def warmup_linear(peak: float, warmup_steps: int, total_steps: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        frac = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        return jnp.where(step < warmup_steps, warm, peak * (1 - frac))

    return lr


def constant(value: float):
    return lambda step: jnp.full((), value, jnp.float32)
