"""Optimizers + schedules (state trees mirror params; shard via same rules)."""
from .optimizers import Optimizer, adamw, adamw8bit, adafactor, global_norm, clip_by_global_norm
from .schedules import warmup_cosine, warmup_linear, constant
