"""Optimizers: AdamW (fp32 / bf16 moments) and Adafactor (factored second
moment — the 340B / 1T fit on 512 x 16 GB chips requires it).

Pure-pytree, schema-agnostic: state trees mirror the param tree, so the same
logical-axes tree shards optimizer state (ZeRO posture falls out of the
'fsdp' rule for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # state_axes(param_axes_leaf, param_shape) -> pytree of axes for this leaf
    state_axes: Callable[[tuple, tuple], Any]


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, moment_dtype=jnp.float32) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
            upd = (m_new / c1) / (jnp.sqrt(v_new / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            return ((-lr * upd).astype(p.dtype),
                    m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = jax.tree.map(leaf, grads, state["m"], state["v"], params)
        upds = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return upds, {"m": m, "v": v, "count": count}

    def state_axes(axes, shape):
        return {"m": axes, "v": axes}

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# int8 block-quantized AdamW (8-bit optimizer states, Dettmers-style)
# ---------------------------------------------------------------------------

_QBLOCK = 256


def _q8(x32: jax.Array, block: int = _QBLOCK):
    """f32 -> (int8 codes, f32 per-block scales, pad). Linear symmetric."""
    flat = x32.reshape(-1)
    pad = (-flat.shape[0]) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def adamw8bit(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
              weight_decay: float = 0.1) -> Optimizer:
    """AdamW with int8 block-quantized moments: ~4.5 bits/param of state
    per moment (int8 + fp32 scale per 256 block) instead of 32 — the m,v
    state of a 340B model drops from 2.7 TB to ~0.77 TB."""

    def _state_of(p):
        n = p.size
        nb = -(-n // _QBLOCK)
        return {
            "mq": jnp.zeros((nb, _QBLOCK), jnp.int8),
            "ms": jnp.zeros((nb,), jnp.float32),
            "vq": jnp.zeros((nb, _QBLOCK), jnp.int8),
            "vs": jnp.zeros((nb,), jnp.float32),
        }

    def init(params):
        return {
            "moments": jax.tree.map(_state_of, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(mom, g, p):
            g32 = g.astype(jnp.float32)
            m = _dq8(mom["mq"], mom["ms"], p.shape)
            v = _dq8(mom["vq"], mom["vs"], p.shape)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            upd = (m / c1) / (jnp.sqrt(v / c2) + eps)
            upd = upd + weight_decay * p.astype(jnp.float32)
            mq, ms = _q8(m)
            vq, vs = _q8(v)
            return ((-lr * upd).astype(p.dtype),
                    {"mq": mq, "ms": ms, "vq": vq, "vs": vs})

        is_mom = lambda x: isinstance(x, dict) and "mq" in x
        out = jax.tree.map(leaf, state["moments"], grads, params,
                           is_leaf=is_mom)
        upds = jax.tree.map(lambda o: o[0], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        moms = jax.tree.map(lambda o: o[1], out,
                            is_leaf=lambda x: isinstance(x, tuple))
        return upds, {"moments": moms, "count": count}

    def state_axes(axes, shape):
        # block layout is flat: shard nothing (scales/codes are tiny relative
        # to fsdp-sharded fp32 states; replicate-over-model, shard via fsdp
        # is a future refinement)
        return {"mq": (None, None), "ms": (None,),
                "vq": (None, None), "vs": (None,)}

    return Optimizer(init, update, state_axes)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments
# ---------------------------------------------------------------------------


def adafactor(decay: float = 0.8, eps: float = 1e-30, clip_threshold: float = 1.0,
              min_dim_factored: int = 128, weight_decay: float = 0.0) -> Optimizer:
    """Memory: O(rows + cols) per matrix instead of O(rows * cols).

    Matrices with both trailing dims >= min_dim_factored factor over the last
    two axes; everything else stores a full second moment.
    """

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] >= min_dim_factored and shape[-2] >= min_dim_factored

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "moments": jax.tree.map(leaf, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay  # t^-0.8 schedule

        def leaf(g, mom, p):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if _factored(p.shape):
                vr = beta * mom["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * mom["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                r_factor = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps
                )
                c_factor = jax.lax.rsqrt(vc + eps)
                upd = g32 * r_factor[..., None] * c_factor[..., None, :]
                new_mom = {"vr": vr, "vc": vc}
            else:
                v = beta * mom["v"] + (1 - beta) * g2
                upd = g32 * jax.lax.rsqrt(v + eps)
                new_mom = {"v": v}
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
            upd = upd / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (-lr * upd).astype(p.dtype), new_mom

        is_mom = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(lambda mom, g, p: leaf(g, mom, p),
                           state["moments"], grads, params, is_leaf=is_mom)
        upds = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        moms = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return upds, {"moments": moms, "count": count}

    def state_axes(axes, shape):
        if _factored(shape):
            return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
        return {"v": axes}

    return Optimizer(init, update, state_axes)
