"""Cycle-accurate model of the VSCNN PE array (paper §II-III, Table I).

Geometry (Fig. 4/5): a PE config ``[B, R, C]`` has B PE-array blocks, each
R rows x C(=3) columns.  Every cycle one block consumes:

  * one input-activation column vector  (R consecutive H positions, one W
    column, one input channel)   — broadcast horizontally, and
  * one weight kernel column            (C=3 ky-elements for one kx, one
    (cin, cout) pair)            — broadcast vertically;

the outer product accumulates diagonally into R (+C-1 boundary) output
partial sums.  Dense cost for an H x W x Cin input and 3x3xCinxCout kernel:

    cycles_dense = ceil(H/R) * W * 3 * Cin * ceil(Cout/B)        (block_map='cout')

(check: 5x5 input, pad 1, R=5, B=1, Cin=Cout=1  ->  1*5*3 = 15 cycles,
exactly the paper's "15 cycles for 5x5 input"; the Table-I sparse example
issues only {A,C,D,E} x {WA,WB} = 8 cycles.)

Sparse rule: a cycle is skipped iff its input vector is all-zero OR every
weight column it would feed in the lockstep block group is all-zero — the
vectors are simply absent from SRAM (paper Fig. 7 dashed blocks).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["PEConfig", "CycleReport", "conv_layer_cycles", "aggregate"]


@dataclasses.dataclass(frozen=True)
class PEConfig:
    blocks: int
    rows: int
    cols: int = 3
    block_map: str = "cout"  # what the B blocks parallelize over: 'cout'|'width'

    @property
    def n_pe(self) -> int:
        return self.blocks * self.rows * self.cols


# The paper's two 168-PE configurations (§IV).
PE_4_14_3 = PEConfig(blocks=4, rows=14, cols=3)
PE_8_7_3 = PEConfig(blocks=8, rows=7, cols=3)


@dataclasses.dataclass
class CycleReport:
    dense: int
    vscnn: int
    ideal_vector: int
    ideal_fine: int
    macs_nonzero: int
    macs_dense: int

    @property
    def speedup(self) -> float:
        return self.dense / max(self.vscnn, 1)

    @property
    def frac_ideal_vector_exploited(self) -> float:
        """Paper §IV: share of ideal-vector-sparse skippable cycles we skip."""
        skippable = self.dense - self.ideal_vector
        return (self.dense - self.vscnn) / max(skippable, 1)

    @property
    def frac_ideal_fine_exploited(self) -> float:
        skippable = self.dense - self.ideal_fine
        return (self.dense - self.vscnn) / max(skippable, 1)


def _input_vector_occupancy(x_nz: np.ndarray, rows: int) -> np.ndarray:
    """(H, W, Cin) nonzero map -> (ceil(H/R), W, Cin) vector occupancy."""
    h, w, cin = x_nz.shape
    hc = math.ceil(h / rows)
    pad = hc * rows - h
    if pad:
        x_nz = np.concatenate([x_nz, np.zeros((pad, w, cin), bool)], axis=0)
    return x_nz.reshape(hc, rows, w, cin).any(axis=1)


def conv_layer_cycles(x: np.ndarray, w: np.ndarray, pe: PEConfig) -> CycleReport:
    """Cycle counts for one 3x3/s1/p1 conv layer.

    x : (H, W, Cin) input activations (already post-ReLU: zeros are real)
    w : (3, 3, Cin, Cout) possibly vector-pruned weights
    """
    x_nz = np.asarray(x) != 0
    w_nz = np.asarray(w) != 0
    h, width, cin = x_nz.shape
    kh, kw, wcin, cout = w_nz.shape
    assert (kh, kw) == (3, 3) and wcin == cin

    iv = _input_vector_occupancy(x_nz, pe.rows)  # (HC, W, Cin)
    wv = w_nz.any(axis=0)  # weight column occupancy: (kx, Cin, Cout)

    hc = iv.shape[0]
    if pe.block_map == "cout":
        g = math.ceil(cout / pe.blocks)
        pad = g * pe.blocks - cout
        wvp = np.concatenate([wv, np.zeros((3, cin, pad), bool)], -1) if pad else wv
        gwv = wvp.reshape(3, cin, g, pe.blocks).any(-1)  # (kx, Cin, G)
        iv_cnt = iv.sum(axis=(0, 1))  # (Cin,) issued input vectors
        vscnn = int((iv_cnt * gwv.sum(axis=(0, 2))).sum())
        dense = hc * width * 3 * cin * g
    elif pe.block_map == "width":
        wg = math.ceil(width / pe.blocks)
        pad = wg * pe.blocks - width
        ivp = np.concatenate([iv, np.zeros((hc, pad, cin), bool)], 1) if pad else iv
        giv = ivp.reshape(hc, wg, pe.blocks, cin).any(2)  # (HC, WG, Cin)
        vscnn = int((giv.sum(axis=(0, 1)) * wv.sum(axis=(0, 2))).sum())
        dense = hc * wg * 3 * cin * cout
    else:
        raise ValueError(pe.block_map)

    # Ideal vector-sparse: every truly-nonzero (input vec, weight col) pair
    # costs 1/B cycles (perfect packing over blocks, no lockstep loss).
    pairs = int((iv.sum(axis=(0, 1)) * wv.sum(axis=(0, 2))).sum())
    ideal_vector = math.ceil(pairs / pe.blocks)

    # Ideal fine-grained: nonzero MACs / total PEs.
    xp = np.pad(x_nz, ((1, 1), (1, 1), (0, 0)))
    # hits[ky,kx,cin] = # output positions whose input tap is nonzero
    hits = np.stack(
        [
            [xp[ky : ky + h, kx : kx + width].sum(axis=(0, 1)) for kx in range(3)]
            for ky in range(3)
        ]
    )  # (3,3,Cin)
    w_cnt = w_nz.sum(axis=3)  # (3,3,Cin) nonzero couts per tap
    macs_nonzero = int((hits * w_cnt).sum())
    macs_dense = h * width * 9 * cin * cout
    ideal_fine = math.ceil(macs_nonzero / pe.n_pe)

    return CycleReport(
        dense=dense,
        vscnn=vscnn,
        ideal_vector=ideal_vector,
        ideal_fine=ideal_fine,
        macs_nonzero=macs_nonzero,
        macs_dense=macs_dense,
    )


def aggregate(reports: list[CycleReport]) -> CycleReport:
    return CycleReport(
        dense=sum(r.dense for r in reports),
        vscnn=sum(r.vscnn for r in reports),
        ideal_vector=sum(r.ideal_vector for r in reports),
        ideal_fine=sum(r.ideal_fine for r in reports),
        macs_nonzero=sum(r.macs_nonzero for r in reports),
        macs_dense=sum(r.macs_dense for r in reports),
    )


def table1_example() -> CycleReport:
    """The paper's 5x5 micro example (Table I / Fig. 7-8).

    Input column B (the 2nd of 5) is all zero; weight column WC (kx=2) is all
    zero.  Expect 15 dense cycles and 8 sparse cycles.
    """
    x = np.ones((5, 5, 1))
    x[:, 1, 0] = 0.0  # column B zero
    w = np.ones((3, 3, 1, 1))
    w[:, 2, 0, 0] = 0.0  # column WC zero
    return conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
