"""Cycle-accurate model of the VSCNN PE array (paper §II-III, Table I).

Geometry (Fig. 4/5): a PE config ``[B, R, C]`` has B PE-array blocks, each
R rows x C(=3) columns.  Every cycle one block consumes:

  * one input-activation column vector  (R consecutive H positions, one W
    column, one input channel)   — broadcast horizontally, and
  * one weight kernel column            (C=3 ky-elements for one kx, one
    (cin, cout) pair)            — broadcast vertically;

the outer product accumulates diagonally into R (+C-1 boundary) output
partial sums.  Dense cost for an H x W x Cin input and 3x3xCinxCout kernel:

    cycles_dense = ceil(H/R) * W * 3 * Cin * ceil(Cout/B)        (block_map='cout')

(check: 5x5 input, pad 1, R=5, B=1, Cin=Cout=1  ->  1*5*3 = 15 cycles,
exactly the paper's "15 cycles for 5x5 input"; the Table-I sparse example
issues only {A,C,D,E} x {WA,WB} = 8 cycles.)

Sparse rule: a cycle is skipped iff its input vector is all-zero OR every
weight column it would feed in the lockstep block group is all-zero — the
vectors are simply absent from SRAM (paper Fig. 7 dashed blocks).

The model generalizes beyond the paper's 3x3/s1 evaluation to arbitrary
kh x kw kernels and strides (`conv_layer_cycles(..., stride=...)`): weight
kernel columns become kh-element ky-runs for each of kw positions, and with
stride s an input column vector only pairs with the weight columns whose
output grid actually reads it (1/s of them), matching the generalized
vector-sparse datapath in kernels/vsconv.

Alongside the cycle counts, `conv_layer_traffic` / `network_traffic_reports`
model the *DRAM side* of the paper's story (its 1-D broadcast input exists
so one fetched vector feeds every PE): modeled HBM bytes per conv layer for
the TPU kernels' two input layouts — the halo-blocked direct input vs the
materialized row-tap stack — plus arithmetic intensity, sharing the exact
formulas the kernels hand XLA as `pl.CostEstimate`.

The model's free constants (seconds per cycle, per-tap overhead, vsmm
flush cost, DMA overlap) are *calibrated, not guessed*: `load_calibration`
returns the constants fitted against per-layer wall-clock measurements
(committed as ``benchmarks/baselines/CALIB_<backend>.json``; see
`core.calibration` and ``benchmarks/calibrate.py``), and
`predicted_layer_time_s` turns a layer's modeled features into calibrated
wall time.  CI re-measures a layer subset and fails on prediction drift.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:
    from .calibration import CalibConstants

__all__ = ["PEConfig", "CycleReport", "TrafficReport", "conv_layer_cycles",
           "conv_layer_traffic", "aggregate", "network_cycle_reports",
           "network_traffic_reports", "load_calibration",
           "predicted_layer_time_s"]


@dataclasses.dataclass(frozen=True)
class PEConfig:
    blocks: int
    rows: int
    cols: int = 3
    block_map: str = "cout"  # what the B blocks parallelize over: 'cout'|'width'

    @property
    def n_pe(self) -> int:
        return self.blocks * self.rows * self.cols


# The paper's two 168-PE configurations (§IV).
PE_4_14_3 = PEConfig(blocks=4, rows=14, cols=3)
PE_8_7_3 = PEConfig(blocks=8, rows=7, cols=3)


@dataclasses.dataclass
class CycleReport:
    dense: int
    vscnn: int
    ideal_vector: int
    ideal_fine: int
    macs_nonzero: int
    macs_dense: int

    @property
    def speedup(self) -> float:
        return self.dense / max(self.vscnn, 1)

    @property
    def frac_ideal_vector_exploited(self) -> float:
        """Paper §IV: share of ideal-vector-sparse skippable cycles we skip."""
        skippable = self.dense - self.ideal_vector
        return (self.dense - self.vscnn) / max(skippable, 1)

    @property
    def frac_ideal_fine_exploited(self) -> float:
        skippable = self.dense - self.ideal_fine
        return (self.dense - self.vscnn) / max(skippable, 1)


def _input_vector_occupancy(x_nz: np.ndarray, rows: int) -> np.ndarray:
    """(H, W, Cin) nonzero map -> (ceil(H/R), W, Cin) vector occupancy."""
    h, w, cin = x_nz.shape
    hc = math.ceil(h / rows)
    pad = hc * rows - h
    if pad:
        x_nz = np.concatenate([x_nz, np.zeros((pad, w, cin), bool)], axis=0)
    return x_nz.reshape(hc, rows, w, cin).any(axis=1)


def _same_geometry(size: int, k: int, stride: int,
                   dilation: int = 1) -> tuple[int, int]:
    """XLA-"SAME": (out_size, pad_low)."""
    from .sparse_ops import same_pads  # lazy: keep accel_model numpy-only

    out, lo, _ = same_pads(size, k, stride, dilation)
    return out, lo


def conv_layer_cycles(
    x: np.ndarray, w: np.ndarray, pe: PEConfig, *, stride: int = 1,
    groups: int = 1, dilation: int = 1,
) -> CycleReport:
    """Cycle counts for one kh x kw / stride / dilation / SAME conv layer,
    optionally grouped.

    x : (H, W, Cin) input activations (already post-ReLU: zeros are real)
    w : (kh, kw, Cin/groups, Cout) possibly vector-pruned weights (XLA's
        grouped HWIO layout: output block g reads input channel group g)

    Generalized geometry: an input column vector broadcast into the array
    pairs with weight kernel column ``kx`` only when some output column reads
    it — i.e. when its column index is congruent to ``kx*dilation - pad_left``
    mod ``stride`` (for stride 1, every column pairs with every kx, the
    paper's Table-I accounting).  Boundary partial sums are issued and
    discarded, as in the paper.

    Grouped convs reduce to the ungrouped accounting: every per-channel sum
    here couples an input channel only with *its own* weight columns, so
    rearranging the block-diagonal grouped weight into a virtual
    (kh, kw, Cin, Cout/groups) layout — row c holding input channel c's own
    group's columns — makes the single pass below compute the exact
    per-group totals (dense, vscnn, MACs are per-group-additive; the ideal
    bounds get the global packing).  Depthwise (groups == Cin) is one pass,
    not Cin slices.
    """
    x = np.asarray(x)
    w = np.asarray(w)
    if groups > 1:
        cin_g = x.shape[-1] // groups
        cout_g = w.shape[-1] // groups
        assert w.shape[2] == cin_g, (w.shape, x.shape, groups)
        kh_, kw_ = w.shape[:2]
        # (kh, kw, cin_g, G*cout_g) -> (kh, kw, G*cin_g, cout_g): input
        # channel c = g*cin_g + i picks up exactly group g's couts
        w = w.reshape(kh_, kw_, cin_g, groups, cout_g) \
             .transpose(0, 1, 3, 2, 4) \
             .reshape(kh_, kw_, groups * cin_g, cout_g)
        return conv_layer_cycles(x, w, pe, stride=stride, dilation=dilation)
    x_nz = x != 0
    w_nz = w != 0
    h, width, cin = x_nz.shape
    kh, kw, wcin, cout = w_nz.shape
    assert wcin == cin, (w_nz.shape, cin)

    iv = _input_vector_occupancy(x_nz, pe.rows)  # (HC, W, Cin)
    wv = w_nz.any(axis=0)  # weight column occupancy: (kw, Cin, Cout)

    hc = iv.shape[0]
    _, pad_l = _same_geometry(width, kw, stride, dilation)
    # input columns compatible with weight column kx (see docstring)
    col_sets = [
        np.nonzero((np.arange(width) - (kx * dilation - pad_l)) % stride == 0)[0]
        for kx in range(kw)
    ]

    if pe.block_map == "cout":
        g = math.ceil(cout / pe.blocks)
        pad = g * pe.blocks - cout
        wvp = np.concatenate([wv, np.zeros((kw, cin, pad), bool)], -1) if pad else wv
        gwv = wvp.reshape(kw, cin, g, pe.blocks).any(-1)  # (kx, Cin, G)
        vscnn = dense = 0
        for kx in range(kw):
            iv_cnt = iv[:, col_sets[kx]].sum(axis=(0, 1))  # (Cin,) issued
            vscnn += int((iv_cnt * gwv[kx].sum(axis=-1)).sum())
            dense += hc * len(col_sets[kx]) * cin * g
    elif pe.block_map == "width":
        vscnn = dense = 0
        for kx in range(kw):
            cols = col_sets[kx]
            wg = math.ceil(len(cols) / pe.blocks)
            pad = wg * pe.blocks - len(cols)
            ivk = iv[:, cols]
            if pad:
                ivk = np.concatenate(
                    [ivk, np.zeros((hc, pad, cin), bool)], 1
                )
            giv = ivk.reshape(hc, wg, pe.blocks, cin).any(2)  # (HC, WG, Cin)
            vscnn += int((giv.sum(axis=(0, 1)) * wv[kx].sum(axis=-1)).sum())
            dense += hc * wg * cin * cout
    else:
        raise ValueError(pe.block_map)

    # Ideal vector-sparse: every truly-nonzero (input vec, weight col) pair
    # costs 1/B cycles (perfect packing over blocks, no lockstep loss).
    pairs = sum(
        int((iv[:, col_sets[kx]].sum(axis=(0, 1)) * wv[kx].sum(axis=-1)).sum())
        for kx in range(kw)
    )
    ideal_vector = math.ceil(pairs / pe.blocks)

    # Ideal fine-grained: nonzero MACs / total PEs.
    ho, pad_t = _same_geometry(h, kh, stride, dilation)
    wo = math.ceil(width / stride)
    ke_h = (kh - 1) * dilation + 1
    ke_w = (kw - 1) * dilation + 1
    pb = max(stride * (ho - 1) + ke_h - h - pad_t, 0)
    pr = max(stride * (wo - 1) + ke_w - width - pad_l, 0)
    xp = np.pad(x_nz, ((pad_t, pb), (pad_l, pr), (0, 0)))
    # hits[ky,kx,cin] = # output positions whose input tap is nonzero
    hits = np.stack(
        [
            [
                xp[
                    ky * dilation : ky * dilation + stride * (ho - 1) + 1 : stride,
                    kx * dilation : kx * dilation + stride * (wo - 1) + 1 : stride,
                ].sum(axis=(0, 1))
                for kx in range(kw)
            ]
            for ky in range(kh)
        ]
    )  # (kh,kw,Cin)
    w_cnt = w_nz.sum(axis=3)  # (kh,kw,Cin) nonzero couts per tap
    macs_nonzero = int((hits * w_cnt).sum())
    macs_dense = ho * wo * kh * kw * cin * cout
    ideal_fine = math.ceil(macs_nonzero / pe.n_pe)

    return CycleReport(
        dense=dense,
        vscnn=vscnn,
        ideal_vector=ideal_vector,
        ideal_fine=ideal_fine,
        macs_nonzero=macs_nonzero,
        macs_dense=macs_dense,
    )


# --------------------------------------------------------------------------
# DRAM traffic model (bytes in/out per conv layer, stack vs halo)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """Modeled HBM traffic of one conv layer on the TPU sparse datapath.

    ``kernel`` bytes are what the Pallas kernel itself moves (inputs
    re-fetched per grid schedule + weights + output — the kernels'
    `pl.CostEstimate.bytes_accessed` contract from `repro.kernels.vsconv`);
    ``build`` bytes are the layout pass that runs *before* the kernel (one
    pad for the halo impl; the kh*stride-plane row-tap stack write for the
    stack impl): bytes touched = read source + write laid-out buffer.
    """

    impl: str
    flops: int
    input_bytes: int    # kernel-side activation fetches
    weight_bytes: int
    output_bytes: int
    build_bytes: int    # layout pass (pad / stack materialization)

    @property
    def kernel_bytes(self) -> int:
        return self.input_bytes + self.weight_bytes + self.output_bytes

    @property
    def bytes_accessed(self) -> int:
        return self.kernel_bytes + self.build_bytes

    @property
    def arithmetic_intensity(self) -> float:
        """FLOPs per HBM byte — the roofline x-coordinate."""
        return self.flops / max(self.bytes_accessed, 1)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def conv_layer_traffic(
    x_shape: tuple[int, int, int, int],
    *,
    kh: int,
    kw: int,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    cout: int,
    s_steps: int,
    vk: int,
    vn: int,
    bh: int = 8,
    impl: str = "halo",
    itemsize: int = 4,
    w_itemsize: int | None = None,
    out_itemsize: int | None = None,
    residual: bool = False,
) -> TrafficReport:
    """Modeled HBM bytes for one vector-sparse conv layer.

    ``x_shape`` is the *encoded* input (N, H, W, Cin) — Cin a vk multiple,
    pad channels included; ``cout`` the encoded output width (a vn
    multiple); ``s_steps`` the stored tiles per strip (density *
    kh*kw*CB/groups).  ``impl``: 'halo' (direct input, halo-blocked;
    assumes the cin-major tile order `models.graph.sparse_conv_from_dense`
    emits) or 'stack' (the materialized row-tap/phase stack).  Ungrouped
    1x1 convs route through the sparse matmul over pixels in both impls and
    cost the same.  A grouped conv's strips only ever fetch their own
    group's Cin/groups channels (per-group fetch, not full-cin); depthwise
    (groups == Cin, vk == 1, vn == the channel-tile width) uses the
    per-channel tap kernels' costs — the halo block there is fetched
    exactly once per (strip, row-block).

    The kernel-side formulas are imported from `repro.kernels.vsconv` —
    the same numbers the kernels hand XLA as `pl.CostEstimate`, so the
    model, the compiler hint, and the benchmark gate can never drift.

    The dtype axis: ``itemsize`` is the activation width, ``w_itemsize``
    the stored-weight width (defaults to ``itemsize``; 1 on the int8
    path), ``out_itemsize`` the output width (the int8 kernels emit f32,
    so 4).  The residual is modeled at ``out_itemsize`` — it stays f32 on
    the int8 path, matching the kernels' real CostEstimate.
    """
    from repro.kernels.vsconv import (  # lazy: keep accel_model numpy-first
        dw_halo_kernel_cost, dw_stack_kernel_cost, halo_kernel_cost,
        stack_kernel_cost, use_resident_halo,
    )
    from .sparse_ops import same_pads

    n, h, w, c = x_shape
    assert c % vk == 0 and cout % vn == 0, (x_shape, cout, vk, vn)
    nb = cout // vn
    cb = c // vk
    # multiplier-1 depthwise only; channel-multiplier convs model through
    # the general grouped branch with vk == 1 (mirrors `ops.vsconv`)
    depthwise = groups > 1 and groups == c and vk == 1 and cout == c
    assert c % groups == 0 and (depthwise or cb % groups == 0), (
        x_shape, vk, groups)
    assert nb % groups == 0 or depthwise, (cout, vn, groups)
    out_itemsize = out_itemsize or itemsize
    w_itemsize = w_itemsize or itemsize
    ho, _, _ = same_pads(h, kh, stride, dilation)
    wo, _, _ = same_pads(w, kw, stride, dilation)

    if kh == 1 and kw == 1 and groups == 1:
        # vsmm over flattened pixels: every sparse step gathers a fresh
        # (bm, vk) activation K-tile; identical for both impls.  The
        # stride-2 subsample is the only layout pass.
        m = n * ho * wo
        flops = 2 * m * nb * s_steps * vk * vn
        return TrafficReport(
            impl=impl,
            flops=flops,
            input_bytes=m * nb * s_steps * vk * itemsize,
            weight_bytes=nb * s_steps * vk * vn * w_itemsize,
            output_bytes=(m * cout * out_itemsize
                          + (m * cout * out_itemsize if residual else 0)),
            build_bytes=(2 * m * c * itemsize if stride != 1 else 0),
        )

    bh = min(bh, ho)
    hop = _round_up(ho, bh)
    hb = hop // bh
    res_bytes = n * hop * wo * cout * out_itemsize if residual else 0
    ke_h = (kh - 1) * dilation + 1
    ke_w = (kw - 1) * dilation + 1
    if impl == "halo":
        rows = stride * (hop - 1) + ke_h
        bwp = _round_up(stride * (wo - 1) + ke_w, 8)
        if depthwise:
            assert vk == 1 and cout == c, (x_shape, cout, vk, groups)
            est = dw_halo_kernel_cost(
                n=n, hop=hop, w_out=wo, kh=kh, stride=stride, bwp=bwp,
                bh=bh, nb=nb, s_steps=s_steps, vc=vn, dilation=dilation,
                in_itemsize=itemsize, w_itemsize=w_itemsize,
                out_itemsize=out_itemsize, residual_bytes=res_bytes,
            )
            input_bytes = n * hb * nb * (stride * (bh - 1) + ke_h) * bwp \
                * vn * itemsize
        else:
            cbg = cb // groups  # cin tiles reachable from one strip
            resident = use_resident_halo(hop, groups)
            est = halo_kernel_cost(
                n=n, hop=hop, w_out=wo, kh=kh, stride=stride, bwp=bwp, bh=bh,
                nb=nb, s_steps=s_steps, cb=cbg, vk=vk, vn=vn,
                dilation=dilation, resident=resident,
                in_itemsize=itemsize, w_itemsize=w_itemsize,
                out_itemsize=out_itemsize, residual_bytes=res_bytes,
            )
            hh = stride * (bh - 1) + ke_h
            if resident:
                # tiny-feature-map layout: the whole-cin halo block is
                # fetched once per (image, row-block), never per strip
                input_bytes = n * hb * hh * bwp * cb * vk * itemsize
            else:
                input_bytes = (n * hb * nb * min(s_steps, cbg) * hh * bwp
                               * vk * itemsize)
        # one jnp.pad: read the input, write the padded copy
        build = n * c * (h * w + rows * bwp) * itemsize
    elif impl == "stack":
        bw = _round_up(wo + ((kw - 1) * dilation) // stride, 8)
        if depthwise:
            assert vk == 1 and cout == c, (x_shape, cout, vk, groups)
            est = dw_stack_kernel_cost(
                n=n, hop=hop, w_out=wo, bw=bw, bh=bh, nb=nb,
                s_steps=s_steps, vc=vn, in_itemsize=itemsize,
                w_itemsize=w_itemsize, out_itemsize=out_itemsize,
                residual_bytes=res_bytes,
            )
            input_bytes = n * hb * nb * s_steps * bh * bw * vn * itemsize
        else:
            est = stack_kernel_cost(
                n=n, hop=hop, w_out=wo, bw=bw, bh=bh, nb=nb,
                s_steps=s_steps, vk=vk, vn=vn, in_itemsize=itemsize,
                w_itemsize=w_itemsize, out_itemsize=out_itemsize,
                residual_bytes=res_bytes,
            )
            input_bytes = n * hb * nb * s_steps * bh * bw * vk * itemsize
        # the stack build: read the input once (pad+gather fuse), write
        # kh*stride output-sized planes
        build = n * c * (h * w + kh * stride * hop * bw) * itemsize
    else:
        raise ValueError(f"impl must be 'halo' or 'stack', got {impl!r}")

    weight_bytes = nb * s_steps * vk * vn * w_itemsize
    output_bytes = n * hop * wo * cout * out_itemsize + res_bytes
    assert input_bytes + weight_bytes + output_bytes == est.bytes_accessed, (
        "traffic model drifted from the kernel CostEstimate")
    return TrafficReport(
        impl=impl,
        flops=est.flops,
        input_bytes=input_bytes,
        weight_bytes=weight_bytes,
        output_bytes=output_bytes,
        build_bytes=build,
    )


def network_traffic_reports(
    traffic: list[tuple], sparse: dict, *, bh: int = 8,
    impls: tuple[str, ...] = ("halo", "stack"),
) -> list[tuple[str, dict]]:
    """Per-layer DRAM traffic for one network's conv traffic, per impl.

    ``traffic`` is `models.graph.collect_conv_traffic`'s record —
    (name, conv input NHWC, weight, stride, groups, dilation) per conv
    layer (the trailing geometry fields are optional for legacy 4-tuple
    records) — and ``sparse`` the `sparsify` dict giving each layer's
    encoded geometry (tile counts, vk/vn, cin padding).  The dtype axis
    keys off the stored weight dtype: an int8 entry (``sparsify(dtype=
    jnp.int8)``) is modeled with int8 activations and weights and f32
    outputs, exactly what the kernels move.  Returns
    [(name, {impl: TrafficReport})] so `bench_kernels`/`bench_serving` can
    emit bytes + arithmetic-intensity columns for both layouts next to the
    cycle speedups.
    """
    out = []
    for name, x, w, stride, *gd in traffic:
        groups = gd[0] if gd else 1
        dilation = gd[1] if len(gd) > 1 else 1
        x = np.asarray(x)
        if x.ndim == 3:
            x = x[None]
        n, h, width, cin = x.shape
        kh, kw = np.asarray(w).shape[:2]
        entry = sparse[name]
        nb, s_steps, vk, vn = entry.vs.vals.shape
        x_shape = (n, h, width, cin + entry.cin_pad)
        out.append((name, {
            impl: conv_layer_traffic(
                x_shape, kh=kh, kw=kw, stride=stride, groups=groups,
                dilation=dilation, cout=nb * vn,
                s_steps=s_steps, vk=vk, vn=vn, bh=bh, impl=impl,
                itemsize=np.dtype(entry.vs.dtype).itemsize,
                w_itemsize=np.dtype(entry.vs.dtype).itemsize,
                out_itemsize=4,
            )
            for impl in impls
        }))
    return out


def network_cycle_reports(traffic: list[tuple], pe: PEConfig) -> list[tuple[str, CycleReport]]:
    """Per-layer cycle reports for one network's conv traffic.

    ``traffic`` is the record produced by `models.graph.collect_conv_traffic`
    — (name, conv input, weight, stride, groups, dilation) per conv layer,
    in execution order (the trailing geometry fields are optional for
    legacy 4-tuple records); the input may be (N, H, W, Cin) (the leading
    image is used, matching the paper's single-image accounting) or already
    (H, W, Cin).  Every network — VGG-16, the ResNets, MobileNet — shares
    this one analysis path: the same graph walk that runs the forward feeds
    the cycle model, residual branches and depthwise stages included.
    """
    reports = []
    for name, x, w, stride, *gd in traffic:
        groups = gd[0] if gd else 1
        dilation = gd[1] if len(gd) > 1 else 1
        x = np.asarray(x)
        if x.ndim == 4:
            x = x[0]
        reports.append((name, conv_layer_cycles(
            x, np.asarray(w), pe, stride=stride, groups=groups,
            dilation=dilation)))
    return reports


def load_calibration(backend: str | None = None,
                     path: str | None = None) -> CalibConstants:
    """The fitted cost-model constants for ``backend`` (default: the active
    jax backend) — `core.calibration.CalibConstants` loaded from the
    committed ``benchmarks/baselines/CALIB_<backend>.json``, or the
    uncalibrated defaults when none exists.  This is what makes the
    modeled numbers calibrated rather than guessed; re-fit with
    ``benchmarks/calibrate.py --fit``."""
    from .calibration import load_constants
    return load_constants(backend, path=path)


def predicted_layer_time_s(traffic: TrafficReport, *, nb: int, s_steps: int,
                           blocks: int, vk: int, vn: int,
                           constants: CalibConstants | None = None
                           ) -> float:
    """Calibrated wall-time prediction for one layer.

    ``blocks`` is the kernel's spatial grid sweep per strip (row-blocks for
    a conv, M-tiles for the matmul path); the remaining geometry comes from
    the encoded weight.  ``constants`` defaults to `load_calibration()`."""
    from .calibration import layer_features, predict_time_s

    c = constants if constants is not None else load_calibration()
    feat = layer_features(flops=traffic.flops,
                          bytes_accessed=traffic.bytes_accessed, nb=nb,
                          s_steps=s_steps, blocks=blocks, vk=vk, vn=vn)
    return predict_time_s(feat, c)


def aggregate(reports: list[CycleReport]) -> CycleReport:
    return CycleReport(
        dense=sum(r.dense for r in reports),
        vscnn=sum(r.vscnn for r in reports),
        ideal_vector=sum(r.ideal_vector for r in reports),
        ideal_fine=sum(r.ideal_fine for r in reports),
        macs_nonzero=sum(r.macs_nonzero for r in reports),
        macs_dense=sum(r.macs_dense for r in reports),
    )


def table1_example() -> CycleReport:
    """The paper's 5x5 micro example (Table I / Fig. 7-8).

    Input column B (the 2nd of 5) is all zero; weight column WC (kx=2) is all
    zero.  Expect 15 dense cycles and 8 sparse cycles.
    """
    x = np.ones((5, 5, 1))
    x[:, 1, 0] = 0.0  # column B zero
    w = np.ones((3, 3, 1, 1))
    w[:, 2, 0, 0] = 0.0  # column WC zero
    return conv_layer_cycles(x, w, PEConfig(blocks=1, rows=5, cols=3))
