"""Measured-vs-modeled calibration of the analytic cost model.

Every performance number in this repo used to be *modeled* — the paper's
cycle model (`accel_model.conv_layer_cycles`), the DRAM traffic model
(`accel_model.conv_layer_traffic`), and the kernels' own
``pl.CostEstimate``.  This module closes the loop the way byteprofile does
for XLA: run every conv/FC layer of a real network wall-clock, extract the
compiled program's deterministic cost features, fit the model's free
constants to the measurements, and persist them so modeled numbers are
calibrated, not guessed.

The time model
--------------
Predicted wall time of one layer on the structural sparse path::

    t = cycle_time_ns * 1e-9
          * (mxu_steps
             + per_tap_overhead   * taps
             + vsmm_flush_cycles  * flushes)
      + (1 - dma_overlap) * bytes / (hbm_gbps * 1e9)
      + fixed_overhead_us * 1e-6

with per-layer features taken from the analytic model (all deterministic
functions of the encoded geometry):

    mxu_steps  modeled FLOPs / (2 * vk * vn) — vector MAC-row issues, the
               TPU analogue of the paper's PE-array cycles
    taps       sparse grid steps (stored tiles x row-blocks): each resolves
               one weight tap — gather/index overhead scales with it
    flushes    output-strip flushes (epilogue: bias + residual + ReLU)
    bytes      modeled HBM bytes (`TrafficReport.bytes_accessed`, halo)

The four *fitted* free constants are exactly the ones the analytic model
could not know: ``cycle_time_ns`` (seconds per vector MAC-row on this
backend), ``per_tap_overhead`` and ``vsmm_flush_cycles`` (in cycles), and
``dma_overlap`` (the fraction of modeled HBM traffic hidden behind
compute); ``fixed_overhead_us`` absorbs per-launch dispatch cost.  The fit
is a deterministic non-negative least squares (active-set on top of
``np.linalg.lstsq``) over per-layer median-of-k wall-clock measurements.

Measured features
-----------------
Next to the wall clock, each layer records the *deterministic* cost of its
compiled program — FLOPs/bytes parsed from the optimized HLO with
`utils.hlo.analyze` (trip-count aware, unlike raw ``cost_analysis()``).
Measured HLO FLOPs equal the modeled structural FLOPs (the zero vectors
are absent from the compiled scan exactly as they are absent from the
paper's SRAM), which is what lets the CI gate hold a *tight* band on the
deterministic features and reserve the wide band for wall-clock noise.

Persistence + drift gate
------------------------
`fit_constants` -> `save_calibration` writes ``CALIB_<backend>.json``
(committed under ``benchmarks/baselines/``): the constants, the fit
settings, and every per-layer record including its ``predicted_us``.
`load_constants` finds it again (``accel_model.load_calibration`` is the
public hook), and `compare_calibration` is the CI drift gate: bit-exact
reproduction of the recorded predictions from the stored constants +
features (so perturbing any fitted constant fails the gate), a tight band
on the deterministic HLO/model features, and a machine-speed-normalized
wide band on fresh wall clock.  ``benchmarks/calibrate.py`` is the CLI.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import pathlib
from typing import Any, Callable
import time

import numpy as np

__all__ = [
    "CalibConstants", "layer_features", "predict_time_s", "fit_constants",
    "save_calibration", "load_calibration_file", "load_constants",
    "default_calib_path", "median_time_s", "compiled_layer_cost",
    "measured_vs_modeled_records", "compare_calibration",
    "CPU_HBM_GBPS", "TPU_HBM_GBPS",
]

# Nominal memory bandwidth per backend: the *denominator* of the byte term,
# never fitted (dma_overlap is the fitted knob).  TPU matches
# utils.roofline.V5E; the CPU figure is a conservative host-DRAM stream
# bandwidth.
CPU_HBM_GBPS = 20.0
TPU_HBM_GBPS = 819.0


@dataclasses.dataclass(frozen=True)
class CalibConstants:
    """The cost model's free constants, fitted per backend.

    ``cycle_time_ns`` is wall nanoseconds per vector MAC-row (mxu_step);
    ``per_tap_overhead`` / ``vsmm_flush_cycles`` are in cycles (multiples
    of ``cycle_time_ns``); ``dma_overlap`` in [0, 1] is the fraction of
    modeled HBM bytes overlapped with compute (1.0 = traffic fully hidden);
    ``fixed_overhead_us`` is the per-launch dispatch floor.  ``hbm_gbps``
    is the nominal bandwidth the byte term divides by (recorded, not
    fitted).  The default instance is *uncalibrated*: pure cycle
    proportionality with everything else zeroed.
    """

    backend: str = "uncalibrated"
    cycle_time_ns: float = 0.0
    per_tap_overhead: float = 0.0
    vsmm_flush_cycles: float = 0.0
    dma_overlap: float = 1.0
    fixed_overhead_us: float = 0.0
    hbm_gbps: float = CPU_HBM_GBPS

    @property
    def calibrated(self) -> bool:
        return self.cycle_time_ns > 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "CalibConstants":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


# --------------------------------------------------------------------------
# Features
# --------------------------------------------------------------------------

def layer_features(*, flops: int, bytes_accessed: int, nb: int, s_steps: int,
                   blocks: int, vk: int, vn: int,
                   cycles: int | None = None) -> dict:
    """Deterministic per-layer features of the time model.

    ``blocks`` is the number of spatial grid blocks the kernel sweeps per
    strip — ``n * ceil(Hout / bh)`` for a conv, ``ceil(M / bm)`` for the
    matmul path (1x1 convs over flattened pixels, FC layers).  ``cycles``
    optionally carries the paper-model vscnn cycles for reporting; it is
    not a fit feature (the structural path does not skip input vectors).
    """
    feat = {
        "mxu_steps": int(flops) // max(2 * vk * vn, 1),
        "taps": int(nb) * int(s_steps) * int(blocks),
        "flushes": int(nb) * int(blocks),
        "bytes": int(bytes_accessed),
        "flops": int(flops),
    }
    if cycles is not None:
        feat["cycles"] = int(cycles)
    return feat


def predict_time_s(feat: dict, c: CalibConstants) -> float:
    """The calibrated time model — seconds for one layer's features."""
    cyc = (feat["mxu_steps"]
           + c.per_tap_overhead * feat["taps"]
           + c.vsmm_flush_cycles * feat["flushes"])
    t = c.cycle_time_ns * 1e-9 * cyc + c.fixed_overhead_us * 1e-6
    if c.hbm_gbps > 0.0:
        t += (1.0 - c.dma_overlap) * feat["bytes"] / (c.hbm_gbps * 1e9)
    return t


# --------------------------------------------------------------------------
# Fitting
# --------------------------------------------------------------------------

def _nnls(A: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Deterministic non-negative least squares: plain lstsq, then drop the
    most-negative column and re-solve until every kept coefficient is
    >= 0.  Small (5-column) systems only — exactness over generality."""
    cols = list(range(A.shape[1]))
    coef = np.zeros(A.shape[1])
    while cols:
        sol, *_ = np.linalg.lstsq(A[:, cols], y, rcond=None)
        if (sol >= 0).all():
            for c_idx, v in zip(cols, sol):
                coef[c_idx] = v
            break
        cols.pop(int(np.argmin(sol)))
    return coef


def fit_constants(features: list[dict], measured_s: list[float], *,
                  backend: str, hbm_gbps: float | None = None,
                  relative: bool = True) -> CalibConstants:
    """Least-squares fit of the free constants to wall-clock measurements.

    The model is linear in (a0..a4) = (cycle_time, cycle_time*per_tap,
    cycle_time*flush, 1-dma_overlap, fixed), so one non-negative lstsq
    solves it; the named constants are recovered by dividing through a0.
    ``relative`` (default) weights each row by 1/measured so the fit
    minimizes *relative* error — the quantity the drift gate bands —
    instead of letting the few biggest layers dominate.  Deterministic:
    same features + times -> bit-identical constants.
    """
    if hbm_gbps is None:
        hbm_gbps = TPU_HBM_GBPS if backend == "tpu" else CPU_HBM_GBPS
    A = np.array([
        [f["mxu_steps"], f["taps"], f["flushes"],
         f["bytes"] / (hbm_gbps * 1e9), 1.0]
        for f in features
    ], dtype=np.float64)
    y = np.asarray(measured_s, dtype=np.float64)
    if relative:
        w = 1.0 / np.maximum(y, 1e-12)
        A = A * w[:, None]
        y = y * w
    # column scaling keeps lstsq well-conditioned across 1e0..1e9 features
    scale = np.maximum(np.abs(A).max(axis=0), 1e-30)
    coef = _nnls(A / scale, y) / scale
    a0, a1, a2, a3, a4 = coef
    return CalibConstants(
        backend=backend,
        cycle_time_ns=a0 * 1e9,
        per_tap_overhead=(a1 / a0) if a0 > 0 else 0.0,
        vsmm_flush_cycles=(a2 / a0) if a0 > 0 else 0.0,
        dma_overlap=float(np.clip(1.0 - a3, 0.0, 1.0)),
        fixed_overhead_us=a4 * 1e6,
        hbm_gbps=hbm_gbps,
    )


# --------------------------------------------------------------------------
# Persistence
# --------------------------------------------------------------------------

def default_calib_path(backend: str) -> pathlib.Path:
    """``benchmarks/baselines/CALIB_<backend>.json`` at the repo root
    (overridable via the ``VSCNN_CALIB_PATH`` environment variable)."""
    env = os.environ.get("VSCNN_CALIB_PATH")
    if env:
        return pathlib.Path(env)
    repo = pathlib.Path(__file__).resolve().parents[3]
    return repo / "benchmarks" / "baselines" / f"CALIB_{backend}.json"


def save_calibration(path: str | pathlib.Path, constants: CalibConstants, rows: list[dict], *,
                     fit_settings: dict | None = None,
                     gate_layers: list[str] | None = None) -> dict:
    """Write the calibration artifact: constants + per-layer records.

    Every row must already carry its ``features`` and ``predicted_us``
    (recomputed bit-exactly by the drift gate), plus the measured columns
    (``measured_us``, ``hlo_flops``, ``hlo_bytes``).
    """
    artifact = {
        "calib": "measured_vs_modeled",
        "constants": constants.to_dict(),
        "fit": fit_settings or {},
        "gate_layers": gate_layers or [r["name"] for r in rows],
        "rows": rows,
    }
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(artifact, f, indent=1)
    return artifact


def load_calibration_file(path: str | pathlib.Path) -> dict:
    with open(path) as f:
        return json.load(f)


def load_constants(backend: str | None = None,
                   path: str | pathlib.Path | None = None
                   ) -> CalibConstants:
    """Fitted constants for ``backend`` (default: the active jax backend).

    Returns the uncalibrated defaults when no committed
    ``CALIB_<backend>.json`` exists — modeled numbers then fall back to
    pure cycle proportionality rather than failing.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    p = pathlib.Path(path) if path else default_calib_path(backend)
    if not p.exists():
        return CalibConstants(backend=backend)
    return CalibConstants.from_dict(load_calibration_file(p)["constants"])


# --------------------------------------------------------------------------
# Measurement
# --------------------------------------------------------------------------

def median_time_s(fn: Callable[..., Any], *args: Any, repeats: int = 5,
                  warmup: int = 2) -> float:
    """Median-of-k wall clock of an already-compiled callable.

    ``jax.block_until_ready`` on every call; ``warmup`` calls are discarded
    (first-touch allocation, frequency ramp).  Median, not mean: one noisy
    CI-runner outlier must not move the statistic.
    """
    import jax
    for _ in range(max(warmup, 1)):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def compiled_layer_cost(fn: Callable[..., Any],
                        *args: Any) -> tuple[Any, Any]:
    """jit-compile ``fn(*args)`` and return ``(compiled, HloCost)``.

    The cost comes from `utils.hlo.analyze` over the optimized HLO text —
    per-op FLOPs/bytes with while-bodies multiplied by their trip count,
    the parse `cost_analysis()` gets wrong for scan-over-strips programs.
    FLOPs count dots/convolutions plus fused floating-point multiplies
    (one MAC pair each), so depthwise layers — which compile to fused
    elementwise multiply-adds — report their structural FLOPs too and
    ``flops_model_ratio`` is 1.0 on every layer.
    """
    import jax

    from repro.utils.hlo import analyze_compiled

    compiled = jax.jit(fn).lower(*args).compile()
    return compiled, analyze_compiled(compiled)


def _conv_blocks(n: int, ho: int, bh: int = 8) -> int:
    return n * math.ceil(ho / min(bh, ho))


def _matmul_blocks(m: int, bm: int = 8) -> int:
    return math.ceil(m / bm)


def measured_vs_modeled_records(
    net: Any, params: Any, x: Any, *, density: float = 0.5, vk: int = 32, vn: int = 128,
    impl: str = "jnp", repeats: int = 5, warmup: int = 2,
    layers: set[str] | None = None, measure: bool = True,
) -> list[dict]:
    """Per-layer measured-vs-modeled records for one network.

    Runs every conv *and* FC layer of ``net`` through the sparse path as a
    standalone jitted function on its real forward-pass input: wall-clock
    (median-of-``repeats`` after ``warmup``), deterministic compiled-HLO
    FLOPs/bytes, the analytic model's cycles/bytes/AI, and the time-model
    features.  ``layers`` restricts to a named subset (the CI gate's fast
    re-measure); ``measure=False`` skips the compile+clock and returns the
    deterministic model side only.

    Deliberately times layers in isolation (no residual input, fused
    epilogue on): the per-layer contract the fitted constants describe.
    """
    import jax.numpy as jnp

    from repro.models.graph import (
        SparseConv, apply_sparse_conv, apply_sparse_fc, net_apply, sparsify,
    )
    from .accel_model import (
        PE_4_14_3, conv_layer_cycles, conv_layer_traffic,
    )

    sparse, pruned = sparsify(net, params, density, vk=vk, vn=vn)
    conv_rec: list = []
    fc_rec: list = []
    net_apply(net, pruned, x, collect=conv_rec, collect_fc=fc_rec)
    rows = []

    for name, xin, w, stride, groups, dilation in conv_rec:
        if layers is not None and f"{net.name}/{name}" not in layers:
            continue
        spec: SparseConv = sparse[name]
        nb, s_steps, vk_l, vn_l = (int(d) for d in spec.vs.vals.shape)
        n, h, width, cin = xin.shape
        x_shape = (n, h, width, cin + spec.cin_pad)
        tr = conv_layer_traffic(
            x_shape, kh=spec.kh, kw=spec.kw, stride=spec.stride,
            groups=spec.groups, dilation=spec.dilation, cout=nb * vn_l,
            s_steps=s_steps, vk=vk_l, vn=vn_l, impl="halo",
            itemsize=np.dtype(spec.vs.dtype).itemsize)
        rep = conv_layer_cycles(
            np.asarray(xin)[0], np.asarray(w), PE_4_14_3, stride=stride,
            groups=groups, dilation=dilation)
        from .sparse_ops import same_pads
        ho = same_pads(h, spec.kh, spec.stride, spec.dilation)[0]
        wo = same_pads(width, spec.kw, spec.stride, spec.dilation)[0]
        if spec.kh == 1 and spec.kw == 1 and spec.groups == 1:
            blocks = _matmul_blocks(n * ho * wo)
        else:
            blocks = _conv_blocks(n, ho)
        feat = layer_features(
            flops=tr.flops, bytes_accessed=tr.bytes_accessed, nb=nb,
            s_steps=s_steps, blocks=blocks, vk=vk_l, vn=vn_l,
            cycles=rep.vscnn)
        layer = next(l for l in net.conv_layers() if l.name == name)
        row = {
            "name": f"{net.name}/{name}",
            "net": net.name,
            "layer": name,
            "kind": "conv",
            "density": density,
            "features": feat,
            "modeled_cycles": rep.vscnn,
            "modeled_flops": tr.flops,
            "modeled_bytes": tr.bytes_accessed,
            "modeled_ai": round(tr.arithmetic_intensity, 4),
        }
        if measure:
            fn = functools.partial(
                apply_sparse_conv, entry=spec, bias=spec.bias,
                fuse_relu=layer.relu, impl=impl)
            compiled, cost = compiled_layer_cost(fn, xin)
            row.update(_measured_cols(compiled, cost, tr.flops, (xin,),
                                      repeats=repeats, warmup=warmup))
        rows.append(row)

    for name, xin, w in fc_rec:
        if layers is not None and f"{net.name}/{name}" not in layers:
            continue
        if name not in sparse:
            continue
        spec = sparse[name]
        nb, s_steps, vk_l, vn_l = (int(d) for d in spec.vs.vals.shape)
        m, din = int(np.prod(xin.shape[:-1])), xin.shape[-1]
        tr = conv_layer_traffic(
            (m, 1, 1, din), kh=1, kw=1, cout=nb * vn_l, s_steps=s_steps,
            vk=vk_l, vn=vn_l, impl="halo",
            itemsize=np.dtype(spec.vs.dtype).itemsize)
        rep = conv_layer_cycles(
            np.asarray(xin).reshape(m, 1, din),
            np.asarray(w)[None, None], PE_4_14_3)
        feat = layer_features(
            flops=tr.flops, bytes_accessed=tr.bytes_accessed, nb=nb,
            s_steps=s_steps, blocks=_matmul_blocks(m), vk=vk_l, vn=vn_l,
            cycles=rep.vscnn)
        layer = next(l for l in net.fc_layers() if l.name == name)
        row = {
            "name": f"{net.name}/{name}",
            "net": net.name,
            "layer": name,
            "kind": "fc",
            "density": density,
            "features": feat,
            "modeled_cycles": rep.vscnn,
            "modeled_flops": tr.flops,
            "modeled_bytes": tr.bytes_accessed,
            "modeled_ai": round(tr.arithmetic_intensity, 4),
        }
        if measure:
            bias = spec.bias if spec.bias is not None else None
            fn = functools.partial(apply_sparse_fc, entry=spec, bias=bias,
                                   fuse_relu=layer.relu, impl=impl)
            compiled, cost = compiled_layer_cost(fn, xin)
            row.update(_measured_cols(compiled, cost, tr.flops, (xin,),
                                      repeats=repeats, warmup=warmup))
        rows.append(row)
    return rows


def _measured_cols(compiled: Callable[..., Any], cost: Any,
                   modeled_flops: int, args: tuple[Any, ...], *,
                   repeats: int, warmup: int) -> dict:
    t = median_time_s(compiled, *args, repeats=repeats, warmup=warmup)
    return {
        "measured_us": round(t * 1e6, 3),
        "hlo_flops": cost.flops,
        "hlo_bytes": cost.bytes,
        "measured_ai": round(cost.flops / max(cost.bytes, 1.0), 4),
        "flops_model_ratio": round(cost.flops / max(modeled_flops, 1), 6),
    }


def attach_predictions(rows: list[dict], c: CalibConstants) -> list[dict]:
    """Fill each record's ``predicted_us`` from its features + constants."""
    for r in rows:
        r["predicted_us"] = predict_time_s(r["features"], c) * 1e6
    return rows


# --------------------------------------------------------------------------
# Drift gate
# --------------------------------------------------------------------------

def compare_calibration(
    fresh_rows: list[dict], calib: dict, *, feature_tol: float = 0.02,
    band: float = 4.0, scale_limits: tuple[float, float] = (0.02, 50.0),
) -> tuple[list[str], list[str]]:
    """The CI drift gate: fresh per-layer records vs the committed
    calibration.  Returns ``(failures, markdown table lines)``.

    Three checks, tightest first:

    1. **Constants round-trip (exact).**  The stored constants + each
       row's stored features must reproduce the stored ``predicted_us``
       bit-exactly — perturbing any fitted constant (or any feature) fails
       here, which is what makes the gate testable without a clock.
    2. **Deterministic features (tight band).**  Fresh compiled-HLO
       FLOPs/bytes and fresh modeled cycles/bytes must stay within
       ``feature_tol`` of the recorded values: cost-model or kernel drift
       is caught exactly, independent of machine speed.
    3. **Wall clock (wide band, machine-normalized).**  One global scale —
       the median of measured/predicted over the gated layers — absorbs
       the CI runner's clock vs the fit machine's; every layer's
       scale-normalized ratio must then stay within ``band``x.  The scale
       itself must sit inside ``scale_limits`` (a sanity rail, wide enough
       for any real machine pair).
    """
    const = CalibConstants.from_dict(calib["constants"])
    stored = {r["name"]: r for r in calib["rows"]}
    failures: list[str] = []
    lines = [
        "| layer | check | recorded | fresh | delta | status |",
        "|---|---|---|---|---|---|",
    ]

    def _check(name: str, check: str, rec: float, new: float,
               tol: float) -> None:
        delta = (new - rec) / max(abs(rec), 1e-12)
        bad = abs(delta) > tol
        if bad:
            failures.append(
                f"{name}: {check} {rec:g} -> {new:g} ({delta:+.2%}, "
                f"tol ±{tol:.0%})")
        lines.append(f"| {name} | {check} | {rec:g} | {new:g} | "
                     f"{delta:+.2%} | {'FAIL' if bad else 'ok'} |")

    # 1. constants + stored features must reproduce stored predictions
    for r in calib["rows"]:
        want = r.get("predicted_us")
        if want is None:
            continue
        got = predict_time_s(r["features"], const) * 1e6
        if not math.isclose(got, want, rel_tol=1e-9, abs_tol=1e-12):
            failures.append(
                f"{r['name']}: constants do not reproduce recorded "
                f"predicted_us ({want:g} recorded, {got:g} recomputed) — "
                f"a fitted constant or feature was changed without refitting")
            lines.append(f"| {r['name']} | predicted_us round-trip | "
                         f"{want:g} | {got:g} | — | FAIL |")

    # 2 + 3. fresh measurements vs the record
    ratios = []
    for f in fresh_rows:
        r = stored.get(f["name"])
        if r is None:
            continue  # newly added layer: nothing recorded to drift from
        for key in ("hlo_flops", "hlo_bytes", "modeled_cycles",
                    "modeled_bytes", "modeled_flops"):
            if key in r and key in f:
                _check(f["name"], key, float(r[key]), float(f[key]),
                       feature_tol)
        if "measured_us" in f:
            pred = predict_time_s(r["features"], const) * 1e6
            ratios.append((f["name"], f["measured_us"], pred))
    missing = [n for n in calib.get("gate_layers", []) if n not in
               {f["name"] for f in fresh_rows}]
    for n in missing:
        failures.append(f"{n}: gated layer missing from fresh records")
        lines.append(f"| {n} | presence | — | MISSING | — | FAIL |")

    if ratios:
        scale = float(np.median([m / max(p, 1e-9) for _, m, p in ratios]))
        lo, hi = scale_limits
        if not (lo <= scale <= hi):
            failures.append(
                f"global wall-clock scale {scale:.3g} outside sanity rail "
                f"[{lo:g}, {hi:g}] — the time model no longer tracks this "
                f"machine at all")
        for name, meas, pred in ratios:
            norm = meas / max(scale * pred, 1e-9)
            bad = not (1.0 / band <= norm <= band)
            if bad:
                failures.append(
                    f"{name}: wall clock {meas:.1f}us vs predicted "
                    f"{scale * pred:.1f}us (normalized x{norm:.2f}, band "
                    f"{band:g}x)")
            lines.append(
                f"| {name} | wall_clock_us | {scale * pred:.1f} | "
                f"{meas:.1f} | x{norm:.2f} | {'FAIL' if bad else 'ok'} |")
        lines.append(f"| (all) | machine scale | 1.0 | {scale:.3g} | — | "
                     f"{'ok' if lo <= scale <= hi else 'FAIL'} |")
    return failures, lines
