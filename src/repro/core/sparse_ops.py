"""Structural vector-sparse ops (pure-JAX path) + dispatch to Pallas kernels.

The jnp path performs *structurally sparse* compute: it multiplies only the
stored tiles, so compiled HLO FLOPs drop with density exactly as the paper's
cycle count does.  It is fully GSPMD-partitionable (the strip axis NB shards
over the tensor-model axis) and scan-over-layers compatible (static S).

impl:
  'jnp'     — structural gather + batched matmul (works everywhere, shardable)
  'pallas'  — `repro.kernels` TPU kernel (interpret=True on CPU)
  'auto'    — pallas on TPU backends, jnp otherwise
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .vector_sparse import VectorSparse

__all__ = ["vs_matmul", "im2col_3x3", "vs_conv2d_3x3", "dense_conv2d_3x3"]


def _use_pallas(impl: str) -> bool:
    if impl == "pallas":
        return True
    if impl == "jnp":
        return False
    return jax.default_backend() == "tpu"


def vs_matmul(
    x: jax.Array,
    vs: VectorSparse,
    *,
    impl: str = "jnp",
    out_dtype=None,
    skip_zero_inputs: bool = True,
) -> jax.Array:
    """x (..., K) @ sparse W (K, N) -> (..., N).

    FLOPs = density * dense FLOPs (structural skip of zero weight vectors —
    the paper's weight-side zero skipping).  ``skip_zero_inputs`` additionally
    skips dynamically-zero activation vectors in the Pallas path (the paper's
    input-side skipping; the jnp path cannot skip dynamically under XLA's
    static schedules, matching a dense-issue accelerator).
    """
    out_dtype = out_dtype or x.dtype
    *batch, k = x.shape
    assert k == vs.shape[0], (x.shape, vs.shape)
    if _use_pallas(impl):
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        x2 = x.reshape(-1, k)
        out = kops.vsmm(x2, vs, skip_zero_inputs=skip_zero_inputs)
        return out.reshape(*batch, vs.shape[1]).astype(out_dtype)

    nb, s, vk, vn = vs.vals.shape
    kb = k // vk
    x2 = x.reshape(-1, kb, vk)  # (M, KB, vk)

    def step(acc, sv):
        idx_s, w_s = sv  # (NB,), (NB, vk, vn)
        xg = jnp.take(x2, idx_s, axis=1)  # (M, NB, vk)
        acc = acc + jnp.einsum(
            "mjk,jkn->mjn", xg, w_s, preferred_element_type=jnp.float32
        )
        return acc, None

    acc0 = jnp.zeros((x2.shape[0], nb, vn), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (vs.idx.T, vs.vals.transpose(1, 0, 2, 3)))
    return acc.reshape(*batch, nb * vn).astype(out_dtype)


def im2col_3x3(x: jax.Array) -> jax.Array:
    """NHWC, pad 1, stride 1 -> (N, H, W, 9*C) patches, (ky, kx) row-major."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [
        jax.lax.dynamic_slice(xp, (0, ky, kx, 0), (n, h, w, c))
        for ky in range(3)
        for kx in range(3)
    ]
    return jnp.concatenate(cols, axis=-1)


def vs_conv2d_3x3(x: jax.Array, w_vs: VectorSparse, *, impl: str = "jnp") -> jax.Array:
    """3x3/s1/p1 conv with vector-sparse weights.

    Weight matrix layout: (9*Cin, Cout) with K ordered (ky, kx, cin) — a zero
    K-tile is a pruned run of input channels for one kernel position, the TPU
    analogue of the paper's pruned kernel columns.
    """
    n, h, w, c = x.shape
    if _use_pallas(impl):
        from repro.kernels import ops as kops

        return kops.vsconv(x, w_vs)
    patches = im2col_3x3(x)
    return vs_matmul(patches, w_vs, impl="jnp")


def dense_conv2d_3x3(x: jax.Array, w: jax.Array) -> jax.Array:
    """Dense oracle: w is (3, 3, Cin, Cout)."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv_weight_to_matrix(w: jax.Array) -> jax.Array:
    """(3,3,Cin,Cout) -> (9*Cin, Cout) in the im2col_3x3 (ky,kx,cin) order."""
    kh, kw, cin, cout = w.shape
    return w.reshape(kh * kw * cin, cout)
