"""Structural vector-sparse ops (pure-JAX path) + dispatch to Pallas kernels.

The jnp path performs *structurally sparse* compute: it multiplies only the
stored tiles, so compiled HLO FLOPs drop with density exactly as the paper's
cycle count does.  It is fully GSPMD-partitionable (the strip axis NB shards
over the tensor-model axis) and scan-over-layers compatible (static S).

impl:
  'jnp'          — structural gather + batched matmul (works everywhere,
                   shardable)
  'pallas'       — `repro.kernels` TPU kernel (interpret=True on CPU); for
                   convs this is the halo-blocked direct-input layout
                   ('pallas-halo' is an explicit alias)
  'pallas-stack' — the conv kernel on the materialized row-tap stack
                   (oracle/fallback layout; ~kh*stride x the HBM traffic)
  'auto'         — pallas (halo) on TPU backends, jnp otherwise
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .vector_sparse import VectorSparse

__all__ = [
    "vs_matmul", "im2col", "im2col_3x3", "vs_conv2d", "vs_conv2d_3x3",
    "dense_conv2d", "dense_conv2d_3x3", "conv_weight_to_matrix", "same_pads",
]


def same_pads(size: int, k: int, stride: int) -> tuple[int, int, int]:
    """XLA-"SAME" geometry: (out_size, pad_low, pad_high)."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    lo = total // 2
    return out, lo, total - lo


def _use_pallas(impl: str) -> bool:
    if impl.startswith("pallas"):
        return True
    if impl == "jnp":
        return False
    return jax.default_backend() == "tpu"


def _conv_impl(impl: str) -> str:
    """Map the public impl string to the conv kernel layout."""
    return "stack" if impl == "pallas-stack" else "halo"


def vs_matmul(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    fuse_relu: bool = False,
    impl: str = "jnp",
    out_dtype=None,
    skip_zero_inputs: bool = True,
) -> jax.Array:
    """x (..., K) @ sparse W (K, N) -> (..., N).

    FLOPs = density * dense FLOPs (structural skip of zero weight vectors —
    the paper's weight-side zero skipping).  ``skip_zero_inputs`` additionally
    skips dynamically-zero activation vectors in the Pallas path (the paper's
    input-side skipping; the jnp path cannot skip dynamically under XLA's
    static schedules, matching a dense-issue accelerator).  ``bias`` (N,),
    ``residual`` (..., N) and ``fuse_relu`` run the epilogue fused in the
    Pallas kernel and in f32 before the output cast in the jnp path
    (residual added before the ReLU — the ResNet shortcut).
    """
    out_dtype = out_dtype or x.dtype
    *batch, k = x.shape
    assert k == vs.shape[0], (x.shape, vs.shape)
    if _use_pallas(impl):
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        x2 = x.reshape(-1, k)
        res2 = (residual.reshape(-1, vs.shape[1])
                if residual is not None else None)
        out = kops.vsmm(x2, vs, bias=bias, residual=res2,
                        fuse_relu=fuse_relu,
                        skip_zero_inputs=skip_zero_inputs)
        return out.reshape(*batch, vs.shape[1]).astype(out_dtype)

    nb, s, vk, vn = vs.vals.shape
    kb = k // vk
    x2 = x.reshape(-1, kb, vk)  # (M, KB, vk)

    def step(acc, sv):
        idx_s, w_s = sv  # (NB,), (NB, vk, vn)
        xg = jnp.take(x2, idx_s, axis=1)  # (M, NB, vk)
        acc = acc + jnp.einsum(
            "mjk,jkn->mjn", xg, w_s, preferred_element_type=jnp.float32
        )
        return acc, None

    acc0 = jnp.zeros((x2.shape[0], nb, vn), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (vs.idx.T, vs.vals.transpose(1, 0, 2, 3)))
    y = acc.reshape(*batch, nb * vn)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(out_dtype)


def im2col(
    x: jax.Array, *, kh: int = 3, kw: int = 3, stride: int = 1
) -> jax.Array:
    """NHWC, SAME padding -> (N, Hout, Wout, kh*kw*C) patches, (ky, kx)
    row-major — the layout `conv_weight_to_matrix` flattens weights into."""
    n, h, w, c = x.shape
    ho, pt, pb = same_pads(h, kh, stride)
    wo, pl_, pr = same_pads(w, kw, stride)
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    cols = [
        jax.lax.slice(
            xp,
            (0, ky, kx, 0),
            (n, ky + stride * (ho - 1) + 1, kx + stride * (wo - 1) + 1, c),
            (1, stride, stride, 1),
        )
        for ky in range(kh)
        for kx in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def im2col_3x3(x: jax.Array) -> jax.Array:
    """3x3/s1/p1 patches (back-compat alias)."""
    return im2col(x, kh=3, kw=3, stride=1)


def vs_conv2d(
    x: jax.Array,
    w_vs: VectorSparse,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    fuse_relu: bool = False,
    impl: str = "jnp",
) -> jax.Array:
    """kh x kw / stride / SAME conv with vector-sparse weights.

    Weight matrix layout: (kh*kw*Cin, Cout) with K ordered (ky, kx, cin) — a
    zero K-tile is a pruned run of input channels for one kernel position,
    the TPU analogue of the paper's pruned kernel columns.  1x1 convs are the
    sparse matmul over pixels (stride subsamples first).  On the Pallas path
    ``impl="pallas"``/``"pallas-halo"`` runs the halo-blocked direct-input
    kernel (~1x-input HBM traffic) and ``impl="pallas-stack"`` the
    materialized row-tap stack oracle.  ``bias``,
    ``residual`` (the output-shaped ResNet shortcut, added before the ReLU)
    and ``fuse_relu`` run the epilogue fused in the Pallas path and in f32
    before the output cast in the jnp path — bit-identical math either way.
    """
    if _use_pallas(impl):
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.vsconv(
            x, w_vs, kh=kh, kw=kw, stride=stride, bias=bias,
            residual=residual, fuse_relu=fuse_relu, impl=_conv_impl(impl),
        )
    if kh == 1 and kw == 1:
        patches = x[:, ::stride, ::stride] if stride != 1 else x
    else:
        patches = im2col(x, kh=kh, kw=kw, stride=stride)
    y = vs_matmul(patches, w_vs, impl="jnp", out_dtype=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def vs_conv2d_3x3(x: jax.Array, w_vs: VectorSparse, *, impl: str = "jnp") -> jax.Array:
    """3x3/s1/p1 conv with vector-sparse weights (back-compat alias)."""
    return vs_conv2d(x, w_vs, kh=3, kw=3, stride=1, impl=impl)


def dense_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """Dense oracle: w is (kh, kw, Cin, Cout), SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dense_conv2d_3x3(x: jax.Array, w: jax.Array) -> jax.Array:
    """Dense 3x3/s1 oracle (back-compat alias)."""
    return dense_conv2d(x, w, stride=1)


def conv_weight_to_matrix(w: jax.Array) -> jax.Array:
    """(kh,kw,Cin,Cout) -> (kh*kw*Cin, Cout) in the im2col (ky,kx,cin) order."""
    kh, kw, cin, cout = w.shape
    return w.reshape(kh * kw * cin, cout)
