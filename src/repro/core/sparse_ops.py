"""Structural vector-sparse ops (pure-JAX path) + dispatch to Pallas kernels.

The jnp path performs *structurally sparse* compute: it multiplies only the
stored tiles, so compiled HLO FLOPs drop with density exactly as the paper's
cycle count does.  It is fully GSPMD-partitionable (the strip axis NB shards
over the tensor-model axis) and scan-over-layers compatible (static S).

impl:
  'jnp'          — structural gather + batched matmul (works everywhere,
                   shardable)
  'pallas'       — `repro.kernels` TPU kernel (interpret=True on CPU); for
                   convs this is the halo-blocked direct-input layout
                   ('pallas-halo' is an explicit alias)
  'pallas-stack' — the conv kernel on the materialized row-tap stack
                   (oracle/fallback layout; ~kh*stride x the HBM traffic)
  'auto'         — pallas (halo) on TPU backends, jnp otherwise
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .vector_sparse import VectorSparse

__all__ = [
    "vs_matmul", "im2col", "im2col_3x3", "vs_conv2d", "vs_conv2d_3x3",
    "dense_conv2d", "dense_conv2d_3x3", "conv_weight_to_matrix", "same_pads",
]


def same_pads(size: int, k: int, stride: int,
              dilation: int = 1) -> tuple[int, int, int]:
    """XLA-"SAME" geometry: (out_size, pad_low, pad_high).

    ``dilation`` spaces the kernel taps ``dilation`` elements apart, so the
    effective kernel extent is ``(k - 1) * dilation + 1`` — exactly XLA's
    ``rhs_dilation`` SAME accounting.
    """
    out = -(-size // stride)
    ke = (k - 1) * dilation + 1
    total = max((out - 1) * stride + ke - size, 0)
    lo = total // 2
    return out, lo, total - lo


def _use_pallas(impl: str) -> bool:
    if impl.startswith("pallas"):
        return True
    if impl == "jnp":
        return False
    return jax.default_backend() == "tpu"


def _conv_impl(impl: str) -> str:
    """Map the public impl string to the conv kernel layout."""
    return "stack" if impl == "pallas-stack" else "halo"


def vs_matmul(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    fuse_relu: bool = False,
    impl: str = "jnp",
    out_dtype: Any = None,
    skip_zero_inputs: bool = True,
) -> jax.Array:
    """x (..., K) @ sparse W (K, N) -> (..., N).

    FLOPs = density * dense FLOPs (structural skip of zero weight vectors —
    the paper's weight-side zero skipping).  ``skip_zero_inputs`` additionally
    skips dynamically-zero activation vectors in the Pallas path (the paper's
    input-side skipping; the jnp path cannot skip dynamically under XLA's
    static schedules, matching a dense-issue accelerator).  ``bias`` (N,),
    ``residual`` (..., N) and ``fuse_relu`` run the epilogue fused in the
    Pallas kernel and in f32 before the output cast in the jnp path
    (residual added before the ReLU — the ResNet shortcut).

    INT8 (int8 ``x`` + int8 ``vs.vals`` + ``scale`` (N,)): each sparse step
    multiply-accumulates in int32 (exact) and enters the shared f32
    accumulator — per-step sums stay < 2^24 so the jnp path is bit-exact
    against the Pallas kernel — and the epilogue dequantizes first:
    acc -> *scale -> +bias -> +residual -> max(0).  Output defaults to f32.
    """
    out_dtype = out_dtype or (jnp.float32 if x.dtype == jnp.int8 else x.dtype)
    *batch, k = x.shape
    assert k == vs.shape[0], (x.shape, vs.shape)
    if _use_pallas(impl):
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        x2 = x.reshape(-1, k)
        res2 = (residual.reshape(-1, vs.shape[1])
                if residual is not None else None)
        out = kops.vsmm(x2, vs, bias=bias, residual=res2, scale=scale,
                        fuse_relu=fuse_relu,
                        skip_zero_inputs=skip_zero_inputs)
        return out.reshape(*batch, vs.shape[1]).astype(out_dtype)

    nb, s, vk, vn = vs.vals.shape
    kb = k // vk
    x2 = x.reshape(-1, kb, vk)  # (M, KB, vk)
    int8 = x2.dtype == jnp.int8

    def step(acc: jax.Array, sv: tuple[jax.Array, jax.Array]
             ) -> tuple[jax.Array, None]:
        idx_s, w_s = sv  # (NB,), (NB, vk, vn)
        xg = jnp.take(x2, idx_s, axis=1)  # (M, NB, vk)
        if int8:
            part = jnp.einsum(
                "mjk,jkn->mjn", xg, w_s, preferred_element_type=jnp.int32
            ).astype(jnp.float32)
        else:
            part = jnp.einsum(
                "mjk,jkn->mjn", xg, w_s, preferred_element_type=jnp.float32
            )
        return acc + part, None

    acc0 = jnp.zeros((x2.shape[0], nb, vn), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, (vs.idx.T, vs.vals.transpose(1, 0, 2, 3)))
    y = acc.reshape(*batch, nb * vn)
    if scale is not None:
        # scales are powers of two (see `models.graph.weight_scales`), so
        # this multiply is exact — FMA contraction by the compiler cannot
        # change the result and parity with the Pallas kernels stays
        # bit-exact under any fusion decisions
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(out_dtype)


def im2col(
    x: jax.Array, *, kh: int = 3, kw: int = 3, stride: int = 1,
    dilation: int = 1,
) -> jax.Array:
    """NHWC, SAME padding -> (N, Hout, Wout, kh*kw*C) patches, (ky, kx)
    row-major — the layout `conv_weight_to_matrix` flattens weights into.
    ``dilation`` spaces the taps: tap (ky, kx) reads the padded input at
    (ky*dilation + stride*i, kx*dilation + stride*j)."""
    n, h, w, c = x.shape
    ho, pt, pb = same_pads(h, kh, stride, dilation)
    wo, pl_, pr = same_pads(w, kw, stride, dilation)
    xp = jnp.pad(x, ((0, 0), (pt, pb), (pl_, pr), (0, 0)))
    cols = [
        jax.lax.slice(
            xp,
            (0, ky * dilation, kx * dilation, 0),
            (n, ky * dilation + stride * (ho - 1) + 1,
             kx * dilation + stride * (wo - 1) + 1, c),
            (1, stride, stride, 1),
        )
        for ky in range(kh)
        for kx in range(kw)
    ]
    return jnp.concatenate(cols, axis=-1)


def im2col_3x3(x: jax.Array) -> jax.Array:
    """3x3/s1/p1 patches (back-compat alias)."""
    return im2col(x, kh=3, kw=3, stride=1)


def _vs_conv2d_depthwise_jnp(
    x: jax.Array, w_vs: VectorSparse, *, kh: int, kw: int, stride: int,
    dilation: int,
) -> jax.Array:
    """Structural depthwise conv: the sparse weight is (kh*kw, C) — one row
    per tap, strips over ``vc``-channel tiles, ``idx[j, s]`` the tap id of
    the s-th stored tap-vector of channel tile j.  The scan multiplies only
    the stored (tap, channel-tile) vectors — elementwise VPU work, the
    per-channel analogue of the weight-side structural skip."""
    n, h, w, c = x.shape
    vc = w_vs.vn
    assert w_vs.vk == 1 and w_vs.shape == (kh * kw, c), (w_vs.shape, kh, kw, c)
    p = im2col(x, kh=kh, kw=kw, stride=stride, dilation=dilation)
    _, ho, wo, _ = p.shape
    p4 = p.reshape(n * ho * wo, kh * kw, c // vc, vc)

    def step(acc: jax.Array, sv: tuple[jax.Array, jax.Array]
             ) -> tuple[jax.Array, None]:
        idx_s, w_s = sv  # (NB,), (NB, 1, vc)
        xg = jnp.take_along_axis(p4, idx_s[None, None, :, None], axis=1)[:, 0]
        return acc + xg.astype(jnp.float32) * w_s[:, 0].astype(jnp.float32), None

    acc0 = jnp.zeros((p4.shape[0], c // vc, vc), jnp.float32)
    acc, _ = jax.lax.scan(
        step, acc0, (w_vs.idx.T, w_vs.vals.transpose(1, 0, 2, 3)))
    return acc.reshape(n, ho, wo, c)


def _vs_conv2d_grouped_jnp(
    x: jax.Array, w_vs: VectorSparse, *, kh: int, kw: int, stride: int,
    groups: int, dilation: int,
) -> jax.Array:
    """Structural grouped conv: the sparse weight is (kh*kw*Cin/G, Cout)
    with strips group-major (strip j belongs to group j // (strips/G) and
    its K-tiles index that group's channels only).  Each group is one
    `vs_matmul` over its channel slice of the im2col patches."""
    c = x.shape[-1]
    cin_g = c // groups
    spg = w_vs.n_strips // groups
    assert w_vs.n_strips % groups == 0, (w_vs.n_strips, groups)
    if kh == 1 and kw == 1:
        patches = x[:, ::stride, ::stride] if stride != 1 else x
    else:
        patches = im2col(x, kh=kh, kw=kw, stride=stride, dilation=dilation)
    *batch, _ = patches.shape
    pg = patches.reshape(*batch, kh * kw, groups, cin_g)
    outs = []
    for g in range(groups):
        sub = VectorSparse(
            vals=w_vs.vals[g * spg:(g + 1) * spg],
            idx=w_vs.idx[g * spg:(g + 1) * spg],
            shape=(kh * kw * cin_g, spg * w_vs.vn),
        )
        outs.append(vs_matmul(
            pg[..., g, :].reshape(*batch, kh * kw * cin_g), sub,
            impl="jnp", out_dtype=jnp.float32))
    return jnp.concatenate(outs, axis=-1)


def vs_conv2d(
    x: jax.Array,
    w_vs: VectorSparse,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    fuse_relu: bool = False,
    impl: str = "jnp",
) -> jax.Array:
    """kh x kw / stride / dilation / SAME conv with vector-sparse weights,
    optionally grouped.

    Weight matrix layout: (kh*kw*Cin/groups, Cout) with K ordered
    (ky, kx, cin-within-group) and output strips group-major — a zero K-tile
    is a pruned run of input channels for one kernel position, the TPU
    analogue of the paper's pruned kernel columns.  Depthwise
    (groups == Cin, multiplier 1) degenerates to a (kh*kw, C) tap matrix
    with vk == 1: strips are ``vn``-channel tiles and each stored vector is
    one tap's weights across the tile.  1x1 ungrouped convs are the sparse
    matmul over pixels (stride subsamples first).  On the Pallas path
    ``impl="pallas"``/``"pallas-halo"`` runs the halo-blocked direct-input
    kernels (~1x-input HBM traffic) and ``impl="pallas-stack"`` the
    materialized row-tap stack oracle.  ``bias``,
    ``residual`` (the output-shaped ResNet shortcut, added before the ReLU)
    and ``fuse_relu`` run the epilogue fused in the Pallas path and in f32
    before the output cast in the jnp path — bit-identical math either way.

    INT8 (int8 ``x`` + int8 ``w_vs.vals`` + ``scale`` (Cout,)): the MAC runs
    exactly (int32 accumulation into the shared f32 accumulator) and the
    epilogue dequantizes first — acc -> *scale -> +bias -> +residual (f32)
    -> max(0) — with f32 output.
    """
    if _use_pallas(impl):
        from repro.kernels import ops as kops  # lazy: avoid import cycle

        return kops.vsconv(
            x, w_vs, kh=kh, kw=kw, stride=stride, groups=groups,
            dilation=dilation, bias=bias, residual=residual, scale=scale,
            fuse_relu=fuse_relu, impl=_conv_impl(impl),
        )
    if groups == 1:
        if kh == 1 and kw == 1:
            patches = x[:, ::stride, ::stride] if stride != 1 else x
        else:
            patches = im2col(x, kh=kh, kw=kw, stride=stride,
                             dilation=dilation)
        y = vs_matmul(patches, w_vs, impl="jnp", out_dtype=jnp.float32)
    elif groups == x.shape[-1] and w_vs.shape == (kh * kw, x.shape[-1]):
        # multiplier-1 depthwise; a channel-multiplier conv (cout > cin)
        # falls through to the general grouped path with vk == 1
        y = _vs_conv2d_depthwise_jnp(x, w_vs, kh=kh, kw=kw, stride=stride,
                                     dilation=dilation)
    else:
        y = _vs_conv2d_grouped_jnp(x, w_vs, kh=kh, kw=kw, stride=stride,
                                   groups=groups, dilation=dilation)
    if scale is not None:
        # exact multiply: scales are powers of two (see
        # `models.graph.weight_scales`) — FMA-contraction-proof
        y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(jnp.float32 if x.dtype == jnp.int8 else x.dtype)


def vs_conv2d_3x3(x: jax.Array, w_vs: VectorSparse, *, impl: str = "jnp") -> jax.Array:
    """3x3/s1/p1 conv with vector-sparse weights (back-compat alias)."""
    return vs_conv2d(x, w_vs, kh=3, kw=3, stride=1, impl=impl)


def dense_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                 groups: int = 1, dilation: int = 1) -> jax.Array:
    """Dense oracle: w is (kh, kw, Cin/groups, Cout), SAME padding."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        rhs_dilation=(dilation, dilation),
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def dense_conv2d_3x3(x: jax.Array, w: jax.Array) -> jax.Array:
    """Dense 3x3/s1 oracle (back-compat alias)."""
    return dense_conv2d(x, w, stride=1)


def conv_weight_to_matrix(w: jax.Array) -> jax.Array:
    """(kh,kw,Cin,Cout) -> (kh*kw*Cin, Cout) in the im2col (ky,kx,cin) order."""
    kh, kw, cin, cout = w.shape
    return w.reshape(kh * kw * cin, cout)
