"""VSCNN core: vector sparsity as a first-class JAX feature.

- `VectorSparse`       balanced block-CSR weight format (paper's index system)
- `pruning`            Mao-style vector pruning (global + balanced)
- `sparse_ops`         structural sparse matmul/conv (jnp + Pallas dispatch)
- `accel_model`        cycle-accurate PE-array simulator (paper Table I/Figs 12-13)
- `calibration`        measured-vs-modeled loop: per-layer wall-clock + HLO
                       cost features, fitted model constants, CI drift gate
"""
from .vector_sparse import (
    VectorSparse, encode, decode, from_mask, tile_mask, conv_cin_major,
)
from .pruning import (
    prune_vectors,
    prune_vectors_balanced,
    prune_conv_columns,
    prune_tree_balanced,
    element_density,
)
from .sparse_ops import (
    vs_matmul,
    vs_conv2d,
    vs_conv2d_3x3,
    dense_conv2d,
    dense_conv2d_3x3,
    im2col,
    im2col_3x3,
    conv_weight_to_matrix,
    same_pads,
)
from .accel_model import (
    PEConfig,
    PE_4_14_3,
    PE_8_7_3,
    CycleReport,
    TrafficReport,
    conv_layer_cycles,
    conv_layer_traffic,
    aggregate,
    network_cycle_reports,
    network_traffic_reports,
    table1_example,
    load_calibration,
    predicted_layer_time_s,
)
from .calibration import (
    CalibConstants,
    fit_constants,
    predict_time_s,
    compare_calibration,
    measured_vs_modeled_records,
)
