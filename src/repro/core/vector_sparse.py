"""VectorSparse: balanced block-CSR weight format (the paper's vector sparsity on TPU).

The paper (VSCNN, ISCAS'19) stores only nonzero 1-D weight/input vectors in
SRAM, with a per-vector index driving the accumulator.  On TPU the natural
"vector" is a (vk, vn) tile aligned to the MXU lanes: a weight matrix
W (K, N) is cut into KB x NB tiles; an all-zero tile is simply not stored.

We additionally impose *balance*: every output strip (column of NB) keeps the
same number S of nonzero K-tiles.  This makes the sparse matmul expressible
with a static-shape gather (scan/jit/GSPMD friendly) and mirrors the lockstep
the paper's PE blocks already impose.  ``idx`` is the paper's "index system":
``idx[j, s]`` names the K-tile that the s-th issued vector of output strip j
multiplies against.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["VectorSparse", "encode", "decode", "from_mask", "tile_mask",
           "conv_cin_major"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class VectorSparse:
    """Balanced block-CSR matrix.

    vals : (NB, S, vk, vn)  -- nonzero tiles, per output strip
    idx  : (NB, S) int32    -- K-tile index of each stored tile
    shape: (K, N) dense shape
    """

    vals: jax.Array
    idx: jax.Array
    shape: tuple[int, int]

    # -- pytree plumbing (idx is a leaf so it can live in param trees) -------
    def tree_flatten(self) -> tuple[tuple[jax.Array, jax.Array],
                                    tuple[tuple[int, int]]]:
        return (self.vals, self.idx), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux: tuple[tuple[int, int]],
                       children: tuple[jax.Array, jax.Array]
                       ) -> VectorSparse:
        vals, idx = children
        return cls(vals=vals, idx=idx, shape=aux[0])

    # -- conveniences --------------------------------------------------------
    @property
    def vk(self) -> int:
        return self.vals.shape[2]

    @property
    def vn(self) -> int:
        return self.vals.shape[3]

    @property
    def nnz_per_strip(self) -> int:
        return self.vals.shape[1]

    @property
    def n_strips(self) -> int:
        return self.vals.shape[0]

    @property
    def kb(self) -> int:
        return self.shape[0] // self.vk

    @property
    def density(self) -> float:
        """Fraction of K-tiles stored (== vector density of the paper)."""
        return self.nnz_per_strip / self.kb

    @property
    def dtype(self) -> np.dtype:
        return self.vals.dtype

    def astype(self, dtype: Any) -> VectorSparse:
        return VectorSparse(self.vals.astype(dtype), self.idx, self.shape)


def tile_mask(w: jax.Array, vk: int, vn: int) -> jax.Array:
    """(KB, NB) bool mask: True where the (vk, vn) tile of w has any nonzero."""
    k, n = w.shape
    assert k % vk == 0 and n % vn == 0, f"{w.shape} not tileable by ({vk},{vn})"
    t = w.reshape(k // vk, vk, n // vn, vn)
    return jnp.any(t != 0, axis=(1, 3))


def from_mask(w: jax.Array, mask: np.ndarray, vk: int, vn: int) -> VectorSparse:
    """Encode w keeping exactly the tiles where mask is True.

    ``mask`` must be balanced: equal count per column (output strip).  Host-side
    (numpy) because the index structure is static data, not traced.
    """
    mask = np.asarray(mask)
    k, n = w.shape
    kb, nb = k // vk, n // vn
    assert mask.shape == (kb, nb)
    counts = mask.sum(axis=0)
    s = int(counts[0])
    if not np.all(counts == s):
        raise ValueError(f"unbalanced mask: per-strip counts {counts}")
    # idx[j, s] = sorted K-tile ids of nonzero tiles in strip j
    idx = np.stack([np.nonzero(mask[:, j])[0] for j in range(nb)]).astype(np.int32)
    tiles = w.reshape(kb, vk, nb, vn).transpose(2, 0, 1, 3)  # (NB, KB, vk, vn)
    vals = jnp.take_along_axis(tiles, jnp.asarray(idx)[:, :, None, None], axis=1)
    return VectorSparse(vals=vals, idx=jnp.asarray(idx), shape=(k, n))


def encode(w: jax.Array, vk: int, vn: int) -> VectorSparse:
    """Encode an already vector-pruned dense matrix (balanced occupancy)."""
    mask = np.asarray(tile_mask(w, vk, vn))
    return from_mask(w, mask, vk, vn)


def conv_cin_major(vs: VectorSparse, cb: int) -> VectorSparse:
    """Reorder each strip's stored tiles cin-tile-major (tap-minor).

    For a conv weight matrix the K-tile id is ``t = tap * cb + cin_tile``
    (tap-major), which is the ascending order `from_mask` emits.  The halo
    conv kernel's input block offset depends only on the cin tile — not the
    tap — so sorting the issue order to ``(cin_tile, tap)`` makes
    consecutive sparse steps revisit the same halo block and Pallas skips
    the re-DMA: each cin tile's halo is fetched once per (strip, row-block)
    instead of once per stored tile.  Pure permutation per strip — the
    accumulated sum is the same set of matmuls (fp reassociation only).

    Host-side (encode-time) like `from_mask`; ``cb`` is Cin // vk — for a
    *grouped* conv pass the per-group count Cin // (groups * vk): the tile
    ids are group-relative, so that is what orders them.  (Depthwise convs
    don't need the reorder at all — their input block is tap-independent.)
    """
    idx = np.asarray(vs.idx)
    kb = vs.shape[0] // vs.vk
    taps = kb // cb
    order = np.argsort((idx % cb) * taps + idx // cb, axis=1, kind="stable")
    vals = jnp.take_along_axis(
        vs.vals, jnp.asarray(order)[:, :, None, None], axis=1)
    return VectorSparse(vals=vals, idx=jnp.asarray(np.take_along_axis(
        idx, order, axis=1)), shape=vs.shape)


@partial(jax.jit, static_argnames=())
def decode(vs: VectorSparse) -> jax.Array:
    """Densify (oracle/debug path)."""
    nb, s, vk, vn = vs.vals.shape
    kb = vs.shape[0] // vk
    tiles = jnp.zeros((nb, kb, vk, vn), vs.vals.dtype)
    tiles = tiles.at[jnp.arange(nb)[:, None], vs.idx].add(vs.vals)
    return tiles.transpose(1, 2, 0, 3).reshape(vs.shape)
