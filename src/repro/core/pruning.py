"""Vector pruning (Mao et al., CVPRW'17 — the paper's reference [18]).

Prunes weights at *vector* granularity: the score of a vector (tile) is its
L2 norm; the lowest-scoring vectors are zeroed until the target density is
reached.  Two flavours:

* ``prune_vectors``        — global threshold (exactly Mao et al.; used by the
                             cycle-accurate accelerator model / paper figures).
* ``prune_vectors_balanced`` — equal quota per output strip (TPU adaptation;
                             required by the balanced block-CSR kernels).

For conv weights the paper prunes kernel *columns*: vectors of length 3 along
ky for each (kx, cin, cout).  ``prune_conv_columns`` implements that exact
granularity for the accelerator model.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "vector_scores",
    "prune_vectors",
    "prune_vectors_balanced",
    "prune_conv_columns",
    "element_density",
]


def element_density(w: np.ndarray | jax.Array) -> float:
    w = np.asarray(w)
    return float(np.count_nonzero(w)) / w.size


def vector_scores(w: np.ndarray, vk: int, vn: int) -> np.ndarray:
    """(KB, NB) L2 norms of (vk, vn) tiles."""
    k, n = w.shape
    t = w.reshape(k // vk, vk, n // vn, vn)
    return np.sqrt((t.astype(np.float64) ** 2).sum(axis=(1, 3)))


def _apply_tile_mask(w: np.ndarray, mask: np.ndarray, vk: int, vn: int) -> np.ndarray:
    k, n = w.shape
    m = np.repeat(np.repeat(mask, vk, axis=0), vn, axis=1)
    return (w * m).astype(w.dtype)


def prune_vectors(w: np.ndarray, density: float, vk: int,
                  vn: int) -> np.ndarray:
    """Global magnitude vector pruning to ~`density` fraction of tiles kept."""
    w = np.asarray(w)
    scores = vector_scores(w, vk, vn)
    keep = max(1, int(round(scores.size * density)))
    thresh = np.partition(scores.ravel(), scores.size - keep)[scores.size - keep]
    mask = scores >= thresh
    return _apply_tile_mask(w, mask, vk, vn)


def prune_vectors_balanced(w: np.ndarray, density: float, vk: int,
                           vn: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-strip equal-quota vector pruning.

    Returns (pruned_dense, mask) where mask is (KB, NB) with identical per-
    column counts — directly encodable by `vector_sparse.from_mask`.
    """
    w = np.asarray(w)
    scores = vector_scores(w, vk, vn)  # (KB, NB)
    kb, nb = scores.shape
    s = max(1, int(round(kb * density)))
    order = np.argsort(-scores, axis=0)  # descending per strip
    mask = np.zeros_like(scores, dtype=bool)
    cols = np.arange(nb)[None, :]
    mask[order[:s], cols] = True
    return _apply_tile_mask(w, mask, vk, vn), mask


def prune_conv_columns(w: np.ndarray, density: float) -> np.ndarray:
    """Paper-granularity pruning of conv weights (kh, kw, cin, cout).

    Vector = the kh-column for each (kw, cin, cout) — e.g. WA1..WA3 in Fig. 6.
    """
    w = np.asarray(w)
    kh, kw, cin, cout = w.shape
    scores = np.sqrt((w.astype(np.float64) ** 2).sum(axis=0))  # (kw, cin, cout)
    keep = max(1, int(round(scores.size * density)))
    thresh = np.partition(scores.ravel(), scores.size - keep)[scores.size - keep]
    mask = (scores >= thresh)[None]  # broadcast over kh
    return (w * mask).astype(w.dtype)


def prune_tree_balanced(params: Any, density: float, vk: int, vn: int,
                        *, min_dim: int = 256) -> tuple[Any, dict]:
    """Vector-prune every 2-D matmul weight in a pytree (leaves named arrays).

    Matrices smaller than `min_dim` on either axis (norms, embeddings' last
    dim, biases) are left dense.  Returns (new_params, report dict).
    """
    report = {}

    def visit(path: Any, leaf: Any) -> Any:
        if not hasattr(leaf, "ndim") or leaf.ndim != 2:
            return leaf
        k, n = leaf.shape
        if k < min_dim or n < min_dim or k % vk or n % vn:
            return leaf
        pruned, _ = prune_vectors_balanced(np.asarray(leaf), density, vk, vn)
        report[jax.tree_util.keystr(path)] = element_density(pruned)
        return jnp.asarray(pruned, dtype=leaf.dtype)

    new = jax.tree_util.tree_map_with_path(visit, params)
    return new, report
