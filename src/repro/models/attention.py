"""GQA attention: flash-style chunked softmax, TP/SP sharding, KV-cache decode.

Sharding modes (cfg.attn_sharding):
  'heads' — Q/K/V heads sharded over the model axis (classic TP; requires
            n_heads % tp == 0).
  'sp'    — sequence-parallel: Q sequence sharded over the model axis, KV
            replicated (Megatron context-parallel style).  Used for archs
            whose head count does not divide the model axis (qwen 20H,
            phi3 40H, granite 24H on tp=16) — zero padding waste.

Decode uses a sequence-sharded KV cache (logical axis 'kv_seq' -> model):
each model shard holds a slice of the context, computes partial scores, and
the global softmax reduction lowers to an all-reduce — flash-decoding
expressed in GSPMD rather than hand-written collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel import sharding as shd
from repro.parallel.sharding import logical
from .layers import P, matmul_out_dtype, rope, rms_norm

__all__ = ["attn_schema", "attention_apply", "flash_attention", "init_kv_cache"]


def attn_schema(cfg) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": P((d, h, hd), ("fsdp", "heads", "head_dim"), fan_in=d),
        "wk": P((d, kv, hd), ("fsdp", "kv_heads", "head_dim"), fan_in=d),
        "wv": P((d, kv, hd), ("fsdp", "kv_heads", "head_dim"), fan_in=d),
        "wo": P((h, hd, d), ("heads", "head_dim", "fsdp"), fan_in=h * hd),
    }
    if cfg.qkv_bias:
        s["bq"] = P((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = P((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = P((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), (None,), init="zeros")
        s["k_norm"] = P((hd,), (None,), init="zeros")
    return s


def _chunk_sizes(t: int, pref: int) -> int:
    b = min(pref, t)
    while t % b:
        b -= 1
    return b


def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, T, KV, hd) -> (B, T, H, hd).

    GSPMD-safe GQA: when Q heads are model-sharded but KV heads are not
    divisible by tp (8 KV on tp=16), the (KV, G) grouped reshape of a sharded
    H dim cannot be partitioned.  Repeating the *replicated* KV up to H keeps
    every einsum on the sharded H dim; each shard materializes only its own
    H/tp repeated heads.
    """
    b, t, kvh, hd = k.shape
    if kvh == n_heads:
        return k
    g = n_heads // kvh
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, t, kvh, g, hd)
    ).reshape(b, t, n_heads, hd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 512,
    bk: int = 1024,
    remat_kv: bool = False,
) -> jax.Array:
    """Online-softmax attention, O(bq*bk) score memory.

    q, k, v (B, T, H, hd) — KV already repeated to H (see `repeat_kv`).
    ``q_offset`` places query positions at q_offset + [0, Tq) against key
    positions [0, Tk).
    """
    b, tq, h, hd = q.shape
    _, tk, _, _ = k.shape
    scale = hd ** -0.5
    bq = _chunk_sizes(tq, bq)
    bk = _chunk_sizes(tk, bk)
    nq, nk = tq // bq, tk // bk

    qc = q.reshape(b, nq, bq, h, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,bq,hd)
    kc = k.reshape(b, nk, bk, h, hd).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, nk, bk, h, hd).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_qc):
        qi, qcur = qi_qc  # (B, H, bq, hd)
        qpos = q_offset + qi * bq + jnp.arange(bq)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kcur, vcur = ki_kv  # (B, H, bk, hd) x2
            kpos = ki * bk + jnp.arange(bk)
            s = jnp.einsum(
                "bhqd,bhsd->bhqs", qcur.astype(jnp.float32),
                kcur.astype(jnp.float32),
            ) * scale
            mask = jnp.ones((bq, bk), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            if matmul_out_dtype() is None:  # bf16-flow: bf16 residuals
                p = p.astype(vcur.dtype)
            pv = jnp.einsum(
                "bhqs,bhsd->bhqd", p, vcur,
                preferred_element_type=jnp.float32,
            )
            acc = acc * corr[..., None] + pv
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, bq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, hd), jnp.float32)
        step = kv_step
        if remat_kv:
            # flash semantics in backward too: recompute scores/p per kv
            # chunk instead of storing (nk, B, H, bq, bk) residual stacks —
            # the dominant HBM term of the training baseline (§Perf)
            step = jax.checkpoint(kv_step)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0), (jnp.arange(nk), kc, vc)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, H, bq, hd)
        return None, out

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qc))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, tq, h, hd)
    return out.astype(q.dtype)


def init_kv_cache(cfg, batch: int, capacity: int, dtype) -> dict:
    """One layer's cache arrays; the stack wrapper adds the group dim."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    shape = (batch, capacity, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


CACHE_AXES = {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
              "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def _flash_pallas(q, k, v, cfg, window):
    """(B, T, H, hd) wrapper around the Pallas flash-fwd kernel."""
    import jax as _jax
    from repro.kernels.flash import flash_fwd_pallas
    b, t, h, hd = q.shape
    tk = k.shape[1]
    to_bh = lambda a: a.transpose(0, 2, 1, 3).reshape(b * h, a.shape[1], hd)
    bq = _chunk_sizes(t, 256)
    bk = _chunk_sizes(tk, 512)
    out = flash_fwd_pallas(
        to_bh(q), to_bh(k), to_bh(v), causal=cfg.causal, window=window,
        bq=bq, bk=bk, interpret=_jax.default_backend() != "tpu",
    )
    return out.reshape(b, h, t, hd).transpose(0, 2, 1, 3)


def _persist_cache(k, v, t, cap, cfg):
    """Prefill K/V persistence (shared by both attention impls)."""
    if cap >= t:
        kc = jnp.pad(k, ((0, 0), (0, cap - t), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, cap - t), (0, 0), (0, 0)))
    else:
        src = t - 1 - (t - 1 - jnp.arange(cap)) % cap
        kc = jnp.take(k, src, axis=1)
        vc = jnp.take(v, src, axis=1)
    return {"k": logical(kc.astype(cfg.cache_dtype), CACHE_AXES["k"]),
            "v": logical(vc.astype(cfg.cache_dtype), CACHE_AXES["v"])}


def _project_qkv(params, x, cfg):
    pt = matmul_out_dtype()
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"],
                   preferred_element_type=pt).astype(x.dtype)
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"],
                   preferred_element_type=pt).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"],
                   preferred_element_type=pt).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    return q, k, v


def attention_apply(
    params: dict,
    x: jax.Array,
    cfg,
    *,
    window: int | None = None,
    cache: dict | None = None,
    pos: jax.Array | None = None,
    decode: bool = False,
    cache_capacity: int | None = None,
):
    """Returns (out, new_cache). new_cache is None in pure-training mode.

    Training / prefill: full-sequence flash attention; if ``cache_capacity``
    is given (prefill) the projected K/V are persisted sequence-sharded.
    Decode:  x is (B, 1, D); reads the cache, writes position ``pos``.

    The cache is *circular*: capacity may be min(window, seq) for sliding-
    window layers; position p lives in slot p % capacity, and the absolute
    position of slot i under write head ``pos`` is pos - ((pos - i) % cap)
    (which degenerates to kpos == i when cap > pos, i.e. a plain cache).
    """
    b, t, d = x.shape
    seq_ax = "seq_sp" if cfg.attn_sharding == "sp" else "seq"
    q, k, v = _project_qkv(params, x, cfg)

    if decode:
        assert cache is not None and pos is not None
        dpos = jnp.reshape(pos, (1,))
        q = rope(q, dpos, theta=cfg.rope_theta)
        k = rope(k, dpos, theta=cfg.rope_theta)
        cap = cache["k"].shape[1]
        slot = pos % cap
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        k_cache = logical(k_cache, CACHE_AXES["k"])
        v_cache = logical(v_cache, CACHE_AXES["v"])
        kvh = cfg.n_kv_heads
        g = cfg.n_heads // kvh
        qg = q.reshape(b, 1, kvh, g, cfg.head_dim)
        scores = jnp.einsum(
            "bqkgd,bskd->bkgqs", qg, k_cache,
            preferred_element_type=jnp.float32,
        ) * (cfg.head_dim ** -0.5)
        kpos = pos - (pos - jnp.arange(cap)) % cap  # absolute pos per slot
        valid = kpos[None, :] >= 0
        if window is not None:
            valid &= pos - kpos[None, :] < window
        scores = jnp.where(valid[None, None, None], scores, -1e30)
        # global softmax over the sequence-sharded axis: GSPMD inserts the
        # max / sum all-reduces (flash-decoding combine)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum(
            "bkgqs,bskd->bqkgd", p, v_cache,
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
        out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        # Pin projection outputs to a *computed-sharded* layout before any
        # replicated-KV relaxation, so GSPMD places the seq all-gather AFTER
        # the projection dots.  Without this the propagation pass sometimes
        # gathers the activations first and computes the K/V projections
        # replicated over the model axis — 16x redundant FLOPs (§Perf C-iter).
        ctx = shd.current()
        tp = 1
        if ctx is not None:
            phys = ctx.rules.get("kv_heads")
            tp = ctx.mesh.shape.get(phys, 1) if isinstance(phys, str) else 1
        kv_sharded = cfg.n_kv_heads % max(tp, 1) == 0
        kv_proj_axes = (
            ("batch", "seq" if cfg.attn_sharding == "heads" else "seq_sp",
             "kv_heads", "head_dim") if kv_sharded
            else ("batch", "seq_sp", None, None)
        )
        q = logical(q, ("batch", seq_ax, "heads", "head_dim"))
        k = logical(k, kv_proj_axes)
        v = logical(v, kv_proj_axes)
        positions = jnp.arange(t)
        q = rope(q, positions, theta=cfg.rope_theta)
        k = rope(k, positions, theta=cfg.rope_theta)
        q = logical(q, ("batch", seq_ax, "heads", "head_dim"))
        k = logical(k, ("batch", None, "kv_heads", "head_dim"))
        v = logical(v, ("batch", None, "kv_heads", "head_dim"))
        kr = repeat_kv(k, cfg.n_heads)
        vr = repeat_kv(v, cfg.n_heads)
        if cfg.attn_impl == "pallas" and shd.current() is None:
            # single-device serving path: the Pallas flash kernel keeps the
            # online-softmax chain VMEM-resident (EXPERIMENTS §Perf C).
            # Sharded meshes use the jnp flash below (GSPMD-partitionable);
            # shard_map-wrapping the kernel is the designated follow-up.
            out = _flash_pallas(q, kr, vr, cfg, window)
            out = logical(out, ("batch", seq_ax, "heads", "head_dim"))
            new_cache = None
            if cache_capacity is not None:
                new_cache = _persist_cache(k, v, t, cache_capacity, cfg)
            y = jnp.einsum("bthk,hkd->btd", out.astype(x.dtype), params["wo"],
                           preferred_element_type=matmul_out_dtype()
                           ).astype(x.dtype)
            return logical(y, ("batch", seq_ax, "embed")), new_cache
        if cfg.attn_sharding == "sp":
            # q is sequence-sharded: a (nq, bq) reshape of the sharded T dim
            # cannot be partitioned, so use a single q chunk (scores stay
            # seq-sharded, (B, H, T/tp, bk) per device per kv step).
            bq = t
        else:
            kr = logical(kr, ("batch", None, "heads", "head_dim"))
            vr = logical(vr, ("batch", None, "heads", "head_dim"))
            bq = cfg.attn_block_q
        out = flash_attention(
            q, kr, vr, causal=cfg.causal, window=window,
            bq=bq, bk=cfg.attn_block_kv, remat_kv=cfg.flash_remat,
        )
        out = logical(out, ("batch", seq_ax, "heads", "head_dim"))
        new_cache = None
        if cache_capacity is not None:  # prefill: persist K/V seq-sharded
            cap = cache_capacity
            if cap >= t:
                kc = jnp.pad(k, ((0, 0), (0, cap - t), (0, 0), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, cap - t), (0, 0), (0, 0)))
            else:  # keep last `cap` positions, circularly addressed
                src = t - 1 - (t - 1 - jnp.arange(cap)) % cap
                kc = jnp.take(k, src, axis=1)
                vc = jnp.take(v, src, axis=1)
            new_cache = {
                "k": logical(kc.astype(cfg.cache_dtype), CACHE_AXES["k"]),
                "v": logical(vc.astype(cfg.cache_dtype), CACHE_AXES["v"]),
            }

    y = jnp.einsum("bthk,hkd->btd", out.astype(x.dtype), params["wo"],
                   preferred_element_type=matmul_out_dtype()).astype(x.dtype)
    return logical(y, ("batch", seq_ax if not decode else None, "embed")), new_cache
