"""Mamba (S6 selective SSM) mixer — the Jamba hybrid's recurrent layer.

TP layout: the inner dimension d_inner (= 2 * d_model) is sharded over the
model axis ('ff' logical), so the recurrence is channel-parallel with zero
cross-device traffic; only in_proj / out_proj touch the TP collectives,
exactly like a dense FFN.

Training uses `chunked_remat_scan`: per-step tensors (dA, dB·x) of size
(B, d_inner, d_state) are built *inside* the scan step (materializing them
for all T would be ~B·T·d_inner·d_state — hundreds of GB at 4k context), and
the backward pass stores one carry per chunk.

Decode carries (conv tail, ssm state) in the cache pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical
from .layers import P, chunked_remat_scan, matmul_out_dtype

__all__ = ["mamba_schema", "mamba_apply", "init_mamba_cache", "MAMBA_CACHE_AXES"]

D_STATE = 16
D_CONV = 4


def _dims(cfg):
    d_in = 2 * cfg.d_model
    dt_rank = -(-cfg.d_model // 16)
    return d_in, dt_rank


def mamba_schema(cfg) -> dict:
    d = cfg.d_model
    d_in, dt_rank = _dims(cfg)
    return {
        "in_proj": P((2, d, d_in), (None, "fsdp", "ff"), fan_in=d),
        "conv_w": P((D_CONV, d_in), (None, "ff"), fan_in=D_CONV),
        "conv_b": P((d_in,), ("ff",), init="zeros"),
        "x_proj": P((d_in, dt_rank + 2 * D_STATE), ("ff", None), fan_in=d_in),
        "dt_proj": P((dt_rank, d_in), (None, "ff"), fan_in=dt_rank),
        "dt_bias": P((d_in,), ("ff",), init="zeros"),
        "a_log": P((d_in, D_STATE), ("ff", None), init="a_log"),
        "d_skip": P((d_in,), ("ff",), init="ones"),
        "out_proj": P((d_in, d), ("ff", "fsdp"), fan_in=d_in),
    }


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    d_in, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, D_CONV - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, D_STATE), jnp.float32),
    }


MAMBA_CACHE_AXES = {
    "conv": ("batch", None, "ff"),
    "ssm": ("batch", "ff", None),
}


def _ssm_inputs(params, xc, cfg):
    """xc (B, T, d_in) post-conv activations -> (dt, B_ssm, C_ssm)."""
    _, dt_rank = _dims(cfg)
    proj = jnp.einsum("bti,ir->btr", xc.astype(jnp.float32),
                      params["x_proj"].astype(jnp.float32))
    dt_raw = proj[..., :dt_rank]
    b_ssm = proj[..., dt_rank : dt_rank + D_STATE]
    c_ssm = proj[..., dt_rank + D_STATE :]
    dt = jax.nn.softplus(
        jnp.einsum("btr,ri->bti", dt_raw, params["dt_proj"],
                   preferred_element_type=jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )
    return dt, b_ssm, c_ssm


def _scan_step(a_neg, carry, xs):
    """h_t = exp(dt A) h_{t-1} + dt B x_t ;  y_t = <h_t, C_t> (per channel)."""
    h = carry
    xc_t, dt_t, b_t, c_t = xs  # (B, d_in), (B, d_in), (B, N), (B, N)
    da = jnp.exp(dt_t[..., None] * a_neg[None])            # (B, d_in, N)
    dbx = (dt_t * xc_t.astype(jnp.float32))[..., None] * b_t[:, None, :]
    h = da * h + dbx
    y = jnp.einsum("bin,bn->bi", h, c_t)                    # (B, d_in)
    return h, y


def mamba_apply(params, x, cfg, *, cache=None, decode=False, prefill=False):
    """x (B, T, D) -> (out (B, T, D), new_cache)."""
    b, t, d = x.shape
    d_in, _ = _dims(cfg)
    xz = jnp.einsum("btd,cdi->cbti", x, params["in_proj"],
                    preferred_element_type=matmul_out_dtype()).astype(x.dtype)
    x_in, z = xz[0], xz[1]
    x_in = logical(x_in, ("batch", "seq", "ff"))
    a_neg = -jnp.exp(params["a_log"].astype(jnp.float32))

    if decode:
        assert cache is not None
        # causal depthwise conv over (cached tail ++ current token)
        window = jnp.concatenate([cache["conv"], x_in], axis=1)  # (B, 4, d_in)
        xc = jnp.einsum("bki,ki->bi", window.astype(jnp.float32),
                        params["conv_w"].astype(jnp.float32))
        xc = jax.nn.silu(xc + params["conv_b"].astype(jnp.float32))
        xc = xc[:, None, :].astype(x.dtype)                      # (B, 1, d_in)
        dt, b_ssm, c_ssm = _ssm_inputs(params, xc, cfg)
        h, y = _scan_step(
            a_neg, cache["ssm"],
            (xc[:, 0], dt[:, 0], b_ssm[:, 0], c_ssm[:, 0]),
        )
        y = y[:, None, :]
        new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype), "ssm": h}
    else:
        kernel = params["conv_w"].astype(x.dtype)[:, None, :]    # (K, 1, d_in)
        xc = jax.lax.conv_general_dilated(
            x_in, kernel, window_strides=(1,), padding=[(D_CONV - 1, 0)],
            dimension_numbers=("NWC", "WIO", "NWC"),
            feature_group_count=d_in,
        )
        xc = jax.nn.silu(
            xc.astype(jnp.float32) + params["conv_b"].astype(jnp.float32)
        ).astype(x.dtype)
        dt, b_ssm, c_ssm = _ssm_inputs(params, xc, cfg)
        h0 = jnp.zeros((b, d_in, D_STATE), jnp.float32)
        xs = (
            xc.transpose(1, 0, 2),
            dt.transpose(1, 0, 2).astype(jnp.float32),
            b_ssm.transpose(1, 0, 2),
            c_ssm.transpose(1, 0, 2),
        )
        step = lambda c, s: _scan_step(a_neg, c, s)
        h, ys = chunked_remat_scan(step, h0, xs, chunk=min(cfg.scan_chunk, t))
        y = ys.transpose(1, 0, 2)                                # (B, T, d_in)
        new_cache = None
        if prefill:  # persist conv tail + final ssm state
            tail = x_in[:, -(D_CONV - 1):, :]
            new_cache = {"conv": tail.astype(cfg.cache_dtype), "ssm": h}

    y = y.astype(jnp.float32) + params["d_skip"].astype(jnp.float32) * x_in.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = logical(y, ("batch", "seq", "ff"))
    out = jnp.einsum("bti,id->btd", y, params["out_proj"],
                     preferred_element_type=matmul_out_dtype()).astype(x.dtype)
    return logical(out, ("batch", "seq", "embed")), new_cache
