"""Expert-parallel MoE with *local dispatch* (no all-to-all).

Design (DESIGN.md §6): activations entering the FFN block are replicated over
the model axis (they just left the attention TP psum), so every model shard
already holds *all* tokens of its data shard.  Experts are sharded over the
model axis; each shard simply *selects* the tokens routed to its own experts
(sort + capacity buffer), runs them through its expert FFNs, scatters the
results back to token order, and the per-shard partial outputs merge in one
psum over the model axis — the same collective a dense TP FFN needs.  Router
and dispatch are computed redundantly per shard; the redundant compute is
O(tokens * experts) router FLOPs, negligible against the expert matmuls.

Token capacity is static: C = ceil(local_tokens * top_k / n_experts * cf),
over-capacity tokens are dropped (standard Switch semantics).  Expert counts
that do not divide the model axis are padded with dead experts whose router
logits are -inf (granite 40 -> 48 on tp=16).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.parallel import sharding as shd
from .layers import P, matmul_out_dtype

__all__ = ["MoEConfig", "moe_schema", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    n_shared: int = 0  # shared-expert width multiplier (kimi-k2: 1)
    aux_weight: float = 0.01

    def padded_experts(self, tp: int) -> int:
        return -(-self.n_experts // tp) * tp


def moe_schema(d_model: int, moe: MoEConfig, *, gated: bool, tp_hint: int = 16) -> dict:
    # FSDP dim is the expert-internal F axis (not D): the 'resident' serving
    # dispatch then computes within-expert partial sums over the data axis
    # with zero weight movement (gate/up activations are elementwise in F).
    ep = moe.padded_experts(tp_hint)
    f = moe.d_ff
    s = {
        "router": P((d_model, ep), ("fsdp", None), fan_in=d_model),
        "wo": P((ep, f, d_model), ("expert", "fsdp", None), fan_in=f),
    }
    if gated:
        s["wi"] = P((2, ep, d_model, f), (None, "expert", None, "fsdp"), fan_in=d_model)
    else:
        s["wi"] = P((ep, d_model, f), ("expert", None, "fsdp"), fan_in=d_model)
    return s


def _expert_ffn(xbuf, wi, wo, *, gated: bool, activation_fn):
    """xbuf (E, C, D); wi/wo expert weight blocks."""
    pt = matmul_out_dtype()
    if gated:
        gate = jnp.einsum("ecd,edf->ecf", xbuf, wi[0],
                          preferred_element_type=pt)
        up = jnp.einsum("ecd,edf->ecf", xbuf, wi[1],
                        preferred_element_type=pt)
        h = (activation_fn(gate.astype(jnp.float32)).astype(xbuf.dtype)
             * up.astype(xbuf.dtype))
    else:
        h = jnp.einsum("ecd,edf->ecf", xbuf, wi,
                       preferred_element_type=pt)
        h = activation_fn(h.astype(jnp.float32)).astype(xbuf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, wo,
                      preferred_element_type=pt).astype(xbuf.dtype)


def _route_and_pack(xf, router, moe, ep, e_loc, e0, capacity):
    """Shared routing: sort/capacity-pack tokens for the local expert range.

    Returns (slot_tok, slot_w, aux) where slot i of the (E_loc * C) buffer
    reads token slot_tok[i] with combine weight slot_w[i]."""
    n, d = xf.shape
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                        router.astype(jnp.float32))
    if ep != moe.n_experts:  # dead padding experts never win top-k
        logits = jnp.where(jnp.arange(ep)[None] < moe.n_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, moe.top_k)  # (N, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    ids = topi.reshape(-1)                          # (N*k,)
    wts = topw.reshape(-1).astype(jnp.float32)
    tok = jnp.arange(n * moe.top_k) // moe.top_k    # owning token of each slot
    order = jnp.argsort(ids)                        # stable
    ids_s, tok_s, w_s = ids[order], tok[order], wts[order]
    starts = jnp.searchsorted(ids_s, jnp.arange(ep))
    pos = jnp.arange(n * moe.top_k) - starts[ids_s]

    local = (ids_s >= e0) & (ids_s < e0 + e_loc) & (pos < capacity)
    slot = jnp.where(local, (ids_s - e0) * capacity + pos,
                     n * moe.top_k + capacity * e_loc)
    slot_tok = jnp.zeros((e_loc * capacity,), jnp.int32).at[slot].set(
        tok_s.astype(jnp.int32), mode="drop")
    slot_w = jnp.zeros((e_loc * capacity,), jnp.float32).at[slot].set(
        w_s, mode="drop")

    # switch-style load-balance loss
    counts = jnp.diff(jnp.append(starts, n * moe.top_k)).astype(jnp.float32)
    frac = counts / (n * moe.top_k)
    pmean = jnp.mean(probs, axis=0)
    aux = moe.n_experts * jnp.sum(frac * pmean)
    return slot_tok, slot_w, aux


def _moe_body(
    x, router, wi, wo, *,
    moe: MoEConfig, ep: int, e_loc: int, e0,
    capacity: int, gated: bool, activation_fn,
    fsdp_axis, model_axis, gather=(False, False, False),
):
    """gather-weights dispatch (training posture): tokens stay put, the
    fsdp-sharded expert weights are gathered per layer (ZeRO-3)."""
    bl, t, d = x.shape
    nl = bl * t
    if fsdp_axis is not None:
        if gather[0]:
            router = jax.lax.all_gather(router, fsdp_axis, axis=0, tiled=True)
        if gather[1]:
            wi = jax.lax.all_gather(wi, fsdp_axis, axis=3 if gated else 2, tiled=True)
        if gather[2]:
            wo = jax.lax.all_gather(wo, fsdp_axis, axis=1, tiled=True)
    xf = x.reshape(nl, d)
    slot_tok, slot_w, aux = _route_and_pack(xf, router, moe, ep, e_loc, e0,
                                            capacity)
    xbuf = jnp.take(xf, slot_tok, axis=0).reshape(e_loc, capacity, d)
    ybuf = _expert_ffn(xbuf, wi, wo, gated=gated, activation_fn=activation_fn)
    yflat = ybuf.reshape(e_loc * capacity, d) * slot_w[:, None].astype(ybuf.dtype)

    out = jnp.zeros((nl, d), x.dtype).at[slot_tok].add(yflat)
    if model_axis is not None:
        out = jax.lax.psum(out, model_axis)
    return out.reshape(bl, t, d), aux


def _moe_body_resident(
    x, router, wi, wo, *,
    moe: MoEConfig, ep: int, e_loc: int, e0,
    gated: bool, activation_fn,
    fsdp_axis, model_axis, batch_axes, gather_router: bool,
):
    """resident-weights dispatch (serving posture): expert weights never
    move — tokens are all-gathered over the data axes (tiny at decode), every
    (expert-shard, F-shard) device computes its partial expert FFN, and one
    psum over (model, data) completes both the within-expert F reduction and
    the cross-expert combine.  Weight traffic per layer: zero (vs ~2 GB/layer
    gathered for a 1T-param MoE under ZeRO-3)."""
    bl, t, d = x.shape
    if gather_router and fsdp_axis is not None:
        router = jax.lax.all_gather(router, fsdp_axis, axis=0, tiled=True)
    if batch_axes:
        xg = jax.lax.all_gather(x, batch_axes, axis=0, tiled=True)  # (B, T, D)
    else:
        xg = x
    ng = xg.shape[0] * t
    xf = xg.reshape(ng, d)
    capacity = _capacity(ng, moe)
    slot_tok, slot_w, aux = _route_and_pack(xf, router, moe, ep, e_loc, e0,
                                            capacity)
    xbuf = jnp.take(xf, slot_tok, axis=0).reshape(e_loc, capacity, d)
    # wi/wo are F-sharded over fsdp: partial expert outputs, summed below
    ybuf = _expert_ffn(xbuf, wi, wo, gated=gated, activation_fn=activation_fn)
    yflat = ybuf.reshape(e_loc * capacity, d) * slot_w[:, None].astype(ybuf.dtype)
    out = jnp.zeros((ng, d), jnp.float32).at[slot_tok].add(
        yflat.astype(jnp.float32))
    axes = tuple(a for a in ((model_axis,) if model_axis else ())
                 + ((fsdp_axis,) if fsdp_axis else ()))
    if axes:
        out = jax.lax.psum(out, axes)
    out = out.astype(x.dtype)
    if batch_axes:
        flat = tuple(batch_axes) if isinstance(batch_axes, (tuple, list)) else (batch_axes,)
        my = jnp.int32(0)
        # jax.lax.axis_size appeared after 0.4.37; psum(1, axis) is the
        # long-standing equivalent (constant-folded to the static size)
        axis_size = getattr(jax.lax, "axis_size",
                            lambda a: jax.lax.psum(1, a))
        for a in flat:
            my = my * axis_size(a) + jax.lax.axis_index(a)
        out = jax.lax.dynamic_slice_in_dim(out, my * (bl * t), bl * t, axis=0)
    return out.reshape(bl, t, d), aux


def moe_apply(params: dict, x: jax.Array, moe: MoEConfig, *, gated: bool,
              activation_fn=jax.nn.silu, dispatch: str = "gather_weights"):
    """Returns (y, aux_loss). Dispatch is shard_mapped when a mesh is active.

    dispatch='gather_weights' — training posture (tokens stay, ZeRO-3 weight
    gathers); 'resident' — serving posture (weights stay, tokens move)."""
    ctx = shd.current()
    router, wi, wo = params["router"], params["wi"], params["wo"]

    if ctx is None:
        ep = router.shape[1]
        y, aux = _moe_body(
            x, router, wi, wo, moe=moe, ep=ep, e_loc=ep, e0=0,
            capacity=_capacity(x.shape[0] * x.shape[1], moe),
            gated=gated, activation_fn=activation_fn,
            fsdp_axis=None, model_axis=None,
        )
        return y, aux

    mesh, rules = ctx.mesh, ctx.rules
    model_axis = rules.get("expert")
    model_axis = model_axis if model_axis in mesh.shape else None
    fsdp_axis = rules.get("fsdp")
    fsdp_axis = fsdp_axis if fsdp_axis in mesh.shape else None
    batch_phys = rules.get("batch")
    batch_phys = tuple(p for p in (batch_phys if isinstance(batch_phys, tuple) else (batch_phys,))
                       if p in mesh.shape) or None

    tp = mesh.shape[model_axis] if model_axis else 1
    ep = router.shape[1]
    e_loc = ep // tp
    b, t, _ = x.shape
    dp = math.prod(mesh.shape[p] for p in (batch_phys or ())) or 1
    if b % dp:  # batch too small to shard (e.g. long_500k B=1): replicate
        batch_phys, dp = None, 1
    nl = (b // dp) * t
    capacity = _capacity(nl, moe)

    def spec(axes, shape):
        return shd.spec_for(axes, mesh=mesh, rules=rules, shape=shape)

    wi_axes = (None, "expert", None, "fsdp") if gated else ("expert", None, "fsdp")
    in_specs = (
        PS(batch_phys, None, None),
        spec(("fsdp", None), router.shape),
        spec(wi_axes, wi.shape),
        spec(("expert", "fsdp", None), wo.shape),
    )
    out_specs = (PS(batch_phys, None, None), PS())

    def body(x_l, router_l, wi_l, wo_l):
        e0 = jax.lax.axis_index(model_axis) * e_loc if model_axis else 0
        if dispatch == "resident":
            y, aux = _moe_body_resident(
                x_l, router_l, wi_l, wo_l, moe=moe, ep=ep, e_loc=e_loc,
                e0=e0, gated=gated, activation_fn=activation_fn,
                fsdp_axis=fsdp_axis if _sharded(in_specs[2], fsdp_axis) else None,
                model_axis=model_axis, batch_axes=batch_phys,
                gather_router=_sharded(in_specs[1], fsdp_axis),
            )
        else:
            y, aux = _moe_body(
                x_l, router_l, wi_l, wo_l, moe=moe, ep=ep, e_loc=e_loc, e0=e0,
                capacity=capacity, gated=gated, activation_fn=activation_fn,
                fsdp_axis=fsdp_axis, model_axis=model_axis,
                gather=tuple(_sharded(s, fsdp_axis) for s in in_specs[1:]),
            )
        if batch_phys:
            aux = jax.lax.pmean(aux, batch_phys)
        return y, aux

    y, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )(x, router, wi, wo)
    return y, aux


def _sharded(pspec: PS, axis) -> bool:
    return axis is not None and any(
        (p == axis or (isinstance(p, tuple) and axis in p)) for p in pspec if p
    )


def _capacity(local_tokens: int, moe: MoEConfig) -> int:
    c = math.ceil(local_tokens * moe.top_k / moe.n_experts * moe.capacity_factor)
    return max(8, -(-c // 8) * 8)
