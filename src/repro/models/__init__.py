"""Model zoo: transformer stack (GQA/MoE/Mamba/RWKV patterns) + the sparse
CNN graph IR (`graph`: VGG-16, ResNet-18, and any `SparseNet` a builder
expresses) with `cnn` keeping the legacy per-model entry points."""
from . import layers, attention, moe, mamba, rwkv, transformer, graph, cnn, frontend
