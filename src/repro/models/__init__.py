"""Model zoo: transformer stack (GQA/MoE/Mamba/RWKV patterns) + VGG-16."""
from . import layers, attention, moe, mamba, rwkv, transformer, cnn, frontend
