"""RWKV-6 ("Finch") — attention-free mixer with data-dependent decay.

Faithful pieces: token-shift lerp mixing, data-dependent per-channel decay
w_t = exp(-exp(w0 + lora(x))), bonus term u, matrix-valued per-head state
S_t = diag(w_t) S_{t-1} + k_t v_t^T, squared-ReLU channel-mix.

TPU/TP adaptation (DESIGN.md §5): head_dim = d_model / 16 (160 for the 3B)
instead of Finch's 64, so heads shard exactly over the 16-way model axis with
zero padding waste.  The recurrence is head-parallel; only the projections
touch TP collectives.  Simplification: the five token-shift mix coefficients
are static learned vectors (Finch adds a small LoRA on them); the *decay*
LoRA — the architecture's signature data dependence — is kept.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical
from .layers import P, chunked_remat_scan, matmul_out_dtype, rms_norm

__all__ = [
    "rwkv_tm_schema",
    "rwkv_cm_schema",
    "rwkv_time_mix",
    "rwkv_channel_mix",
    "init_rwkv_tm_cache",
    "init_rwkv_cm_cache",
    "RWKV_TM_CACHE_AXES",
    "RWKV_CM_CACHE_AXES",
]

W_LORA = 64


def _heads(cfg):
    return cfg.n_heads, cfg.d_model // cfg.n_heads


def rwkv_tm_schema(cfg) -> dict:
    d = cfg.d_model
    h, hd = _heads(cfg)
    proj = lambda: P((d, h, hd), ("fsdp", "heads", "head_dim"), fan_in=d)
    return {
        "mu_r": P((d,), (None,), init="zeros"),
        "mu_k": P((d,), (None,), init="zeros"),
        "mu_v": P((d,), (None,), init="zeros"),
        "mu_g": P((d,), (None,), init="zeros"),
        "mu_w": P((d,), (None,), init="zeros"),
        "w0": P((d,), (None,), init="zeros"),
        "w_lora_a": P((d, W_LORA), ("fsdp", None), fan_in=d),
        "w_lora_b": P((W_LORA, d), (None, "fsdp"), fan_in=W_LORA),
        "wr": proj(), "wk": proj(), "wv": proj(), "wg": proj(),
        "u": P((h, hd), ("heads", "head_dim"), init="zeros"),
        "ln_x": P((h, hd), ("heads", "head_dim"), init="zeros"),
        "wo": P((h, hd, d), ("heads", "head_dim", "fsdp"), fan_in=d),
    }


def rwkv_cm_schema(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": P((d,), (None,), init="zeros"),
        "mu_r": P((d,), (None,), init="zeros"),
        "wr": P((d, d), ("fsdp", None), fan_in=d),
        "wk": P((d, f), ("fsdp", "ff"), fan_in=d),
        "wv": P((f, d), ("ff", "fsdp"), fan_in=f),
    }


def init_rwkv_tm_cache(cfg, batch: int, dtype) -> dict:
    h, hd = _heads(cfg)
    return {
        "x_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "s": jnp.zeros((batch, h, hd, hd), jnp.float32),
    }


def init_rwkv_cm_cache(cfg, batch: int, dtype) -> dict:
    return {"x_prev": jnp.zeros((batch, cfg.d_model), dtype)}


RWKV_TM_CACHE_AXES = {
    "x_prev": ("batch", None),
    "s": ("batch", "heads", "head_dim", None),
}
RWKV_CM_CACHE_AXES = {"x_prev": ("batch", None)}


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def _shift(x, x_prev):
    """(B, T, D) -> previous-token stream, seeded by x_prev (B, D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _tm_step(carry, xs):
    """State S (B, H, K, V); per-token r, k, v (B, H, hd), w (B, H, hd)."""
    s = carry
    r_t, k_t, v_t, w_t, u = xs
    kv = k_t[..., :, None] * v_t[..., None, :]              # (B, H, K, V)
    y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
    s = w_t[..., :, None] * s + kv
    return s, y


def rwkv_time_mix(params, x, cfg, *, cache=None, decode=False, prefill=False):
    b, t, d = x.shape
    h, hd = _heads(cfg)
    if decode:
        xs = cache["x_prev"][:, None, :].astype(x.dtype)
    else:
        xs = _shift(x, jnp.zeros((b, d), x.dtype))

    xr = _lerp(x, xs, params["mu_r"])
    xk = _lerp(x, xs, params["mu_k"])
    xv = _lerp(x, xs, params["mu_v"])
    xg = _lerp(x, xs, params["mu_g"])
    xw = _lerp(x, xs, params["mu_w"])

    proj = lambda inp, w: jnp.einsum(
        "btd,dhk->bthk", inp, w, preferred_element_type=matmul_out_dtype()
    )
    r = proj(xr, params["wr"])
    k = proj(xk, params["wk"])
    v = proj(xv, params["wv"])
    g = jax.nn.silu(proj(xg, params["wg"]))
    r = logical(r.astype(x.dtype), ("batch", "seq", "heads", "head_dim"))
    k = logical(k.astype(x.dtype), ("batch", "seq", "heads", "head_dim"))
    v = logical(v.astype(x.dtype), ("batch", "seq", "heads", "head_dim"))

    # data-dependent decay (the RWKV-6 signature): per channel, in (0, 1)
    lora = jnp.einsum("btd,dl->btl", xw.astype(jnp.float32),
                      params["w_lora_a"].astype(jnp.float32))
    lora = jnp.einsum("btl,ld->btd", jnp.tanh(lora), params["w_lora_b"],
                      preferred_element_type=jnp.float32)
    w_dec = jnp.exp(-jnp.exp(params["w0"].astype(jnp.float32) + lora))
    w_dec = logical(w_dec.reshape(b, t, h, hd), ("batch", "seq", "heads", "head_dim"))

    r32, k32, v32 = (a.astype(jnp.float32) for a in (r, k, v))
    u = params["u"].astype(jnp.float32)

    if decode:
        s, y = _tm_step(
            cache["s"], (r32[:, 0], k32[:, 0], v32[:, 0], w_dec[:, 0], u)
        )
        y = y[:, None]
        new_cache = {"x_prev": x[:, -1, :], "s": s}
    else:
        s0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        xs_seq = (
            r32.transpose(1, 0, 2, 3), k32.transpose(1, 0, 2, 3),
            v32.transpose(1, 0, 2, 3), w_dec.transpose(1, 0, 2, 3),
        )
        step = lambda c, el: _tm_step(c, (*el, u))
        s, ys = chunked_remat_scan(step, s0, xs_seq, chunk=min(cfg.scan_chunk, t))
        y = ys.transpose(1, 0, 2, 3)                         # (B, T, H, hd)
        new_cache = None
        if prefill:
            new_cache = {"x_prev": x[:, -1, :].astype(cfg.cache_dtype), "s": s}

    y = rms_norm(y, params["ln_x"])  # per-head group norm
    y = (y * g).astype(x.dtype)
    out = jnp.einsum("bthk,hkd->btd", y, params["wo"],
                     preferred_element_type=matmul_out_dtype()).astype(x.dtype)
    return logical(out, ("batch", "seq", "embed")), new_cache


def rwkv_channel_mix(params, x, cfg, *, cache=None, decode=False, prefill=False):
    b, t, d = x.shape
    if decode:
        xs = cache["x_prev"][:, None, :].astype(x.dtype)
    else:
        xs = _shift(x, jnp.zeros((b, d), x.dtype))
    xk = _lerp(x, xs, params["mu_k"])
    xr = _lerp(x, xs, params["mu_r"])
    r = jax.nn.sigmoid(
        jnp.einsum("btd,de->bte", xr.astype(jnp.float32),
                   params["wr"].astype(jnp.float32))
    )
    k = jnp.einsum("btd,df->btf", xk, params["wk"],
                   preferred_element_type=matmul_out_dtype())
    k = logical(k, ("batch", "seq", "ff"))
    hidden = jnp.square(jax.nn.relu(k))                      # squared ReLU
    v = jnp.einsum("btf,fd->btd", hidden.astype(x.dtype), params["wv"],
                   preferred_element_type=matmul_out_dtype())
    out = (r * v).astype(x.dtype)
    new_cache = None
    if decode or prefill:
        new_cache = {"x_prev": x[:, -1, :].astype(cfg.cache_dtype)}
    return logical(out, ("batch", "seq", "embed")), new_cache
