"""Modality frontend stubs (per assignment: [vlm]/[audio] backbones only).

The assigned internvl2 (InternViT) and hubert (conv feature encoder)
frontends are STUBS: `input_specs()` for those architectures provides
precomputed patch/frame embeddings of shape (batch, seq, d_model), and these
helpers generate deterministic synthetic embeddings with realistic statistics
for smoke tests and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["synthetic_embeddings", "synthetic_tokens", "synthetic_labels"]


def synthetic_embeddings(key, batch: int, seq: int, d_model: int,
                         dtype=jnp.bfloat16) -> jax.Array:
    """Unit-variance embeddings with a shared low-rank structure (so the
    sequence is not pure white noise — attention has something to attend to)."""
    k1, k2, k3 = jax.random.split(key, 3)
    basis = jax.random.normal(k1, (16, d_model), jnp.float32)
    coef = jax.random.normal(k2, (batch, seq, 16), jnp.float32) / 4.0
    noise = jax.random.normal(k3, (batch, seq, d_model), jnp.float32)
    return (coef @ basis + 0.5 * noise).astype(dtype)


def synthetic_tokens(key, batch: int, seq: int, vocab: int) -> jax.Array:
    # Zipf-ish marginal: realistic softmax mass distribution for CE losses
    u = jax.random.uniform(key, (batch, seq), jnp.float32, 1e-6, 1.0)
    ranks = jnp.floor(jnp.exp(u * jnp.log(float(vocab)))) - 1
    return jnp.clip(ranks.astype(jnp.int32), 0, vocab - 1)


def synthetic_labels(key, batch: int, seq: int, vocab: int) -> jax.Array:
    return synthetic_tokens(jax.random.fold_in(key, 1), batch, seq, vocab)
