"""Param schema + common layers.

Every model is described by a *schema*: a nested dict whose leaves are `P`
entries (shape, logical axes, init law).  One schema drives

  * `init_params`    — deterministic parameter initialization (traceable, so
                       `jax.eval_shape(init)` gives the dry-run param tree
                       without allocating 1T parameters),
  * `axes_tree`      — the logical-sharding tree consumed by
                       `parallel.sharding.sharding_tree`,
  * scan stacking    — `stack(schema, n)` prepends a 'stack' axis to every
                       leaf so homogeneous layer groups lower as one
                       `lax.scan` body (compile time ∝ unique layers, not
                       total layers).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import zlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical

# --- matmul output precision (beyond-paper perf knob) -----------------------
# Baseline ('f32-out'): every matmul emits f32 and is cast back — faithful
# accumulation everywhere, but backward cotangents (and therefore the TP
# all-reduces and flash-attention residuals) are f32.
# bf16-flow: matmuls emit the activation dtype (the MXU still accumulates in
# f32 internally for bf16 inputs on TPU); softmax/norm/loss math stays f32.
_MATMUL_OUT_F32 = contextvars.ContextVar("matmul_out_f32", default=True)


def matmul_out_dtype():
    """preferred_element_type for activation matmuls (None = input dtype)."""
    return jnp.float32 if _MATMUL_OUT_F32.get() else None


@contextlib.contextmanager
def precision_flow(bf16_flow: bool):
    tok = _MATMUL_OUT_F32.set(not bf16_flow)
    try:
        yield
    finally:
        _MATMUL_OUT_F32.reset(tok)

__all__ = [
    "P",
    "init_params",
    "axes_tree",
    "stack",
    "is_param",
    "rms_norm",
    "dense",
    "rope",
    "mlp_schema",
    "mlp_apply",
    "chunked_remat_scan",
]


@dataclasses.dataclass(frozen=True)
class P:
    """Schema leaf: one parameter array."""

    shape: tuple
    axes: tuple  # logical axis name (or None) per dim
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    fan_in: int | None = None  # scaled normal: std = 1/sqrt(fan_in)
    dtype: Any = None  # None -> model default

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_param(x) -> bool:
    return isinstance(x, P)


def _leaf_init(p: P, key, path: str, default_dtype) -> jax.Array:
    dtype = p.dtype or default_dtype
    sub = jax.random.fold_in(key, zlib.crc32(path.encode()))
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        # std 1/sqrt(d): tied-unembedding logits stay O(1); the lookup path
        # rescales by sqrt(d) (Gemma convention)
        std = p.shape[-1] ** -0.5
        return (std * jax.random.normal(sub, p.shape, jnp.float32)).astype(dtype)
    if p.init == "a_log":  # Mamba A init: A_n = -(n+1), stored as log
        row = jnp.log(jnp.arange(1, p.shape[-1] + 1, dtype=jnp.float32))
        return jnp.broadcast_to(row, p.shape).astype(dtype)
    if p.init == "vs_idx":  # VectorSparse indices: S evenly-spaced K-tiles
        kb = p.fan_in  # number of K-tiles in the dense matrix
        s = p.shape[-1]
        stride = max(1, kb // s)
        row = (jnp.arange(s, dtype=jnp.int32) * stride) % kb
        row = jnp.sort(row)
        return jnp.broadcast_to(row, p.shape)
    fan_in = p.fan_in or (p.shape[0] if p.shape else 1)
    std = fan_in ** -0.5
    return (std * jax.random.normal(sub, p.shape, jnp.float32)).astype(dtype)


def init_params(schema, key, default_dtype=jnp.bfloat16):
    """Deterministic init; traceable (eval_shape-safe)."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        schema, is_leaf=is_param
    )[0]
    out = {}
    for path, leaf in leaves_with_paths:
        out[path] = _leaf_init(leaf, key, jax.tree_util.keystr(path), default_dtype)
    treedef = jax.tree_util.tree_structure(schema, is_leaf=is_param)
    return jax.tree_util.tree_unflatten(
        treedef, [out[p] for p, _ in leaves_with_paths]
    )


def axes_tree(schema):
    """Schema -> tree of logical-axes tuples (leaves are tuples)."""
    return jax.tree.map(lambda p: p.axes, schema, is_leaf=is_param)


def stack(schema, n: int):
    """Prepend a scanned-layer-group dim of size n to every leaf."""
    return jax.tree.map(
        lambda p: P(
            (n, *p.shape), ("stack", *p.axes), p.init, p.fan_in, p.dtype
        ),
        schema,
        is_leaf=is_param,
    )


# ---------------------------------------------------------------------------
# layers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    """x (..., K) @ w (K, ...out) with f32 accumulation, back to x.dtype."""
    kdims = w.ndim - 1
    out = jax.lax.dot_general(
        x,
        w,
        ((tuple(range(x.ndim - 1, x.ndim)), (0,)), ((), ())),
        preferred_element_type=matmul_out_dtype(),
    )
    del kdims
    if b is not None:
        out = out + b.astype(out.dtype)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, *, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x (B, T, H, hd), positions (B, T) or (T,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# -- gated / plain MLP -------------------------------------------------------

_GATED = {"swiglu", "geglu"}


def mlp_schema(d_model: int, d_ff: int, activation: str) -> dict:
    if activation in _GATED:
        wi = P((2, d_model, d_ff), (None, "fsdp", "ff"), fan_in=d_model)
    else:
        wi = P((d_model, d_ff), ("fsdp", "ff"), fan_in=d_model)
    return {
        "wi": wi,
        "wo": P((d_ff, d_model), ("ff", "fsdp"), fan_in=d_ff),
    }


def _act(h: jax.Array, kind: str) -> jax.Array:
    if kind == "relu2":  # nemotron squared-ReLU: real dynamic sparsity
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    if kind == "relu":
        return jax.nn.relu(h)
    raise ValueError(kind)


def mlp_apply(params: dict, x: jax.Array, *, activation: str) -> jax.Array:
    if activation in _GATED:
        gate = dense(x, params["wi"][0])
        up = dense(x, params["wi"][1])
        gate = logical(gate, ("batch", "seq", "ff"))
        up = logical(up, ("batch", "seq", "ff"))
        act = jax.nn.silu if activation == "swiglu" else jax.nn.gelu
        h = act(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = dense(x, params["wi"])
        h = logical(h, ("batch", "seq", "ff"))
        h = _act(h.astype(jnp.float32), activation).astype(x.dtype)
    out = dense(h, params["wo"])
    return logical(out, ("batch", "seq", "embed"))


# -- chunked remat scan (Mamba / RWKV recurrences) ---------------------------


def chunked_remat_scan(step_fn, carry, xs, *, chunk: int):
    """lax.scan over time with per-chunk rematerialization.

    Splits the T leading axis of ``xs`` into chunks; the inner scan over each
    chunk is wrapped in jax.checkpoint, so the backward pass stores only one
    carry per chunk (T/chunk checkpoints) and recomputes inside — the memory
    posture Mamba-style recurrences need at 4k-500k sequence lengths.
    """
    t = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, t)
    while t % chunk:  # largest divisor <= requested (exact state carry)
        chunk -= 1
    nchunks = t // chunk
    xs_c = jax.tree.map(
        lambda a: a.reshape(nchunks, chunk, *a.shape[1:]), xs
    )

    @jax.checkpoint
    def chunk_body(c, xc):
        c, ys = jax.lax.scan(step_fn, c, xc)
        return c, ys

    carry, ys_c = jax.lax.scan(chunk_body, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(t, *a.shape[2:]), ys_c)
    return carry, ys
