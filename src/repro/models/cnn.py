"""VGG-16 / ResNet-stem entry points, now thin shims over `models.graph`.

The model layer lives in `repro.models.graph`: a `SparseNet` IR + one
`net_apply` walker covers VGG-16, ResNet-18 and any network a builder can
express, with a single generic `sparsify` (BN folding + vector pruning +
FC remainder strips).  This module keeps the PR-1-era entry points
(`vgg16_apply`, `sparsify_vgg16`, `resnet_stem_apply`, ...) as delegations
so existing callers and tests keep working; new code should target the
graph API directly.
"""
from __future__ import annotations

from .graph import (  # noqa: F401  (re-exported layer-level helpers)
    SparseConv,
    SparseFC,
    VGG16_LAYERS,
    apply_sparse_conv,
    apply_sparse_fc,
    build_resnet_stem,
    build_vgg16,
    net_apply,
    sparse_conv_from_dense,
    sparsify,
)

__all__ = [
    "VGG16_LAYERS", "vgg16_schema", "vgg16_apply", "sparsify_vgg16",
    "SparseConv", "sparse_conv_from_dense", "apply_sparse_conv",
    "RESNET_STEM_LAYERS", "resnet_stem_schema", "resnet_stem_apply",
    "sparsify_resnet_stem", "collect_conv_traffic", "conv_names",
]

# Layer names/geometry are size-agnostic: one net instance serves every
# image_size/num_classes at apply time (dims only matter for the schema).
_VGG16_NET = build_vgg16()
_STEM_NET = build_resnet_stem()

# (name, kh, kw, stride, cin, cout) — kept for back-compat introspection.
RESNET_STEM_LAYERS = tuple(
    (l.name, l.kh, l.kw, l.stride, l.cin, l.cout)
    for l in _STEM_NET.conv_layers()
)


def conv_names():
    """[(name, cin, cout)] for VGG-16's 13 convs."""
    return [(l.name, l.cin, l.cout) for l in _VGG16_NET.conv_layers()]


def vgg16_schema(num_classes: int = 1000, *, image_size: int = 224) -> dict:
    return build_vgg16(num_classes, image_size=image_size).schema()


def vgg16_apply(params, x, *, sparse: dict | None = None, impl: str = "jnp",
                collect=None):
    """x (N, H, W, 3) -> logits (N, classes).  See `graph.net_apply`.

    ``collect`` keeps the PR-1 contract: (name, conv input, weight) triples.
    """
    rec = [] if collect is not None else None
    out = net_apply(_VGG16_NET, params, x, sparse=sparse, impl=impl,
                    collect=rec)
    if collect is not None:
        collect.extend((n, xi, w) for n, xi, w, *_ in rec)
    return out


def sparsify_vgg16(params, density: float, *, vk: int = 32, vn: int = 128,
                   include_fc: bool = True):
    """Vector-prune VGG-16 to `density`; see `graph.sparsify`.

    Unlike PR 1, FC layers whose Cout doesn't tile (the 1000-class head)
    now run sparse via a zero-padded remainder strip.
    """
    return sparsify(_VGG16_NET, params, density, vk=vk, vn=vn,
                    include_fc=include_fc)


def resnet_stem_schema() -> dict:
    return _STEM_NET.schema()


def resnet_stem_apply(params, x, *, sparse: dict | None = None,
                      impl: str = "jnp"):
    """x (N, H, W, 3) -> (N, H/4, W/4, 128) feature map, ReLU after each conv."""
    return net_apply(_STEM_NET, params, x, sparse=sparse, impl=impl)


def sparsify_resnet_stem(params, density: float, *, vk: int = 32,
                         vn: int = 128):
    """Vector-prune the ResNet-style stem; same contract as `sparsify_vgg16`."""
    return sparsify(_STEM_NET, params, density, vk=vk, vn=vn)


def collect_conv_traffic(params, x):
    """Forward pass recording (name, conv input NHWC, weight) per conv layer."""
    rec = []
    vgg16_apply(params, x, collect=rec)
    return rec
