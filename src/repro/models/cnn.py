"""VGG-16 (the paper's evaluation model) with first-class vector sparsity.

Dense path: jax.lax conv.  Sparse path: every 3x3 conv (except the 3-channel
stem, whose 27-row K doesn't tile and whose FLOPs are negligible) and every
FC layer can run through the vector-sparse ops — `impl='jnp'` for the
structural GSPMD-friendly path, `impl='pallas'` for the TPU kernel.

`collect_conv_traffic` exposes per-layer (input activations, weights) so the
cycle-accurate accelerator model (core.accel_model) can replay the paper's
Figs 9-13 on real post-ReLU activation sparsity.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    VectorSparse,
    encode,
    prune_vectors_balanced,
    vs_matmul,
    vs_conv2d_3x3,
    dense_conv2d_3x3,
    conv_weight_to_matrix,
)
from .layers import P

__all__ = [
    "VGG16_LAYERS", "vgg16_schema", "vgg16_apply", "sparsify_vgg16",
    "collect_conv_traffic", "conv_names",
]

# channels per conv layer; 'M' = 2x2 max-pool
VGG16_LAYERS = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                512, 512, 512, "M", 512, 512, 512, "M"]

FC_DIMS = [(512 * 7 * 7, 4096), (4096, 4096)]


def conv_names():
    names, cin = [], 3
    i = 1
    for c in VGG16_LAYERS:
        if c == "M":
            continue
        names.append((f"conv{i}", cin, c))
        cin = c
        i += 1
    return names


def vgg16_schema(num_classes: int = 1000, *, image_size: int = 224) -> dict:
    s = {}
    for name, cin, cout in conv_names():
        s[name] = {
            "w": P((3, 3, cin, cout), (None, None, None, "ff"), fan_in=9 * cin),
            "b": P((cout,), ("ff",), init="zeros"),
        }
    fc_in = 512 * (image_size // 32) ** 2
    dims = [(fc_in, 4096), (4096, 4096), (4096, num_classes)]
    for j, (din, dout) in enumerate(dims, start=1):
        s[f"fc{j}"] = {
            "w": P((din, dout), ("fsdp", "ff"), fan_in=din),
            "b": P((dout,), ("ff",), init="zeros"),
        }
    return s


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def vgg16_apply(params, x, *, sparse: dict | None = None, impl: str = "jnp",
                collect=None):
    """x (N, H, W, 3) -> logits (N, classes).

    sparse: {layer_name: VectorSparse} — layers present run the paper's
    vector-sparse path (weight-side structural skip + input-side skip);
    absent layers run dense.
    """
    sparse = sparse or {}
    names = iter(conv_names())
    for c in VGG16_LAYERS:
        if c == "M":
            x = _maxpool2(x)
            continue
        name, cin, cout = next(names)
        p = params[name]
        if collect is not None:
            collect.append((name, x, p["w"]))
        if name in sparse:
            y = vs_conv2d_3x3(x, sparse[name], impl=impl)
        else:
            y = dense_conv2d_3x3(x, p["w"].astype(x.dtype))
        x = jax.nn.relu(y + p["b"].astype(y.dtype))
    n = x.shape[0]
    x = x.reshape(n, -1)
    for j in (1, 2, 3):
        p = params[f"fc{j}"]
        key = f"fc{j}"
        if key in sparse:
            x = vs_matmul(x, sparse[key], impl=impl)
        else:
            x = jnp.dot(x, p["w"].astype(x.dtype),
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = x + p["b"].astype(x.dtype)
        if j < 3:
            x = jax.nn.relu(x)
    return x


def sparsify_vgg16(params, density: float, *, vk: int = 32, vn: int = 128,
                   include_fc: bool = True):
    """Vector-prune VGG-16 to `density` (fraction of nonzero weight vectors).

    Returns (sparse dict for vgg16_apply, pruned dense params for oracles).
    The 3-channel stem conv stays dense (27-row K; negligible FLOPs), as in
    standard pruning practice.
    """
    sparse, pruned = {}, jax.tree.map(lambda a: a, params)
    for name, cin, cout in conv_names():
        if cin < vk:  # conv1: K = 9*3 = 27, not tileable
            continue
        w = np.asarray(params[name]["w"], np.float32)
        wm = w.reshape(9 * cin, cout)
        vn_l = min(vn, cout)
        wp, _ = prune_vectors_balanced(wm, density, vk, vn_l)
        sparse[name] = encode(jnp.asarray(wp, params[name]["w"].dtype), vk, vn_l)
        pruned[name]["w"] = jnp.asarray(
            wp.reshape(3, 3, cin, cout), params[name]["w"].dtype
        )
    if include_fc:
        for j in (1, 2, 3):
            w = np.asarray(params[f"fc{j}"]["w"], np.float32)
            dout = w.shape[1]
            vn_l = min(vn, dout)
            if w.shape[0] % vk or dout % vn_l:
                continue
            wp, _ = prune_vectors_balanced(w, density, vk, vn_l)
            sparse[f"fc{j}"] = encode(
                jnp.asarray(wp, params[f"fc{j}"]["w"].dtype), vk, vn_l
            )
            pruned[f"fc{j}"]["w"] = jnp.asarray(wp, params[f"fc{j}"]["w"].dtype)
    return sparse, pruned


def collect_conv_traffic(params, x):
    """Forward pass recording (name, conv input NHWC, weight) per conv layer."""
    rec = []
    vgg16_apply(params, x, collect=rec)
    return rec
