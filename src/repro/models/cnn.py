"""VGG-16 (the paper's evaluation model) with first-class vector sparsity.

Dense path: jax.lax conv.  Sparse path: *every* conv — including the
3-channel stem, whose input channels are zero-padded to a tileable K — and
every FC layer can run through the vector-sparse ops: `impl='jnp'` for the
structural GSPMD-friendly path, `impl='pallas'` for the TPU kernel.  Sparse
convs use the kernel's fused bias+ReLU epilogue, so the post-ReLU zeros the
next layer's input-side skip elides are produced in-kernel.

A sparse conv layer is described by a `SparseConv` spec (VectorSparse weights
+ geometry + input-channel padding); `sparse_conv_from_dense` builds one from
any dense (kh, kw, Cin, Cout) weight.  Besides VGG-16, a small ResNet-style
stem (7x7/s2 conv -> 1x1 projection -> 3x3/s2 downsample) exercises the
generalized kernel family end-to-end.

`collect_conv_traffic` exposes per-layer (input activations, weights) so the
cycle-accurate accelerator model (core.accel_model) can replay the paper's
Figs 9-13 on real post-ReLU activation sparsity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    VectorSparse,
    encode,
    from_mask,
    prune_vectors_balanced,
    vs_matmul,
    vs_conv2d,
    dense_conv2d,
    dense_conv2d_3x3,
    conv_weight_to_matrix,
)
from .layers import P

__all__ = [
    "VGG16_LAYERS", "vgg16_schema", "vgg16_apply", "sparsify_vgg16",
    "SparseConv", "sparse_conv_from_dense", "apply_sparse_conv",
    "RESNET_STEM_LAYERS", "resnet_stem_schema", "resnet_stem_apply",
    "sparsify_resnet_stem", "collect_conv_traffic", "conv_names",
]

# channels per conv layer; 'M' = 2x2 max-pool
VGG16_LAYERS = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                512, 512, 512, "M", 512, 512, 512, "M"]

FC_DIMS = [(512 * 7 * 7, 4096), (4096, 4096)]


@dataclasses.dataclass
class SparseConv:
    """One vector-sparse conv layer: weights + geometry.

    ``cin_pad`` zero channels are appended to the input before the conv —
    how a non-tileable Cin (e.g. the 3-channel stem) becomes a multiple of
    the K-tile length.  The padded weight rows are zero, so the math is
    unchanged; the padded input vectors are all-zero and the kernel's
    input-side skip elides them at runtime.
    """

    vs: VectorSparse
    kh: int = 3
    kw: int = 3
    stride: int = 1
    cin_pad: int = 0


def sparse_conv_from_dense(
    w,
    density: float,
    *,
    vk: int = 32,
    vn: int = 128,
    stride: int = 1,
    prune: bool = True,
    dtype=None,
):
    """Dense (kh, kw, Cin, Cout) weight -> (SparseConv, pruned dense weight).

    Handles non-tileable Cin by zero-padding channels to a multiple of a
    reduced K-tile length (min(vk, 8)); handles non-tileable Cout by
    shrinking the output strip to the largest divisor of Cout that is <= vn.
    ``prune=False`` (or density >= 1) keeps every tile — the dense network
    in the same format, the paper's single-datapath story.
    """
    w = np.asarray(w, np.float32)
    kh, kw, cin, cout = w.shape
    if cin % vk == 0:
        vk_l, cp = vk, 0
    else:
        vk_l = min(vk, 8)
        cp = -cin % vk_l
    wpad = np.pad(w, ((0, 0), (0, 0), (0, cp), (0, 0))) if cp else w
    wm = wpad.reshape(kh * kw * (cin + cp), cout)
    vn_l = min(vn, cout)
    while cout % vn_l:
        vn_l -= 1
    if prune and density < 1.0:
        wp, mask = prune_vectors_balanced(wm, density, vk_l, vn_l)
    else:
        wp = wm
        mask = np.ones((wm.shape[0] // vk_l, cout // vn_l), bool)
    dtype = dtype or jnp.float32
    vs = from_mask(jnp.asarray(wp, dtype), mask, vk_l, vn_l)
    spec = SparseConv(vs, kh=kh, kw=kw, stride=stride, cin_pad=cp)
    wp_dense = wp.reshape(kh, kw, cin + cp, cout)[:, :, :cin]
    return spec, wp_dense


def apply_sparse_conv(x, entry, *, bias=None, fuse_relu=True,
                      impl: str = "jnp"):
    """Run one conv through the vector-sparse path.

    ``entry`` is a `SparseConv` or a bare `VectorSparse` (legacy 3x3/s1).
    """
    spec = entry if isinstance(entry, SparseConv) else SparseConv(entry)
    if spec.cin_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, spec.cin_pad)))
    return vs_conv2d(
        x, spec.vs, kh=spec.kh, kw=spec.kw, stride=spec.stride, bias=bias,
        fuse_relu=fuse_relu, impl=impl,
    )


def conv_names():
    names, cin = [], 3
    i = 1
    for c in VGG16_LAYERS:
        if c == "M":
            continue
        names.append((f"conv{i}", cin, c))
        cin = c
        i += 1
    return names


def vgg16_schema(num_classes: int = 1000, *, image_size: int = 224) -> dict:
    s = {}
    for name, cin, cout in conv_names():
        s[name] = {
            "w": P((3, 3, cin, cout), (None, None, None, "ff"), fan_in=9 * cin),
            "b": P((cout,), ("ff",), init="zeros"),
        }
    fc_in = 512 * (image_size // 32) ** 2
    dims = [(fc_in, 4096), (4096, 4096), (4096, num_classes)]
    for j, (din, dout) in enumerate(dims, start=1):
        s[f"fc{j}"] = {
            "w": P((din, dout), ("fsdp", "ff"), fan_in=din),
            "b": P((dout,), ("ff",), init="zeros"),
        }
    return s


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def vgg16_apply(params, x, *, sparse: dict | None = None, impl: str = "jnp",
                collect=None):
    """x (N, H, W, 3) -> logits (N, classes).

    sparse: {layer_name: SparseConv | VectorSparse} — layers present run the
    paper's vector-sparse path (weight-side structural skip + input-side skip,
    bias+ReLU fused into the kernel epilogue); absent layers run dense.
    """
    sparse = sparse or {}
    names = iter(conv_names())
    for c in VGG16_LAYERS:
        if c == "M":
            x = _maxpool2(x)
            continue
        name, cin, cout = next(names)
        p = params[name]
        if collect is not None:
            collect.append((name, x, p["w"]))
        if name in sparse:
            x = apply_sparse_conv(x, sparse[name], bias=p["b"], impl=impl)
        else:
            y = dense_conv2d_3x3(x, p["w"].astype(x.dtype))
            x = jax.nn.relu(y + p["b"].astype(y.dtype))
    n = x.shape[0]
    x = x.reshape(n, -1)
    for j in (1, 2, 3):
        p = params[f"fc{j}"]
        key = f"fc{j}"
        if key in sparse:
            x = vs_matmul(x, sparse[key], impl=impl)
        else:
            x = jnp.dot(x, p["w"].astype(x.dtype),
                        preferred_element_type=jnp.float32).astype(x.dtype)
        x = x + p["b"].astype(x.dtype)
        if j < 3:
            x = jax.nn.relu(x)
    return x


def sparsify_vgg16(params, density: float, *, vk: int = 32, vn: int = 128,
                   include_fc: bool = True):
    """Vector-prune VGG-16 to `density` (fraction of nonzero weight vectors).

    Returns (sparse dict for vgg16_apply, pruned dense params for oracles).
    Every conv runs the sparse datapath: the 3-channel stem keeps its weights
    (27-row K, negligible FLOPs — standard pruning practice) but is encoded
    at density 1 with its input channels zero-padded to a tileable K, so even
    conv1 exercises the kernel's index system and input-side skip.
    """
    sparse, pruned = {}, jax.tree.map(lambda a: a, params)
    for name, cin, cout in conv_names():
        w = params[name]["w"]
        spec, wp = sparse_conv_from_dense(
            w, density, vk=vk, vn=vn, stride=1, prune=cin >= vk,
            dtype=w.dtype,
        )
        sparse[name] = spec
        pruned[name]["w"] = jnp.asarray(wp, w.dtype)
    if include_fc:
        for j in (1, 2, 3):
            w = np.asarray(params[f"fc{j}"]["w"], np.float32)
            dout = w.shape[1]
            vn_l = min(vn, dout)
            if w.shape[0] % vk or dout % vn_l:
                continue
            wp, _ = prune_vectors_balanced(w, density, vk, vn_l)
            sparse[f"fc{j}"] = encode(
                jnp.asarray(wp, params[f"fc{j}"]["w"].dtype), vk, vn_l
            )
            pruned[f"fc{j}"]["w"] = jnp.asarray(wp, params[f"fc{j}"]["w"].dtype)
    return sparse, pruned


# -- ResNet-style stem: the geometries VGG doesn't exercise ------------------

# (name, kh, kw, stride, cin, cout): 7x7/s2 stem, 1x1 projection, 3x3/s2
# downsample — the conv vocabulary of every ResNet-family network.
RESNET_STEM_LAYERS = (
    ("stem7x7", 7, 7, 2, 3, 64),
    ("proj1x1", 1, 1, 1, 64, 128),
    ("down3x3", 3, 3, 2, 128, 128),
)


def resnet_stem_schema() -> dict:
    s = {}
    for name, kh, kw, _, cin, cout in RESNET_STEM_LAYERS:
        s[name] = {
            "w": P((kh, kw, cin, cout), (None, None, None, "ff"),
                   fan_in=kh * kw * cin),
            "b": P((cout,), ("ff",), init="zeros"),
        }
    return s


def resnet_stem_apply(params, x, *, sparse: dict | None = None,
                      impl: str = "jnp"):
    """x (N, H, W, 3) -> (N, H/4, W/4, 128) feature map, ReLU after each conv."""
    sparse = sparse or {}
    for name, kh, kw, stride, cin, cout in RESNET_STEM_LAYERS:
        p = params[name]
        if name in sparse:
            x = apply_sparse_conv(x, sparse[name], bias=p["b"], impl=impl)
        else:
            y = dense_conv2d(x, p["w"].astype(x.dtype), stride=stride)
            x = jax.nn.relu(y + p["b"].astype(y.dtype))
    return x


def sparsify_resnet_stem(params, density: float, *, vk: int = 32,
                         vn: int = 128):
    """Vector-prune the ResNet-style stem; same contract as `sparsify_vgg16`."""
    sparse, pruned = {}, jax.tree.map(lambda a: a, params)
    for name, kh, kw, stride, cin, cout in RESNET_STEM_LAYERS:
        w = params[name]["w"]
        spec, wp = sparse_conv_from_dense(
            w, density, vk=vk, vn=vn, stride=stride, prune=cin >= vk,
            dtype=w.dtype,
        )
        sparse[name] = spec
        pruned[name]["w"] = jnp.asarray(wp, w.dtype)
    return sparse, pruned


def collect_conv_traffic(params, x):
    """Forward pass recording (name, conv input NHWC, weight) per conv layer."""
    rec = []
    vgg16_apply(params, x, collect=rec)
    return rec
