"""Network IR + graph executor: whole networks on the vector-sparse datapath.

VSCNN's claim is that *one* vector-sparse datapath serves whole networks.
This module is the model-side half of that claim: instead of a hand-written
apply function per network, a network is data — a `SparseNet` holding a flat
tuple of `LayerSpec`s — and one walker (`net_apply`) runs any of them dense
or sparse, with one generic `sparsify` that vector-prunes every conv and FC
layer (BN folded into the conv weights/bias first, so batch-norm costs
nothing at inference).

LayerSpec vocabulary
--------------------
  Conv(name, cin, cout, kh, kw, stride, bn, relu, residual, src, dst)
      kh x kw / stride / SAME conv.  ``bn=True`` gives the layer inference
      batch-norm parameters (scale/offset/mean/var) instead of a bias; at
      sparsify time BN is folded into the weights and a bias, so the sparse
      path never sees it.  ``residual`` names a saved slot whose tensor is
      added *before* the ReLU — on the sparse path this rides the kernels'
      fused epilogue (one extra VMEM read, no extra HBM round trip).
      ``src`` reads the layer input from a saved slot instead of the stream
      and ``dst`` writes the output to a slot without touching the stream —
      together they express shortcut branches (the ResNet downsample
      projection) without a general DAG.
  FC(name, din, dout, relu)      dense/sparse fully-connected (+bias, ReLU).
  Classifier(name, din, dout)    FC with relu=False — the logits head.
  Pool(kind, size, stride, padding)   'max' | 'avg' window pool or 'gap'
      (global average pool, the ResNet head).
  ResidualAdd(key, relu)         explicit unfused shortcut add (for graphs
      whose producer layer can't absorb it; builders prefer the fused
      Conv(residual=...) form).
  Save(key)                      checkpoint the stream into a named slot.
  Flatten()                      NHWC -> (N, features).

Adding a new network = writing a builder that returns a `SparseNet` (see
`build_vgg16` / `build_resnet18`); schema, forward, sparsification, traffic
collection and the accelerator cycle model all come for free from the
walker.

Sparse layer specs
------------------
`sparsify(net, params, density)` returns ``(sparse, pruned)``: a dict
mapping layer name -> `SparseConv` / `SparseFC` (balanced block-CSR weights
+ geometry + folded bias), and a pruned *dense* param tree computing the
identical function (BN folded, remainders intact) for oracle comparison.
FC layers whose Cout doesn't tile (e.g. a 1000-class head) are zero-padded
to the strip width and the padded columns are sliced off after the kernel —
the remainder strip, so every FC runs sparse.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.diagnostics import Diagnostic, VSCheckError
from repro.core import (
    VectorSparse,
    conv_cin_major,
    from_mask,
    prune_vectors_balanced,
    vs_matmul,
    vs_conv2d,
    dense_conv2d,
)
from .layers import P

__all__ = [
    "Conv", "FC", "Classifier", "Pool", "ResidualAdd", "Save", "Flatten",
    "SparseNet", "SparseConv", "SparseFC", "BatchedApply", "shard_sparse",
    "ConvTileGeometry", "FCTileGeometry", "conv_tile_geometry",
    "fc_tile_geometry", "strip_steps",
    "sparse_conv_from_dense", "apply_sparse_conv", "apply_sparse_fc",
    "weight_scales", "quantize_weights_int8", "quantize_activations_int8",
    "net_schema", "net_apply", "sparsify", "collect_conv_traffic",
    "build_vgg16", "build_resnet18", "build_resnet34", "build_resnet50",
    "build_mobilenet_v1", "build_resnet_stem",
    "VGG16_LAYERS", "RESNET18_STAGES", "RESNET34_STAGES", "RESNET50_STAGES",
    "MOBILENET_V1_PLAN", "BN_EPS",
]

BN_EPS = 1e-5


# --------------------------------------------------------------------------
# Layer specs (the IR)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Conv:
    """kh x kw / stride / dilation / SAME (grouped) conv (+BN) (+residual)
    (+ReLU).

    ``groups`` shards the channels: the weight is XLA's grouped HWIO
    (kh, kw, cin/groups, cout) and output block g reads input group g only.
    ``groups == cin`` is a depthwise conv (multiplier 1, cout == cin) —
    routed through the per-channel tap kernels on the sparse path.
    ``dilation`` spaces the taps (effective extent (k-1)*dilation + 1).
    """

    name: str
    cin: int
    cout: int
    kh: int = 3
    kw: int = 3
    stride: int = 1
    groups: int = 1
    dilation: int = 1
    bn: bool = False
    relu: bool = True
    residual: str | None = None  # slot added before ReLU (fused epilogue)
    src: str | None = None       # read input from slot, not the stream
    dst: str | None = None       # write output to slot, leave stream as-is
    # a depthwise conv with channel multiplier > 1 (groups == cin,
    # cout == m*cin) has no per-channel tap encoding; it can only run the
    # general grouped kernels with vk == 1 — correct but MXU-wasteful.
    # `sparsify`/vscheck refuse it (rule VSC109) unless explicitly allowed.
    allow_fallback: bool = False


@dataclasses.dataclass(frozen=True)
class FC:
    """Fully-connected layer: x @ W + b (+ReLU)."""

    name: str
    din: int
    dout: int
    relu: bool = True


def Classifier(name: str, din: int, dout: int) -> FC:
    """The logits head: an FC without the ReLU."""
    return FC(name, din, dout, relu=False)


@dataclasses.dataclass(frozen=True)
class Pool:
    """'max' | 'avg' window pool, or 'gap' (global average pool)."""

    kind: str = "max"
    size: int = 2
    stride: int | None = None  # None -> size
    padding: str = "VALID"


@dataclasses.dataclass(frozen=True)
class ResidualAdd:
    """Explicit (unfused) shortcut add: x = [relu](x + saved[key])."""

    key: str
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class Save:
    """Checkpoint the stream into a named slot."""

    key: str


@dataclasses.dataclass(frozen=True)
class Flatten:
    """NHWC -> (N, features)."""


@dataclasses.dataclass(frozen=True)
class SparseNet:
    """A network as data: a name and a flat tuple of LayerSpecs."""

    name: str
    layers: tuple

    def schema(self) -> dict:
        return net_schema(self)

    def apply(self, params: dict, x: jax.Array, *,
              sparse: dict | None = None, impl: str = "auto",
              collect: list | None = None) -> jax.Array:
        return net_apply(self, params, x, sparse=sparse, impl=impl,
                         collect=collect)

    def sparsify(self, params: dict, density: float, *, vk: int = 32,
                 vn: int = 128, include_fc: bool = True,
                 dtype: Any = None) -> tuple[dict, dict]:
        return sparsify(self, params, density, vk=vk, vn=vn,
                        include_fc=include_fc, dtype=dtype)

    def batched_apply(self, params: dict, *,
                      sparse: dict | None = None, impl: str = "auto",
                      key: tuple = (), cache: dict | None = None
                      ) -> "BatchedApply":
        """Serving entry point: jit-compiled apply with a compile cache
        keyed on (net, weight-set key, impl, batch bucket)."""
        return BatchedApply(self, params, sparse=sparse, impl=impl, key=key,
                            cache=cache if cache is not None else {})

    def conv_layers(self) -> list[Conv]:
        return [l for l in self.layers if isinstance(l, Conv)]

    def fc_layers(self) -> list[FC]:
        return [l for l in self.layers if isinstance(l, FC)]


# --------------------------------------------------------------------------
# Sparse layer entries (what `sparsify` produces, what the walker consumes)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SparseConv:
    """One vector-sparse conv layer: weights + geometry.

    ``cin_pad`` zero channels are appended to the input before the conv —
    how a non-tileable Cin (e.g. the 3-channel stem) becomes a multiple of
    the K-tile length.  The padded weight rows are zero, so the math is
    unchanged; the padded input vectors are all-zero and the kernel's
    input-side skip elides them at runtime.  ``groups``/``dilation`` carry
    the grouped/dilated geometry (``groups == cin`` is depthwise: the
    encoded matrix is the (kh*kw, C) tap matrix with vk == 1).  ``bias``
    (when set) overrides the param-tree bias — this is where the BN-folded
    bias lives.  ``scale`` (set iff the weights are int8-quantized) holds
    the per-cout symmetric dequant scales; the walker quantizes the layer
    input per-tensor and hands the combined scale to the kernel epilogue.
    """

    vs: VectorSparse
    kh: int = 3
    kw: int = 3
    stride: int = 1
    groups: int = 1
    dilation: int = 1
    cin_pad: int = 0
    bias: jax.Array | None = None
    scale: jax.Array | None = None


@dataclasses.dataclass
class SparseFC:
    """One vector-sparse FC layer.

    ``dout`` is the true output width; the encoded matrix may be zero-padded
    to a strip multiple (the remainder strip for non-tileable heads, e.g.
    1000 classes) — the walker slices the pad columns off after the kernel.
    ``bias`` (when set) overrides the param-tree bias.  ``scale`` (set iff
    the weights are int8-quantized) holds per-cout dequant scales padded to
    the encoded width (pad columns get scale 1.0).
    """

    vs: VectorSparse
    dout: int | None = None
    bias: jax.Array | None = None
    scale: jax.Array | None = None


# --------------------------------------------------------------------------
# Tile geometry (the single source for sparsify AND the static analyzer)
# --------------------------------------------------------------------------

def _largest_divisor(n: int, cap: int) -> int:
    """The largest divisor of ``n`` that is <= ``cap``."""
    d = min(cap, n)
    while n % d:
        d -= 1
    return d


@dataclasses.dataclass(frozen=True)
class ConvTileGeometry:
    """How one conv layer's weights encode into the balanced block-CSR.

    ``vk``/``vn`` are the *encoded* tile dims (possibly shrunk from the
    requested ones), ``cin_pad`` the zero channels appended to the input,
    ``kb`` the stored-tile-id bound per strip (idx values < kb) and ``nb``
    the output-strip count.  `sparse_conv_from_dense` follows exactly this
    geometry; `repro.analysis` re-derives kernel plans from it.
    """

    depthwise: bool
    vk: int
    vn: int
    cin_pad: int
    kb: int
    nb: int


@dataclasses.dataclass(frozen=True)
class FCTileGeometry:
    """FC encoding geometry: ``pad`` zero output columns (the remainder
    strip for non-tileable heads), ``kb`` K-tiles, ``nb`` output strips."""

    vk: int
    vn: int
    pad: int
    kb: int
    nb: int


def conv_tile_geometry(
    kh: int, kw: int, cin_g: int, cout: int, *, vk: int = 32, vn: int = 128,
    groups: int = 1, allow_fallback: bool = False, path: str = "conv",
) -> ConvTileGeometry:
    """Tile geometry of a (kh, kw, cin/groups, cout) conv weight.

    Depthwise (groups == cin, multiplier 1): the (kh*kw, C) per-channel tap
    matrix, vk == 1, strips over channel tiles.  Grouped: K-tiles stay
    inside the group (vk shrinks to a divisor of cin/groups, no padding),
    strips to a divisor of cout/groups.  Ungrouped: channel-pad to a
    multiple of a reduced K-tile when cin doesn't tile.

    A depthwise conv with channel multiplier > 1 (groups > 1, cin_g == 1,
    cout != groups) would fall back to the general grouped kernels with
    vk == 1 — correct but MXU-wasteful (vk-1 dead lanes every issue).
    Raises `VSCheckError` (rule VSC109) unless ``allow_fallback``.
    """
    depthwise = groups > 1 and cin_g == 1 and cout == groups
    if depthwise:
        vn_l = _largest_divisor(cout, vn)
        return ConvTileGeometry(
            depthwise=True, vk=1, vn=vn_l, cin_pad=0, kb=kh * kw,
            nb=cout // vn_l)
    if groups > 1 and cin_g == 1 and not allow_fallback:
        raise VSCheckError(Diagnostic(
            "VSC109", "error", path,
            f"depthwise channel-multiplier {cout // groups} > 1 "
            f"(groups={groups}, cout={cout}) has no per-channel tap "
            f"encoding and would run grouped kernels with vk == 1",
            hint="set Conv(allow_fallback=True) to accept the vk==1 "
                 "grouped fallback, or split into depthwise + 1x1",
        ))
    if groups > 1:
        # K-tiles stay inside the group; no channel padding (shrink vk to a
        # divisor of Cin/groups instead — padding would interleave zeros
        # into every group)
        vk_l = _largest_divisor(cin_g, vk)
        cp = 0
        vn_l = _largest_divisor(cout // groups, vn)
    else:
        if cin_g % vk == 0:
            vk_l, cp = vk, 0
        else:
            vk_l = min(vk, 8)
            cp = -cin_g % vk_l
        vn_l = _largest_divisor(cout, vn)
    return ConvTileGeometry(
        depthwise=False, vk=vk_l, vn=vn_l, cin_pad=cp,
        kb=kh * kw * (cin_g + cp) // vk_l, nb=cout // vn_l)


def fc_tile_geometry(din: int, dout: int, *, vk: int = 32, vn: int = 128
                     ) -> FCTileGeometry | None:
    """FC encoding geometry, or None when the layer stays dense (fan-in not
    a vk multiple — rule VSC116)."""
    if din % vk:
        return None
    vn_l = min(vn, dout)
    pad = -dout % vn_l
    return FCTileGeometry(vk=vk, vn=vn_l, pad=pad, kb=din // vk,
                          nb=(dout + pad) // vn_l)


def strip_steps(kb: int, density: float, *, prune: bool = True) -> int:
    """Stored tiles per strip after balanced pruning — the S grid axis.
    Mirrors `core.pruning.prune_vectors_balanced`'s per-strip quota."""
    if not prune or density >= 1.0:
        return kb
    return max(1, int(round(kb * density)))


# --------------------------------------------------------------------------
# INT8 quantization (compound sparsity x precision)
# --------------------------------------------------------------------------

def _wants_int8(dtype: Any) -> bool:
    """True iff ``dtype`` names int8 (string or dtype-like)."""
    if dtype is None:
        return False
    try:
        return jnp.dtype(dtype) == jnp.dtype(jnp.int8)
    except TypeError:
        return False


def _pow2_up(s: np.ndarray) -> np.ndarray:
    """Round positive scales UP to the next power of two (exactly
    representable in f32).  Po2 scales make every dequant multiply exact —
    scaling an f32 by 2^k only shifts the exponent — so the fused epilogue
    ``acc*s + bias`` is immune to FMA contraction (fma == two-step, bit for
    bit, under any compiler fusion) and matches the shift-based requant of
    fixed-point accelerator datapaths."""
    s64 = np.asarray(s, np.float64)
    p = np.exp2(np.ceil(np.log2(s64)))
    p = np.where(p < s64, p * 2.0, p)  # guard log2 rounding at po2 inputs
    return p.astype(np.float32)


def weight_scales(wm: np.ndarray) -> np.ndarray:
    """Per-cout symmetric int8 scales of a (K, Cout) weight matrix.

    ``s[c] = max|wm[:, c]| / 127`` rounded up to the next power of two (see
    `_pow2_up` — exact dequant multiplies, deterministic epilogue); an
    all-zero column (e.g. a remainder-strip pad column) gets scale 1.0 so
    dequant stays a no-op there.
    """
    s = np.abs(np.asarray(wm, np.float32)).max(axis=0) / 127.0
    return _pow2_up(np.where(s > 0, s, 1.0))


def quantize_weights_int8(wm: np.ndarray,
                          s: np.ndarray) -> np.ndarray:
    """Symmetric round-to-nearest int8 encode of ``wm`` at per-cout scales
    ``s`` (decode is ``wq.astype(f32) * s``, within s/2 of the source)."""
    q = np.rint(np.asarray(wm, np.float32) / s)
    return np.clip(q, -127, 127).astype(np.int8)


def quantize_activations_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 activation quantization (traceable).

    Returns ``(xq, sx)`` with ``xq = clip(round(x / sx), -127, 127)`` and
    ``sx = max|x| / 127`` rounded up to the next power of two (1.0 when the
    tensor is all-zero, so the encode never divides by zero).  Po2 scales
    keep the combined dequant scale ``sx * s_w`` a power of two, so the
    kernels' epilogue multiply is exact (see `_pow2_up`).
    """
    sx = (jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0).astype(jnp.float32)
    sx = jnp.where(sx > 0, sx, jnp.float32(1.0))
    p = jnp.exp2(jnp.ceil(jnp.log2(sx))).astype(jnp.float32)
    sx = jnp.where(p < sx, p * jnp.float32(2.0), p)
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) / sx), -127, 127)
    return xq.astype(jnp.int8), sx


def sparse_conv_from_dense(
    w: np.ndarray | jax.Array,
    density: float,
    *,
    vk: int = 32,
    vn: int = 128,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    prune: bool = True,
    dtype: Any = None,
    allow_fallback: bool = False,
    path: str = "conv",
) -> tuple[SparseConv, np.ndarray]:
    """Dense (kh, kw, Cin/groups, Cout) weight -> (SparseConv, pruned dense
    weight).

    Handles non-tileable Cin by zero-padding channels to a multiple of a
    reduced K-tile length (min(vk, 8)); handles non-tileable Cout by
    shrinking the output strip to the largest divisor of Cout that is <= vn.
    ``prune=False`` (or density >= 1) keeps every tile — the dense network
    in the same format, the paper's single-datapath story.

    Grouped convs (1 < groups < Cin) keep the K axis within the group:
    the matrix is (kh*kw*Cin/groups, Cout), the K-tile length shrinks to a
    divisor of Cin/groups, and the output strip to a divisor of Cout/groups
    so no strip straddles a group boundary — pruning quotas are therefore
    *per group* automatically (each strip scores only its group's weights).
    Depthwise (groups == Cin, multiplier 1) encodes the (kh*kw, Cout) tap
    matrix with vk == 1 and strips over channel tiles — the vectors are
    per-tap channel runs, pruned the same balanced way.
    """
    w = np.asarray(w, np.float32)
    kh, kw, cin_g, cout = w.shape
    int8 = _wants_int8(dtype)
    dtype = jnp.float32 if int8 else (dtype or jnp.float32)
    g = conv_tile_geometry(kh, kw, cin_g, cout, vk=vk, vn=vn, groups=groups,
                           allow_fallback=allow_fallback, path=path)
    vk_l, vn_l, cp = g.vk, g.vn, g.cin_pad
    if g.depthwise:
        # per-channel tap matrix: one row per tap, strips = channel tiles
        wm = w.reshape(kh * kw, cout)
        if prune and density < 1.0:
            wp, mask = prune_vectors_balanced(wm, density, vk_l, vn_l)
        else:
            wp = wm
            mask = np.ones((kh * kw, cout // vn_l), bool)
        scale: np.ndarray | None = None
        if int8:
            # quantize the PRUNED weights: scales see only surviving taps
            scale = weight_scales(wp)
            wq = quantize_weights_int8(wp, scale)
            wp = wq.astype(np.float32) * scale  # dequantized dense oracle
            vs = from_mask(jnp.asarray(wq), mask, vk_l, vn_l)
        else:
            vs = from_mask(jnp.asarray(wp, dtype), mask, vk_l, vn_l)
        spec = SparseConv(vs, kh=kh, kw=kw, stride=stride, groups=groups,
                          dilation=dilation,
                          scale=None if scale is None else jnp.asarray(scale))
        return spec, wp.reshape(kh, kw, 1, cout)
    wpad = np.pad(w, ((0, 0), (0, 0), (0, cp), (0, 0))) if cp else w
    wm = wpad.reshape(kh * kw * (cin_g + cp), cout)
    if prune and density < 1.0:
        wp, mask = prune_vectors_balanced(wm, density, vk_l, vn_l)
    else:
        wp = wm
        mask = np.ones((wm.shape[0] // vk_l, cout // vn_l), bool)
    scale = None
    if int8:
        scale = weight_scales(wp)
        wq = quantize_weights_int8(wp, scale)
        wp = wq.astype(np.float32) * scale  # dequantized dense oracle
        vs = from_mask(jnp.asarray(wq), mask, vk_l, vn_l)
    else:
        vs = from_mask(jnp.asarray(wp, dtype), mask, vk_l, vn_l)
    if kh * kw > 1:
        # cin-major issue order: the halo kernel's input block then revisits
        # (no re-DMA) across consecutive taps of one cin tile — the layout
        # the halo HBM-traffic model assumes.  Order-agnostic everywhere
        # else (the kernels decode each tile id independently).  For a
        # grouped conv the tile ids are group-relative, so the per-group
        # tile count is what orders them.
        vs = conv_cin_major(vs, (cin_g + cp) // vk_l)
    spec = SparseConv(vs, kh=kh, kw=kw, stride=stride, groups=groups,
                      dilation=dilation, cin_pad=cp,
                      scale=None if scale is None else jnp.asarray(scale))
    wp_dense = wp.reshape(kh, kw, cin_g + cp, cout)[:, :, :cin_g]
    return spec, wp_dense


def apply_sparse_conv(x: jax.Array, entry: SparseConv | VectorSparse, *,
                      bias: jax.Array | None = None, fuse_relu: bool = True,
                      residual: jax.Array | None = None,
                      impl: str = "auto") -> jax.Array:
    """Run one conv through the vector-sparse path.

    ``entry`` is a `SparseConv` or a bare `VectorSparse` (legacy 3x3/s1).
    ``residual`` is the output-shaped shortcut added before the ReLU in the
    kernels' fused epilogue.

    An int8 entry (``spec.scale`` set) quantizes the layer input per-tensor
    first; the kernel accumulates int8 x int8 in int32 and the combined
    scale ``sx * s_w`` dequantizes in the fused epilogue (before bias).
    """
    spec = entry if isinstance(entry, SparseConv) else SparseConv(entry)
    scale = spec.scale
    if scale is not None:
        x, sx = quantize_activations_int8(x)
        scale = sx * scale
    if spec.cin_pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, spec.cin_pad)))
    return vs_conv2d(
        x, spec.vs, kh=spec.kh, kw=spec.kw, stride=spec.stride,
        groups=spec.groups, dilation=spec.dilation, bias=bias,
        residual=residual, fuse_relu=fuse_relu, impl=impl, scale=scale,
    )


def apply_sparse_fc(x: jax.Array, entry: SparseFC | VectorSparse, *,
                    bias: jax.Array | None = None, fuse_relu: bool = False,
                    residual: jax.Array | None = None,
                    impl: str = "auto") -> jax.Array:
    """Run one FC layer through the vector-sparse path.

    ``entry`` is a `SparseFC` or a bare `VectorSparse`.  The encoded matrix
    may carry remainder-strip zero columns; bias/residual are padded to the
    encoded width and the pad columns sliced off after the kernel.
    """
    spec = entry if isinstance(entry, SparseFC) else SparseFC(entry)
    n_enc = spec.vs.shape[1]
    dout = spec.dout or n_enc
    if bias is not None and bias.shape[-1] != n_enc:
        bias = jnp.pad(bias, (0, n_enc - bias.shape[-1]))
    if residual is not None and residual.shape[-1] != n_enc:
        residual = jnp.pad(
            residual,
            [(0, 0)] * (residual.ndim - 1) + [(0, n_enc - residual.shape[-1])],
        )
    scale = spec.scale
    if scale is not None:
        x, sx = quantize_activations_int8(x)
        scale = sx * scale
    y = vs_matmul(x, spec.vs, bias=bias, residual=residual,
                  fuse_relu=fuse_relu, impl=impl, scale=scale)
    return y[..., :dout] if dout != n_enc else y


# --------------------------------------------------------------------------
# Schema
# --------------------------------------------------------------------------

def net_schema(net: SparseNet) -> dict:
    """P-schema for `models.layers.init_params` from the layer specs.

    BN convs get inference batch-norm parameters (scale/offset/mean/var,
    identity-initialized) instead of a bias; `sparsify` folds them away.
    """
    s = {}
    for l in net.layers:
        if isinstance(l, Conv):
            cin_g = l.cin // l.groups
            e = {
                "w": P((l.kh, l.kw, cin_g, l.cout), (None, None, None, "ff"),
                       fan_in=l.kh * l.kw * cin_g),
            }
            if l.bn:
                e["scale"] = P((l.cout,), ("ff",), init="ones")
                e["offset"] = P((l.cout,), ("ff",), init="zeros")
                e["mean"] = P((l.cout,), ("ff",), init="zeros")
                e["var"] = P((l.cout,), ("ff",), init="ones")
            else:
                e["b"] = P((l.cout,), ("ff",), init="zeros")
            s[l.name] = e
        elif isinstance(l, FC):
            s[l.name] = {
                "w": P((l.din, l.dout), ("fsdp", "ff"), fan_in=l.din),
                "b": P((l.dout,), ("ff",), init="zeros"),
            }
    return s


# --------------------------------------------------------------------------
# Executor
# --------------------------------------------------------------------------

def _bn_fold(p: dict) -> tuple[np.ndarray, np.ndarray]:
    """Inference BN -> (per-cout scale g, bias b): y*g + b == BN(y)."""
    g = (np.asarray(p["scale"], np.float32)
         / np.sqrt(np.asarray(p["var"], np.float32) + BN_EPS))
    b = (np.asarray(p["offset"], np.float32)
         - np.asarray(p["mean"], np.float32) * g)
    return g, b


def _dense_conv(l: Conv, p: dict, x: jax.Array,
                res: jax.Array | None) -> jax.Array:
    """Dense oracle for one Conv layer (BN applied explicitly if present)."""
    w = p["w"].astype(jnp.float32)
    y = dense_conv2d(x.astype(jnp.float32), w, stride=l.stride,
                     groups=l.groups, dilation=l.dilation)
    if "scale" in p:
        g = p["scale"].astype(jnp.float32) * jax.lax.rsqrt(
            p["var"].astype(jnp.float32) + BN_EPS)
        y = (y - p["mean"].astype(jnp.float32)) * g \
            + p["offset"].astype(jnp.float32)
    elif "b" in p:
        y = y + p["b"].astype(jnp.float32)
    if res is not None:
        y = y + res.astype(jnp.float32)
    if l.relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def _pool(l: Pool, x: jax.Array) -> jax.Array:
    if l.kind == "gap":
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    stride = l.stride or l.size
    window = (1, l.size, l.size, 1)
    strides = (1, stride, stride, 1)
    if l.kind == "max":
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, window, strides, l.padding)
    if l.kind == "avg":
        s = jax.lax.reduce_window(
            x, 0.0, jax.lax.add, window, strides, l.padding)
        return s / (l.size * l.size)
    raise ValueError(l.kind)


def net_apply(net: SparseNet, params: dict, x: jax.Array, *,
              sparse: dict | None = None, impl: str = "auto",
              collect: list | None = None,
              collect_fc: list | None = None) -> jax.Array:
    """Walk the graph: x (N, H, W, C) -> logits / features.

    sparse: {layer_name: SparseConv | SparseFC | VectorSparse} — layers
    present run the paper's vector-sparse path (weight-side structural skip
    + input-side skip, bias + residual + ReLU fused into the kernel
    epilogue); absent layers run dense.  ``collect`` (a list) records
    (name, layer input NHWC, weight, stride) per conv for the accelerator
    cycle model; ``collect_fc`` (a separate list, so the conv record's
    shape stays stable for its consumers) records (name, layer input,
    weight) per FC layer — the calibration harness measures FC layers on
    their real flattened activations through this hook.
    """
    sparse = sparse or {}
    saved: dict[str, jax.Array] = {}
    for l in net.layers:
        if isinstance(l, Save):
            saved[l.key] = x
        elif isinstance(l, Conv):
            xin = saved[l.src] if l.src else x
            res = saved[l.residual] if l.residual else None
            p = params[l.name]
            if collect is not None:
                collect.append((l.name, xin, p["w"], l.stride, l.groups,
                                l.dilation))
            if l.name in sparse:
                entry = sparse[l.name]
                spec = (entry if isinstance(entry, SparseConv)
                        else SparseConv(entry))
                bias = spec.bias if spec.bias is not None else p.get("b")
                if l.bn and spec.bias is None:
                    # a bare entry can't carry the folded scale/bias — running
                    # it would silently drop batch-norm; demand `sparsify`'s
                    # folded SparseConv instead of computing wrong activations
                    raise ValueError(
                        f"sparse entry for BN conv {l.name!r} has no folded "
                        f"bias; build it with graph.sparsify (which folds BN "
                        f"into the weights and bias) rather than encoding "
                        f"raw weights")
                y = apply_sparse_conv(xin, spec, bias=bias,
                                      fuse_relu=l.relu, residual=res,
                                      impl=impl)
            else:
                y = _dense_conv(l, p, xin, res)
            if l.dst:
                saved[l.dst] = y
            else:
                x = y
        elif isinstance(l, ResidualAdd):
            y = x.astype(jnp.float32) + saved[l.key].astype(jnp.float32)
            if l.relu:
                y = jnp.maximum(y, 0.0)
            x = y.astype(x.dtype)
        elif isinstance(l, Pool):
            x = _pool(l, x)
        elif isinstance(l, Flatten):
            x = x.reshape(x.shape[0], -1)
        elif isinstance(l, FC):
            p = params[l.name]
            if collect_fc is not None:
                collect_fc.append((l.name, x, p["w"]))
            if l.name in sparse:
                entry = sparse[l.name]
                spec = (entry if isinstance(entry, SparseFC)
                        else SparseFC(entry))
                bias = spec.bias if spec.bias is not None else p["b"]
                x = apply_sparse_fc(x, spec, bias=bias,
                                    fuse_relu=l.relu, impl=impl)
            else:
                y = jnp.dot(x, p["w"].astype(x.dtype),
                            preferred_element_type=jnp.float32
                            ).astype(x.dtype)
                y = y + p["b"].astype(y.dtype)
                x = jax.nn.relu(y) if l.relu else y
        else:
            raise TypeError(f"unknown layer spec: {l!r}")
    return x


def input_refusal(image: Any, *, max_size: int | None = None,
                  channels: int | None = None) -> str | None:
    """Admission-time validation of one serving input image.

    Returns a machine-readable refusal reason, or None when the image is
    servable.  Serving backends call this *before* a request can join a
    batch, so a malformed input becomes a structured refusal instead of a
    mid-wave shape/dtype error that takes the whole batch down.  The
    checks mirror what `net_apply` actually requires of one (H, W, C)
    image: a rank-3 float array of finite values, within the net's fixed
    input size (``max_size``) when it has one.
    """
    if not isinstance(image, np.ndarray):
        return f"not_an_array:{type(image).__name__}"
    if image.ndim != 3:
        return f"bad_rank:{image.ndim}"
    if not np.issubdtype(image.dtype, np.floating):
        return f"bad_dtype:{image.dtype}"
    if image.size == 0:
        return "empty_image"
    h, w, c = image.shape
    if channels is not None and c != channels:
        return f"bad_channels:{c}"
    if max_size is not None and max(h, w) > max_size:
        return f"oversize:{h}x{w}>{max_size}"
    if not bool(np.isfinite(image).all()):
        return "non_finite_input"
    return None


def output_finite(emission: Any) -> bool:
    """Output-validation guard predicate: True iff every value in one
    emission (a logits row) is finite.  The fleet scheduler uses this to
    quarantine a replica whose wave produced NaN/inf instead of delivering
    the garbage (`launch.scheduler.FleetScheduler`)."""
    arr = np.asarray(emission)
    if not np.issubdtype(arr.dtype, np.floating):
        return True
    return bool(np.isfinite(arr).all())


@dataclasses.dataclass
class BatchedApply:
    """Batched serving entry point: `net_apply` behind a jit-compile cache.

    One compiled executable per (net, weight set, impl, input-shape
    bucket): the serving scheduler pads request batches onto a small set of
    shape buckets, so steady-state traffic never recompiles — the cache hit
    is the hot path.  The key includes the identity of the closed-over
    params/sparse trees (two nets sharing a name never alias each other's
    weights); ``key`` adds a readable variant tag (e.g. ``(density,)``) so
    one *shared* ``cache`` dict can hold several sparsified nets side by
    side.  By default each instance gets its own cache.

    Sharded compile path: when ``mesh`` (+ ``rules``) is set, tracing and
    execution run inside ``sharding.use_mesh(mesh, rules)`` and the cache
    key includes the mesh, so a weight tree whose leaves carry
    `NamedSharding`s (see `shard_sparse`) compiles to a GSPMD-partitioned
    executable — e.g. an FC head cout-sharded over the ``model`` axis runs
    each device's strip slice locally and all-gathers the logits in the
    epilogue.
    """

    net: SparseNet
    params: dict
    sparse: dict | None = None
    impl: str = "auto"
    key: tuple = ()
    cache: dict = dataclasses.field(default_factory=dict)
    mesh: object = None
    rules: object = None

    def cache_key(self, shape: tuple) -> tuple:
        # id() is stable and unique here: self (and every cached closure)
        # keeps the weight trees alive
        return (self.net.name, id(self.params), id(self.sparse), self.key,
                self.impl, id(self.mesh), tuple(shape))

    def __call__(self, x: jax.Array) -> jax.Array:
        k = self.cache_key(x.shape)
        fn = self.cache.get(k)
        if fn is None:
            net, params = self.net, self.params
            sparse, impl = self.sparse, self.impl
            jitted = jax.jit(lambda xx: net_apply(net, params, xx,
                                                  sparse=sparse, impl=impl))
            if self.mesh is not None:
                from repro.parallel import sharding as shd
                mesh, rules = self.mesh, self.rules
                def fn(xx: jax.Array, _j: Any = jitted) -> jax.Array:
                    with shd.use_mesh(mesh, rules or shd.SERVE_RULES):
                        return _j(xx)
            else:
                fn = jitted
            self.cache[k] = fn
        return fn(x)

    @property
    def compiles(self) -> int:
        """Distinct compiled entries in the cache (all variants)."""
        return len(self.cache)


def shard_sparse(sparse: dict, *, ctx: Any = None) -> dict:
    """Device-place a `sparsify` tree under the active mesh context.

    FC heads shard over their output strips: `VectorSparse.vals`
    (NB, S, vk, vn) and ``idx`` (NB, S) split on the leading NB axis — the
    cout strip axis, the paper's per-strip PE-block parallelism — via the
    ``ff`` logical rule (``model`` mesh axis by default); the bias stays
    replicated (it is sliced per-strip inside the epilogue by GSPMD).
    Conv entries follow the ``conv`` rule, replicated by default (serving
    shards the cheap wide FC heads; convs scale across replicas instead) —
    map ``conv`` to a mesh axis to cout-shard them the same way.  Strip
    counts that don't divide the mesh axis demote to replicated
    (`sharding.spec_for`), so odd heads degrade gracefully.
    """
    from repro.parallel import sharding as shd

    ctx = ctx or shd.current()
    assert ctx is not None, "shard_sparse requires an active use_mesh()"

    def place(arr: jax.Array, axes: tuple) -> jax.Array:
        s = shd.named_sharding(axes, shape=arr.shape, ctx=ctx)
        return jax.device_put(arr, s)

    def place_vs(vs: VectorSparse, axis: str) -> VectorSparse:
        return VectorSparse(
            vals=place(vs.vals, (axis, None, None, None)),
            idx=place(vs.idx, (axis, None)),
            shape=vs.shape)

    out = {}
    for name, entry in sparse.items():
        if isinstance(entry, SparseFC):
            out[name] = dataclasses.replace(
                entry, vs=place_vs(entry.vs, "ff"),
                bias=None if entry.bias is None
                else place(entry.bias, (None,)),
                scale=None if entry.scale is None
                else place(entry.scale, (None,)))
        elif isinstance(entry, SparseConv):
            out[name] = dataclasses.replace(
                entry, vs=place_vs(entry.vs, "conv"),
                bias=None if entry.bias is None
                else place(entry.bias, (None,)),
                scale=None if entry.scale is None
                else place(entry.scale, (None,)))
        else:  # bare VectorSparse entry (FC-style)
            out[name] = place_vs(entry, "ff")
    return out


def collect_conv_traffic(net: SparseNet, params: dict,
                         x: jax.Array) -> list:
    """Forward pass recording (name, conv input NHWC, weight, stride,
    groups, dilation) per conv layer — the input of
    `core.accel_model.network_cycle_reports` / `network_traffic_reports`."""
    rec: list = []
    net_apply(net, params, x, collect=rec)
    return rec


# --------------------------------------------------------------------------
# Generic sparsification (BN folding + vector pruning + remainder strips)
# --------------------------------------------------------------------------

def sparsify(net: SparseNet, params: dict, density: float, *,
             vk: int = 32, vn: int = 128,
             include_fc: bool = True, dtype: Any = None) -> tuple[dict, dict]:
    """Vector-prune a whole network to `density` (fraction of kept vectors).

    Returns ``(sparse, pruned)``:

    * ``sparse`` — {layer name: SparseConv | SparseFC} for `net_apply`.
      Every conv runs the sparse datapath — BN is folded into the weights
      and a bias *before* pruning (so pruning scores see the true inference
      magnitudes), small-Cin stems keep their weights (density 1, standard
      pruning practice) with input channels zero-padded to a tileable K,
      and non-tileable FC heads get a zero-padded remainder strip.
    * ``pruned`` — a dense param tree computing the identical function
      (folded weights + bias; BN entries replaced by a plain bias), the
      oracle for parity tests.

    ``dtype=jnp.int8`` (or ``"int8"``) quantizes every encoded weight
    per-cout symmetric from the pruned folded-BN weights and stores the
    dequant scales on the specs; the pruned dense tree then holds the
    DEQUANTIZED f32 weights, so the oracle and cycle model see exactly the
    values the int8 kernels reconstruct.
    """
    int8 = _wants_int8(dtype)
    sparse: dict = {}
    pruned = {name: dict(entry) for name, entry in params.items()}
    for l in net.layers:
        if isinstance(l, Conv):
            p = params[l.name]
            wdt = p["w"].dtype
            w = np.asarray(p["w"], np.float32)
            cin_g = w.shape[2]  # channels per group (== cin when ungrouped)
            if l.bn:
                g, b = _bn_fold(p)
                w = w * g  # scale per cout (last axis)
            elif "b" in p:
                b = np.asarray(p["b"], np.float32)
            else:
                b = np.zeros((w.shape[3],), np.float32)
            # grouped/depthwise layers always prune (their quota is per
            # strip, i.e. per group); ungrouped small-Cin stems stay dense
            prune = True if l.groups > 1 else cin_g >= vk
            spec, wp = sparse_conv_from_dense(
                w, density, vk=vk, vn=vn, stride=l.stride, groups=l.groups,
                dilation=l.dilation, prune=prune,
                dtype=jnp.int8 if int8 else wdt,
                allow_fallback=l.allow_fallback, path=f"{net.name}/{l.name}",
            )
            spec.bias = jnp.asarray(b, wdt)
            sparse[l.name] = spec
            pruned[l.name] = {"w": jnp.asarray(wp, wdt),
                              "b": jnp.asarray(b, wdt)}
        elif isinstance(l, FC) and include_fc:
            p = params[l.name]
            wdt = p["w"].dtype
            w = np.asarray(p["w"], np.float32)
            din, dout = w.shape
            fg = fc_tile_geometry(din, dout, vk=vk, vn=vn)
            if fg is None:
                continue  # non-tileable K: stays dense (none of our nets)
            wpad = np.pad(w, ((0, 0), (0, fg.pad))) if fg.pad else w
            wp, mask = prune_vectors_balanced(wpad, density, fg.vk, fg.vn)
            if int8:
                s_w = weight_scales(wp)  # pad columns (all-zero) -> 1.0
                wq = quantize_weights_int8(wp, s_w)
                wp = wq.astype(np.float32) * s_w
                vs = from_mask(jnp.asarray(wq), mask, fg.vk, fg.vn)
                sparse[l.name] = SparseFC(vs, dout=dout, bias=p["b"],
                                          scale=jnp.asarray(s_w))
            else:
                vs = from_mask(jnp.asarray(wp, wdt), mask, fg.vk, fg.vn)
                sparse[l.name] = SparseFC(vs, dout=dout, bias=p["b"])
            pruned[l.name] = {"w": jnp.asarray(wp[:, :dout], wdt),
                              "b": p["b"]}
    return sparse, pruned


# --------------------------------------------------------------------------
# Builders
# --------------------------------------------------------------------------

# channels per conv layer; 'M' = 2x2 max-pool
VGG16_LAYERS = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
                512, 512, 512, "M", 512, 512, 512, "M"]


def build_vgg16(num_classes: int = 1000, *, image_size: int = 224) -> SparseNet:
    """The paper's evaluation model: 13 convs + 3 FC, classic VGG (no BN)."""
    layers: list = []
    cin, i = 3, 1
    for c in VGG16_LAYERS:
        if c == "M":
            layers.append(Pool("max", 2))
        else:
            layers.append(Conv(f"conv{i}", cin, c))
            cin, i = c, i + 1
    fc_in = 512 * (image_size // 32) ** 2
    layers += [
        Flatten(),
        FC("fc1", fc_in, 4096),
        FC("fc2", 4096, 4096),
        Classifier("fc3", 4096, num_classes),
    ]
    return SparseNet("vgg16", tuple(layers))


# (channels, blocks) per stage — the ResNet-18 basic-block plan.
RESNET18_STAGES = ((64, 2), (128, 2), (256, 2), (512, 2))


def _basic_block(layers: list, prefix: str, cin: int, cout: int,
                 stride: int) -> None:
    """Append one ResNet basic block: conv-BN-ReLU -> conv-BN -> (+id) ReLU.

    The shortcut is the saved block input, or a stride-matched 1x1
    BN-projection of it when the shape changes; either way it is added in
    conv2's fused epilogue (Conv.residual), before the final ReLU.
    """
    inkey = f"{prefix}_in"
    layers.append(Save(inkey))
    idkey = inkey
    if stride != 1 or cin != cout:
        idkey = f"{prefix}_id"
        layers.append(Conv(f"{prefix}_down", cin, cout, 1, 1, stride,
                           bn=True, relu=False, src=inkey, dst=idkey))
    layers.append(Conv(f"{prefix}_conv1", cin, cout, 3, 3, stride, bn=True))
    layers.append(Conv(f"{prefix}_conv2", cout, cout, 3, 3, 1, bn=True,
                       residual=idkey))


def build_resnet18(num_classes: int = 1000, *,
                   image_size: int = 224) -> SparseNet:
    """ResNet-18: 7x7/s2 BN stem, 3x3/s2 max-pool, 4 stages x 2 basic
    blocks (stride-2 1x1 BN-projection downsamples), GAP, 512-d classifier.

    Every conv geometry here — 7x7/s2, 3x3/s1, 3x3/s2, 1x1/s2 — maps onto
    the generalized vector-sparse kernel family; residual adds ride the
    fused epilogue and BN folds away at sparsify time, so the whole network
    runs end-to-end on the paper's single sparse datapath.
    """
    del image_size  # geometry is size-agnostic; kept for config symmetry
    layers: list = [
        Conv("conv1", 3, 64, 7, 7, 2, bn=True),
        Pool("max", 3, stride=2, padding="SAME"),
    ]
    cin = 64
    for si, (c, blocks) in enumerate(RESNET18_STAGES):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            _basic_block(layers, f"layer{si + 1}_{bi}", cin, c, stride)
            cin = c
    layers += [Pool("gap"), Flatten(), Classifier("fc", 512, num_classes)]
    return SparseNet("resnet18", tuple(layers))


# (channels, blocks) per stage — the ResNet-34 basic-block plan: the
# ResNet-50 stage depths on ResNet-18's block type.
RESNET34_STAGES = ((64, 3), (128, 4), (256, 6), (512, 3))


def build_resnet34(num_classes: int = 1000, *,
                   image_size: int = 224) -> SparseNet:
    """ResNet-34: ResNet-18's basic-block architecture at the (3, 4, 6, 3)
    stage depths — no new conv geometry at all (7x7/s2 stem, 3x3 bodies,
    1x1/s2 BN-projection downsamples), so the builder is the whole cost of
    the network; schema, sparsification, serving and the cycle/traffic
    models come from the shared walker."""
    del image_size  # geometry is size-agnostic; kept for config symmetry
    layers: list = [
        Conv("conv1", 3, 64, 7, 7, 2, bn=True),
        Pool("max", 3, stride=2, padding="SAME"),
    ]
    cin = 64
    for si, (c, blocks) in enumerate(RESNET34_STAGES):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            _basic_block(layers, f"layer{si + 1}_{bi}", cin, c, stride)
            cin = c
    layers += [Pool("gap"), Flatten(), Classifier("fc", 512, num_classes)]
    return SparseNet("resnet34", tuple(layers))


# (bottleneck width, blocks) per stage — ResNet-50's plan; output channels
# are 4x the bottleneck width (the expansion).
RESNET50_STAGES = ((64, 3), (128, 4), (256, 6), (512, 3))


def _bottleneck_block(layers: list, prefix: str, cin: int, c: int,
                      stride: int) -> None:
    """Append one ResNet bottleneck: 1x1 reduce -> 3x3 (stride) -> 1x1
    expand (4x), BN throughout, shortcut added in the expand conv's fused
    epilogue before the final ReLU (a 1x1/stride BN-projection when the
    shape changes)."""
    cout = 4 * c
    inkey = f"{prefix}_in"
    layers.append(Save(inkey))
    idkey = inkey
    if stride != 1 or cin != cout:
        idkey = f"{prefix}_id"
        layers.append(Conv(f"{prefix}_down", cin, cout, 1, 1, stride,
                           bn=True, relu=False, src=inkey, dst=idkey))
    layers.append(Conv(f"{prefix}_conv1", cin, c, 1, 1, 1, bn=True))
    layers.append(Conv(f"{prefix}_conv2", c, c, 3, 3, stride, bn=True))
    layers.append(Conv(f"{prefix}_conv3", c, cout, 1, 1, 1, bn=True,
                       residual=idkey))


def build_resnet50(num_classes: int = 1000, *,
                   image_size: int = 224) -> SparseNet:
    """ResNet-50: the 7x7/s2 BN stem and max-pool of ResNet-18, then 4
    stages of (3, 4, 6, 3) bottleneck blocks (1x1 -> 3x3 -> 1x1 with 4x
    expansion, stride-2 1x1 BN-projection downsamples), GAP, 2048-d
    classifier — the credibility bar SCNN (Parashar et al.) and the
    structured-sparse FPGA accelerator (Zhu et al.) both benchmark.

    Every geometry — 7x7/s2, 1x1/s1, 1x1/s2, 3x3/s1, 3x3/s2 — was already
    expressible in the kernel family; this builder just cashes the IR in.
    """
    del image_size  # geometry is size-agnostic; kept for config symmetry
    layers: list = [
        Conv("conv1", 3, 64, 7, 7, 2, bn=True),
        Pool("max", 3, stride=2, padding="SAME"),
    ]
    cin = 64
    for si, (c, blocks) in enumerate(RESNET50_STAGES):
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            _bottleneck_block(layers, f"layer{si + 1}_{bi}", cin, c, stride)
            cin = 4 * c
    layers += [Pool("gap"), Flatten(), Classifier("fc", 2048, num_classes)]
    return SparseNet("resnet50", tuple(layers))


# (pointwise output channels, depthwise stride) per separable block — the
# standard MobileNetV1 plan after the 3x3/s2/32 stem.
MOBILENET_V1_PLAN = ((64, 1), (128, 2), (128, 1), (256, 2), (256, 1),
                     (512, 2), (512, 1), (512, 1), (512, 1), (512, 1),
                     (512, 1), (1024, 2), (1024, 1))


def build_mobilenet_v1(num_classes: int = 1000, *,
                       image_size: int = 224) -> SparseNet:
    """MobileNetV1: 3x3/s2 stem then 13 depthwise-separable blocks
    (3x3 depthwise BN-ReLU -> 1x1 pointwise BN-ReLU), GAP, 1024-d
    classifier.

    The depthwise stages are ``Conv(groups=cin)`` — the degenerate grouped
    conv routed through the per-channel tap kernels — and every pointwise
    conv is the 1x1 sparse matmul, so the whole efficient-CNN vocabulary
    runs on the one vector-sparse datapath.
    """
    del image_size  # geometry is size-agnostic; kept for config symmetry
    layers: list = [Conv("conv0", 3, 32, 3, 3, 2, bn=True)]
    cin = 32
    for i, (c, s) in enumerate(MOBILENET_V1_PLAN, 1):
        layers.append(Conv(f"dw{i}", cin, cin, 3, 3, s, bn=True,
                           groups=cin))
        layers.append(Conv(f"pw{i}", cin, c, 1, 1, 1, bn=True))
        cin = c
    layers += [Pool("gap"), Flatten(), Classifier("fc", 1024, num_classes)]
    return SparseNet("mobilenet_v1", tuple(layers))


def build_resnet_stem() -> SparseNet:
    """The PR-1 ResNet-style stem (7x7/s2 -> 1x1 -> 3x3/s2), kept as the
    minimal geometry-coverage network (no BN, plain biases)."""
    return SparseNet("resnet_stem", (
        Conv("stem7x7", 3, 64, 7, 7, 2),
        Conv("proj1x1", 64, 128, 1, 1, 1),
        Conv("down3x3", 128, 128, 3, 3, 2),
    ))
