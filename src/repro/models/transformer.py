"""Pattern-based LM stack: segments of scanned homogeneous super-blocks.

An architecture is a list of `Segment`s; each segment repeats a tuple of
`LayerSpec`s (mixer x ffn x window).  All repeats of a segment share one
scanned body (params stacked on a leading 'stack' axis), so compile time and
HLO size scale with the number of *unique* layer kinds, not total depth —
gemma3's 48 layers lower as one scan over 8 groups of [5 local + 1 global],
jamba's 32 as 4 groups of its 8-layer block.

Modes:
  train   — full-sequence forward, remat per super-block, returns hidden
  prefill — forward + populated decode caches (KV seq-sharded, SSM states)
  decode  — one token through cached states at position ``pos``
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical
from repro.parallel.losses import chunked_cross_entropy
from .layers import P, precision_flow, rms_norm, mlp_schema, mlp_apply, stack
from .attention import attn_schema, attention_apply, init_kv_cache, CACHE_AXES
from .mamba import (
    mamba_schema, mamba_apply, init_mamba_cache, MAMBA_CACHE_AXES,
)
from .rwkv import (
    rwkv_tm_schema, rwkv_cm_schema, rwkv_time_mix, rwkv_channel_mix,
    init_rwkv_tm_cache, init_rwkv_cm_cache,
    RWKV_TM_CACHE_AXES, RWKV_CM_CACHE_AXES,
)
from .moe import moe_schema, moe_apply

__all__ = [
    "lm_schema", "init_cache", "cache_axes", "forward_hidden",
    "loss_fn", "prefill", "decode_step", "lm_apply",
]


def _gated(cfg) -> bool:
    return cfg.activation in ("swiglu", "geglu")


def _act_fn(cfg):
    return jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


def layer_schema(spec, cfg) -> dict:
    d = cfg.d_model
    s = {}
    if spec.mixer != "none":
        s["ln1"] = P((d,), (None,), init="zeros")
        if spec.mixer == "attn":
            s["mix"] = attn_schema(cfg)
        elif spec.mixer == "mamba":
            s["mix"] = mamba_schema(cfg)
        elif spec.mixer == "rwkv_tm":
            s["mix"] = rwkv_tm_schema(cfg)
        else:
            raise ValueError(spec.mixer)
    if spec.ffn != "none":
        s["ln2"] = P((d,), (None,), init="zeros")
        if spec.ffn == "mlp":
            if cfg.use_sparse_ffn and cfg.sparsity is not None:
                from .sparse_lm import sparse_mlp_schema
                s["ffn"] = sparse_mlp_schema(cfg, cfg.sparsity)
            else:
                s["ffn"] = mlp_schema(d, cfg.d_ff, cfg.activation)
        elif spec.ffn == "moe":
            s["ffn"] = moe_schema(d, cfg.moe, gated=_gated(cfg), tp_hint=cfg.tp_hint)
            if cfg.moe.n_shared:
                s["ffn_shared"] = mlp_schema(
                    d, cfg.moe.d_ff * cfg.moe.n_shared, cfg.activation
                )
        elif spec.ffn == "rwkv_cm":
            s["ffn"] = rwkv_cm_schema(cfg)
        else:
            raise ValueError(spec.ffn)
    return s


def lm_schema(cfg) -> dict:
    d, vp = cfg.d_model, cfg.padded_vocab
    s = {"final_norm": P((d,), (None,), init="zeros")}
    if cfg.embed_inputs:
        s["embed"] = P((vp, d), ("vocab", "fsdp"), init="embed")
    if not (cfg.tie_embeddings and cfg.embed_inputs):
        s["out_head"] = P((d, vp), ("fsdp", "vocab"), fan_in=d)
    s["segments"] = [
        stack({f"l{i}": layer_schema(sp, cfg) for i, sp in enumerate(seg.layers)},
              seg.repeat)
        for seg in cfg.segments
    ]
    return s


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

_CACHE_AXES_BY_MIXER = {
    "attn": CACHE_AXES,
    "mamba": MAMBA_CACHE_AXES,
    "rwkv_tm": RWKV_TM_CACHE_AXES,
}


def _slot_cache(spec, cfg, batch, capacity, dtype):
    slot = {}
    if spec.mixer == "attn":
        cap = min(capacity, spec.window) if spec.window else capacity
        slot["mix"] = init_kv_cache(cfg, batch, cap, dtype)
    elif spec.mixer == "mamba":
        slot["mix"] = init_mamba_cache(cfg, batch, dtype)
    elif spec.mixer == "rwkv_tm":
        slot["mix"] = init_rwkv_tm_cache(cfg, batch, dtype)
    if spec.ffn == "rwkv_cm":
        slot["ffn"] = init_rwkv_cm_cache(cfg, batch, dtype)
    return slot


def init_cache(cfg, batch: int, capacity: int, dtype=None):
    """Decode caches: one stacked tree per segment (leading dim = repeat)."""
    dtype = dtype or cfg.cache_dtype
    caches = []
    for seg in cfg.segments:
        group = {
            f"l{i}": _slot_cache(sp, cfg, batch, capacity, dtype)
            for i, sp in enumerate(seg.layers)
        }
        caches.append(
            jax.tree.map(
                lambda a: jnp.zeros((seg.repeat, *a.shape), a.dtype), group
            )
        )
    return caches


def cache_axes(cfg):
    """Logical-axes tree matching init_cache's structure."""
    out = []
    for seg in cfg.segments:
        group = {}
        for i, sp in enumerate(seg.layers):
            slot = {}
            if sp.mixer in _CACHE_AXES_BY_MIXER:
                slot["mix"] = {
                    k: ("stack", *v)
                    for k, v in _CACHE_AXES_BY_MIXER[sp.mixer].items()
                }
            if sp.ffn == "rwkv_cm":
                slot["ffn"] = {
                    k: ("stack", *v) for k, v in RWKV_CM_CACHE_AXES.items()
                }
            group[f"l{i}"] = slot
        out.append(group)
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _residual_axes(cfg, mode):
    if cfg.seq_shard_residual and mode != "decode":
        return ("batch", "seq_sp", "embed")
    return ("batch", "seq", "embed")


def apply_layer(p, h, spec, cfg, *, mode, cache=None, pos=None, capacity=None):
    """One (mixer, ffn) residual layer. Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    decode = mode == "decode"
    prefill = mode == "prefill"
    cache = cache or {}
    if cfg.seq_shard_residual:  # Megatron-SP stream (knob; see §Perf)
        h = logical(h, _residual_axes(cfg, mode))

    if spec.mixer != "none":
        inp = rms_norm(h, p["ln1"])
        if spec.mixer == "attn":
            cap = None
            if prefill:
                cap = min(capacity, spec.window) if spec.window else capacity
            out, nc = attention_apply(
                p["mix"], inp, cfg, window=spec.window,
                cache=cache.get("mix"), pos=pos, decode=decode,
                cache_capacity=cap,
            )
        elif spec.mixer == "mamba":
            out, nc = mamba_apply(
                p["mix"], inp, cfg, cache=cache.get("mix"),
                decode=decode, prefill=prefill,
            )
        else:  # rwkv_tm
            out, nc = rwkv_time_mix(
                p["mix"], inp, cfg, cache=cache.get("mix"),
                decode=decode, prefill=prefill,
            )
        h = h + out
        if nc is not None:
            new_cache["mix"] = nc

    if spec.ffn != "none":
        inp = rms_norm(h, p["ln2"])
        if spec.ffn == "mlp":
            if cfg.use_sparse_ffn and cfg.sparsity is not None:
                from .sparse_lm import sparse_mlp_apply
                out = sparse_mlp_apply(p["ffn"], inp, cfg)
            else:
                out = mlp_apply(p["ffn"], inp, activation=cfg.activation)
        elif spec.ffn == "moe":
            out, aux = moe_apply(
                p["ffn"], inp, cfg.moe, gated=_gated(cfg),
                activation_fn=_act_fn(cfg), dispatch=cfg.moe_dispatch,
            )
            if cfg.moe.n_shared:
                out = out + mlp_apply(
                    p["ffn_shared"], inp, activation=cfg.activation
                )
        else:  # rwkv_cm
            out, nc = rwkv_channel_mix(
                p["ffn"], inp, cfg, cache=cache.get("ffn"),
                decode=decode, prefill=prefill,
            )
            if nc is not None:
                new_cache["ffn"] = nc
        h = h + out
    return h, new_cache, aux


def _segment_scan(p_seg, h, seg, cfg, *, mode, caches=None, pos=None,
                  capacity=None):
    """Scan one segment's stacked params (and caches) over its repeats."""

    def body(h, xs):
        p_group, c_group = xs if mode == "decode" else (xs, None)
        ncs = {}
        aux = jnp.zeros((), jnp.float32)
        for i, sp in enumerate(seg.layers):
            key = f"l{i}"
            h, nc, a = apply_layer(
                p_group[key], h, sp, cfg, mode=mode,
                cache=(c_group or {}).get(key) if c_group is not None else None,
                pos=pos, capacity=capacity,
            )
            ncs[key] = nc
            aux = aux + a
        return h, (ncs, aux)

    if mode == "train" and cfg.remat:
        body = jax.checkpoint(body)
    xs = (p_seg, caches) if mode == "decode" else p_seg
    h, (new_caches, auxs) = jax.lax.scan(body, h, xs)
    return h, new_caches, jnp.sum(auxs)


def forward_hidden(params, x, cfg, *, mode="train", caches=None, pos=None,
                   capacity=None):
    """x (B, T, D) embeddings -> (h, new_caches, aux)."""
    h = logical(x, _residual_axes(cfg, mode))
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for si, seg in enumerate(cfg.segments):
        h, ncs, aux = _segment_scan(
            params["segments"][si], h, seg, cfg, mode=mode,
            caches=caches[si] if caches is not None else None,
            pos=pos, capacity=capacity,
        )
        new_caches.append(ncs)
        aux_total = aux_total + aux
    h = rms_norm(h, params["final_norm"])
    return h, new_caches, aux_total


# ---------------------------------------------------------------------------
# token embedding / logits / losses / serve steps
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg):
    h = jnp.take(params["embed"], tokens, axis=0)
    h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)  # see layers 'embed' init
    return logical(h, ("batch", "seq", "embed"))


def unembed_matrix(params, cfg):
    if cfg.tie_embeddings and cfg.embed_inputs:
        return params["embed"].T
    return params["out_head"]


def _inputs_to_hidden(params, batch, cfg):
    if cfg.embed_inputs:
        return embed_tokens(params, batch["tokens"], cfg)
    return logical(batch["embeds"].astype(cfg.dtype), ("batch", "seq", "embed"))


def loss_fn(params, batch, cfg):
    """Token-level CE (vocab-sharded, chunked) + MoE aux. Returns (loss, metrics)."""
    with precision_flow(cfg.bf16_flow):
        return _loss_fn_inner(params, batch, cfg)


def _loss_fn_inner(params, batch, cfg):
    x = _inputs_to_hidden(params, batch, cfg)
    h, _, aux = forward_hidden(params, x, cfg, mode="train")
    # CE chunks over T: gather the (bf16) residuals if sequence-sharded
    h = logical(h, ("batch", "seq", "embed"))
    w_out = unembed_matrix(params, cfg)
    ce = chunked_cross_entropy(
        h, batch["labels"], w_out, real_vocab=cfg.vocab, chunk=cfg.ce_chunk,
        z_weight=cfg.z_loss,
    )
    loss = ce
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


def lm_apply(params, batch, cfg):
    """Plain forward to last-position logits (smoke tests / examples)."""
    with precision_flow(cfg.bf16_flow):
        return _lm_apply_inner(params, batch, cfg)


def _lm_apply_inner(params, batch, cfg):
    x = _inputs_to_hidden(params, batch, cfg)
    h, _, _ = forward_hidden(params, x, cfg, mode="train")
    logits = jnp.einsum(
        "btd,dv->btv", h, unembed_matrix(params, cfg),
        preferred_element_type=jnp.float32,
    )
    return logits


def prefill(params, batch, cfg, *, capacity: int, logit_pos=None):
    """Full-context forward; returns (logits (B, Vp), caches).

    Logits are read at the last position by default; ``logit_pos`` (a
    traced scalar) reads them at a chosen position instead — the hook that
    lets a backfill prefill right-pad its context up to a bucketed length
    (bounding the compile-shape family) while still emitting the token
    after the true context end.  The right-pad junk beyond ``logit_pos``
    is causally masked for the logits and its K/V rows are overwritten by
    subsequent decode steps before any query can attend them.
    """
    with precision_flow(cfg.bf16_flow):
        return _prefill_inner(params, batch, cfg, capacity=capacity,
                              logit_pos=logit_pos)


def _prefill_inner(params, batch, cfg, *, capacity: int, logit_pos=None):
    x = _inputs_to_hidden(params, batch, cfg)
    h, caches, _ = forward_hidden(params, x, cfg, mode="prefill",
                                  capacity=capacity)
    if logit_pos is None:
        h_last = h[:, -1:, :]
    else:
        h_last = jax.lax.dynamic_slice_in_dim(h, logit_pos, 1, axis=1)
    logits = jnp.einsum(
        "btd,dv->btv", h_last, unembed_matrix(params, cfg),
        preferred_element_type=jnp.float32,
    )[:, 0]
    return logical(logits, ("batch", "vocab")), caches


def decode_step(params, caches, tokens, pos, cfg):
    """One decode step. tokens (B, 1) int32, pos scalar int32.

    Returns (logits (B, Vp), updated caches).
    """
    with precision_flow(cfg.bf16_flow):
        return _decode_step_inner(params, caches, tokens, pos, cfg)


def _decode_step_inner(params, caches, tokens, pos, cfg):
    x = embed_tokens(params, tokens, cfg) if cfg.embed_inputs else tokens
    h, new_caches, _ = forward_hidden(params, x, cfg, mode="decode",
                                      caches=caches, pos=pos)
    logits = jnp.einsum(
        "btd,dv->btv", h, unembed_matrix(params, cfg),
        preferred_element_type=jnp.float32,
    )[:, 0]
    return logical(logits, ("batch", "vocab")), new_caches
