"""Vector-sparse FFN for the LM serving path — the paper's technique applied
beyond CNNs (DESIGN.md §4 'beyond paper').

Weights are stored in the VectorSparse balanced block-CSR (only nonzero
(vk, vn) vectors exist; FLOPs and weight bytes scale with density exactly as
the paper's SRAM/cycle accounting does).  TP layout under shard_map:

  wi  (D, F):  output strips (F) sharded over the model axis; K = D is
               replicated, so index gathers are local.
  wo  (F, D):  K = F is model-sharded, so the CSR is *shard-local*: each
               model shard stores a balanced CSR over its own F/tp K-range
               (leading tp dim on the vals/idx params).  Partial outputs
               merge in the same psum a dense TP FFN needs.

The structural jnp path lowers everywhere (GSPMD-friendly); on TPU the
`repro.kernels.vsmm` Pallas kernel additionally skips dynamically-zero
activation vectors (the paper's input-side skip — real for squared-ReLU /
ReLU activations).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PS

from repro.parallel import sharding as shd
from .layers import P

__all__ = ["sparse_mlp_schema", "sparse_mlp_apply"]


def _s_of(kb: int, density: float) -> int:
    return max(1, round(kb * density))


def _fit(pref: int, dim: int) -> int:
    """Largest divisor of dim <= pref (tile-size guard for small configs)."""
    v = min(pref, dim)
    while dim % v:
        v -= 1
    return v


def sparse_mlp_schema(cfg, sp) -> dict:
    """Schema for a vector-sparse (gated or plain) FFN block."""
    d, f = cfg.d_model, cfg.d_ff
    tp = cfg.tp_hint
    f_loc = f // tp
    gated = cfg.activation in ("swiglu", "geglu")
    vk, vn = _fit(sp.vk, d), _fit(sp.vn, f_loc)
    nb_i, kb_i = f // vn, d // vk
    s_i = _s_of(kb_i, sp.density)
    vk_o, vn_o = _fit(sp.vk, f_loc), _fit(sp.vn, d)
    nb_o, kb_o = d // vn_o, f_loc // vk_o
    s_o = _s_of(kb_o, sp.density)
    lead = (2,) if gated else ()
    return {
        "wi_vals": P((*lead, nb_i, s_i, vk, vn),
                     (*(None,) * len(lead), "ff", None, None, None),
                     fan_in=d),
        "wi_idx": P((*lead, nb_i, s_i),
                    (*(None,) * len(lead), "ff", None),
                    init="vs_idx", fan_in=kb_i, dtype=jnp.int32),
        "wo_vals": P((tp, nb_o, s_o, vk_o, vn_o),
                     ("ff", None, None, None, None), fan_in=f),
        "wo_idx": P((tp, nb_o, s_o), ("ff", None, None),
                    init="vs_idx", fan_in=kb_o, dtype=jnp.int32),
    }


def _vs_mm(x2, vals, idx):
    """x2 (M, KB, vk) x CSR vals (NB, S, vk, vn), idx (NB, S) -> (M, NB*vn).

    FLOPs = S/KB * dense — the paper's weight-vector skip, structurally.
    """
    nb, s, vk, vn = vals.shape

    def step(acc, sv):
        idx_s, w_s = sv  # (NB,), (NB, vk, vn)
        xg = jnp.take(x2, idx_s, axis=1)  # (M, NB, vk)
        acc = acc + jnp.einsum("mjk,jkn->mjn", xg, w_s,
                               preferred_element_type=jnp.float32)
        return acc, None

    acc0 = jnp.zeros((x2.shape[0], nb, vn), jnp.float32)
    acc, _ = jax.lax.scan(
        step, acc0, (jnp.swapaxes(idx, 0, 1),
                     jnp.swapaxes(vals, 0, 1)))
    return acc.reshape(x2.shape[0], nb * vn)


def _act(h, kind):
    if kind in ("swiglu",):
        return jax.nn.silu(h)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if kind == "relu2":
        r = jax.nn.relu(h)
        return r * r
    return jax.nn.relu(h)


def _body(x, wi_vals, wi_idx, wo_vals, wo_idx, *, cfg, model_axis):
    """Per-shard sparse FFN. x (B, T, D); wo_* carry a leading local-shard
    dim of size 1 under shard_map (tp when unmapped)."""
    b, t, d = x.shape
    gated = cfg.activation in ("swiglu", "geglu")
    vk = wi_vals.shape[-2]
    x2 = x.reshape(b * t, d // vk, vk)
    if gated:
        gate = _vs_mm(x2, wi_vals[0], wi_idx[0])
        up = _vs_mm(x2, wi_vals[1], wi_idx[1])
        h = (_act(gate, cfg.activation) * up).astype(x.dtype)
    else:
        h = _act(_vs_mm(x2, wi_vals, wi_idx), cfg.activation).astype(x.dtype)
    # wo: shard-local CSR over this shard's F-slice
    wo_v, wo_i = wo_vals[0], wo_idx[0]
    vko = wo_v.shape[-2]
    h2 = h.reshape(b * t, h.shape[-1] // vko, vko)
    y = _vs_mm(h2, wo_v, wo_i).astype(x.dtype)
    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y.reshape(b, t, d)


def sparse_mlp_apply(params, x, cfg) -> jax.Array:
    ctx = shd.current()
    if ctx is None:
        # single-device: concatenate the shard-local wo CSRs sequentially
        tp = params["wo_vals"].shape[0]
        gated = params["wi_vals"].ndim == 5
        b, t, d = x.shape
        vk = params["wi_vals"].shape[-2]
        x2 = x.reshape(b * t, d // vk, vk)
        if gated:
            gate = _vs_mm(x2, params["wi_vals"][0], params["wi_idx"][0])
            up = _vs_mm(x2, params["wi_vals"][1], params["wi_idx"][1])
            h = (_act(gate, cfg.activation) * up).astype(x.dtype)
        else:
            h = _act(_vs_mm(x2, params["wi_vals"], params["wi_idx"]),
                     cfg.activation).astype(x.dtype)
        f_loc = h.shape[-1] // tp
        vko = params["wo_vals"].shape[-2]
        y = 0.0
        for r in range(tp):
            h_r = h[:, r * f_loc:(r + 1) * f_loc]
            h2 = h_r.reshape(b * t, f_loc // vko, vko)
            y = y + _vs_mm(h2, params["wo_vals"][r], params["wo_idx"][r])
        return y.reshape(b, t, d).astype(x.dtype)

    mesh, rules = ctx.mesh, ctx.rules
    model_axis = rules.get("ff")
    model_axis = model_axis if model_axis in mesh.shape else None
    batch_phys = rules.get("batch")
    batch_phys = tuple(p for p in (batch_phys if isinstance(batch_phys, tuple)
                                   else (batch_phys,)) if p in mesh.shape) or None
    if batch_phys:
        import math
        dp = math.prod(mesh.shape[p] for p in batch_phys)
        if x.shape[0] % dp:
            batch_phys = None

    def spec(axes, shape):
        return shd.spec_for(axes, mesh=mesh, rules=rules, shape=shape)

    gated = params["wi_vals"].ndim == 5
    lead = (None,) if gated else ()
    in_specs = (
        PS(batch_phys, None, None),
        spec((*lead, "ff", None, None, None), params["wi_vals"].shape),
        spec((*lead, "ff", None), params["wi_idx"].shape),
        spec(("ff", None, None, None, None), params["wo_vals"].shape),
        spec(("ff", None, None), params["wo_idx"].shape),
    )
    y = shard_map(
        lambda *a: _body(*a, cfg=cfg, model_axis=model_axis),
        mesh=mesh, in_specs=in_specs,
        out_specs=PS(batch_phys, None, None), check_rep=False,
    )(x, params["wi_vals"], params["wi_idx"], params["wo_vals"],
      params["wo_idx"])
    return y
