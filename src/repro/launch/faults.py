"""Deterministic fault injection for the serving fleet.

The fault-tolerance contract of `launch.scheduler.FleetScheduler` (replica
quarantine + drain, request re-placement, deadlines, NaN guards) is only
trustworthy if the failure paths can be *exercised* — and only debuggable
if a failing chaos run can be *replayed*.  Both come from the same design
rule the scheduler already follows: no wall-clock reads.  A `FaultPlan` is
a pure function of its seed, indexed by ``(replica, wave)`` where ``wave``
is the replica's own monotone dispatch counter, so the same plan against
the same request queue injects byte-identical failures on every run.

`ChaosBackend` wraps any scheduler backend (see the protocol in
`launch.scheduler`) and fires the planned faults around the real
dispatch/collect calls:

  ``die_dispatch``   the replica raises `ReplicaDead` when dispatching the
                     wave and stays dead (permanent hardware loss);
  ``die_collect``    dispatch succeeds, the replica dies before its results
                     can be collected (in-flight work lost);
  ``transient``      one retryable `TransientFault` at dispatch (driver
                     hiccup; the replica survives);
  ``start_fail``     `CompileFault` when admitting a run (a bucket whose
                     executable cannot be built);
  ``nan``            the wave computes but every emission is corrupted to
                     non-finite values (silent numerical fault — caught by
                     the scheduler's output guard, never delivered);
  ``stall``          the wave produces nothing for ``ticks`` scheduler
                     ticks (slow replica; other replicas keep retiring and
                     may steal its queue).

Fault exceptions form a typed hierarchy under `ReplicaFault` — the
scheduler catches exactly `FAULT_TYPES`, never bare ``except`` (enforced
by vscheck rule VSC304), so an injected fault can't be silently swallowed
by an overbroad handler.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Sequence

import numpy as np

__all__ = [
    "ReplicaFault", "ReplicaDead", "TransientFault", "CompileFault",
    "NonFiniteOutput", "FAULT_TYPES", "Fault", "FaultPlan", "ChaosBackend",
]


class ReplicaFault(Exception):
    """Base of every injectable (and scheduler-handled) replica failure."""

    transient = False


class ReplicaDead(ReplicaFault):
    """Permanent replica loss: quarantine, drain, never dispatch again."""


class TransientFault(ReplicaFault):
    """One-shot retryable failure: the replica survives (suspect)."""

    transient = True


class CompileFault(ReplicaFault):
    """A run could not be admitted (e.g. a bucket's executable fails to
    build on this replica)."""


class NonFiniteOutput(ReplicaFault):
    """A wave produced non-finite outputs; raised by the scheduler's
    output-validation guard, never by the backend math itself."""


# what the fleet scheduler catches around backend calls — typed, so a real
# programming error (TypeError, ValueError, ...) still fails fast
FAULT_TYPES: tuple[type[BaseException], ...] = (ReplicaFault,)

KINDS = ("die_dispatch", "die_collect", "transient", "start_fail", "nan",
         "stall")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned failure: fire ``kind`` on ``replica`` at its local wave
    counter ``wave`` (counting `start` and `dispatch` calls from 0).
    ``ticks`` is the stall duration for ``kind == 'stall'``."""

    kind: str
    replica: int
    wave: int
    ticks: int = 2

    def __post_init__(self) -> None:
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.wave < 0 or self.replica < 0 or self.ticks < 1:
            raise ValueError(f"invalid fault coordinates: {self}")


class FaultPlan:
    """A replayable failure schedule: ``(replica, wave) -> faults``.

    Deterministic by construction — built either from an explicit fault
    list or from a seed (`FaultPlan.random`), and indexed only by counters
    the scheduler already maintains.  Contains no clock, no randomness at
    fire time, and no mutable state, so the same plan replayed against the
    same queue reproduces the exact wave/steal/retire/refusal trajectory.
    """

    def __init__(self, faults: Iterable[Fault] = ()):
        self.faults: tuple[Fault, ...] = tuple(faults)
        self._at: dict[tuple[int, int], list[Fault]] = {}
        for f in self.faults:
            self._at.setdefault((f.replica, f.wave), []).append(f)

    @classmethod
    def random(cls, seed: int, *, replicas: int, horizon: int = 16,
               rate: float = 0.15,
               kinds: Sequence[str] = KINDS) -> "FaultPlan":
        """A seeded schedule: each (replica, wave) cell in the horizon
        independently draws one fault with probability ``rate``."""
        rng = np.random.default_rng(seed)
        faults = []
        for r in range(replicas):
            for w in range(horizon):
                if rng.random() < rate:
                    kind = kinds[int(rng.integers(len(kinds)))]
                    faults.append(Fault(kind, r, w,
                                        ticks=int(rng.integers(1, 4))))
        return cls(faults)

    def at(self, replica: int, wave: int) -> list[Fault]:
        return self._at.get((replica, wave), [])

    def __len__(self) -> int:
        return len(self.faults)

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for f in self.faults:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def describe(self) -> str:
        return " ".join(f"{f.kind}@r{f.replica}w{f.wave}"
                        for f in self.faults) or "(empty)"


def _poison(e: Any) -> Any:
    """Corrupt one emission to non-finite values, preserving its type."""
    if e is None:
        return None
    if isinstance(e, np.ndarray):
        return np.full_like(e, np.nan) if np.issubdtype(
            e.dtype, np.floating) else e
    if isinstance(e, float):
        return float("nan")
    return e


class ChaosBackend:
    """A scheduler backend that injects a `FaultPlan` around the real one.

    Implements the dispatch/collect split of the backend protocol (falling
    back to the inner backend's synchronous ``step`` when it has no split)
    and delegates every other protocol method — ``bucket_key``,
    ``validate_request``, ``append``, ... — to the wrapped backend
    untouched, so an empty plan is behaviorally invisible.

    ``waves`` counts this replica's ``start`` + ``dispatch`` calls; faults
    fire when the plan has entries at the current count.  ``injected``
    records every fired fault as ``(wave, kind)`` for telemetry.
    """

    def __init__(self, inner: Any, plan: FaultPlan, *, replica: int):
        self.inner = inner
        self.plan = plan
        self.replica = replica
        self.waves = 0
        self.dead = False
        self._stall = 0
        self.injected: list[tuple[int, str]] = []

    def __getattr__(self, name: str) -> Any:
        # protocol methods we don't intercept delegate to the inner backend
        return getattr(self.inner, name)

    # -- fault firing -------------------------------------------------------

    def _tick(self) -> list[Fault]:
        w = self.waves
        self.waves += 1
        if self.dead:
            raise ReplicaDead(
                f"replica {self.replica} is dead (wave {w})")
        return self.plan.at(self.replica, w)

    def _die(self, wave: int, kind: str) -> None:
        self.dead = True
        self.injected.append((wave, kind))
        raise ReplicaDead(
            f"injected {kind} on replica {self.replica} at wave {wave}")

    # -- scheduler protocol -------------------------------------------------

    def start(self, reqs: list, width: int):
        w = self.waves
        for f in self._tick():
            if f.kind == "die_dispatch":
                self._die(w, "die_dispatch")
            if f.kind == "start_fail":
                self.injected.append((w, "start_fail"))
                raise CompileFault(
                    f"injected start_fail on replica {self.replica} "
                    f"at wave {w}")
            if f.kind == "transient":
                self.injected.append((w, "transient"))
                raise TransientFault(
                    f"injected transient on replica {self.replica} "
                    f"at wave {w} (start)")
        return self.inner.start(reqs, width)

    def dispatch(self, state, slots):
        w = self.waves
        fired = self._tick()
        for f in fired:
            if f.kind == "stall" and self._stall == 0:
                self._stall = f.ticks
        if self._stall > 0:
            self._stall -= 1
            self.injected.append((w, "stall"))
            return ("stall", None, False)
        for f in fired:
            if f.kind == "die_dispatch":
                self._die(w, "die_dispatch")
            if f.kind == "transient":
                self.injected.append((w, "transient"))
                raise TransientFault(
                    f"injected transient on replica {self.replica} "
                    f"at wave {w}")
        corrupt = any(f.kind == "nan" for f in fired)
        die_collect = any(f.kind == "die_collect" for f in fired)
        if corrupt:
            self.injected.append((w, "nan"))
        fn = getattr(self.inner, "dispatch", None)
        if fn is not None:
            handle = ("split", fn(state, slots), corrupt)
        else:
            handle = ("sync", self.inner.step(state, slots), corrupt)
        if die_collect:
            # remember to die when the scheduler comes back for the result
            handle = ("die_collect", (w, handle), corrupt)
        return handle

    def collect(self, state, handle, slots):
        tag, h, corrupt = handle
        if self.dead:
            raise ReplicaDead(
                f"replica {self.replica} is dead (collect)")
        if tag == "stall":
            return state, [None] * len(slots)
        if tag == "die_collect":
            w, _inner_handle = h
            self._die(w, "die_collect")
        if tag == "split":
            state, emis = self.inner.collect(state, h, slots)
        else:
            state, emis = h
        if corrupt:
            emis = [_poison(e) for e in emis]
        return state, emis
