"""Model-agnostic lockstep scheduler: queue, batch bucketing, slot
retirement, backfill.

The scheduler owns *when* things run — admission from the queue, bucketing
requests that may share a batch, the slot lifecycle (live -> retired ->
backfilled) — and a backend owns *what* runs (the model math).  The LM
prefill/decode stack and the CNN `SparseNet.apply` path both plug in here
(`launch.serve.LMBackend` / `launch.serve.CNNBackend`), so retirement and
backfill are one tested code path instead of per-model loop bodies.

Backend protocol (duck-typed)
-----------------------------
  bucket_key(req) -> hashable
      Requests sharing a key may share a lockstep batch (LM: prompt-length
      bucket; CNN: padded image shape).
  sort_key(req) -> sortable
      Admission order within a bucket (LM: longest prompt first, so every
      later backfill fits the already-grown context).
  context() -> context manager
      Entered around one whole lockstep run (mesh/sharding scope).
  start(reqs, width) -> (state, emissions | None)
      Admit the first wave into a width-slot batch (LM: prefill, emitting
      each slot's first token; CNN: nothing to emit before the first step).
  step(state, slots) -> (state, emissions)
      One lockstep step over all slots; ``slots`` is the width-long list of
      in-flight requests (None = idle lane).  Emissions is per-slot.
  append(req, emission) -> bool
      Record one emission on the request; True means the request finished
      (EOS, token budget, or — for one-shot image requests — always).
  can_backfill(state, req) -> bool
      May ``req`` join this in-flight run?  (LM: its prompt fits the
      current context length and capacity; CNN: same shape bucket.)
  backfill(state, slot, req) -> (state, emission | None)
      Admit ``req`` into freed slot ``slot`` mid-run (LM: prefill padded to
      the current context and merge its cache rows into the live batch).
  finish(state) -> dict
      Backend-specific stats merged into the run's stats dict.

A finished request frees its slot *immediately*: the scheduler scans the
bucket queue first-fit and backfills in the same delivery pass, chaining if
the newcomer itself finishes instantly (e.g. ``max_new=1``: its admission
emission already completes it).  A run ends when every slot is idle; a
bucket's leftover requests that never fit an in-flight run (capacity,
context length) get a fresh lockstep run of their own.

Replica fleet
-------------
`FleetScheduler` scales the same protocol across N data-parallel backend
replicas (one weight copy per replica, typically device-placed — see
`launch.serve.ReplicaGroup`).  Admission becomes *per-replica bucket
ladders*: each bucket's sorted queue is cut into wave-sized chunks placed
on the least-loaded replica.  The run loop interleaves the replicas'
lockstep runs one step per tick — per-replica wave dispatch, so a slow
wave on replica 0 never stalls retirement or backfill on replicas
1..N-1 — and an idle replica *steals* the tail half of the longest queue
still waiting on any other replica.  Backends may split ``step`` into

  dispatch(state, slots) -> handle
  collect(state, handle, slots) -> (state, emissions)

so one tick issues every replica's computation before blocking on any
result (JAX async dispatch overlaps the replicas' device work); backends
without the split fall back to the synchronous ``step``.  With one
replica the ladder, admission order and step sequence are exactly
`LockstepScheduler.serve`'s.
"""
from __future__ import annotations

import contextlib
import time

__all__ = ["LockstepScheduler", "FleetScheduler"]


def _deliver(be, state, slots, queue, emis):
    """One delivery pass: append emissions, retire finished requests,
    first-fit backfill from ``queue`` (consumed in place), chaining when a
    backfilled request finishes on its admission emission.  Returns
    ``(state, finished, backfills, emitted)``; ``slots`` mutates in place.
    """
    finished = backfills = emitted = 0
    for j in range(len(slots)):
        req = slots[j]
        e = None if emis is None else emis[j]
        while req is not None and e is not None:
            done = be.append(req, e)
            emitted += 1
            e = None
            if not done:
                break
            finished += 1
            req = None
            for qi, cand in enumerate(queue):
                if be.can_backfill(state, cand):
                    req = queue.pop(qi)
                    backfills += 1
                    state, e = be.backfill(state, j, req)
                    break
        slots[j] = req
    return state, finished, backfills, emitted


class LockstepScheduler:
    """Generic lockstep serving loop over a pluggable model backend."""

    def __init__(self, backend, *, batch: int):
        assert batch >= 1
        self.backend = backend
        self.batch = batch

    def serve(self, requests: list) -> list[dict]:
        """Bucket the queue, then run lockstep batches until it drains.

        Returns one stats dict per lockstep run (see `run_lockstep`).
        """
        buckets: dict = {}
        for r in requests:
            buckets.setdefault(self.backend.bucket_key(r), []).append(r)
        stats = []
        for queue in buckets.values():
            queue.sort(key=self.backend.sort_key)
            while queue:
                stats.append(self.run_lockstep(queue))
        return stats

    def run_lockstep(self, queue: list) -> dict:
        """One lockstep run: admit up to ``batch`` requests, step until every
        slot retires, backfilling freed slots from ``queue`` (consumed in
        place).  Stats: steps, finished, backfills, emissions, start_s,
        run_s, plus whatever `backend.finish` adds.
        """
        be = self.backend
        assert queue, "run_lockstep needs at least one request"
        width = self.batch
        admitted = [queue.pop(0) for _ in range(min(width, len(queue)))]
        slots: list = admitted + [None] * (width - len(admitted))
        steps = finished = backfills = emitted = 0
        ctx = getattr(be, "context", None)
        with (ctx() if ctx else contextlib.nullcontext()):
            t0 = time.time()
            state, emis = be.start(admitted, width)
            start_s = time.time() - t0
            t1 = time.time()
            while True:
                state, f, b, e = _deliver(be, state, slots, queue, emis)
                finished += f
                backfills += b
                emitted += e
                if all(s is None for s in slots):
                    break
                state, emis = be.step(state, slots)
                steps += 1
            run_s = time.time() - t1
        out = {
            "steps": steps,
            "finished": finished,
            "backfills": backfills,
            "emissions": emitted,
            "start_s": start_s,
            "run_s": run_s,
        }
        out.update(be.finish(state) or {})
        return out


class _ReplicaRun:
    """One resumable in-flight lockstep run on one fleet replica.

    The same lifecycle as `LockstepScheduler.run_lockstep`, unrolled so the
    fleet loop can advance many replicas' runs one step at a time: admit +
    start + deliver on construction, then repeated ``dispatch`` /
    ``collect_and_deliver`` ticks until every slot is idle.
    """

    def __init__(self, replica: int, be, queue: list, width: int):
        self.replica = replica
        self.be = be
        self.queue = queue
        admitted = [queue.pop(0) for _ in range(min(width, len(queue)))]
        self.slots: list = admitted + [None] * (width - len(admitted))
        self.steps = self.finished = self.backfills = self.emitted = 0
        self._handle = None
        with self._ctx():
            t0 = time.time()
            self.state, emis = be.start(admitted, width)
            self.start_s = time.time() - t0
            self._t1 = time.time()
            self._deliver(emis)

    def _ctx(self):
        ctx = getattr(self.be, "context", None)
        return ctx() if ctx else contextlib.nullcontext()

    def _deliver(self, emis):
        self.state, f, b, e = _deliver(
            self.be, self.state, self.slots, self.queue, emis)
        self.finished += f
        self.backfills += b
        self.emitted += e

    def drained(self) -> bool:
        return all(s is None for s in self.slots)

    def dispatch(self):
        """Issue this replica's next step; backends with a dispatch/collect
        split return without blocking on the result."""
        fn = getattr(self.be, "dispatch", None)
        with self._ctx():
            if fn is not None:
                self._handle = ("pending", fn(self.state, self.slots))
            else:
                self._handle = ("ready", self.be.step(self.state, self.slots))
        self.steps += 1

    def collect_and_deliver(self):
        kind, h = self._handle
        self._handle = None
        with self._ctx():
            if kind == "pending":
                self.state, emis = self.be.collect(self.state, h, self.slots)
            else:
                self.state, emis = h
            self._deliver(emis)

    def finish(self) -> dict:
        out = {
            "replica": self.replica,
            "steps": self.steps,
            "finished": self.finished,
            "backfills": self.backfills,
            "emissions": self.emitted,
            "start_s": self.start_s,
            "run_s": time.time() - self._t1,
        }
        with self._ctx():
            out.update(self.be.finish(self.state) or {})
        return out


class FleetScheduler:
    """Data-parallel replica fleet: N backends, per-replica wave dispatch.

    ``backends`` hold the same model behind the `LockstepScheduler` backend
    protocol, one weight copy each (see module docstring).  ``serve``
    returns one stats dict per lockstep run, tagged with the ``replica``
    that ran it; ``steals`` counts queues moved between replicas since
    construction.
    """

    def __init__(self, backends: list, *, batch: int):
        assert backends, "FleetScheduler needs at least one backend"
        assert batch >= 1
        self.backends = list(backends)
        self.batch = batch
        self.steals = 0

    @property
    def replicas(self) -> int:
        return len(self.backends)

    def _place(self, requests: list) -> list[dict]:
        """Per-replica bucket ladders: each bucket's sorted queue is cut
        into wave-sized chunks, each placed on the least-loaded replica (by
        queued request count; ties to the lowest index, so one replica
        degenerates to `LockstepScheduler.serve`'s admission order)."""
        be0 = self.backends[0]
        buckets: dict = {}
        for r in requests:
            buckets.setdefault(be0.bucket_key(r), []).append(r)
        ladders: list[dict] = [{} for _ in self.backends]
        loads = [0] * len(self.backends)
        for key, q in buckets.items():
            q.sort(key=be0.sort_key)
            while q:
                chunk = q[: self.batch]
                del q[: self.batch]
                i = min(range(len(loads)), key=lambda j: (loads[j], j))
                ladders[i].setdefault(key, []).extend(chunk)
                loads[i] += len(chunk)
        return ladders

    def _claim(self, i: int, ladders: list[dict], runs: list):
        """Next queue for replica ``i``: its own ladder first, then steal
        the tail half (ceil, so lone stragglers move too) of the longest
        queue still waiting on any other replica — a pending ladder queue,
        or the *queued* remainder of an in-flight run's backfill source
        (admitted slots never move; only requests still waiting do)."""
        ladder = ladders[i]
        for key in list(ladder):
            if ladder[key]:
                return ladder.pop(key)
            del ladder[key]
        victim = None
        for j, other in enumerate(ladders):
            if j != i:
                for q in other.values():
                    if q and (victim is None or len(q) > len(victim)):
                        victim = q
        for run in runs:
            if run is not None and run.replica != i:
                q = run.queue
                if q and (victim is None or len(q) > len(victim)):
                    victim = q
        if victim is None:
            return None
        n = -(-len(victim) // 2)
        stolen = victim[len(victim) - n:]
        del victim[len(victim) - n:]
        self.steals += 1
        return stolen

    def _retire(self, run, ladders: list[dict], stats: list[dict]):
        """Record a drained run; leftover queued requests its backend
        refused to backfill go back on the replica's ladder for a fresh run
        (the `LockstepScheduler.serve` ``while queue`` loop, fleet-wise)."""
        stats.append(run.finish())
        if run.queue:
            key = self.backends[0].bucket_key(run.queue[0])
            ladders[run.replica].setdefault(key, []).extend(run.queue)
            run.queue.clear()

    def serve(self, requests: list) -> list[dict]:
        """Place the queue on per-replica ladders, then drain every replica
        with interleaved per-replica wave dispatch (one step per replica
        per tick; each tick dispatches all replicas before collecting any,
        so split backends overlap their device work)."""
        ladders = self._place(requests)
        runs: list = [None] * self.replicas
        stats: list[dict] = []
        while True:
            for i in range(self.replicas):
                while runs[i] is None:
                    q = self._claim(i, ladders, runs)
                    if q is None:
                        break
                    run = _ReplicaRun(i, self.backends[i], q, self.batch)
                    if run.drained():  # instant finish (e.g. max_new=1 LM)
                        self._retire(run, ladders, stats)
                    else:
                        runs[i] = run
            active = [r for r in runs if r is not None]
            if not active:
                return stats
            for run in active:
                run.dispatch()
            for i, run in enumerate(runs):
                if run is None:
                    continue
                run.collect_and_deliver()
                if run.drained():
                    self._retire(run, ladders, stats)
                    runs[i] = None
