"""Model-agnostic lockstep scheduler: queue, batch bucketing, slot
retirement, backfill — plus fleet-level fault tolerance.

The scheduler owns *when* things run — admission from the queue, bucketing
requests that may share a batch, the slot lifecycle (live -> retired ->
backfilled) — and a backend owns *what* runs (the model math).  The LM
prefill/decode stack and the CNN `SparseNet.apply` path both plug in here
(`launch.serve.LMBackend` / `launch.serve.CNNBackend`), so retirement and
backfill are one tested code path instead of per-model loop bodies.

Backend protocol (duck-typed)
-----------------------------
  bucket_key(req) -> hashable
      Requests sharing a key may share a lockstep batch (LM: prompt-length
      bucket; CNN: padded image shape).
  sort_key(req) -> sortable
      Admission order within a bucket (LM: longest prompt first, so every
      later backfill fits the already-grown context).
  context() -> context manager
      Entered around one whole lockstep run (mesh/sharding scope).
  start(reqs, width) -> (state, emissions | None)
      Admit the first wave into a width-slot batch (LM: prefill, emitting
      each slot's first token; CNN: nothing to emit before the first step).
  step(state, slots) -> (state, emissions)
      One lockstep step over all slots; ``slots`` is the width-long list of
      in-flight requests (None = idle lane).  Emissions is per-slot.
  append(req, emission) -> bool
      Record one emission on the request; True means the request finished
      (EOS, token budget, or — for one-shot image requests — always).
  can_backfill(state, req) -> bool
      May ``req`` join this in-flight run?  (LM: its prompt fits the
      current context length and capacity; CNN: same shape bucket.)
  backfill(state, slot, req) -> (state, emission | None)
      Admit ``req`` into freed slot ``slot`` mid-run (LM: prefill padded to
      the current context and merge its cache rows into the live batch).
  finish(state) -> dict
      Backend-specific stats merged into the run's stats dict.

Optional protocol extensions (fault tolerance / admission control):

  validate_request(req) -> str | None
      Admission-time request validation: a refusal reason string rejects
      the request with a structured `RequestOutcome` *before* it can cause
      a mid-wave shape/dtype error; None admits it.
  check_emission(emission) -> bool
      Output-validation guard: False means the emission is corrupt (e.g.
      non-finite logits).  The fleet scheduler quarantines the producing
      replica and re-serves the wave instead of delivering garbage.
  reset(req) -> None
      Clear a request's partial progress before it is re-served after a
      replica fault.  Backends without ``reset`` get partially-delivered
      requests refused (``partial_stream_lost``) rather than duplicated.

A finished request frees its slot *immediately*: the scheduler scans the
bucket queue first-fit and backfills in the same delivery pass, chaining if
the newcomer itself finishes instantly (e.g. ``max_new=1``: its admission
emission already completes it).  A run ends when every slot is idle; a
bucket's leftover requests that never fit an in-flight run (capacity,
context length) get a fresh lockstep run of their own.

Replica fleet
-------------
`FleetScheduler` scales the same protocol across N data-parallel backend
replicas (one weight copy per replica, typically device-placed — see
`launch.serve.ReplicaGroup`).  Admission becomes *per-replica bucket
ladders*: each bucket's sorted queue is cut into wave-sized chunks placed
on the least-loaded replica.  The run loop interleaves the replicas'
lockstep runs one step per tick — per-replica wave dispatch, so a slow
wave on replica 0 never stalls retirement or backfill on replicas
1..N-1 — and an idle replica *steals* the tail half of the longest queue
still waiting on any other replica.  Backends may split ``step`` into

  dispatch(state, slots) -> handle
  collect(state, handle, slots) -> (state, emissions)

so one tick issues every replica's computation before blocking on any
result (JAX async dispatch overlaps the replicas' device work); backends
without the split fall back to the synchronous ``step``.  With one
replica the ladder, admission order and step sequence are exactly
`LockstepScheduler.serve`'s.

Fault tolerance
---------------
Every backend call in the fleet loop is guarded by the typed
`launch.faults.FAULT_TYPES` hierarchy (never a blanket ``except`` —
vscheck VSC304).  Replica health walks ``healthy -> suspect ->
quarantined -> drained``:

  * a transient fault marks the replica *suspect* and re-queues its wave;
    ``suspect_limit`` transients quarantine it;
  * a non-transient fault (`ReplicaDead`, `CompileFault`, the
    `NonFiniteOutput` raised by the output guard) quarantines immediately;
  * quarantine re-places the replica's in-flight slots and pending ladder
    on the surviving replicas (no request lost, no duplicate delivery —
    nothing that reached ``append`` is ever re-served), then marks the
    replica *drained* (terminal).

Per-request budgets are accounted in deterministic wave counts, never the
clock: ``deadline_waves`` refuses a request still *queued* after that many
fleet ticks, ``max_attempts`` bounds fault-driven re-placements.  Bounded
admission (``max_queue``) sheds load at serve() entry.  Every admitted
request ends in exactly one terminal `RequestOutcome` — delivered, or a
structured refusal (reason strings: ``queue_full``, ``invalid:*``,
``deadline_exceeded``, ``retry_budget_exhausted``,
``no_healthy_replicas``, ``partial_stream_lost``) — and control flow stays
clock-free, so a faulty run (chaos-injected or real) is exactly
replayable.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

from repro.launch.faults import FAULT_TYPES, NonFiniteOutput

__all__ = ["LockstepScheduler", "FleetScheduler", "RequestOutcome",
           "HEALTHY", "SUSPECT", "QUARANTINED", "DRAINED"]


# replica health states (fleet): healthy -> suspect -> quarantined -> drained
HEALTHY = "healthy"
SUSPECT = "suspect"
QUARANTINED = "quarantined"
DRAINED = "drained"


@dataclasses.dataclass
class RequestOutcome:
    """The single terminal outcome of one admitted request.

    ``status`` is ``"delivered"`` or ``"refused"``; refusals carry a
    machine-readable ``reason``.  ``wave`` is the fleet tick (or lockstep
    delivery pass) the outcome was decided at; ``attempts`` counts
    fault-driven re-placements the request survived before its outcome.
    """

    rid: object
    status: str
    reason: str | None = None
    replica: int | None = None
    attempts: int = 0
    wave: int = 0


def _deliver(be, state, slots, queue, emis, on_finish=None):
    """One delivery pass: append emissions, retire finished requests,
    first-fit backfill from ``queue`` (consumed in place), chaining when a
    backfilled request finishes on its admission emission.  Returns
    ``(state, finished, backfills, emitted)``; ``slots`` mutates in place.
    ``on_finish`` (optional) is called once per retired request.
    """
    finished = backfills = emitted = 0
    for j in range(len(slots)):
        req = slots[j]
        e = None if emis is None else emis[j]
        while req is not None and e is not None:
            done = be.append(req, e)
            emitted += 1
            e = None
            if not done:
                break
            finished += 1
            if on_finish is not None:
                on_finish(req)
            req = None
            for qi, cand in enumerate(queue):
                if be.can_backfill(state, cand):
                    req = queue.pop(qi)
                    backfills += 1
                    state, e = be.backfill(state, j, req)
                    break
        slots[j] = req
    return state, finished, backfills, emitted


def _admit(be, requests, outcomes, *, max_queue=None, wave=0):
    """Admission control shared by both schedulers: validate each request
    through the backend's optional ``validate_request`` and shed load
    beyond ``max_queue``.  Refused requests get a structured
    `RequestOutcome`; the admitted remainder is returned in order."""
    validate = getattr(be, "validate_request", None)
    admitted = []
    for req in requests:
        reason = None
        if validate is not None:
            reason = validate(req)
            if reason is not None:
                reason = f"invalid:{reason}"
        if reason is None and max_queue is not None \
                and len(admitted) >= max_queue:
            reason = "queue_full"
        if reason is None:
            admitted.append(req)
        else:
            _record(outcomes, req, RequestOutcome(
                rid=getattr(req, "rid", None), status="refused",
                reason=reason, wave=wave))
    return admitted


def _record(outcomes, req, outcome):
    """Record a terminal outcome exactly once (first one wins)."""
    rid = outcome.rid
    if rid in outcomes:
        return
    outcomes[rid] = outcome
    req.outcome = outcome


class LockstepScheduler:
    """Generic lockstep serving loop over a pluggable model backend.

    ``max_queue`` bounds admission per `serve` call: requests beyond the
    depth are shed with a structured ``queue_full`` refusal (recorded in
    ``self.outcomes``) instead of growing the queue without bound.
    """

    def __init__(self, backend, *, batch: int, max_queue: int | None = None):
        assert batch >= 1
        self.backend = backend
        self.batch = batch
        self.max_queue = max_queue
        self.outcomes: dict = {}

    def serve(self, requests: list) -> list[dict]:
        """Admission-check and bucket the queue, then run lockstep batches
        until it drains.

        Returns one stats dict per lockstep run (see `run_lockstep`);
        per-request terminal outcomes land in ``self.outcomes`` (and on
        each request's ``.outcome``).
        """
        self.outcomes = {}
        admitted = _admit(self.backend, list(requests), self.outcomes,
                          max_queue=self.max_queue)
        buckets: dict = {}
        for r in admitted:
            buckets.setdefault(self.backend.bucket_key(r), []).append(r)
        stats = []
        for queue in buckets.values():
            queue.sort(key=self.backend.sort_key)
            while queue:
                stats.append(self.run_lockstep(queue))
        return stats

    def _on_finish(self, req) -> None:
        _record(self.outcomes, req, RequestOutcome(
            rid=getattr(req, "rid", None), status="delivered"))

    def run_lockstep(self, queue: list) -> dict:
        """One lockstep run: admit up to ``batch`` requests, step until every
        slot retires, backfilling freed slots from ``queue`` (consumed in
        place).  Stats: steps, finished, backfills, emissions, start_s,
        run_s, plus whatever `backend.finish` adds.
        """
        be = self.backend
        assert queue, "run_lockstep needs at least one request"
        width = self.batch
        admitted = [queue.pop(0) for _ in range(min(width, len(queue)))]
        slots: list = admitted + [None] * (width - len(admitted))
        steps = finished = backfills = emitted = 0
        ctx = getattr(be, "context", None)
        with (ctx() if ctx else contextlib.nullcontext()):
            t0 = time.time()
            state, emis = be.start(admitted, width)
            start_s = time.time() - t0
            t1 = time.time()
            while True:
                state, f, b, e = _deliver(be, state, slots, queue, emis,
                                          self._on_finish)
                finished += f
                backfills += b
                emitted += e
                if all(s is None for s in slots):
                    break
                state, emis = be.step(state, slots)
                steps += 1
            run_s = time.time() - t1
        out = {
            "steps": steps,
            "finished": finished,
            "backfills": backfills,
            "emissions": emitted,
            "start_s": start_s,
            "run_s": run_s,
        }
        out.update(be.finish(state) or {})
        return out


class _ReplicaRun:
    """One resumable in-flight lockstep run on one fleet replica.

    The same lifecycle as `LockstepScheduler.run_lockstep`, unrolled so the
    fleet loop can advance many replicas' runs one step at a time: start +
    deliver on construction (the caller pops the admission wave so a
    failing ``start`` can re-queue it), then repeated ``dispatch`` /
    ``collect_and_deliver`` ticks until every slot is idle.  ``guard``
    (optional) validates each wave's emissions before delivery — it raises
    to reject the whole wave (output corruption), so corrupt emissions are
    never appended.
    """

    def __init__(self, replica: int, be, admitted: list, queue: list,
                 width: int, *, on_finish=None, guard=None):
        self.replica = replica
        self.be = be
        self.queue = queue
        self.on_finish = on_finish
        self.guard = guard
        self.slots: list = admitted + [None] * (width - len(admitted))
        self.steps = self.finished = self.backfills = self.emitted = 0
        self._handle = None
        with self._ctx():
            t0 = time.time()
            self.state, emis = be.start(admitted, width)
            self.start_s = time.time() - t0
            self._t1 = time.time()
            self._deliver(emis)

    def _ctx(self):
        ctx = getattr(self.be, "context", None)
        return ctx() if ctx else contextlib.nullcontext()

    def _deliver(self, emis):
        if self.guard is not None and emis is not None:
            self.guard(emis)
        self.state, f, b, e = _deliver(
            self.be, self.state, self.slots, self.queue, emis,
            self.on_finish)
        self.finished += f
        self.backfills += b
        self.emitted += e

    def drained(self) -> bool:
        return all(s is None for s in self.slots)

    def in_flight(self) -> list:
        """Requests currently occupying slots (for fault re-placement)."""
        return [s for s in self.slots if s is not None]

    def dispatch(self):
        """Issue this replica's next step; backends with a dispatch/collect
        split return without blocking on the result."""
        fn = getattr(self.be, "dispatch", None)
        with self._ctx():
            if fn is not None:
                self._handle = ("pending", fn(self.state, self.slots))
            else:
                self._handle = ("ready", self.be.step(self.state, self.slots))
        self.steps += 1

    def collect_and_deliver(self):
        kind, h = self._handle
        self._handle = None
        with self._ctx():
            if kind == "pending":
                self.state, emis = self.be.collect(self.state, h, self.slots)
            else:
                self.state, emis = h
            self._deliver(emis)

    def finish(self) -> dict:
        out = {
            "replica": self.replica,
            "steps": self.steps,
            "finished": self.finished,
            "backfills": self.backfills,
            "emissions": self.emitted,
            "start_s": self.start_s,
            "run_s": time.time() - self._t1,
        }
        with self._ctx():
            out.update(self.be.finish(self.state) or {})
        return out


class FleetScheduler:
    """Data-parallel replica fleet: N backends, per-replica wave dispatch,
    replica health tracking and fault-driven re-placement.

    ``backends`` hold the same model behind the `LockstepScheduler` backend
    protocol, one weight copy each (see module docstring).  ``serve``
    returns one stats dict per lockstep run, tagged with the ``replica``
    that ran it; ``steals`` counts queues moved between replicas since
    construction.  Fault handling (see the module docstring's
    *Fault tolerance* section) is configured by:

      fault_types     exception types treated as replica faults (default
                      `launch.faults.FAULT_TYPES`); anything else
                      propagates — a bug should still fail fast;
      suspect_limit   transient faults a replica survives before
                      quarantine;
      max_attempts    fault-driven re-placements one request survives
                      before a ``retry_budget_exhausted`` refusal;
      deadline_waves  default per-request deadline in fleet ticks (a
                      request may override via its own ``deadline_waves``
                      attribute; None = no deadline);
      max_queue       bounded admission depth (load shedding).

    Health, fault events and per-request outcomes are exposed as
    ``self.health`` / ``self.fault_events`` / ``self.outcomes``.
    """

    def __init__(self, backends: list, *, batch: int,
                 max_queue: int | None = None,
                 deadline_waves: int | None = None,
                 max_attempts: int = 3, suspect_limit: int = 2,
                 fault_types: tuple = FAULT_TYPES):
        assert backends, "FleetScheduler needs at least one backend"
        assert batch >= 1
        self.backends = list(backends)
        self.batch = batch
        self.max_queue = max_queue
        self.deadline_waves = deadline_waves
        self.max_attempts = max_attempts
        self.suspect_limit = suspect_limit
        self.fault_types = fault_types
        self.steals = 0
        self.waves = 0                       # fleet ticks since construction
        self.health = [HEALTHY] * len(self.backends)
        self.fault_counts = [0] * len(self.backends)
        self.fault_events: list[dict] = []
        self.outcomes: dict = {}
        self._attempts: dict = {}

    @property
    def replicas(self) -> int:
        return len(self.backends)

    def _live(self, i: int) -> bool:
        return self.health[i] in (HEALTHY, SUSPECT)

    def live_replicas(self) -> list[int]:
        return [i for i in range(self.replicas) if self._live(i)]

    # -- placement ----------------------------------------------------------

    def _place(self, requests: list) -> list[dict]:
        """Per-replica bucket ladders: each bucket's sorted queue is cut
        into wave-sized chunks placed on the least-loaded replica (by
        queued request count; ties to the lowest index, so one replica
        degenerates to `LockstepScheduler.serve`'s admission order)."""
        ladders: list[dict] = [{} for _ in self.backends]
        self._place_into(requests, ladders)
        return ladders

    def _place_into(self, requests: list, ladders: list[dict]) -> None:
        """Place (or re-place) ``requests`` onto the live replicas'
        ladders, least-loaded first."""
        be0 = self.backends[0]
        live = self.live_replicas()
        assert live, "_place_into requires at least one live replica"
        buckets: dict = {}
        for r in requests:
            buckets.setdefault(be0.bucket_key(r), []).append(r)
        loads = [sum(len(q) for q in lad.values()) for lad in ladders]
        for key, q in buckets.items():
            q.sort(key=be0.sort_key)
            while q:
                chunk = q[: self.batch]
                del q[: self.batch]
                i = min(live, key=lambda j: (loads[j], j))
                ladders[i].setdefault(key, []).extend(chunk)
                loads[i] += len(chunk)

    def _claim(self, i: int, ladders: list[dict], runs: list):
        """Next queue for replica ``i``: its own ladder first, then steal
        the tail half (ceil, so lone stragglers move too) of the longest
        queue still waiting on any other replica — a pending ladder queue,
        or the *queued* remainder of an in-flight run's backfill source
        (admitted slots never move; only requests still waiting do)."""
        ladder = ladders[i]
        for key in list(ladder):
            if ladder[key]:
                return ladder.pop(key)
            del ladder[key]
        victim = None
        for j, other in enumerate(ladders):
            if j != i:
                for q in other.values():
                    if q and (victim is None or len(q) > len(victim)):
                        victim = q
        for run in runs:
            if run is not None and run.replica != i:
                q = run.queue
                if q and (victim is None or len(q) > len(victim)):
                    victim = q
        if victim is None:
            return None
        n = -(-len(victim) // 2)
        stolen = victim[len(victim) - n:]
        del victim[len(victim) - n:]
        self.steals += 1
        return stolen

    def _retire(self, run, ladders: list[dict], stats: list[dict]):
        """Record a drained run; leftover queued requests its backend
        refused to backfill go back on the replica's ladder for a fresh run
        (the `LockstepScheduler.serve` ``while queue`` loop, fleet-wise)."""
        stats.append(run.finish())
        if run.queue:
            key = self.backends[0].bucket_key(run.queue[0])
            ladders[run.replica].setdefault(key, []).extend(run.queue)
            run.queue.clear()

    # -- outcomes -----------------------------------------------------------

    def _refuse(self, req, reason: str) -> None:
        _record(self.outcomes, req, RequestOutcome(
            rid=getattr(req, "rid", None), status="refused", reason=reason,
            attempts=self._attempts.get(id(req), 0), wave=self.waves))

    def _on_finish(self, replica: int):
        def cb(req):
            _record(self.outcomes, req, RequestOutcome(
                rid=getattr(req, "rid", None), status="delivered",
                replica=replica,
                attempts=self._attempts.get(id(req), 0), wave=self.waves))
        return cb

    def _guard(self, be, replica: int):
        """Output-validation guard for one replica's waves: reject a wave
        whose emissions fail the backend's ``check_emission`` by raising
        `NonFiniteOutput` — the tick loop quarantines the replica and
        re-serves the wave elsewhere, so corrupt values never reach
        ``append``."""
        check = getattr(be, "check_emission", None)
        if check is None:
            return None

        def guard(emis):
            bad = [j for j, e in enumerate(emis)
                   if e is not None and not check(e)]
            if bad:
                raise NonFiniteOutput(
                    f"replica {replica} emitted non-finite output in "
                    f"slot(s) {bad}")
        return guard

    # -- fault handling -----------------------------------------------------

    def _log_fault(self, i: int, exc: BaseException) -> None:
        self.fault_events.append({
            "wave": self.waves,
            "replica": i,
            "fault": type(exc).__name__,
            "transient": bool(getattr(exc, "transient", False)),
            "health": self.health[i],
            "error": str(exc),
        })

    def _degrade(self, i: int, exc: BaseException) -> None:
        """Walk replica ``i``'s health state for one fault."""
        if getattr(exc, "transient", False):
            self.fault_counts[i] += 1
            if self.health[i] == HEALTHY:
                self.health[i] = SUSPECT
            if self.fault_counts[i] >= self.suspect_limit:
                self.health[i] = QUARANTINED
        else:
            self.health[i] = QUARANTINED

    def _requeue(self, reqs: list, ladders: list[dict]) -> None:
        """Re-place fault-displaced requests on the surviving replicas.

        Each re-placement spends one retry-budget attempt; a request whose
        delivery already started (partial emissions) is only re-served if
        the backend can ``reset`` it — duplicate delivery is never an
        option.  With no live replica left, everything is refused."""
        be = self.backends[0]
        reset = getattr(be, "reset", None)
        survivors = []
        for req in reqs:
            n = self._attempts.get(id(req), 0) + 1
            self._attempts[id(req)] = n
            if n > self.max_attempts:
                self._refuse(req, "retry_budget_exhausted")
                continue
            if getattr(req, "out", None):
                if reset is None:
                    self._refuse(req, "partial_stream_lost")
                    continue
                reset(req)
            survivors.append(req)
        if not survivors:
            return
        if not self.live_replicas():
            for req in survivors:
                self._refuse(req, "no_healthy_replicas")
            return
        self._place_into(survivors, ladders)

    def _on_fault(self, i: int, exc: BaseException, displaced: list,
                  ladders: list[dict]) -> None:
        """One replica fault: log it, walk the health state, re-place the
        displaced requests, and — on quarantine — drain the replica's
        pending ladder onto the survivors."""
        self._log_fault(i, exc)
        self._degrade(i, exc)
        if self.health[i] == QUARANTINED:
            pending = []
            for q in ladders[i].values():
                pending.extend(q)
            ladders[i].clear()
            displaced = displaced + pending
            self._requeue(displaced, ladders)
            self.health[i] = DRAINED
        else:
            self._requeue(displaced, ladders)

    def _expire(self, ladders: list[dict], runs: list) -> None:
        """Deadline sweep: refuse requests still *queued* (not in-flight)
        after their wave budget.  ``deadline_waves`` counts fleet ticks
        since this serve() started; in-flight requests always complete."""
        default = self.deadline_waves
        age = self.waves - self._tick0
        queues = [q for lad in ladders for q in lad.values()]
        queues += [run.queue for run in runs if run is not None]
        for q in queues:
            keep = []
            for req in q:
                dl = getattr(req, "deadline_waves", None)
                dl = default if dl is None else dl
                if dl is not None and age >= dl:
                    self._refuse(req, "deadline_exceeded")
                else:
                    keep.append(req)
            q[:] = keep

    def _spawn(self, i: int, q: list, ladders: list[dict]):
        """Admit a wave from queue ``q`` on replica ``i``.  Returns the
        live `_ReplicaRun`, or None if ``start`` faulted (the wave is
        re-queued and the replica's health degraded)."""
        be = self.backends[i]
        admitted = [q.pop(0) for _ in range(min(self.batch, len(q)))]
        try:
            return _ReplicaRun(i, be, admitted, q, self.batch,
                               on_finish=self._on_finish(i),
                               guard=self._guard(be, i))
        except self.fault_types as e:
            self._on_fault(i, e, admitted + q, ladders)
            return None

    # -- serve --------------------------------------------------------------

    def serve(self, requests: list) -> list[dict]:
        """Admission-check the queue, place it on per-replica ladders, then
        drain every replica with interleaved per-replica wave dispatch (one
        step per replica per tick; each tick dispatches all replicas before
        collecting any, so split backends overlap their device work).
        Faulting replicas degrade and drain per the module docstring; the
        serve always returns — degraded service is structured refusals in
        ``self.outcomes``, not an exception."""
        self.outcomes = {}
        self._attempts = {}
        self._tick0 = self.waves
        admitted = _admit(self.backends[0], list(requests), self.outcomes,
                          max_queue=self.max_queue, wave=self.waves)
        if not self.live_replicas():
            for req in admitted:
                self._refuse(req, "no_healthy_replicas")
            return []
        ladders = self._place(admitted)
        runs: list = [None] * self.replicas
        stats: list[dict] = []
        while True:
            self._expire(ladders, runs)
            for i in range(self.replicas):
                while self._live(i) and runs[i] is None:
                    q = self._claim(i, ladders, runs)
                    if q is None:
                        break
                    if not q:
                        continue
                    run = self._spawn(i, q, ladders)
                    if run is None:
                        continue
                    if run.drained():  # instant finish (e.g. max_new=1 LM)
                        self._retire(run, ladders, stats)
                    else:
                        runs[i] = run
            active = [r for r in runs if r is not None]
            if not active:
                # queued work with no live replica to run it: refuse it
                leftovers = [r for lad in ladders
                             for q in lad.values() for r in q]
                for req in leftovers:
                    self._refuse(req, "no_healthy_replicas")
                return stats
            self.waves += 1
            faulted: list = []
            for run in active:
                try:
                    run.dispatch()
                except self.fault_types as e:
                    faulted.append((run, e))
            for i, run in enumerate(runs):
                if run is None:
                    continue
                exc = next((e for r, e in faulted if r is run), None)
                if exc is None:
                    try:
                        run.collect_and_deliver()
                    except self.fault_types as e:
                        exc = e
                if exc is not None:
                    runs[i] = None
                    self._on_fault(i, exc, run.in_flight() + run.queue,
                                   ladders)
                    continue
                if run.drained():
                    self._retire(run, ladders, stats)
                    runs[i] = None
