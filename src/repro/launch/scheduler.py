"""Model-agnostic lockstep scheduler: queue, batch bucketing, slot
retirement, backfill.

The scheduler owns *when* things run — admission from the queue, bucketing
requests that may share a batch, the slot lifecycle (live -> retired ->
backfilled) — and a backend owns *what* runs (the model math).  The LM
prefill/decode stack and the CNN `SparseNet.apply` path both plug in here
(`launch.serve.LMBackend` / `launch.serve.CNNBackend`), so retirement and
backfill are one tested code path instead of per-model loop bodies.

Backend protocol (duck-typed)
-----------------------------
  bucket_key(req) -> hashable
      Requests sharing a key may share a lockstep batch (LM: prompt-length
      bucket; CNN: padded image shape).
  sort_key(req) -> sortable
      Admission order within a bucket (LM: longest prompt first, so every
      later backfill fits the already-grown context).
  context() -> context manager
      Entered around one whole lockstep run (mesh/sharding scope).
  start(reqs, width) -> (state, emissions | None)
      Admit the first wave into a width-slot batch (LM: prefill, emitting
      each slot's first token; CNN: nothing to emit before the first step).
  step(state, slots) -> (state, emissions)
      One lockstep step over all slots; ``slots`` is the width-long list of
      in-flight requests (None = idle lane).  Emissions is per-slot.
  append(req, emission) -> bool
      Record one emission on the request; True means the request finished
      (EOS, token budget, or — for one-shot image requests — always).
  can_backfill(state, req) -> bool
      May ``req`` join this in-flight run?  (LM: its prompt fits the
      current context length and capacity; CNN: same shape bucket.)
  backfill(state, slot, req) -> (state, emission | None)
      Admit ``req`` into freed slot ``slot`` mid-run (LM: prefill padded to
      the current context and merge its cache rows into the live batch).
  finish(state) -> dict
      Backend-specific stats merged into the run's stats dict.

A finished request frees its slot *immediately*: the scheduler scans the
bucket queue first-fit and backfills in the same delivery pass, chaining if
the newcomer itself finishes instantly (e.g. ``max_new=1``: its admission
emission already completes it).  A run ends when every slot is idle; a
bucket's leftover requests that never fit an in-flight run (capacity,
context length) get a fresh lockstep run of their own.
"""
from __future__ import annotations

import contextlib
import time

__all__ = ["LockstepScheduler"]


class LockstepScheduler:
    """Generic lockstep serving loop over a pluggable model backend."""

    def __init__(self, backend, *, batch: int):
        assert batch >= 1
        self.backend = backend
        self.batch = batch

    def serve(self, requests: list) -> list[dict]:
        """Bucket the queue, then run lockstep batches until it drains.

        Returns one stats dict per lockstep run (see `run_lockstep`).
        """
        buckets: dict = {}
        for r in requests:
            buckets.setdefault(self.backend.bucket_key(r), []).append(r)
        stats = []
        for queue in buckets.values():
            queue.sort(key=self.backend.sort_key)
            while queue:
                stats.append(self.run_lockstep(queue))
        return stats

    def run_lockstep(self, queue: list) -> dict:
        """One lockstep run: admit up to ``batch`` requests, step until every
        slot retires, backfilling freed slots from ``queue`` (consumed in
        place).  Stats: steps, finished, backfills, emissions, start_s,
        run_s, plus whatever `backend.finish` adds.
        """
        be = self.backend
        assert queue, "run_lockstep needs at least one request"
        width = self.batch
        admitted = [queue.pop(0) for _ in range(min(width, len(queue)))]
        slots: list = admitted + [None] * (width - len(admitted))
        steps = finished = backfills = emitted = 0
        ctx = getattr(be, "context", None)
        with (ctx() if ctx else contextlib.nullcontext()):
            t0 = time.time()
            state, emis = be.start(admitted, width)
            start_s = time.time() - t0
            t1 = time.time()
            while True:
                for j in range(width):
                    req = slots[j]
                    e = None if emis is None else emis[j]
                    while req is not None and e is not None:
                        done = be.append(req, e)
                        emitted += 1
                        e = None
                        if not done:
                            break
                        finished += 1
                        req = None
                        for qi, cand in enumerate(queue):
                            if be.can_backfill(state, cand):
                                req = queue.pop(qi)
                                backfills += 1
                                state, e = be.backfill(state, j, req)
                                break
                    slots[j] = req
                if all(s is None for s in slots):
                    break
                state, emis = be.step(state, slots)
                steps += 1
            run_s = time.time() - t1
        out = {
            "steps": steps,
            "finished": finished,
            "backfills": backfills,
            "emissions": emitted,
            "start_s": start_s,
            "run_s": run_s,
        }
        out.update(be.finish(state) or {})
        return out
