"""Production mesh builders (functions, never module-level constants — the
module must be importable without touching jax device state)."""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "mesh_name"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading 'pod'
    axis.  Requires 256/512 (placeholder) devices — see launch/dryrun.py."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = jax.device_count()
    data = data or (n // model)
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(f"{k}{v}" for k, v in mesh.shape.items())
