"""Model-agnostic batched serving: one lockstep scheduler, two backends.

`launch.scheduler.LockstepScheduler` owns the queue, batch bucketing, slot
retirement and backfill; this module plugs in the model math:

* `LMBackend` / `Server` — the production prefill/decode jits with working
  continuous batching.  A sequence retires the moment it emits ``eos_id``
  (or exhausts its ``max_new`` budget) and its slot is backfilled from the
  queue in the same run: the newcomer is prefilled left-padded to the
  current context length and its cache rows are merged into the live batch
  (the KV/state cache is donated and updated in place).  A uniform batch
  with no EOS spends exactly ``max_new - 1`` decode steps — the prefill
  emits each slot's first token, so there is no trailing wasted decode.
  Admission prompt lengths are bucketed (``len_bucket``) so first-wave
  prefill compile shapes stay bounded; on attention archs a backfill
  prefill right-pads the context to the same bucket ladder and reads its
  logits at the true position, so backfill shapes are bounded too (one
  executable per bucket, not one per retirement step).  Recurrent archs
  (rwkv/mamba) keep the exact-length backfill prefill — their state folds
  in every processed token — see the ROADMAP serving follow-ups.

* `CNNBackend` / `CNNServer` — CNN inference traffic through
  `SparseNet.apply`: requests carry images, batches pad/bucket on image
  shape, every request finishes in one lockstep step, and freed slots are
  refilled from the queue so the compiled batch shape is reused wave after
  wave; a partial final wave shrinks to its occupied slots (pow2 ladder)
  instead of computing zero images.  A jit cache keyed on (net, density,
  impl, batch bucket) — see `models.graph.BatchedApply` — keeps recompiles
  off the hot path; ``impl`` defaults to ``auto`` (the halo-layout Pallas
  conv kernels on TPU, the structural jnp path elsewhere).

Both run end-to-end on CPU with reduced configs; the LM jits are the same
step functions the decode_32k / long_500k dry-run cells lower on the
production mesh.

Multi-device serving: ``--replicas N`` serves a `ReplicaGroup` — N
data-parallel CNN backend instances with `jax.device_put`-placed weight
copies — behind `launch.scheduler.FleetScheduler` (per-replica wave
dispatch, least-loaded placement, work stealing).  ``--shard-fc``
additionally cout-shards the FC heads' strips over each replica's
``model`` devices (`models.graph.shard_sparse`).  On CPU, force a device
mesh with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

LM requests carry per-request sampling params (``temperature`` /
``top_k``); temperature 0 is greedy argmax, bit-identical to the
pre-sampling decode path.

Fault tolerance: both backends validate requests at admission
(`validate_request` — malformed images / prompts become structured
`RequestOutcome` refusals, never mid-wave shape errors), `CNNBackend`
guards its outputs (`check_emission` — non-finite logits quarantine the
producing replica), and `CNNServer` accepts a ``fault_plan``
(`launch.faults.FaultPlan`) that wraps every replica in a `ChaosBackend`
for deterministic chaos runs, plus ``max_queue`` / ``deadline_waves`` /
``max_attempts`` budgets forwarded to the schedulers.  Per-request
outcomes of the last serve land on ``srv.outcomes`` (and each request's
``.outcome``).

Usage (CPU examples):
  python -m repro.launch.serve --arch rwkv6-3b --requests 16 --tokens 32
  python -m repro.launch.serve --cnn vscnn-vgg16 --requests 16 --batch 8
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    python -m repro.launch.serve --cnn vscnn-vgg16 --replicas 4 --shard-fc
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.faults import ChaosBackend, FaultPlan
from repro.launch.mesh import make_local_mesh
from repro.launch.scheduler import FleetScheduler, LockstepScheduler
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.parallel import sharding as shd

__all__ = [
    "Request", "ImageRequest", "LMBackend", "CNNBackend", "ReplicaGroup",
    "Server", "CNNServer", "random_prompt_lengths", "main",
]


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@dataclasses.dataclass
class Request:
    """One LM generation request.

    ``temperature``/``top_k`` select per-request sampling for every token
    this request emits: 0 temperature (the default) is greedy argmax,
    bit-identical to a request that never set the fields; ``top_k > 0``
    restricts sampling to the k highest logits.  Requests with different
    sampling params share a batch — the sampler is per-slot.
    """

    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new: int
    temperature: float = 0.0
    top_k: int = 0
    out: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ImageRequest:
    """One CNN inference request."""

    rid: int
    image: np.ndarray            # (H, W, C) float
    max_new: int = 1             # one-shot: a single emission finishes it
    out: list = dataclasses.field(default_factory=list)  # [predicted class]
    logits: np.ndarray | None = None


# --------------------------------------------------------------------------
# LM backend: prefill/decode lockstep with EOS retirement + cache-merge
# backfill
# --------------------------------------------------------------------------

def _sample_tokens(logits, temp, top_k, keys):
    """Per-slot temperature/top-k sampling over (B, V) logits.

    Slots with ``temp == 0`` take the plain ``jnp.argmax`` branch of the
    final select — the greedy operand is computed from the raw logits, so
    a zero-temperature slot reproduces the greedy path bit-exactly even
    when its batch neighbors sample.  ``top_k == 0`` means no truncation.
    Ranking uses a stable double-argsort, so ``top_k=1`` keeps exactly the
    argmax candidate (first max on ties, like argmax itself).
    """
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    order = jnp.argsort(-logits, axis=-1)
    rank = jnp.argsort(order, axis=-1)          # 0 = largest logit
    k = jnp.where(top_k > 0, top_k, logits.shape[-1])[:, None]
    masked = jnp.where(rank < k, logits, -jnp.inf)
    scaled = masked / jnp.maximum(temp, 1e-30)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temp > 0.0, sampled.astype(jnp.int32), greedy)


def _positional_caches(cfg) -> bool:
    """True when every cached layer state is plain positional attention K/V.

    Recurrent mixers (rwkv/mamba and their channel-mix halves) fold every
    processed token into their state, so a backfill prefill right-padded
    past the true context would corrupt it.  Sliding-window attention is
    excluded too: its K/V cache is *circular* (slot = pos % window), so the
    right-pad junk at positions [cur, curb) would wrap onto slots holding
    real in-window history and be attended as it.  Only plain full-context
    attention caches (slot == position; future slots masked by kpos >= 0,
    then overwritten) survive the right-pad, and they gate the bucketed
    backfill below.
    """
    return all(
        sp.mixer in ("attn", "none") and sp.window is None
        and sp.ffn in ("mlp", "moe", "none")
        for seg in cfg.segments for sp in seg.layers
    )


class LMBackend:
    """Continuous-batching backend over the transformer prefill/decode jits.

    Backfill prefills the newcomer at the full batch width (idle lanes
    zeroed) and merges only its cache rows: the wasted lanes buy two things
    — the prefill compile shape family stays the same as admission's, and a
    backfilled request computes bit-identically to the same request served
    alone at that context length (regression-tested).

    For attention archs the backfill context length is additionally
    *bucketed*: the newcomer's tokens are right-padded from the true
    context length ``cur`` up to the ``len_bucket`` ladder and the first
    token is read at position ``cur - 1`` (`tfm.prefill(logit_pos=...)`),
    so retirements at distinct steps stop compiling a fresh prefill shape
    each — one executable per bucket instead of one per context length.
    The pad rows' K/V junk is causally masked and then overwritten by the
    following decode steps before any query attends it.  Recurrent archs
    (rwkv/mamba) keep the exact-length prefill: their state folds in every
    processed token, pad included (see ROADMAP serving follow-ups).
    """

    def __init__(self, cfg, params, mesh, *, capacity: int,
                 eos_id: int | None = None, len_bucket: int = 16,
                 sample_seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.capacity = capacity
        self.eos_id = eos_id
        self.len_bucket = max(1, len_bucket)
        self.backfill_bucket = (self.len_bucket if _positional_caches(cfg)
                                else 1)
        self.sample_seed = sample_seed
        self._bkey = None
        self._sample = jax.jit(_sample_tokens)
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, capacity=capacity))
        # backfill prefill: logits at a chosen (traced) position, so the
        # compile key is the bucketed token shape only
        self._prefill_at = jax.jit(
            lambda p, b, pos: tfm.prefill(p, b, cfg, capacity=capacity,
                                          logit_pos=pos))
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, c, t, pos, cfg),
            donate_argnums=(1,))
        # scatter one prefilled request's cache rows into the live batch;
        # cache leaves are (repeat, batch, ...) so batch is axis 1
        self._merge = jax.jit(
            lambda caches, new, j: jax.tree.map(
                lambda c, n: c.at[:, j].set(n[:, j]), caches, new),
            donate_argnums=(0,))

    # -- per-slot sampling --------------------------------------------------

    @staticmethod
    def _greedy_lane() -> list:
        return [0.0, 0, -1, 0]           # temperature, top_k, rid, count

    def _base_key(self):
        if self._bkey is None:
            self._bkey = jax.random.PRNGKey(self.sample_seed)
        return self._bkey

    def _emit_tokens(self, state, logits, js):
        """Next token for each slot index in ``js``; ``logits[i]`` is slot
        ``js[i]``'s row.  All-greedy batches keep the legacy plain-argmax
        path (bit-identical, no sampler dispatch); otherwise each sampling
        slot draws with a key folded from (seed, rid, emission count), so
        a request's stream is reproducible wherever its slot lands."""
        sel = [state["samp"][j] for j in js]
        if not any(s[0] > 0 for s in sel):
            return jnp.argmax(logits, -1).astype(jnp.int32)
        temps = jnp.asarray([s[0] for s in sel], jnp.float32)
        topks = jnp.asarray([s[1] for s in sel], jnp.int32)
        base = self._base_key()
        keys = jnp.stack([jax.random.fold_in(
            jax.random.fold_in(base, s[2] & 0x7FFFFFFF), s[3])
            for s in sel])
        toks = self._sample(logits, temps, topks, keys)
        for s in sel:
            s[3] += 1
        return toks

    # -- scheduler protocol -------------------------------------------------

    def validate_request(self, req: Request) -> str | None:
        """Admission-time validation: a reason string refuses the request
        (structured `RequestOutcome`) before it can poison a batch."""
        p = req.prompt
        if not isinstance(p, np.ndarray):
            return f"not_an_array:{type(p).__name__}"
        if p.ndim != 1:
            return f"bad_rank:{p.ndim}"
        if not np.issubdtype(p.dtype, np.integer):
            return f"bad_dtype:{p.dtype}"
        if len(p) == 0:
            return "empty_prompt"
        if req.max_new < 1:
            return f"bad_max_new:{req.max_new}"
        padded = _round_up(len(p), self.len_bucket)
        if padded >= self.capacity:
            return f"prompt_too_long:{padded}>={self.capacity}"
        return None

    def reset(self, req: Request) -> None:
        """Clear partial progress before a fault-displaced re-serve.  The
        regenerated stream is bit-identical: sampling keys fold (seed, rid,
        emission count) and the count restarts at 0 with the request."""
        req.out.clear()

    def bucket_key(self, req: Request):
        return _round_up(max(len(req.prompt), 1), self.len_bucket)

    def sort_key(self, req: Request):
        # longest prompts first: every later backfill then fits the
        # already-grown context (can_backfill below)
        return -len(req.prompt)

    def context(self):
        return shd.use_mesh(self.mesh, shd.SERVE_RULES)

    def start(self, requests: list[Request], width: int):
        lens = [len(r.prompt) for r in requests]
        max_len = _round_up(max(max(lens), 1), self.len_bucket)
        if max_len >= self.capacity:
            raise ValueError(
                f"padded prompt length {max_len} >= capacity {self.capacity}")
        toks = np.zeros((width, max_len), np.int32)
        for i, r in enumerate(requests):  # left-pad
            toks[i, max_len - len(r.prompt):] = r.prompt
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)})
        samp = [[r.temperature, r.top_k, r.rid, 0] for r in requests]
        samp += [self._greedy_lane() for _ in range(width - len(requests))]
        state = {"caches": caches, "nxt": None, "len": max_len, "i": 0,
                 "samp": samp}
        nxt = self._emit_tokens(state, logits, range(width))[:, None]
        state["nxt"] = nxt
        first = np.asarray(nxt[:, 0])
        emis = [int(first[j]) if j < len(requests) else None
                for j in range(width)]
        return state, emis

    def step(self, state, slots):
        logits, caches = self._decode(
            self.params, state["caches"], state["nxt"],
            jnp.int32(state["len"] + state["i"]))
        for j, s in enumerate(slots):
            if s is None:                # retired lane: back to greedy
                state["samp"][j] = self._greedy_lane()
        nxt = self._emit_tokens(state, logits, range(len(slots)))[:, None]
        state.update(caches=caches, nxt=nxt, i=state["i"] + 1)
        toks = np.asarray(nxt[:, 0])
        return state, [int(toks[j]) for j in range(len(slots))]

    def can_backfill(self, state, req: Request) -> bool:
        cur = state["len"] + state["i"]
        return (len(req.prompt) <= cur
                and cur + req.max_new <= self.capacity)

    def backfill(self, state, slot: int, req: Request):
        cur = state["len"] + state["i"]
        width = int(state["nxt"].shape[0])
        # right-pad the context to the bucket ladder: positions [0, cur)
        # are exactly the exact-length prefill's, logits are read at
        # cur - 1, and the junk K/V rows beyond cur are masked/overwritten
        curb = min(_round_up(cur, self.backfill_bucket), self.capacity)
        toks = np.zeros((width, curb), np.int32)
        toks[slot, cur - len(req.prompt):cur] = req.prompt
        logits, caches1 = self._prefill_at(
            self.params, {"tokens": jnp.asarray(toks)}, jnp.int32(cur - 1))
        state["samp"][slot] = [req.temperature, req.top_k, req.rid, 0]
        tok = int(self._emit_tokens(state, logits[slot][None], [slot])[0])
        state["caches"] = self._merge(state["caches"], caches1, slot)
        state["nxt"] = state["nxt"].at[slot, 0].set(tok)
        return state, tok

    def append(self, req: Request, tok: int) -> bool:
        req.out.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.out) >= req.max_new

    def finish(self, state) -> dict:
        jax.block_until_ready(state["nxt"])
        return {}


class Server:
    """Batched LM serving: prefill/decode behind the lockstep scheduler."""

    def __init__(self, cfg, *, batch: int, capacity: int, seed: int = 0,
                 mesh=None, eos_id: int | None = None, len_bucket: int = 16,
                 max_queue: int | None = None):
        assert cfg.embed_inputs, "serving driver expects token-input archs"
        self.cfg = cfg
        self.batch = batch
        self.capacity = capacity
        self.mesh = mesh or make_local_mesh()
        with shd.use_mesh(self.mesh, shd.SERVE_RULES):
            self.params = init_params(
                tfm.lm_schema(cfg), jax.random.PRNGKey(seed), cfg.dtype)
        self.backend = LMBackend(cfg, self.params, self.mesh,
                                 capacity=capacity, eos_id=eos_id,
                                 len_bucket=len_bucket)
        self.scheduler = LockstepScheduler(self.backend, batch=batch,
                                           max_queue=max_queue)

    @property
    def outcomes(self) -> dict:
        """Per-request terminal outcomes of the last `serve` call."""
        return self.scheduler.outcomes

    @staticmethod
    def _legacy_stats(s: dict) -> dict:
        return {
            "prefill_s": s["start_s"],
            "decode_s": s["run_s"],
            "decode_steps": s["steps"],
            "new_tokens": s["emissions"],
            "decode_tok_s": s["emissions"] / max(s["run_s"], 1e-9),
            "finished": s["finished"],
            "backfills": s["backfills"],
        }

    def run_batch(self, requests: list[Request]) -> dict:
        """One lockstep run: the first ``batch`` requests are admitted, the
        rest backfill retired slots.  Returns timing stats.  Raises if a
        request can never join this run (capacity/context limits) — use
        `serve`, which gives leftovers a fresh run, for the general case."""
        queue = list(requests)
        stats = self.scheduler.run_lockstep(queue)
        if queue:
            raise ValueError(
                f"{len(queue)} request(s) could not backfill into this "
                f"lockstep run (capacity/context limits); use serve()")
        return self._legacy_stats(stats)

    def serve(self, requests: list[Request]) -> list[dict]:
        """Bucket the queue by prompt length, then run lockstep batches with
        retirement + backfill until it drains (continuous batching)."""
        return [self._legacy_stats(s)
                for s in self.scheduler.serve(list(requests))]


# --------------------------------------------------------------------------
# CNN backend: SparseNet.apply on padded image batches
# --------------------------------------------------------------------------

class CNNBackend:
    """One-shot image backend: a request finishes in a single lockstep step.

    Slot reuse across waves is the batch-reuse story — the compiled
    (width, H, W, C) executable from `models.graph.BatchedApply` serves
    every wave of a bucket.  ``image_size`` pins the bucket to the net's
    fixed input (Flatten-head nets like VGG); when None the bucket pads
    each image's H/W up to ``pad_multiple`` (size-agnostic nets like the
    GAP-headed ResNets).

    A partial wave (the tail of a drained queue) computes on a batch shrunk
    to the occupied slots — rounded up to the next power of two, capped at
    the full width — instead of padding with zero images that burn full
    sparse-path FLOPs.  The pow2 ladder bounds the compile count per shape
    bucket at log2(width)+1 executables.

    ``step`` is split into ``dispatch`` (build the padded batch and issue
    the jitted apply — JAX async dispatch returns before the device
    finishes) and ``collect`` (block on the result): the fleet scheduler
    dispatches every replica's wave before collecting any, so replicas'
    device work overlaps.  ``mesh``/``rules`` flow to `BatchedApply`'s
    sharded compile path (sharded FC heads — see `ReplicaGroup`).
    """

    def __init__(self, net, params, *, sparse=None, impl: str = "auto",
                 density: float | None = None, image_size: int | None = None,
                 pad_multiple: int = 8, mesh=None, rules=None):
        from repro.models.graph import (BatchedApply, input_refusal,
                                        output_finite)
        self.image_size = image_size
        self.pad_multiple = pad_multiple
        self.channels = next((l.cin for l in net.conv_layers()), None)
        self._input_refusal = input_refusal
        self._output_finite = output_finite
        self.apply = BatchedApply(net, params, sparse=sparse, impl=impl,
                                  key=(density,), mesh=mesh, rules=rules)

    # -- scheduler protocol -------------------------------------------------

    def validate_request(self, req: ImageRequest) -> str | None:
        """Admission-time validation via `models.graph.input_refusal`:
        malformed images (wrong type/rank/dtype, non-finite values,
        oversize for a fixed-input net) become structured refusals."""
        return self._input_refusal(req.image, max_size=self.image_size,
                                   channels=self.channels)

    def check_emission(self, emission) -> bool:
        """Output guard: non-finite logits quarantine the replica that
        produced them (`models.graph.output_finite`)."""
        return self._output_finite(emission)

    def reset(self, req: ImageRequest) -> None:
        req.out.clear()
        req.logits = None

    def bucket_key(self, req: ImageRequest):
        h, w, c = req.image.shape
        if self.image_size is not None:
            if max(h, w) > self.image_size:
                raise ValueError(
                    f"image {h}x{w} exceeds the net's fixed input size "
                    f"{self.image_size}")
            return (self.image_size, self.image_size, c)
        m = self.pad_multiple
        return (_round_up(h, m), _round_up(w, m), c)

    def sort_key(self, req: ImageRequest):
        return req.rid  # arrival order; all images in a bucket are equal

    def start(self, requests: list[ImageRequest], width: int):
        return {"width": width, "bucket": self.bucket_key(requests[0])}, None

    def dispatch(self, state, slots):
        """Issue one wave: pad the occupied slots into a batch and call the
        jitted apply.  The returned handle holds device arrays still in
        flight (JAX async dispatch) — `collect` blocks on them."""
        hb, wb, c = state["bucket"]
        occ = [j for j, r in enumerate(slots) if r is not None]
        # shrink a partial wave to the occupied slots (pow2 ladder): zero
        # images are no longer computed at full sparse-path cost
        nb = min(state["width"], 1 << max(len(occ) - 1, 0).bit_length())
        x = np.zeros((nb, hb, wb, c), np.float32)
        for i, j in enumerate(occ):
            h, w, _ = slots[j].image.shape
            x[i, :h, :w] = slots[j].image
        return occ, self.apply(jnp.asarray(x))

    def collect(self, state, handle, slots):
        occ, y_dev = handle
        y = np.asarray(y_dev)
        emis = [None] * state["width"]
        for i, j in enumerate(occ):
            emis[j] = y[i]
        return state, emis

    def step(self, state, slots):
        return self.collect(state, self.dispatch(state, slots), slots)

    def can_backfill(self, state, req: ImageRequest) -> bool:
        return self.bucket_key(req) == state["bucket"]

    def backfill(self, state, slot: int, req: ImageRequest):
        return state, None  # computed on the next lockstep step

    def append(self, req: ImageRequest, logits) -> bool:
        req.logits = np.asarray(logits)
        req.out.append(int(req.logits.argmax()))
        return True

    def finish(self, state) -> dict:
        return {"compiles": self.apply.compiles}


class ReplicaGroup:
    """N data-parallel CNN backend replicas with device-placed weights.

    The available devices form a (data, model) grid: one device group per
    replica along ``data`` (replicas beyond the grid wrap around, so CPU
    tests run many replicas on one device), and — when ``shard_fc`` — a
    per-replica ``model`` axis over which the FC heads' output strips are
    sharded (`models.graph.shard_sparse`: each device computes its strip
    slice of the cout-sharded `vsmm`, GSPMD all-gathers the logits in the
    epilogue).  Each replica holds its own `jax.device_put` copy of the
    params and sparse trees, so each compiles an executable resident on
    its own devices and the fleet scheduler's dispatch-all-then-collect
    tick overlaps the replicas' device work.
    """

    def __init__(self, net, params, *, sparse=None, impl: str = "auto",
                 density: float | None = None, image_size: int | None = None,
                 pad_multiple: int = 8, replicas: int = 1,
                 shard_fc: bool = False, rules=None, validate: bool = True):
        from repro.models import graph as G
        assert replicas >= 1
        if validate and image_size is not None:
            validate_net(net, image_size, density=density)
        self.replicas = replicas
        self.shard_fc = shard_fc
        self.rules = rules or shd.SERVE_RULES
        ndev = jax.device_count()
        model = max(1, ndev // replicas) if shard_fc else 1
        data = max(1, ndev // model)
        grid = np.array(jax.devices()[: data * model]).reshape(data, model)
        self.meshes: list = []
        self.backends: list[CNNBackend] = []
        for i in range(replicas):
            mesh = jax.sharding.Mesh(grid[i % data], ("model",))
            with shd.use_mesh(mesh, self.rules) as ctx:
                p_i = jax.device_put(
                    params, shd.named_sharding((), ctx=ctx))
                s_i = (None if sparse is None
                       else G.shard_sparse(sparse, ctx=ctx))
            self.meshes.append(mesh)
            self.backends.append(CNNBackend(
                net, p_i, sparse=s_i, impl=impl, density=density,
                image_size=image_size, pad_multiple=pad_multiple,
                mesh=mesh, rules=self.rules))


def validate_net(net, image_size: int, *, density: float | None = None,
                 vk: int = 32, vn: int = 128) -> None:
    """vscheck IR gate before any device placement: walk the net's shapes
    and tile geometry at the serving input size and refuse placement
    (`analysis.VSCheckError`) on structural errors — a malformed net
    otherwise fails mid-compile on one replica after the others already
    hold weights."""
    from repro.analysis.ir import check_net
    cin = next((l.cin for l in net.conv_layers()), 3)
    nc = check_net(net, (1, image_size, image_size, cin),
                   density=density if density is not None else 0.25,
                   vk=vk, vn=vn)
    nc.report.raise_errors()


class CNNServer:
    """Batched CNN serving: `SparseNet.apply` behind the lockstep scheduler.

    ``cfg`` is a VSCNN config (`configs.vscnn_vgg16` / `vscnn_resnet18`):
    ``cfg.build()`` gives the `SparseNet`, ``cfg.weight_density`` the
    default pruning point.  ``sparse=False`` serves the dense jnp path (the
    XLA conv baseline the benchmarks compare against).

    ``replicas > 1`` (or ``shard_fc``) serves a `ReplicaGroup` behind the
    `FleetScheduler` — per-replica wave dispatch over device-placed weight
    copies, with the FC heads optionally cout-sharded over each replica's
    ``model`` devices.  One replica without sharding keeps the exact
    single-backend `LockstepScheduler` path.
    """

    def __init__(self, cfg, *, batch: int, impl: str = "auto",
                 density: float | None = None, sparse: bool = True,
                 dtype: str | None = None,
                 seed: int = 0, pad_multiple: int = 8, replicas: int = 1,
                 shard_fc: bool = False, validate: bool = True,
                 fault_plan: FaultPlan | None = None,
                 max_queue: int | None = None,
                 deadline_waves: int | None = None, max_attempts: int = 3):
        self.cfg = cfg
        self.replicas = replicas
        self.fault_plan = fault_plan
        self.net = cfg.build()
        self.density = cfg.weight_density if density is None else density
        if validate:
            validate_net(self.net, cfg.image_size, density=self.density,
                         vk=cfg.vk, vn=cfg.vn)
        self.params = init_params(
            self.net.schema(), jax.random.PRNGKey(seed), jnp.float32)
        self.sparse = None
        if sparse:
            # dtype="int8" serves the compound sparsity x precision path:
            # per-cout power-of-two weight scales baked in at sparsify time,
            # activations quantized per-tensor at apply time
            self.sparse, _ = self.net.sparsify(
                self.params, self.density, vk=cfg.vk, vn=cfg.vn, dtype=dtype)
        image_size = cfg.image_size if cfg.fixed_image_size else None
        fleet = (replicas > 1 or shard_fc or fault_plan is not None
                 or deadline_waves is not None)
        if not fleet:
            self.backend = CNNBackend(
                self.net, self.params, sparse=self.sparse, impl=impl,
                density=self.density if sparse else None,
                image_size=image_size, pad_multiple=pad_multiple)
            self.backends = [self.backend]
            self.scheduler = LockstepScheduler(self.backend, batch=batch,
                                               max_queue=max_queue)
        else:
            self.group = ReplicaGroup(
                self.net, self.params, sparse=self.sparse, impl=impl,
                density=self.density if sparse else None,
                image_size=image_size, pad_multiple=pad_multiple,
                replicas=replicas, shard_fc=shard_fc, validate=False)
            self.backends = self.group.backends
            if fault_plan is not None:
                self.backends = [ChaosBackend(b, fault_plan, replica=i)
                                 for i, b in enumerate(self.backends)]
            self.backend = self.backends[0]
            self.scheduler = FleetScheduler(
                self.backends, batch=batch, max_queue=max_queue,
                deadline_waves=deadline_waves, max_attempts=max_attempts)

    @property
    def outcomes(self) -> dict:
        """Per-request terminal outcomes of the last `serve` call."""
        return self.scheduler.outcomes

    def serve(self, requests: list[ImageRequest]) -> list[dict]:
        stats = self.scheduler.serve(list(requests))
        for s in stats:
            s["images"] = s.pop("emissions")
            s["images_per_s"] = s["images"] / max(s["run_s"], 1e-9)
        return stats


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def random_prompt_lengths(rng, n: int, max_len: int, lo: int = 8) -> list[int]:
    """n prompt lengths in [lo', max_len) with lo' clamped so the range is
    never empty — ``--prompt-len 8`` used to crash on integers(8, 8)."""
    if max_len < 2:
        raise ValueError(f"--prompt-len must be >= 2, got {max_len}")
    lo = max(1, min(lo, max_len - 1))
    return [int(rng.integers(lo, max_len)) for _ in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LM arch to serve")
    ap.add_argument("--cnn", default=None,
                    help="CNN arch to serve (e.g. vscnn-vgg16) instead of "
                         "an LM")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas", "pallas-halo",
                             "pallas-stack"],
                    help="CNN sparse path: auto = halo Pallas kernels on "
                         "TPU, structural jnp elsewhere")
    ap.add_argument("--replicas", type=int, default=1,
                    help="CNN data-parallel replica fleet size")
    ap.add_argument("--shard-fc", action="store_true",
                    help="cout-shard FC heads over each replica's model-"
                         "axis devices")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="LM sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="LM top-k truncation (0 = full vocab)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="CNN fleet: inject a seeded FaultPlan "
                         "(deterministic chaos; forces the fleet path)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded admission depth (load shedding)")
    ap.add_argument("--deadline-waves", type=int, default=None,
                    help="CNN fleet: per-request deadline in fleet ticks")
    args = ap.parse_args()
    if (args.arch is None) == (args.cnn is None):
        ap.error("choose exactly one of --arch (LM) or --cnn")

    rng = np.random.default_rng(0)
    if args.cnn:
        cfg = get_config(args.cnn).reduce()
        if getattr(cfg, "modality", "lm") != "cnn":
            ap.error(f"{cfg.name} is an LM arch; serve it with --arch")
        s = cfg.image_size
        reqs = [ImageRequest(
                    rid=i,
                    image=rng.standard_normal((s, s, 3)).astype(np.float32))
                for i in range(args.requests)]
        plan = (None if args.chaos_seed is None else FaultPlan.random(
            args.chaos_seed, replicas=max(args.replicas, 1)))
        srv = CNNServer(cfg, batch=args.batch, impl=args.impl,
                        replicas=args.replicas, shard_fc=args.shard_fc,
                        fault_plan=plan, max_queue=args.max_queue,
                        deadline_waves=args.deadline_waves)
        t0 = time.time()
        stats = srv.serve(reqs)
        wall = time.time() - t0
        tot = sum(st["images"] for st in stats)
        print(f"served {tot} images in {len(stats)} lockstep runs, "
              f"{tot / max(wall, 1e-9):.1f} img/s "
              f"(density {srv.density}, batch {args.batch}, "
              f"replicas {args.replicas}"
              f"{', shard-fc' if args.shard_fc else ''}"
              f"{f', chaos seed {args.chaos_seed}' if plan else ''})")
        outcomes = list(srv.outcomes.values())
        refused = [o for o in outcomes if o.status == "refused"]
        if plan is not None or refused:
            print(f"  outcomes: {len(outcomes) - len(refused)} delivered, "
                  f"{len(refused)} refused "
                  f"{sorted({o.reason for o in refused})}")
            if plan is not None:
                sch = srv.scheduler
                print(f"  plan: {plan.describe()}")
                print(f"  health: {sch.health}  "
                      f"faults fired: {len(sch.fault_events)}")
        for st in stats:
            print("  ", {k: (round(v, 4) if isinstance(v, float) else v)
                         for k, v in st.items()})
        return

    cfg = get_config(args.arch).reduce()
    if getattr(cfg, "modality", "lm") != "lm":
        ap.error(f"{cfg.name} is a CNN arch; serve it with --cnn")
    lens = random_prompt_lengths(rng, args.requests, args.prompt_len)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, lens[i], dtype=np.int32),
                max_new=args.tokens, temperature=args.temperature,
                top_k=args.top_k)
        for i in range(args.requests)
    ]
    srv = Server(cfg, batch=args.batch,
                 capacity=_round_up(args.prompt_len, 16) + args.tokens + 8,
                 eos_id=args.eos_id)
    stats = srv.serve(reqs)
    tot_new = sum(s["new_tokens"] for s in stats)
    tot_dec = sum(s["decode_s"] for s in stats)
    print(f"served {len(reqs)} requests in {len(stats)} lockstep runs: "
          f"{tot_new} tokens, {tot_new/max(tot_dec,1e-9):.1f} tok/s decode")
    for s in stats:
        print("  ", {k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in s.items()})


if __name__ == "__main__":
    main()
