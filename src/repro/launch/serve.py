"""Batched inference driver: continuous-batching style serving loop.

Runs end-to-end on CPU with reduced configs; the same prefill/decode jits
lower on the production mesh (that is what decode_32k / long_500k dry-run
cells prove).  Requests arrive with different prompt lengths; the scheduler
left-pads to the batch bucket, prefills once, then decodes the whole batch
in lockstep, retiring sequences that emit EOS and backfilling from the
queue (slot reuse — the KV cache is donated and updated in place).

Usage (CPU example):
  python -m repro.launch.serve --arch rwkv6-3b --requests 16 --tokens 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.launch.mesh import make_local_mesh
from repro.parallel import sharding as shd

__all__ = ["Server", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)


class Server:
    def __init__(self, cfg, *, batch: int, capacity: int, seed: int = 0,
                 mesh=None):
        assert cfg.embed_inputs, "serving driver expects token-input archs"
        self.cfg = cfg
        self.batch = batch
        self.capacity = capacity
        self.mesh = mesh or make_local_mesh()
        with shd.use_mesh(self.mesh, shd.SERVE_RULES):
            self.params = init_params(
                tfm.lm_schema(cfg), jax.random.PRNGKey(seed), cfg.dtype)
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, capacity=capacity))
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, c, t, pos, cfg),
            donate_argnums=(1,))

    def run_batch(self, requests: list[Request]) -> dict:
        """Prefill + decode one lockstep batch. Returns timing stats."""
        cfg = self.cfg
        assert len(requests) <= self.batch
        lens = [len(r.prompt) for r in requests]
        max_len = max(lens)
        toks = np.zeros((self.batch, max_len), np.int32)
        for i, r in enumerate(requests):  # left-pad
            toks[i, max_len - len(r.prompt):] = r.prompt
        with shd.use_mesh(self.mesh, shd.SERVE_RULES):
            t0 = time.time()
            logits, caches = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)})
            logits.block_until_ready()
            t_prefill = time.time() - t0
            nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            max_new = max(r.max_new for r in requests)
            live = np.array([True] * len(requests) +
                            [False] * (self.batch - len(requests)))
            t1 = time.time()
            steps = 0
            for i in range(max_new):
                for j, r in enumerate(requests):
                    if live[j] and len(r.out) < r.max_new:
                        r.out.append(int(nxt[j, 0]))
                    elif live[j]:
                        live[j] = False  # retired; slot idles until backfill
                if not live.any():
                    break
                logits, caches = self._decode(
                    self.params, caches, nxt, jnp.int32(max_len + i))
                nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                steps += 1
            jax.block_until_ready(nxt)
            t_decode = time.time() - t1
        new_tokens = sum(len(r.out) for r in requests)
        return {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_steps": steps,
            "new_tokens": new_tokens,
            "decode_tok_s": new_tokens / max(t_decode, 1e-9),
        }

    def serve(self, requests: list[Request]) -> list[dict]:
        """Bucket the queue into lockstep batches (continuous batching lite)."""
        stats = []
        queue = list(requests)
        while queue:
            batch, queue = queue[: self.batch], queue[self.batch:]
            stats.append(self.run_batch(batch))
        return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduce()
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab,
                                rng.integers(8, args.prompt_len),
                                dtype=np.int32),
            max_new=args.tokens,
        )
        for i in range(args.requests)
    ]
    srv = Server(cfg, batch=args.batch,
                 capacity=args.prompt_len + args.tokens + 8)
    stats = srv.serve(reqs)
    tot_new = sum(s["new_tokens"] for s in stats)
    tot_dec = sum(s["decode_s"] for s in stats)
    print(f"served {len(reqs)} requests in {len(stats)} batches: "
          f"{tot_new} tokens, {tot_new/max(tot_dec,1e-9):.1f} tok/s decode")
    for s in stats:
        print("  ", {k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in s.items()})


if __name__ == "__main__":
    main()
