"""Model-agnostic batched serving: one lockstep scheduler, two backends.

`launch.scheduler.LockstepScheduler` owns the queue, batch bucketing, slot
retirement and backfill; this module plugs in the model math:

* `LMBackend` / `Server` — the production prefill/decode jits with working
  continuous batching.  A sequence retires the moment it emits ``eos_id``
  (or exhausts its ``max_new`` budget) and its slot is backfilled from the
  queue in the same run: the newcomer is prefilled left-padded to the
  current context length and its cache rows are merged into the live batch
  (the KV/state cache is donated and updated in place).  A uniform batch
  with no EOS spends exactly ``max_new - 1`` decode steps — the prefill
  emits each slot's first token, so there is no trailing wasted decode.
  Admission prompt lengths are bucketed (``len_bucket``) so first-wave
  prefill compile shapes stay bounded; on attention archs a backfill
  prefill right-pads the context to the same bucket ladder and reads its
  logits at the true position, so backfill shapes are bounded too (one
  executable per bucket, not one per retirement step).  Recurrent archs
  (rwkv/mamba) keep the exact-length backfill prefill — their state folds
  in every processed token — see the ROADMAP serving follow-ups.

* `CNNBackend` / `CNNServer` — CNN inference traffic through
  `SparseNet.apply`: requests carry images, batches pad/bucket on image
  shape, every request finishes in one lockstep step, and freed slots are
  refilled from the queue so the compiled batch shape is reused wave after
  wave; a partial final wave shrinks to its occupied slots (pow2 ladder)
  instead of computing zero images.  A jit cache keyed on (net, density,
  impl, batch bucket) — see `models.graph.BatchedApply` — keeps recompiles
  off the hot path; ``impl`` defaults to ``auto`` (the halo-layout Pallas
  conv kernels on TPU, the structural jnp path elsewhere).

Both run end-to-end on CPU with reduced configs; the LM jits are the same
step functions the decode_32k / long_500k dry-run cells lower on the
production mesh.

Usage (CPU examples):
  python -m repro.launch.serve --arch rwkv6-3b --requests 16 --tokens 32
  python -m repro.launch.serve --cnn vscnn-vgg16 --requests 16 --batch 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.scheduler import LockstepScheduler
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.parallel import sharding as shd

__all__ = [
    "Request", "ImageRequest", "LMBackend", "CNNBackend",
    "Server", "CNNServer", "random_prompt_lengths", "main",
]


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@dataclasses.dataclass
class Request:
    """One LM generation request."""

    rid: int
    prompt: np.ndarray           # (L,) int32
    max_new: int
    out: list = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ImageRequest:
    """One CNN inference request."""

    rid: int
    image: np.ndarray            # (H, W, C) float
    max_new: int = 1             # one-shot: a single emission finishes it
    out: list = dataclasses.field(default_factory=list)  # [predicted class]
    logits: np.ndarray | None = None


# --------------------------------------------------------------------------
# LM backend: prefill/decode lockstep with EOS retirement + cache-merge
# backfill
# --------------------------------------------------------------------------

def _positional_caches(cfg) -> bool:
    """True when every cached layer state is plain positional attention K/V.

    Recurrent mixers (rwkv/mamba and their channel-mix halves) fold every
    processed token into their state, so a backfill prefill right-padded
    past the true context would corrupt it.  Sliding-window attention is
    excluded too: its K/V cache is *circular* (slot = pos % window), so the
    right-pad junk at positions [cur, curb) would wrap onto slots holding
    real in-window history and be attended as it.  Only plain full-context
    attention caches (slot == position; future slots masked by kpos >= 0,
    then overwritten) survive the right-pad, and they gate the bucketed
    backfill below.
    """
    return all(
        sp.mixer in ("attn", "none") and sp.window is None
        and sp.ffn in ("mlp", "moe", "none")
        for seg in cfg.segments for sp in seg.layers
    )


class LMBackend:
    """Continuous-batching backend over the transformer prefill/decode jits.

    Backfill prefills the newcomer at the full batch width (idle lanes
    zeroed) and merges only its cache rows: the wasted lanes buy two things
    — the prefill compile shape family stays the same as admission's, and a
    backfilled request computes bit-identically to the same request served
    alone at that context length (regression-tested).

    For attention archs the backfill context length is additionally
    *bucketed*: the newcomer's tokens are right-padded from the true
    context length ``cur`` up to the ``len_bucket`` ladder and the first
    token is read at position ``cur - 1`` (`tfm.prefill(logit_pos=...)`),
    so retirements at distinct steps stop compiling a fresh prefill shape
    each — one executable per bucket instead of one per context length.
    The pad rows' K/V junk is causally masked and then overwritten by the
    following decode steps before any query attends it.  Recurrent archs
    (rwkv/mamba) keep the exact-length prefill: their state folds in every
    processed token, pad included (see ROADMAP serving follow-ups).
    """

    def __init__(self, cfg, params, mesh, *, capacity: int,
                 eos_id: int | None = None, len_bucket: int = 16):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.capacity = capacity
        self.eos_id = eos_id
        self.len_bucket = max(1, len_bucket)
        self.backfill_bucket = (self.len_bucket if _positional_caches(cfg)
                                else 1)
        self._prefill = jax.jit(
            lambda p, b: tfm.prefill(p, b, cfg, capacity=capacity))
        # backfill prefill: logits at a chosen (traced) position, so the
        # compile key is the bucketed token shape only
        self._prefill_at = jax.jit(
            lambda p, b, pos: tfm.prefill(p, b, cfg, capacity=capacity,
                                          logit_pos=pos))
        self._decode = jax.jit(
            lambda p, c, t, pos: tfm.decode_step(p, c, t, pos, cfg),
            donate_argnums=(1,))
        # scatter one prefilled request's cache rows into the live batch;
        # cache leaves are (repeat, batch, ...) so batch is axis 1
        self._merge = jax.jit(
            lambda caches, new, j: jax.tree.map(
                lambda c, n: c.at[:, j].set(n[:, j]), caches, new),
            donate_argnums=(0,))

    # -- scheduler protocol -------------------------------------------------

    def bucket_key(self, req: Request):
        return _round_up(max(len(req.prompt), 1), self.len_bucket)

    def sort_key(self, req: Request):
        # longest prompts first: every later backfill then fits the
        # already-grown context (can_backfill below)
        return -len(req.prompt)

    def context(self):
        return shd.use_mesh(self.mesh, shd.SERVE_RULES)

    def start(self, requests: list[Request], width: int):
        lens = [len(r.prompt) for r in requests]
        max_len = _round_up(max(max(lens), 1), self.len_bucket)
        if max_len >= self.capacity:
            raise ValueError(
                f"padded prompt length {max_len} >= capacity {self.capacity}")
        toks = np.zeros((width, max_len), np.int32)
        for i, r in enumerate(requests):  # left-pad
            toks[i, max_len - len(r.prompt):] = r.prompt
        logits, caches = self._prefill(
            self.params, {"tokens": jnp.asarray(toks)})
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        state = {"caches": caches, "nxt": nxt, "len": max_len, "i": 0}
        first = np.asarray(nxt[:, 0])
        emis = [int(first[j]) if j < len(requests) else None
                for j in range(width)]
        return state, emis

    def step(self, state, slots):
        logits, caches = self._decode(
            self.params, state["caches"], state["nxt"],
            jnp.int32(state["len"] + state["i"]))
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        state.update(caches=caches, nxt=nxt, i=state["i"] + 1)
        toks = np.asarray(nxt[:, 0])
        return state, [int(toks[j]) for j in range(len(slots))]

    def can_backfill(self, state, req: Request) -> bool:
        cur = state["len"] + state["i"]
        return (len(req.prompt) <= cur
                and cur + req.max_new <= self.capacity)

    def backfill(self, state, slot: int, req: Request):
        cur = state["len"] + state["i"]
        width = int(state["nxt"].shape[0])
        # right-pad the context to the bucket ladder: positions [0, cur)
        # are exactly the exact-length prefill's, logits are read at
        # cur - 1, and the junk K/V rows beyond cur are masked/overwritten
        curb = min(_round_up(cur, self.backfill_bucket), self.capacity)
        toks = np.zeros((width, curb), np.int32)
        toks[slot, cur - len(req.prompt):cur] = req.prompt
        logits, caches1 = self._prefill_at(
            self.params, {"tokens": jnp.asarray(toks)}, jnp.int32(cur - 1))
        tok = int(jnp.argmax(logits[slot], -1))
        state["caches"] = self._merge(state["caches"], caches1, slot)
        state["nxt"] = state["nxt"].at[slot, 0].set(tok)
        return state, tok

    def append(self, req: Request, tok: int) -> bool:
        req.out.append(tok)
        if self.eos_id is not None and tok == self.eos_id:
            return True
        return len(req.out) >= req.max_new

    def finish(self, state) -> dict:
        jax.block_until_ready(state["nxt"])
        return {}


class Server:
    """Batched LM serving: prefill/decode behind the lockstep scheduler."""

    def __init__(self, cfg, *, batch: int, capacity: int, seed: int = 0,
                 mesh=None, eos_id: int | None = None, len_bucket: int = 16):
        assert cfg.embed_inputs, "serving driver expects token-input archs"
        self.cfg = cfg
        self.batch = batch
        self.capacity = capacity
        self.mesh = mesh or make_local_mesh()
        with shd.use_mesh(self.mesh, shd.SERVE_RULES):
            self.params = init_params(
                tfm.lm_schema(cfg), jax.random.PRNGKey(seed), cfg.dtype)
        self.backend = LMBackend(cfg, self.params, self.mesh,
                                 capacity=capacity, eos_id=eos_id,
                                 len_bucket=len_bucket)
        self.scheduler = LockstepScheduler(self.backend, batch=batch)

    @staticmethod
    def _legacy_stats(s: dict) -> dict:
        return {
            "prefill_s": s["start_s"],
            "decode_s": s["run_s"],
            "decode_steps": s["steps"],
            "new_tokens": s["emissions"],
            "decode_tok_s": s["emissions"] / max(s["run_s"], 1e-9),
            "finished": s["finished"],
            "backfills": s["backfills"],
        }

    def run_batch(self, requests: list[Request]) -> dict:
        """One lockstep run: the first ``batch`` requests are admitted, the
        rest backfill retired slots.  Returns timing stats.  Raises if a
        request can never join this run (capacity/context limits) — use
        `serve`, which gives leftovers a fresh run, for the general case."""
        queue = list(requests)
        stats = self.scheduler.run_lockstep(queue)
        if queue:
            raise ValueError(
                f"{len(queue)} request(s) could not backfill into this "
                f"lockstep run (capacity/context limits); use serve()")
        return self._legacy_stats(stats)

    def serve(self, requests: list[Request]) -> list[dict]:
        """Bucket the queue by prompt length, then run lockstep batches with
        retirement + backfill until it drains (continuous batching)."""
        return [self._legacy_stats(s)
                for s in self.scheduler.serve(list(requests))]


# --------------------------------------------------------------------------
# CNN backend: SparseNet.apply on padded image batches
# --------------------------------------------------------------------------

class CNNBackend:
    """One-shot image backend: a request finishes in a single lockstep step.

    Slot reuse across waves is the batch-reuse story — the compiled
    (width, H, W, C) executable from `models.graph.BatchedApply` serves
    every wave of a bucket.  ``image_size`` pins the bucket to the net's
    fixed input (Flatten-head nets like VGG); when None the bucket pads
    each image's H/W up to ``pad_multiple`` (size-agnostic nets like the
    GAP-headed ResNets).

    A partial wave (the tail of a drained queue) computes on a batch shrunk
    to the occupied slots — rounded up to the next power of two, capped at
    the full width — instead of padding with zero images that burn full
    sparse-path FLOPs.  The pow2 ladder bounds the compile count per shape
    bucket at log2(width)+1 executables.
    """

    def __init__(self, net, params, *, sparse=None, impl: str = "auto",
                 density: float | None = None, image_size: int | None = None,
                 pad_multiple: int = 8):
        from repro.models.graph import BatchedApply
        self.image_size = image_size
        self.pad_multiple = pad_multiple
        self.apply = BatchedApply(net, params, sparse=sparse, impl=impl,
                                  key=(density,))

    # -- scheduler protocol -------------------------------------------------

    def bucket_key(self, req: ImageRequest):
        h, w, c = req.image.shape
        if self.image_size is not None:
            if max(h, w) > self.image_size:
                raise ValueError(
                    f"image {h}x{w} exceeds the net's fixed input size "
                    f"{self.image_size}")
            return (self.image_size, self.image_size, c)
        m = self.pad_multiple
        return (_round_up(h, m), _round_up(w, m), c)

    def sort_key(self, req: ImageRequest):
        return req.rid  # arrival order; all images in a bucket are equal

    def start(self, requests: list[ImageRequest], width: int):
        return {"width": width, "bucket": self.bucket_key(requests[0])}, None

    def step(self, state, slots):
        hb, wb, c = state["bucket"]
        occ = [j for j, r in enumerate(slots) if r is not None]
        # shrink a partial wave to the occupied slots (pow2 ladder): zero
        # images are no longer computed at full sparse-path cost
        nb = min(state["width"], 1 << max(len(occ) - 1, 0).bit_length())
        x = np.zeros((nb, hb, wb, c), np.float32)
        for i, j in enumerate(occ):
            h, w, _ = slots[j].image.shape
            x[i, :h, :w] = slots[j].image
        y = np.asarray(self.apply(jnp.asarray(x)))
        emis = [None] * state["width"]
        for i, j in enumerate(occ):
            emis[j] = y[i]
        return state, emis

    def can_backfill(self, state, req: ImageRequest) -> bool:
        return self.bucket_key(req) == state["bucket"]

    def backfill(self, state, slot: int, req: ImageRequest):
        return state, None  # computed on the next lockstep step

    def append(self, req: ImageRequest, logits) -> bool:
        req.logits = np.asarray(logits)
        req.out.append(int(req.logits.argmax()))
        return True

    def finish(self, state) -> dict:
        return {"compiles": self.apply.compiles}


class CNNServer:
    """Batched CNN serving: `SparseNet.apply` behind the lockstep scheduler.

    ``cfg`` is a VSCNN config (`configs.vscnn_vgg16` / `vscnn_resnet18`):
    ``cfg.build()`` gives the `SparseNet`, ``cfg.weight_density`` the
    default pruning point.  ``sparse=False`` serves the dense jnp path (the
    XLA conv baseline the benchmarks compare against).
    """

    def __init__(self, cfg, *, batch: int, impl: str = "auto",
                 density: float | None = None, sparse: bool = True,
                 seed: int = 0, pad_multiple: int = 8):
        self.cfg = cfg
        self.net = cfg.build()
        self.params = init_params(
            self.net.schema(), jax.random.PRNGKey(seed), jnp.float32)
        self.density = cfg.weight_density if density is None else density
        self.sparse = None
        if sparse:
            self.sparse, _ = self.net.sparsify(
                self.params, self.density, vk=cfg.vk, vn=cfg.vn)
        image_size = cfg.image_size if cfg.fixed_image_size else None
        self.backend = CNNBackend(
            self.net, self.params, sparse=self.sparse, impl=impl,
            density=self.density if sparse else None,
            image_size=image_size, pad_multiple=pad_multiple)
        self.scheduler = LockstepScheduler(self.backend, batch=batch)

    def serve(self, requests: list[ImageRequest]) -> list[dict]:
        stats = self.scheduler.serve(list(requests))
        for s in stats:
            s["images"] = s.pop("emissions")
            s["images_per_s"] = s["images"] / max(s["run_s"], 1e-9)
        return stats


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def random_prompt_lengths(rng, n: int, max_len: int, lo: int = 8) -> list[int]:
    """n prompt lengths in [lo', max_len) with lo' clamped so the range is
    never empty — ``--prompt-len 8`` used to crash on integers(8, 8)."""
    if max_len < 2:
        raise ValueError(f"--prompt-len must be >= 2, got {max_len}")
    lo = max(1, min(lo, max_len - 1))
    return [int(rng.integers(lo, max_len)) for _ in range(n)]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="LM arch to serve")
    ap.add_argument("--cnn", default=None,
                    help="CNN arch to serve (e.g. vscnn-vgg16) instead of "
                         "an LM")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "jnp", "pallas", "pallas-halo",
                             "pallas-stack"],
                    help="CNN sparse path: auto = halo Pallas kernels on "
                         "TPU, structural jnp elsewhere")
    args = ap.parse_args()
    if (args.arch is None) == (args.cnn is None):
        ap.error("choose exactly one of --arch (LM) or --cnn")

    rng = np.random.default_rng(0)
    if args.cnn:
        cfg = get_config(args.cnn).reduce()
        if getattr(cfg, "modality", "lm") != "cnn":
            ap.error(f"{cfg.name} is an LM arch; serve it with --arch")
        s = cfg.image_size
        reqs = [ImageRequest(
                    rid=i,
                    image=rng.standard_normal((s, s, 3)).astype(np.float32))
                for i in range(args.requests)]
        srv = CNNServer(cfg, batch=args.batch, impl=args.impl)
        t0 = time.time()
        stats = srv.serve(reqs)
        wall = time.time() - t0
        tot = sum(st["images"] for st in stats)
        print(f"served {tot} images in {len(stats)} lockstep runs, "
              f"{tot / max(wall, 1e-9):.1f} img/s "
              f"(density {srv.density}, batch {args.batch})")
        for st in stats:
            print("  ", {k: (round(v, 4) if isinstance(v, float) else v)
                         for k, v in st.items()})
        return

    cfg = get_config(args.arch).reduce()
    if getattr(cfg, "modality", "lm") != "lm":
        ap.error(f"{cfg.name} is a CNN arch; serve it with --cnn")
    lens = random_prompt_lengths(rng, args.requests, args.prompt_len)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab, lens[i], dtype=np.int32),
                max_new=args.tokens)
        for i in range(args.requests)
    ]
    srv = Server(cfg, batch=args.batch,
                 capacity=_round_up(args.prompt_len, 16) + args.tokens + 8,
                 eos_id=args.eos_id)
    stats = srv.serve(reqs)
    tot_new = sum(s["new_tokens"] for s in stats)
    tot_dec = sum(s["decode_s"] for s in stats)
    print(f"served {len(reqs)} requests in {len(stats)} lockstep runs: "
          f"{tot_new} tokens, {tot_new/max(tot_dec,1e-9):.1f} tok/s decode")
    for s in stats:
        print("  ", {k: (round(v, 4) if isinstance(v, float) else v)
                     for k, v in s.items()})


if __name__ == "__main__":
    main()
