import os
# vscheck: ignore[VSC303] — must run before the jax import below
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init, and the production meshes need 512 placeholder host
devices (16x16 single pod, 2x16x16 multi-pod).

Per cell this script:
  1. builds the step function + ShapeDtypeStruct inputs + shardings,
  2. jit(...).lower(...).compile()  — proving the distribution config is
     coherent (sharding mismatches / unsupported collectives fail here),
  3. prints compiled.memory_analysis()  (fits-in-HBM proof),
  4. derives the three roofline terms (utils.hlo + utils.roofline) and
     appends a row to the results JSON consumed by EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma3-12b --shape train_4k
  python -m repro.launch.dryrun --all [--multipod] [--out results.json]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import SHAPES, get_config, list_archs
from repro.launch import step_builders as sb
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.parallel import sharding as shd
from repro.utils import hlo, roofline

__all__ = ["run_cell", "main"]


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             rules: shd.MeshRules | None = None, verbose: bool = True,
             keep_text: bool = False, overrides: dict | None = None,
             tag: str = "") -> dict:
    import dataclasses
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    reason = cfg.supported_shapes()[shape_name]
    if reason:
        row = {"arch": arch, "shape": shape_name,
               "mesh": "pod2x16x16" if multi_pod else "pod16x16",
               "status": "skip", "reason": reason}
        if verbose:
            print(f"SKIP  {arch} x {shape_name}: {reason}")
        return row

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or shd.TRAIN_RULES
    t0 = time.time()
    with shd.use_mesh(mesh, rules) as ctx:
        art = sb.build(cfg, shape, ctx)
        jitted = jax.jit(
            art.fn,
            in_shardings=art.in_shardings,
            out_shardings=art.out_shardings,
            donate_argnums=art.donate,
        )
        lowered = jitted.lower(*art.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        text = compiled.as_text()

    cost = hlo.analyze(text)
    rep = roofline.report(
        arch=arch, shape=shape_name, mesh_name=mesh_name(mesh),
        chips=mesh.size, cost=cost,
        model_flops=sb.model_flops(cfg, shape), mem_stats=mem,
    )
    row = rep.row()
    row.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               hlo_bytes=len(text), tag=tag,
               overrides={k: str(v) for k, v in (overrides or {}).items()})
    if keep_text:
        row["_hlo_text"] = text
    if verbose:
        print(rep.summary())
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s "
              f"| HLO {len(text)/1e6:.1f} MB")
        print("  " + hlo.collective_report(cost).replace("\n", "\n  "))
    return row


OPTIMIZED_FLAGS = {
    # validated by the §Perf hillclimb (benchmarks/hillclimb.py)
    "train": {"bf16_flow": True, "flash_remat": True},     # + per-arch mb
    "prefill": {"bf16_flow": True},
    "decode": {"moe_dispatch": "resident", "bf16_flow": True},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the hillclimb-validated beyond-paper flags")
    ap.add_argument("--out", default="benchmarks/results/dryrun.json")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_archs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    rows = []
    for arch, shape in cells:
        print(f"=== {arch} x {shape} "
              f"({'2x16x16' if args.multipod else '16x16'}) ===", flush=True)
        overrides = None
        if args.optimized:
            overrides = dict(OPTIMIZED_FLAGS[SHAPES[shape].kind])
            if SHAPES[shape].kind != "train":
                overrides.pop("flash_remat", None)
        elif not args.optimized:
            # baseline semantics: no microbatching (configs carry tuned
            # defaults for the optimized sweep)
            overrides = {"microbatches": 1}
        try:
            rows.append(run_cell(arch, shape, multi_pod=args.multipod,
                                 overrides=overrides,
                                 tag="optimized" if args.optimized else "baseline"))
        # vscheck: ignore[VSC304] — sweep driver, not a serving fault path
        except Exception as e:  # a failing cell is a bug; record and continue
            traceback.print_exc()
            rows.append({"arch": arch, "shape": shape, "status": "error",
                         "error": f"{type(e).__name__}: {e}"})
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    suffix = "_multipod" if args.multipod else ""
    out = args.out.replace(".json", f"{suffix}.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1, default=str)
    ok = sum(r.get("status") == "ok" for r in rows)
    skip = sum(r.get("status") == "skip" for r in rows)
    err = sum(r.get("status") == "error" for r in rows)
    print(f"\n{ok} ok / {skip} skip / {err} error -> {out}")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
