"""Build (step_fn, ShapeDtypeStruct inputs, shardings) for train/prefill/decode.

Shared by the dry-run (lower + compile, no allocation), the trainer, and the
server.  Everything here derives from the param schema: input_specs are
ShapeDtypeStructs (weak-type-correct, shardable, no device memory), and every
sharding comes from the logical-axes trees via the active MeshRules.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.configs.base import ArchConfig, ShapeSpec
from repro.models import transformer as tfm
from repro.models.layers import axes_tree, init_params, is_param
from repro.optim import adamw, adafactor, clip_by_global_norm
from repro.optim.schedules import warmup_cosine
from repro.parallel import sharding as shd

__all__ = [
    "make_optimizer", "param_structs", "param_shardings", "opt_state_axes",
    "build_train", "build_prefill", "build_decode", "model_flops",
]


def make_optimizer(cfg: ArchConfig):
    if cfg.optimizer == "adafactor":
        return adafactor()
    return adamw()


# ---------------------------------------------------------------------------
# structures + shardings
# ---------------------------------------------------------------------------


def param_structs(cfg: ArchConfig):
    schema = tfm.lm_schema(cfg)
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: init_params(schema, key, cfg.dtype)), schema


def _shard(axes, shape, ctx):
    return NamedSharding(
        ctx.mesh, shd.spec_for(axes, mesh=ctx.mesh, rules=ctx.rules, shape=shape)
    )


def tree_shardings(axes_tr, struct_tr, ctx):
    return jax.tree.map(
        lambda a, s: _shard(a, s.shape, ctx),
        axes_tr, struct_tr,
        is_leaf=lambda t: isinstance(t, tuple),
    )


def param_shardings(cfg: ArchConfig, ctx, schema=None):
    schema = schema or tfm.lm_schema(cfg)
    structs = jax.eval_shape(
        lambda: init_params(schema, jax.random.PRNGKey(0), cfg.dtype)
    )
    return tree_shardings(axes_tree(schema), structs, ctx), structs


def opt_state_axes(cfg: ArchConfig, schema):
    """Logical-axes tree matching the optimizer state structure."""
    p_axes = axes_tree(schema)
    if cfg.optimizer == "adafactor":
        opt = adafactor()
        moments = jax.tree.map(
            lambda p: opt.state_axes(p.axes, p.shape), schema, is_leaf=is_param
        )
        return {"moments": moments, "count": ()}
    return {"m": p_axes, "v": p_axes, "count": ()}


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------


def _train_batch(cfg: ArchConfig, shape: ShapeSpec, ctx):
    b, t = shape.global_batch, shape.seq_len
    structs, axes = {}, {}
    if cfg.embed_inputs:
        structs["tokens"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
        axes["tokens"] = ("batch", "seq")
    else:
        structs["embeds"] = jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype)
        axes["embeds"] = ("batch", "seq", None)
    structs["labels"] = jax.ShapeDtypeStruct((b, t), jnp.int32)
    axes["labels"] = ("batch", "seq")
    shards = {k: _shard(axes[k], structs[k].shape, ctx) for k in structs}
    return structs, shards


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepArtifacts:
    fn: object                 # python callable
    args: tuple                # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: object      # tree or None
    donate: tuple


def build_train(cfg: ArchConfig, shape: ShapeSpec, ctx,
                *, grad_clip: float = 1.0) -> StepArtifacts:
    opt = make_optimizer(cfg)
    lr_fn = warmup_cosine(3e-4, 200, 10_000)
    schema = tfm.lm_schema(cfg)
    p_struct, _ = param_structs(cfg)
    p_shard = tree_shardings(axes_tree(schema), p_struct, ctx)
    s_struct = jax.eval_shape(opt.init, p_struct)
    s_shard = tree_shardings(opt_state_axes(cfg, schema), s_struct, ctx)
    b_struct, b_shard = _train_batch(cfg, shape, ctx)
    step_struct = jax.ShapeDtypeStruct((), jnp.int32)
    repl = NamedSharding(ctx.mesh, PS())
    mb = cfg.microbatches
    assert shape.global_batch % max(mb, 1) == 0, (shape.global_batch, mb)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            tfm.loss_fn, has_aux=True)(params, batch, cfg)
        return loss, metrics, grads

    def train_step(params, opt_state, batch, step):
        if mb <= 1:
            loss, metrics, grads = grads_of(params, batch)
        else:
            # gradient accumulation: activation memory / mb, one optimizer
            # step.  fp32 accumulators keep the sum exact across microbatches.
            batch_mb = jax.tree.map(
                lambda a: a.reshape(mb, a.shape[0] // mb, *a.shape[1:]), batch)

            def one(carry, b_i):
                g_acc, l_acc, c_acc, a_acc = carry
                loss, metrics, grads = grads_of(params, b_i)
                # NOTE §Perf A-iterations: pinning grads/accumulator to the
                # param shardings here (with_sharding_constraint) was tried
                # and REVERTED — it forced ~40 GB of extra accumulator
                # materialization (A9) for no collective win over A7/A8.
                g_acc = jax.tree.map(
                    lambda a, g: a + (g.astype(jnp.float32) / mb).astype(a.dtype),
                    g_acc, grads)
                return (g_acc, l_acc + loss / mb, c_acc + metrics["ce"] / mb,
                        a_acc + metrics["aux"] / mb), None

            acc_dt = jnp.dtype(cfg.grad_accum_dtype)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            z = jnp.zeros((), jnp.float32)
            (grads, loss, ce, aux), _ = jax.lax.scan(
                one, (g0, z, z, z), batch_mb)
            metrics = {"ce": ce, "aux": aux}
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params, lr_fn(step))
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    metrics_shard = {"ce": repl, "aux": repl, "loss": repl, "grad_norm": repl}
    return StepArtifacts(
        fn=train_step,
        args=(p_struct, s_struct, b_struct, step_struct),
        in_shardings=(p_shard, s_shard, b_shard, repl),
        out_shardings=(p_shard, s_shard, metrics_shard),
        donate=(0, 1),
    )


def build_prefill(cfg: ArchConfig, shape: ShapeSpec, ctx) -> StepArtifacts:
    b, t = shape.global_batch, shape.seq_len
    capacity = t
    schema = tfm.lm_schema(cfg)
    p_struct, _ = param_structs(cfg)
    p_shard = tree_shardings(axes_tree(schema), p_struct, ctx)
    if cfg.embed_inputs:
        batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        b_shard = {"tokens": _shard(("batch", "seq"), (b, t), ctx)}
    else:
        batch = {"embeds": jax.ShapeDtypeStruct((b, t, cfg.d_model), cfg.dtype)}
        b_shard = {"embeds": _shard(("batch", "seq", None), (b, t, cfg.d_model), ctx)}

    fn = functools.partial(_prefill_fn, cfg=cfg, capacity=capacity)
    if cfg.encoder_only:
        # encoder inference emits per-position logits, no cache
        out_shard = _shard(("batch", "seq", "vocab"),
                           (b, t, cfg.padded_vocab), ctx)
    else:
        c_struct = jax.eval_shape(lambda: tfm.init_cache(cfg, b, capacity))
        c_shard = tree_shardings(tfm.cache_axes(cfg), c_struct, ctx)
        logits_shard = _shard(("batch", "vocab"), (b, cfg.padded_vocab), ctx)
        out_shard = (logits_shard, c_shard)
    return StepArtifacts(
        fn=fn,
        args=(p_struct, batch),
        in_shardings=(p_shard, b_shard),
        out_shardings=out_shard,
        donate=(),
    )


def _prefill_fn(params, batch, *, cfg, capacity):
    if cfg.encoder_only:
        # encoder inference: per-position logits, no cache
        return tfm.lm_apply(params, batch, cfg)
    return tfm.prefill(params, batch, cfg, capacity=capacity)


def build_decode(cfg: ArchConfig, shape: ShapeSpec, ctx) -> StepArtifacts:
    b, t = shape.global_batch, shape.seq_len
    schema = tfm.lm_schema(cfg)
    p_struct, _ = param_structs(cfg)
    p_shard = tree_shardings(axes_tree(schema), p_struct, ctx)
    c_struct = jax.eval_shape(lambda: tfm.init_cache(cfg, b, t))
    c_shard = tree_shardings(tfm.cache_axes(cfg), c_struct, ctx)
    if cfg.embed_inputs:
        tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        tok_shard = _shard(("batch", None), (b, 1), ctx)
    else:
        tok = jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.dtype)
        tok_shard = _shard(("batch", None, None), (b, 1, cfg.d_model), ctx)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    repl = NamedSharding(ctx.mesh, PS())
    logits_shard = _shard(("batch", "vocab"), (b, cfg.padded_vocab), ctx)

    def decode(params, caches, tokens, pos):
        return tfm.decode_step(params, caches, tokens, pos, cfg)

    return StepArtifacts(
        fn=decode,
        args=(p_struct, c_struct, tok, pos),
        in_shardings=(p_shard, c_shard, tok_shard, repl),
        out_shardings=(logits_shard, c_shard),
        donate=(1,),
    )


def build(cfg: ArchConfig, shape: ShapeSpec, ctx) -> StepArtifacts:
    if shape.kind == "train":
        return build_train(cfg, shape, ctx)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, ctx)
    return build_decode(cfg, shape, ctx)


# ---------------------------------------------------------------------------
# useful-work reference FLOPs
# ---------------------------------------------------------------------------


def model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """6*N_active*D for training, 2*N_active*D for inference (global;
    attention-score FLOPs excluded by convention)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
