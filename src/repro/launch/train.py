"""Fault-tolerant training loop (runs end-to-end on CPU with reduced
configs; the same loop drives the production mesh on real hardware).

Features exercised here and required at 1000+ node scale:
  * auto-resume: restarts pick up the latest complete checkpoint and the
    data pipeline skips to the right step deterministically,
  * atomic async checkpoints (never blocks the step loop),
  * straggler detection: per-step wall time against a rolling median, slow
    steps logged + counted (on a real cluster this feeds preemption/
    replacement; here it is simulated on the host),
  * heartbeat file for external watchdogs,
  * optional fp8-block cross-pod gradient compression (--grad-compression).

Usage (CPU example):
  python -m repro.launch.train --arch qwen1.5-4b --smoke --steps 50
"""
from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import LMBatchSpec, SyntheticLM, SyntheticEmbeds
from repro.launch import step_builders as sb
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.parallel import sharding as shd

__all__ = ["TrainLoop", "main"]


class StragglerMonitor:
    """Rolling-median step-time watchdog (simulated straggler mitigation)."""

    def __init__(self, window: int = 32, factor: float = 3.0):
        self.times: list[float] = []
        self.window = window
        self.factor = factor
        self.events = 0

    def observe(self, dt: float) -> bool:
        slow = False
        if len(self.times) >= 8:
            med = statistics.median(self.times[-self.window:])
            if dt > self.factor * med:
                self.events += 1
                slow = True
        self.times.append(dt)
        return slow


class TrainLoop:
    def __init__(self, cfg, *, batch: int, seq: int, ckpt_dir: str | None,
                 ckpt_every: int = 50, seed: int = 0, mesh=None,
                 rules=None):
        self.cfg = cfg
        self.batch, self.seq = batch, seq
        self.mesh = mesh or make_local_mesh()
        self.rules = rules or shd.TRAIN_RULES
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        spec = LMBatchSpec(global_batch=batch, seq_len=seq,
                           vocab=cfg.vocab, n_shards=1, shard=0)
        if cfg.embed_inputs:
            self.data = SyntheticLM(spec, seed=seed)
        else:
            self.data = SyntheticEmbeds(spec, cfg.d_model, seed=seed)
        self.opt = sb.make_optimizer(cfg)
        self.monitor = StragglerMonitor()
        self._build(seed)

    def _build(self, seed):
        cfg = self.cfg
        with shd.use_mesh(self.mesh, self.rules) as ctx:
            from repro.configs.base import ShapeSpec
            shape = ShapeSpec("custom", self.seq, self.batch, "train")
            art = sb.build_train(cfg, shape, ctx)
            self.step_fn = jax.jit(
                art.fn, in_shardings=art.in_shardings,
                out_shardings=art.out_shardings, donate_argnums=art.donate,
            )
            self.batch_shardings = art.in_shardings[2]
        self.ctx_args = (self.mesh, self.rules)

    def init_state(self, seed: int = 0):
        cfg = self.cfg
        with shd.use_mesh(*self.ctx_args):
            params = init_params(tfm.lm_schema(cfg), jax.random.PRNGKey(seed),
                                 cfg.dtype)
            opt_state = self.opt.init(params)
        return params, opt_state, 0

    def maybe_resume(self):
        """Returns (params, opt_state, start_step); resumes if possible."""
        params, opt_state, step = self.init_state()
        if self.ckpt and self.ckpt.latest_step() is not None:
            target = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                {"params": params, "opt": opt_state},
            )
            tree, ck_step, _ = self.ckpt.restore(target)
            print(f"[train] resumed from checkpoint step {ck_step}")
            return tree["params"], tree["opt"], ck_step
        return params, opt_state, step

    def run(self, steps: int, *, log_every: int = 10,
            heartbeat: str | None = None):
        params, opt_state, start = self.maybe_resume()
        history = []
        with shd.use_mesh(*self.ctx_args):
            for step in range(start, steps):
                t0 = time.time()
                batch = {k: jnp.asarray(v) for k, v in
                         self.data.batch_at(step).items()}
                params, opt_state, metrics = self.step_fn(
                    params, opt_state, batch, jnp.int32(step))
                loss = float(metrics["loss"])
                dt = time.time() - t0
                slow = self.monitor.observe(dt)
                if slow:
                    print(f"[straggler] step {step} took {dt:.2f}s "
                          f"(median {statistics.median(self.monitor.times[-32:]):.2f}s)")
                if heartbeat:
                    with open(heartbeat, "w") as f:
                        json.dump({"step": step, "t": time.time(),
                                   "loss": loss}, f)
                history.append(loss)
                if step % log_every == 0 or step == steps - 1:
                    tok_s = self.batch * self.seq / dt
                    print(f"step {step:5d} loss {loss:8.4f} "
                          f"grad_norm {float(metrics['grad_norm']):7.3f} "
                          f"{dt*1e3:7.1f} ms/step {tok_s:9.0f} tok/s")
                if self.ckpt and step and step % self.ckpt_every == 0:
                    self.ckpt.save(step, {"params": params, "opt": opt_state},
                                   metadata={"loss": loss})
        if self.ckpt:
            self.ckpt.save(steps, {"params": params, "opt": opt_state},
                           block=True)
        return params, opt_state, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--heartbeat", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduce()
    loop = TrainLoop(cfg, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     seed=args.seed)
    _, _, history = loop.run(args.steps, heartbeat=args.heartbeat)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f}); "
          f"straggler events: {loop.monitor.events}")


if __name__ == "__main__":
    main()
