"""vsconv — direct 3x3 vector-sparse convolution Pallas TPU kernel.

The paper decomposes a 3x3 conv into kernel *columns* (WA/WB/WC in Fig. 6) and
skips all-zero columns and all-zero input column vectors.  The TPU analogue
decomposes the conv into kernel *taps* x input-channel tiles:

    conv(x, w) = sum_{ky, kx} shift(x, ky, kx) @ w[ky, kx]       (9 matmuls)
               = sum over K-tiles t=(ky, kx, cin-tile) of
                 shift(x, ky, kx)[cin-tile] @ w_tile[t]

A "weight vector" here is one (vk cin, vn cout) tile of one tap — pruned tiles
are structurally absent from the balanced block-CSR, so their matmuls never
enter the grid (the paper's weight-side skip).  An all-zero shifted-input row
block is skipped at runtime with ``@pl.when`` (the input-side skip).

Input layout: the `ops.vsconv` wrapper pre-builds a row-tap stack
  XT (N, 3, H, bW, C)   with XT[:, ky] = pad(x)[:, ky : ky + H, :, :]
so the ky shift becomes a unit-block index (selectable from the scalar-
prefetched tap id), and the kx shift is a dynamic sublane slice inside the
kernel.  This is the paper's "broadcast the right input column" realized as
Pallas index_map arithmetic; bW = W+2 rounded up to the sublane multiple.

Grid: ``(NB, N * HB, S)`` — cout strip j, (image, row-block) m, sparse step s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.vector_sparse import VectorSparse

__all__ = ["vsconv_pallas", "build_row_tap_stack"]


def build_row_tap_stack(x: jax.Array, *, sublane: int = 8) -> jax.Array:
    """NHWC -> (N, 3, H, bW, C) row-tap stack of the pad-1 input.

    bW = W + 2 rounded up to ``sublane`` so the kernel's kx slice stays
    in-bounds and sublane-aligned.
    """
    n, h, w, c = x.shape
    bw = -(-(w + 2) // sublane) * sublane
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, bw - w - 1), (0, 0)))
    return jnp.stack([xp[:, ky : ky + h] for ky in range(3)], axis=1)


def _kernel(idx_ref, xt_ref, w_ref, o_ref, acc_ref, *, cb: int, w_out: int,
             skip_zero_inputs: bool):
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id: t = (ky*3 + kx) * CB + cin_tile
    t = idx_ref[j, s]
    kx = (t // cb) % 3

    xt = xt_ref[0, 0]  # (bh, bW, vk) — ky and cin-tile selected by index_map
    xs = jax.lax.dynamic_slice_in_dim(xt, kx, w_out, axis=1)  # (bh, W, vk)
    xs2 = xs.reshape(-1, xs.shape[-1])  # (bh*W, vk)

    def _mac():
        acc_ref[...] += jnp.dot(
            xs2, w_ref[0, 0], preferred_element_type=jnp.float32
        )

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("w_out", "bh", "skip_zero_inputs", "interpret", "out_dtype"),
)
def vsconv_pallas(
    xt: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Row-tap stack xt (N, 3, H, bW, C) * sparse (9C, Cout) -> (N, H, W, Cout).

    H must be a multiple of ``bh``; the `ops.vsconv` wrapper pads.
    """
    n, three, h, bw, c = xt.shape
    assert three == 3
    nb, s_steps, vk, vn = vs.vals.shape
    assert vs.shape[0] == 9 * c and c % vk == 0, (vs.shape, c, vk)
    assert h % bh == 0, (h, bh)
    cb = c // vk  # cin-tiles per tap
    hb = h // bh
    out_dtype = out_dtype or xt.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=[
            # block: one image, one ky tap, one row block, full width, one cin tile
            pl.BlockSpec(
                (1, 1, bh, bw, vk),
                lambda j, m, s, idx: (
                    m // hb,                      # image
                    idx[j, s] // cb // 3,         # ky
                    m % hb,                       # row block
                    0,
                    idx[j, s] % cb,               # cin tile
                ),
            ),
            pl.BlockSpec((1, 1, vk, vn), lambda j, m, s, idx: (j, s, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, cb=cb, w_out=w_out, skip_zero_inputs=skip_zero_inputs
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=2 * n * h * w_out * nb * s_steps * vk * vn,
            bytes_accessed=(
                n * hb * nb * s_steps * bh * bw * vk * xt.dtype.itemsize
                + vs.vals.size * vs.vals.dtype.itemsize
                + n * h * w_out * nb * vn * jnp.dtype(out_dtype).itemsize
            ),
            transcendentals=0,
        ),
    )(vs.idx, xt, vs.vals)
