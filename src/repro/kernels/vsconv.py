"""vsconv — direct KxK vector-sparse convolution Pallas TPU kernels.

The paper decomposes a conv into kernel *columns* (WA/WB/WC in Fig. 6) and
skips all-zero columns and all-zero input column vectors.  The TPU analogue
decomposes an arbitrary ``kh x kw`` / stride-``s`` conv into kernel *taps*
x input-channel tiles:

    conv(x, w)[i, j] = sum_{ky, kx} x[s*i + ky - pt, s*j + kx - pl] @ w[ky, kx]
                     = sum over K-tiles t = (ky*kw + kx, cin-tile) of
                       gather(x, t)[i, j] @ w_tile[t]          (kh*kw*CB matmuls)

A "weight vector" here is one (vk cin, vn cout) tile of one tap — pruned tiles
are structurally absent from the balanced block-CSR, so their matmuls never
enter the grid (the paper's weight-side skip).  An all-zero shifted-input row
block is skipped at runtime with ``@pl.when`` (the input-side skip).

Input layouts — two implementations of the same math
----------------------------------------------------

**Halo (default, `vsconv_halo_pallas`)** reads the raw SAME-padded NHWC
input *directly*.  `build_halo_input` only pads and reshapes:

  XH (N, rows, bW, CB, vk),  rows = stride*(Hout-1) + kh

(the reshape C -> (CB, vk) is free — channels are contiguous).  The
BlockSpec carves, per output row-block of ``bh`` rows, an overlapping
*halo block* of ``bh*stride + kh - stride`` input rows (`pl.Unblocked`
element-offset indexing), and the tap ``(ky, kx)`` is resolved *inside*
the kernel: row ``ky + stride*i`` and column ``kx + stride*j`` of the halo
block feed output pixel ``(i, j)``, i.e. one dynamic slice plus a static
strided subselect.  Because the halo offsets depend only on the row-block
and the cin tile — not on the tap — consecutive sparse steps over the same
cin tile *revisit* the same block and Pallas skips the DMA: with the
stored tiles ordered cin-major (`core.vector_sparse.conv_cin_major`, the
order `models.graph.sparse_conv_from_dense` emits), each cin tile's halo
is fetched once per (strip, row-block), so input HBM traffic is ~1x the
input plus the halo overlap — the paper's fetch-once-broadcast-everywhere
data movement story, realized as index arithmetic.

**Row-tap/phase stack (`vsconv_pallas`, oracle + fallback)** materializes
``build_row_tap_stack``:

  XT (N, kh*stride, Hout, bW, C)
  XT[:, ky*stride + phase, i, j'] = pad(x)[:, stride*i + ky, phase + stride*j']

Rows are pre-strided per tap row ``ky`` and the width axis pre-split into
its ``stride`` phases, so the whole tap select is BlockSpec index_map
arithmetic plus one contiguous width slice.  The price is data movement:
the stack is ``kh*stride`` output-sized planes written to HBM before every
conv (an extra XLA pass over every activation) and the kernel re-fetches
its plane on every sparse step.  It is kept as the bandwidth-dumb oracle
the halo path is tested against, and as a fallback layout.

`stack_kernel_cost` / `halo_kernel_cost` are the shared HBM-traffic
contract: the same formulas feed the kernels' `pl.CostEstimate`, the
`core.accel_model` DRAM traffic model, and the benchmark gate that keeps
the halo path's bytes strictly below the stack path's.

Padding is XLA-"SAME" for the given stride (Hout = ceil(H/stride)); the
`ops.vsconv` wrapper computes it and pads Hout to a ``bh`` multiple.

Fused epilogue (both kernels): optional per-cout ``bias`` add, optional
``residual`` (ResNet shortcut) add, and ReLU run inside the kernel at flush
time (f32 accumulator -> +bias -> +residual -> max(0) -> cast).  Fusing the
ReLU means the *next* layer's input zeros — the vectors its input-side skip
elides — are produced on-chip for free, exactly the paper's post-ReLU
input-zero-vector story; fusing the residual means a whole ResNet basic
block retires with a single extra VMEM read, no extra HBM round-trip.

Grid (both): ``(NB, N * HB, S)`` — cout strip j, (image, row-block) m,
sparse step s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_ops import same_pads
from repro.core.vector_sparse import VectorSparse

__all__ = [
    "vsconv_pallas", "vsconv_halo_pallas", "build_row_tap_stack",
    "build_halo_input", "stack_kernel_cost", "halo_kernel_cost", "same_pads",
]


# --------------------------------------------------------------------------
# HBM traffic contract (shared by kernels, accel model, and benchmarks)
# --------------------------------------------------------------------------

def stack_kernel_cost(
    *, n: int, hop: int, w_out: int, bw: int, bh: int, nb: int, s_steps: int,
    vk: int, vn: int, in_itemsize: int = 4, w_itemsize: int = 4,
    out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the row-tap stack impl (stack *build* excluded —
    that extra pass is modeled in `core.accel_model.conv_layer_traffic`).

    Every sparse step changes the (plane, cin-tile) block index, so the
    input block (bh, bw, vk) is DMA'd on every one of the NB*S steps per
    row-block.
    """
    hb = hop // bh
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vk * vn,
        bytes_accessed=(
            n * hb * nb * s_steps * bh * bw * vk * in_itemsize
            + nb * s_steps * vk * vn * w_itemsize
            + n * hop * w_out * nb * vn * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


def halo_kernel_cost(
    *, n: int, hop: int, w_out: int, kh: int, stride: int, bwp: int, bh: int,
    nb: int, s_steps: int, cb: int, vk: int, vn: int, in_itemsize: int = 4,
    w_itemsize: int = 4, out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the halo impl.

    The halo block offset depends only on (row-block, cin tile): with the
    stored tiles cin-major per strip, consecutive taps of one cin tile
    revisit the same block (no DMA), so each of the min(S, CB) distinct cin
    tiles is fetched once per (strip, row-block) — a halo block of
    ``bh*stride + kh - stride`` rows instead of S fetches of bh rows.
    """
    hb = hop // bh
    hh = stride * (bh - 1) + kh
    fetches = min(s_steps, cb)
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vk * vn,
        bytes_accessed=(
            n * hb * nb * fetches * hh * bwp * vk * in_itemsize
            + nb * s_steps * vk * vn * w_itemsize
            + n * hop * w_out * nb * vn * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


# --------------------------------------------------------------------------
# Input layouts
# --------------------------------------------------------------------------

def build_halo_input(
    x: jax.Array,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    vk: int,
    h_out: int | None = None,
    sublane: int = 8,
) -> jax.Array:
    """NHWC -> (N, rows, bW, CB, vk) SAME-padded direct input for the halo
    kernel.  One `jnp.pad` (the only HBM copy of the layout) plus a free
    channel-split reshape; rows = stride*(Hout-1) + kh so every halo block
    and in-kernel tap slice stays in bounds, bW = stride*(Wout-1) + kw
    rounded up to ``sublane``.

    ``h_out`` lets the caller round Hout up to a row-block multiple (the
    extra rows read zero padding).
    """
    n, h, w, c = x.shape
    assert c % vk == 0, (c, vk)
    ho, pt, _ = same_pads(h, kh, stride)
    wo, pl_, _ = same_pads(w, kw, stride)
    ho = h_out or ho
    rows = stride * (ho - 1) + kh
    bw = -(-(stride * (wo - 1) + kw) // sublane) * sublane
    xp = jnp.pad(
        x,
        ((0, 0), (pt, rows - h - pt), (pl_, bw - w - pl_), (0, 0)),
    )
    return xp.reshape(n, rows, bw, c // vk, vk)


def build_row_tap_stack(
    x: jax.Array,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    h_out: int | None = None,
    sublane: int = 8,
) -> jax.Array:
    """NHWC -> (N, kh*stride, Hout, bW, C) row-tap/phase stack (SAME padding).

    The stack-impl (oracle) layout: kh*stride output-sized planes
    materialized in HBM.  ``h_out`` lets the caller round Hout up to a
    row-block multiple (the extra rows read zero padding).  bW = Wout +
    (kw-1)//stride rounded up to ``sublane`` so the kernel's kx slice stays
    in-bounds and sublane-aligned.
    """
    n, h, w, c = x.shape
    ho, pt, _ = same_pads(h, kh, stride)
    wo, pl_, _ = same_pads(w, kw, stride)
    ho = h_out or ho
    bw = -(-(wo + (kw - 1) // stride) // sublane) * sublane
    rows_needed = stride * (ho - 1) + kh  # padded-row index ceiling
    cols_needed = stride * bw  # every phase plane must reach bw columns
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pt, max(rows_needed - h - pt, 0)),
            (pl_, max(cols_needed - w - pl_, 0)),
            (0, 0),
        ),
    )
    planes = [
        xp[:, ky : ky + stride * (ho - 1) + 1 : stride, phase :: stride][
            :, :, :bw
        ]
        for ky in range(kh)
        for phase in range(stride)
    ]
    return jnp.stack(planes, axis=1)


# --------------------------------------------------------------------------
# Halo kernel (default): direct input, tap resolved in-kernel
# --------------------------------------------------------------------------

def _halo_kernel(idx_ref, xh_ref, w_ref, *refs, cb: int, kw: int, stride: int,
                 bh: int, w_out: int, fuse_relu: bool, has_bias: bool,
                 has_residual: bool, skip_zero_inputs: bool):
    it = iter(refs)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id t = (ky*kw + kx) * CB + cin_tile; the cin tile is
    # already resolved by the index_map, the whole tap resolves here
    t = idx_ref[j, s]
    tap = t // cb
    ky = tap // kw
    kx = tap % kw

    # output pixel (i, jj) of this row block reads halo element
    # (ky + stride*i, kx + stride*jj): dynamic tap offset + static stride
    rlen = stride * (bh - 1) + 1
    clen = stride * (w_out - 1) + 1
    xt = xh_ref[0, pl.ds(ky, rlen), pl.ds(kx, clen), 0]  # (rlen, clen, vk)
    if stride > 1:
        xt = xt[::stride, ::stride]
    xs2 = xt.reshape(bh * w_out, xt.shape[-1])

    def _mac():
        acc_ref[...] += jnp.dot(
            xs2, w_ref[0, 0], preferred_element_type=jnp.float32
        )

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            # ResNet shortcut fused at flush: add before the ReLU so the
            # whole basic block retires with one on-chip epilogue
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "w_out", "bh", "skip_zero_inputs", "fuse_relu",
        "interpret", "out_dtype",
    ),
)
def vsconv_halo_pallas(
    xh: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Direct input xh (N, rows, bW, CB, vk) * sparse (kh*kw*CB*vk, Cout)
    -> (N, Hout, w_out, Cout), Hout = (rows - kh) // stride + 1.

    ``xh`` is `build_halo_input`'s SAME-padded raw input; Hout must be a
    multiple of ``bh`` (the `ops.vsconv` wrapper pads).  Each grid step sees
    an overlapping ``bh*stride + kh - stride``-row halo block
    (`pl.Unblocked` element offsets) and slices its tap out in-kernel, so
    no tap-shifted copy of the input ever exists in HBM.  ``bias`` (Cout,),
    ``residual`` (N, Hout, w_out, Cout) and ``fuse_relu`` run the epilogue
    at flush time, identically to the stack kernel.
    """
    n, rows, bwp, cb, vk = xh.shape
    assert (rows - kh) % stride == 0, (rows, kh, stride)
    h = (rows - kh) // stride + 1
    nb, s_steps, vk_w, vn = vs.vals.shape
    assert vk_w == vk and vs.shape[0] == kh * kw * cb * vk, (
        vs.shape, xh.shape, kh, kw)
    assert h % bh == 0, (h, bh)
    hb = h // bh
    hh = stride * (bh - 1) + kh  # halo rows per output row-block
    out_dtype = out_dtype or xh.dtype
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        # one image, one overlapping halo row window, full width, one cin
        # tile — element offsets (Unblocked): row-blocks overlap by
        # kh - stride rows, and the offsets are tap-independent so
        # consecutive sparse steps on one cin tile revisit the block
        # without a new DMA (cin-major tile order makes that the common
        # case).
        pl.BlockSpec(
            (1, hh, bwp, 1, vk),
            lambda j, m, s, idx: (
                m // hb,                    # image
                (m % hb) * stride * bh,     # halo window start row
                0,
                idx[j, s] % cb,             # cin tile
                0,
            ),
            indexing_mode=pl.Unblocked(),
        ),
        pl.BlockSpec((1, 1, vk, vn), lambda j, m, s, idx: (j, s, 0, 0)),
    ]
    args = [vs.idx, xh, vs.vals]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), lambda j, m, s, idx: (j, 0)))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vn), (
            residual.shape, (n, h, w_out, nb * vn))
        in_specs.append(pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _halo_kernel, cb=cb, kw=kw, stride=stride, bh=bh, w_out=w_out,
            fuse_relu=fuse_relu, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=halo_kernel_cost(
            n=n, hop=h, w_out=w_out, kh=kh, stride=stride, bwp=bwp, bh=bh,
            nb=nb, s_steps=s_steps, cb=cb, vk=vk, vn=vn,
            in_itemsize=xh.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)


# --------------------------------------------------------------------------
# Row-tap stack kernel (oracle + fallback)
# --------------------------------------------------------------------------

def _kernel(idx_ref, xt_ref, w_ref, *refs, cb: int, kw: int, stride: int,
            w_out: int, fuse_relu: bool, has_bias: bool, has_residual: bool,
            skip_zero_inputs: bool):
    it = iter(refs)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id: t = (ky*kw + kx) * CB + cin_tile.  ky and the
    # width phase (kx % stride) are already resolved by the index_map; only
    # the in-plane column offset kx // stride remains.
    t = idx_ref[j, s]
    kx = (t // cb) % kw

    xt = xt_ref[0, 0]  # (bh, bW, vk) — plane and cin-tile selected by index_map
    xs = jax.lax.dynamic_slice_in_dim(xt, kx // stride, w_out, axis=1)
    xs2 = xs.reshape(-1, xs.shape[-1])  # (bh*w_out, vk)

    def _mac():
        acc_ref[...] += jnp.dot(
            xs2, w_ref[0, 0], preferred_element_type=jnp.float32
        )

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            # ResNet shortcut fused at flush: add before the ReLU so the
            # whole basic block retires with one on-chip epilogue
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "w_out", "bh", "skip_zero_inputs", "fuse_relu",
        "interpret", "out_dtype",
    ),
)
def vsconv_pallas(
    xt: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Row-tap stack xt (N, kh*stride, H, bW, C) * sparse (kh*kw*C, Cout)
    -> (N, H, w_out, Cout).

    The materialized-stack impl, kept as the oracle/fallback for
    `vsconv_halo_pallas`.  H (the stack's output-row count) must be a
    multiple of ``bh``; the `ops.vsconv` wrapper pads.  ``bias`` (Cout,),
    ``residual`` (N, H, w_out, Cout) — the ResNet shortcut, added before the
    ReLU — and ``fuse_relu`` run the epilogue inside the kernel at flush
    time.
    """
    n, planes, h, bw, c = xt.shape
    assert planes == kh * stride, (planes, kh, stride)
    nb, s_steps, vk, vn = vs.vals.shape
    assert vs.shape[0] == kh * kw * c and c % vk == 0, (vs.shape, c, vk)
    assert h % bh == 0, (h, bh)
    cb = c // vk  # cin-tiles per tap
    hb = h // bh
    out_dtype = out_dtype or xt.dtype
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        # block: one image, one (ky, phase) plane, one row block, full width,
        # one cin tile — the plane id is the generalized tap select:
        #   plane = ky*stride + kx % stride,  tap = idx[j, s] // cb
        pl.BlockSpec(
            (1, 1, bh, bw, vk),
            lambda j, m, s, idx: (
                m // hb,                                      # image
                (idx[j, s] // cb // kw) * stride
                + ((idx[j, s] // cb) % kw) % stride,          # (ky, phase)
                m % hb,                                       # row block
                0,
                idx[j, s] % cb,                               # cin tile
            ),
        ),
        pl.BlockSpec((1, 1, vk, vn), lambda j, m, s, idx: (j, s, 0, 0)),
    ]
    args = [vs.idx, xt, vs.vals]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), lambda j, m, s, idx: (j, 0)))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vn), (
            residual.shape, (n, h, w_out, nb * vn))
        in_specs.append(pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, cb=cb, kw=kw, stride=stride, w_out=w_out,
            fuse_relu=fuse_relu, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=stack_kernel_cost(
            n=n, hop=h, w_out=w_out, bw=bw, bh=bh, nb=nb, s_steps=s_steps,
            vk=vk, vn=vn, in_itemsize=xt.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)
