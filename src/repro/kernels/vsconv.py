"""vsconv — direct KxK vector-sparse convolution Pallas TPU kernels.

The paper decomposes a conv into kernel *columns* (WA/WB/WC in Fig. 6) and
skips all-zero columns and all-zero input column vectors.  The TPU analogue
decomposes an arbitrary ``kh x kw`` / stride-``s`` conv into kernel *taps*
x input-channel tiles:

    conv(x, w)[i, j] = sum_{ky, kx} x[s*i + ky - pt, s*j + kx - pl] @ w[ky, kx]
                     = sum over K-tiles t = (ky*kw + kx, cin-tile) of
                       gather(x, t)[i, j] @ w_tile[t]          (kh*kw*CB matmuls)

A "weight vector" here is one (vk cin, vn cout) tile of one tap — pruned tiles
are structurally absent from the balanced block-CSR, so their matmuls never
enter the grid (the paper's weight-side skip).  An all-zero shifted-input row
block is skipped at runtime with ``@pl.when`` (the input-side skip).

Input layouts — two implementations of the same math
----------------------------------------------------

**Halo (default, `vsconv_halo_pallas`)** reads the raw SAME-padded NHWC
input *directly*.  `build_halo_input` only pads and reshapes:

  XH (N, rows, bW, CB, vk),  rows = stride*(Hout-1) + kh

(the reshape C -> (CB, vk) is free — channels are contiguous).  The
BlockSpec carves, per output row-block of ``bh`` rows, an overlapping
*halo block* of ``bh*stride + kh - stride`` input rows (`pl.Unblocked`
element-offset indexing), and the tap ``(ky, kx)`` is resolved *inside*
the kernel: row ``ky + stride*i`` and column ``kx + stride*j`` of the halo
block feed output pixel ``(i, j)``, i.e. one dynamic slice plus a static
strided subselect.  Because the halo offsets depend only on the row-block
and the cin tile — not on the tap — consecutive sparse steps over the same
cin tile *revisit* the same block and Pallas skips the DMA: with the
stored tiles ordered cin-major (`core.vector_sparse.conv_cin_major`, the
order `models.graph.sparse_conv_from_dense` emits), each cin tile's halo
is fetched once per (strip, row-block), so input HBM traffic is ~1x the
input plus the halo overlap — the paper's fetch-once-broadcast-everywhere
data movement story, realized as index arithmetic.

At tiny output heights (Hout < `RESIDENT_MAX_H`, e.g. ResNet layer4 on
32px inputs) the per-strip fetch floor min(S, CB) re-reads a halo window
that is essentially the whole padded input, so the ungrouped halo kernel
switches to a *resident* layout (`use_resident_halo`): one block holding
all CB cin tiles, offsets a function of the row-block only, the
(image, row-block) grid axis outermost — the input is DMA'd exactly once
per (image, row-block) and both tap and cin tile resolve in-kernel.

**Row-tap/phase stack (`vsconv_pallas`, oracle + fallback)** materializes
``build_row_tap_stack``:

  XT (N, kh*stride, Hout, bW, C)
  XT[:, ky*stride + phase, i, j'] = pad(x)[:, stride*i + ky, phase + stride*j']

Rows are pre-strided per tap row ``ky`` and the width axis pre-split into
its ``stride`` phases, so the whole tap select is BlockSpec index_map
arithmetic plus one contiguous width slice.  The price is data movement:
the stack is ``kh*stride`` output-sized planes written to HBM before every
conv (an extra XLA pass over every activation) and the kernel re-fetches
its plane on every sparse step.  It is kept as the bandwidth-dumb oracle
the halo path is tested against, and as a fallback layout.

Grouped, depthwise and dilated geometry
---------------------------------------

``dilation`` spaces the taps: the in-kernel tap resolve reads row
``ky*dilation`` / column ``kx*dilation`` (halo) or the dilated plane slice
(stack) and every extent formula uses the effective kernel size
``(k-1)*dilation + 1``.  ``groups`` shards the cin-tile axis: the weight
matrix is (kh*kw*Cin/groups, Cout) with output strips group-major, a
strip's stored tile ids are group-relative, and the input index_map adds
the group's base cin tile — so a grouped strip fetches only its own
group's channels (the per-group traffic accounting in
`halo_kernel_cost(cb=Cin/(groups*vk))`).  Depthwise (groups == Cin,
multiplier 1) degenerates to the per-channel tap kernels
(`vsconv_dw_halo_pallas` / `vsconv_dw_stack_pallas`): the weight is the
(kh*kw, C) tap matrix encoded vk=1 over vn-channel tiles, the MAC is
elementwise on the VPU, and the halo block — tap-independent AND strip ==
channel tile — is fetched exactly once per (strip, row-block).

`stack_kernel_cost` / `halo_kernel_cost` (and their `dw_*` depthwise
variants) are the shared HBM-traffic contract: the same formulas feed the
kernels' `pl.CostEstimate`, the `core.accel_model` DRAM traffic model, and
the benchmark gate that keeps the halo path's bytes strictly below the
stack path's.

Padding is XLA-"SAME" for the given stride (Hout = ceil(H/stride)); the
`ops.vsconv` wrapper computes it and pads Hout to a ``bh`` multiple.

Fused epilogue (both kernels): optional per-cout ``bias`` add, optional
``residual`` (ResNet shortcut) add, and ReLU run inside the kernel at flush
time (f32 accumulator -> +bias -> +residual -> max(0) -> cast).  Fusing the
ReLU means the *next* layer's input zeros — the vectors its input-side skip
elides — are produced on-chip for free, exactly the paper's post-ReLU
input-zero-vector story; fusing the residual means a whole ResNet basic
block retires with a single extra VMEM read, no extra HBM round-trip.

Grid (both): ``(NB, N * HB, S)`` — cout strip j, (image, row-block) m,
sparse step s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_ops import same_pads
from repro.core.vector_sparse import VectorSparse
from repro.kernels.vsmm import _mac_dot

__all__ = [
    "vsconv_pallas", "vsconv_halo_pallas", "vsconv_dw_halo_pallas",
    "vsconv_dw_stack_pallas", "build_row_tap_stack", "build_halo_input",
    "stack_kernel_cost", "halo_kernel_cost", "dw_halo_kernel_cost",
    "dw_stack_kernel_cost", "same_pads", "use_resident_halo",
    "RESIDENT_MAX_H", "halo_in_index_map", "resident_in_index_map",
    "dw_halo_in_index_map", "stack_in_index_map", "dw_stack_in_index_map",
    "conv_weight_index_map", "conv_out_index_map", "conv_bias_index_map",
    "halo_layout_dims", "stack_layout_dims",
]


# --------------------------------------------------------------------------
# HBM traffic contract (shared by kernels, accel model, and benchmarks)
# --------------------------------------------------------------------------

def stack_kernel_cost(
    *, n: int, hop: int, w_out: int, bw: int, bh: int, nb: int, s_steps: int,
    vk: int, vn: int, in_itemsize: int = 4, w_itemsize: int = 4,
    out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the row-tap stack impl (stack *build* excluded —
    that extra pass is modeled in `core.accel_model.conv_layer_traffic`).

    Every sparse step changes the (plane, cin-tile) block index, so the
    input block (bh, bw, vk) is DMA'd on every one of the NB*S steps per
    row-block.
    """
    hb = hop // bh
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vk * vn,
        bytes_accessed=(
            n * hb * nb * s_steps * bh * bw * vk * in_itemsize
            + nb * s_steps * vk * vn * w_itemsize
            + n * hop * w_out * nb * vn * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


# Below this output height the per-strip halo fetch floor (min(S, cb)
# re-fetches of a window that is mostly the whole padded input) stops
# amortizing; the halo kernel switches to the resident whole-input layout.
RESIDENT_MAX_H = 4


def use_resident_halo(h_out: int, groups: int) -> bool:
    """True when the halo impl runs the tiny-feature-map resident layout:
    the (padded) output height fits one VMEM-resident block of *all* cin
    tiles, fetched once per (image, row-block) — grid reordered row-block
    outermost so every strip and sparse step revisits it DMA-free.
    Grouped convs keep the per-group streaming layout (a resident block
    would fetch other groups' channels)."""
    return h_out < RESIDENT_MAX_H and groups == 1


def halo_kernel_cost(
    *, n: int, hop: int, w_out: int, kh: int, stride: int, bwp: int, bh: int,
    nb: int, s_steps: int, cb: int, vk: int, vn: int, dilation: int = 1,
    resident: bool = False, in_itemsize: int = 4, w_itemsize: int = 4,
    out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the halo impl.

    The halo block offset depends only on (row-block, cin tile): with the
    stored tiles cin-major per strip, consecutive taps of one cin tile
    revisit the same block (no DMA), so each of the min(S, cb) distinct cin
    tiles is fetched once per (strip, row-block) — a halo block of
    ``stride*(bh-1) + (kh-1)*dilation + 1`` rows instead of S fetches of bh
    rows.  ``cb`` is the cin tiles *reachable from one strip* — Cin/vk for
    an ungrouped conv, Cin/(groups*vk) for a grouped one (a strip only ever
    touches its own group's channels, the per-group fetch accounting).

    ``resident`` is the tiny-feature-map layout (`use_resident_halo`): one
    block holding *all* ``cb`` cin tiles, offset independent of both strip
    and sparse step, with the row-block grid axis outermost — fetched once
    per (image, row-block), no per-strip re-fetch at all.
    """
    hb = hop // bh
    hh = stride * (bh - 1) + (kh - 1) * dilation + 1
    if resident:
        input_bytes = n * hb * hh * bwp * cb * vk * in_itemsize
    else:
        input_bytes = n * hb * nb * min(s_steps, cb) * hh * bwp * vk \
            * in_itemsize
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vk * vn,
        bytes_accessed=(
            input_bytes
            + nb * s_steps * vk * vn * w_itemsize
            + n * hop * w_out * nb * vn * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


def dw_halo_kernel_cost(
    *, n: int, hop: int, w_out: int, kh: int, stride: int, bwp: int, bh: int,
    nb: int, s_steps: int, vc: int, dilation: int = 1, in_itemsize: int = 4,
    w_itemsize: int = 4, out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the depthwise halo impl.

    The halo block offset depends only on (row-block, channel tile) — not
    the tap at all — so every sparse step of strip j revisits the same
    block: exactly ONE halo fetch per (strip, row-block), whatever the tap
    order.  MACs are elementwise (VPU), one per (pixel, channel, stored
    tap).
    """
    hb = hop // bh
    hh = stride * (bh - 1) + (kh - 1) * dilation + 1
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vc,
        bytes_accessed=(
            n * hb * nb * hh * bwp * vc * in_itemsize
            + nb * s_steps * vc * w_itemsize
            + n * hop * w_out * nb * vc * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


def dw_stack_kernel_cost(
    *, n: int, hop: int, w_out: int, bw: int, bh: int, nb: int, s_steps: int,
    vc: int, in_itemsize: int = 4, w_itemsize: int = 4, out_itemsize: int = 4,
    residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the depthwise row-tap-stack impl: every sparse
    step changes the (plane, channel-tile) block index, so the (bh, bw, vc)
    input block is DMA'd on every one of the S steps per row-block."""
    hb = hop // bh
    return pl.CostEstimate(
        flops=2 * n * hop * w_out * nb * s_steps * vc,
        bytes_accessed=(
            n * hb * nb * s_steps * bh * bw * vc * in_itemsize
            + nb * s_steps * vc * w_itemsize
            + n * hop * w_out * nb * vc * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


# --------------------------------------------------------------------------
# BlockSpec index maps (named factories — shared with `repro.analysis`)
# --------------------------------------------------------------------------
#
# Every index map below is closed arithmetic (+ - * // %) over the grid
# indices and the prefetched idx table, with a uniform (g0, g1, g2, idx)
# signature in *grid order*.  Naming them (instead of inlining lambdas in
# the pallas_call specs) lets the static analyzer evaluate the exact same
# functions abstractly — over `analysis.intervals.Interval` grid axes for
# the in-bounds proof, and over concrete numpy index arrays for the
# DMA-byte derivation — so the kernels and their verifier can never use
# different offset arithmetic.
#
# Grid orders: streaming conv kernels (j, m, s) = (cout strip,
# image*row-block, sparse step); the resident halo kernel (m, j, s) with
# the row-block outermost; vsmm (j, mi, s).


def halo_in_index_map(hb: int, stride: int, bh: int, cbg: int, spg: int):
    """Streaming halo input (element offsets, `pl.Unblocked`): one image,
    one overlapping halo row window, full width, one cin tile.  The offset
    is tap-independent, so consecutive sparse steps on one cin tile revisit
    the block without a new DMA; a grouped strip adds its group's base cin
    tile."""
    def index_map(j, m, s, idx):
        return (
            m // hb,                    # image
            (m % hb) * stride * bh,     # halo window start row
            0,
            (j // spg) * cbg + idx[j, s] % cbg,  # cin tile (+ group base)
            0,
        )
    return index_map


def resident_in_index_map(hb: int, stride: int, bh: int):
    """Resident (tiny-feature-map) halo input: one block holding ALL cin
    tiles, offset a function of the row-block only — with the
    (image, row-block) grid axis outermost the block is DMA'd exactly once
    per (image, row-block)."""
    def index_map(m, j, s, idx):
        return (m // hb, (m % hb) * stride * bh, 0, 0, 0)
    return index_map


def dw_halo_in_index_map(hb: int, stride: int, bh: int):
    """Depthwise halo input: strip j IS the channel tile; the offset is
    tap-independent, so the halo is fetched once per (strip, row-block)."""
    def index_map(j, m, s, idx):
        return (m // hb, (m % hb) * stride * bh, 0, j, 0)
    return index_map


def stack_in_index_map(hb: int, cbg: int, spg: int, kw: int, stride: int,
                       dilation: int):
    """Row-tap stack input (block indices): the plane id is the generalized
    tap select ``ky*stride + (kx*dilation) % stride`` decoded from the
    stored tile id, plus the strip's group-based cin tile."""
    def index_map(j, m, s, idx):
        t = idx[j, s]
        return (
            m // hb,                                            # image
            (t // cbg // kw) * stride
            + (((t // cbg) % kw) * dilation) % stride,          # (ky, phase)
            m % hb,                                             # row block
            0,
            (j // spg) * cbg + t % cbg,                         # cin tile
        )
    return index_map


def dw_stack_in_index_map(hb: int, kw: int, stride: int, dilation: int):
    """Depthwise row-tap stack input: idx[j, s] is the bare tap id and the
    strip is the channel tile."""
    def index_map(j, m, s, idx):
        t = idx[j, s]
        return (
            m // hb,
            (t // kw) * stride + ((t % kw) * dilation) % stride,  # (ky, ph)
            m % hb,
            0,
            j,
        )
    return index_map


def conv_weight_index_map(resident: bool = False):
    """The s-th stored weight tile of strip j (both conv grid orders)."""
    if resident:
        def index_map(m, j, s, idx):
            return (j, s, 0, 0)
    else:
        def index_map(j, m, s, idx):
            return (j, s, 0, 0)
    return index_map


def conv_out_index_map(hb: int, resident: bool = False):
    """Output/residual row-block tile of (strip j, image*row-block m)."""
    if resident:
        def index_map(m, j, s, idx):
            return (m // hb, m % hb, 0, j)
    else:
        def index_map(j, m, s, idx):
            return (m // hb, m % hb, 0, j)
    return index_map


def conv_bias_index_map(resident: bool = False):
    """Strip j's bias tile (excluded from the byte contract: one (1, vn)
    tile per strip, noise next to the input/weight/output terms)."""
    if resident:
        def index_map(m, j, s, idx):
            return (j, 0)
    else:
        def index_map(j, m, s, idx):
            return (j, 0)
    return index_map


def halo_layout_dims(h: int, w: int, *, kh: int, kw: int, stride: int,
                     dilation: int, h_out: int, sublane: int = 8
                     ) -> tuple[int, int]:
    """(rows, bW) of `build_halo_input`'s padded buffer for the given
    geometry — the single source the builder, the cost model, and the
    analyzer's bounds proof all share."""
    wo, _, _ = same_pads(w, kw, stride, dilation)
    rows = stride * (h_out - 1) + (kh - 1) * dilation + 1
    bw = -(-(stride * (wo - 1) + (kw - 1) * dilation + 1) // sublane) * sublane
    return rows, bw


def stack_layout_dims(h: int, w: int, *, kh: int, kw: int, stride: int,
                      dilation: int, h_out: int, sublane: int = 8
                      ) -> tuple[int, int]:
    """(planes, bW) of `build_row_tap_stack`'s materialized buffer."""
    wo, _, _ = same_pads(w, kw, stride, dilation)
    bw = -(-(wo + ((kw - 1) * dilation) // stride) // sublane) * sublane
    return kh * stride, bw


# --------------------------------------------------------------------------
# Input layouts
# --------------------------------------------------------------------------

def build_halo_input(
    x: jax.Array,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    dilation: int = 1,
    vk: int,
    h_out: int | None = None,
    sublane: int = 8,
) -> jax.Array:
    """NHWC -> (N, rows, bW, CB, vk) SAME-padded direct input for the halo
    kernel.  One `jnp.pad` (the only HBM copy of the layout) plus a free
    channel-split reshape; with the effective (dilated) kernel extent
    ke = (k-1)*dilation + 1, rows = stride*(Hout-1) + ke_h so every halo
    block and in-kernel tap slice stays in bounds, bW = stride*(Wout-1) +
    ke_w rounded up to ``sublane``.

    ``h_out`` lets the caller round Hout up to a row-block multiple (the
    extra rows read zero padding).
    """
    n, h, w, c = x.shape
    assert c % vk == 0, (c, vk)
    ho, pt, _ = same_pads(h, kh, stride, dilation)
    _, pl_, _ = same_pads(w, kw, stride, dilation)
    ho = h_out or ho
    rows, bw = halo_layout_dims(h, w, kh=kh, kw=kw, stride=stride,
                                dilation=dilation, h_out=ho, sublane=sublane)
    xp = jnp.pad(
        x,
        ((0, 0), (pt, rows - h - pt), (pl_, bw - w - pl_), (0, 0)),
    )
    return xp.reshape(n, rows, bw, c // vk, vk)


def build_row_tap_stack(
    x: jax.Array,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    dilation: int = 1,
    h_out: int | None = None,
    sublane: int = 8,
) -> jax.Array:
    """NHWC -> (N, kh*stride, Hout, bW, C) row-tap/phase stack (SAME padding).

    The stack-impl (oracle) layout: kh*stride output-sized planes
    materialized in HBM; tap row ky reads padded rows ky*dilation + stride*i
    (dilation spaces the taps, the plane count stays kh*stride).  ``h_out``
    lets the caller round Hout up to a row-block multiple (the extra rows
    read zero padding).  bW = Wout + ((kw-1)*dilation)//stride rounded up to
    ``sublane`` so the kernel's kx slice stays in-bounds and
    sublane-aligned.
    """
    n, h, w, c = x.shape
    ho, pt, _ = same_pads(h, kh, stride, dilation)
    _, pl_, _ = same_pads(w, kw, stride, dilation)
    ho = h_out or ho
    _, bw = stack_layout_dims(h, w, kh=kh, kw=kw, stride=stride,
                              dilation=dilation, h_out=ho, sublane=sublane)
    # padded-row index ceiling (effective kernel extent)
    rows_needed = stride * (ho - 1) + (kh - 1) * dilation + 1
    cols_needed = stride * bw  # every phase plane must reach bw columns
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pt, max(rows_needed - h - pt, 0)),
            (pl_, max(cols_needed - w - pl_, 0)),
            (0, 0),
        ),
    )
    planes = [
        xp[:, ky * dilation : ky * dilation + stride * (ho - 1) + 1 : stride,
           phase :: stride][:, :, :bw]
        for ky in range(kh)
        for phase in range(stride)
    ]
    return jnp.stack(planes, axis=1)


# --------------------------------------------------------------------------
# Halo kernel (default): direct input, tap resolved in-kernel
# --------------------------------------------------------------------------

def _halo_kernel(idx_ref, xh_ref, w_ref, *refs, cb: int, kw: int, stride: int,
                 dilation: int, bh: int, w_out: int, fuse_relu: bool,
                 has_scale: bool, has_bias: bool, has_residual: bool,
                 skip_zero_inputs: bool):
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id t = (ky*kw + kx) * cb + cin_tile (cb = cin tiles
    # reachable from this strip — per group for a grouped conv); the cin
    # tile is already resolved by the index_map, the whole tap resolves here
    t = idx_ref[j, s]
    tap = t // cb
    ky = tap // kw
    kx = tap % kw

    # output pixel (i, jj) of this row block reads halo element
    # (ky*dilation + stride*i, kx*dilation + stride*jj): dynamic tap offset
    # + static stride
    rlen = stride * (bh - 1) + 1
    clen = stride * (w_out - 1) + 1
    xt = xh_ref[0, pl.ds(ky * dilation, rlen),
                pl.ds(kx * dilation, clen), 0]  # (rlen, clen, vk)
    if stride > 1:
        xt = xt[::stride, ::stride]
    xs2 = xt.reshape(bh * w_out, xt.shape[-1])

    def _mac():
        acc_ref[...] += _mac_dot(xs2, w_ref[0, 0])

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_scale:
            # int8 dequant first: the accumulator holds exact int sums and
            # the scales are powers of two, so this multiply is exact —
            # FMA contraction with the bias add cannot change the result
            acc = acc * scale_ref[0].astype(jnp.float32)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            # ResNet shortcut fused at flush: add before the ReLU so the
            # whole basic block retires with one on-chip epilogue
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


def _halo_resident_kernel(idx_ref, xh_ref, w_ref, *refs, cb: int, kw: int,
                          stride: int, dilation: int, bh: int, w_out: int,
                          fuse_relu: bool, has_scale: bool, has_bias: bool,
                          has_residual: bool, skip_zero_inputs: bool):
    """Tiny-feature-map variant of `_halo_kernel`: the block holds ALL cb
    cin tiles (offset independent of strip and sparse step; the row-block
    axis is the outermost grid axis, so the whole thing is DMA'd once per
    (image, row-block)) and the cin tile is resolved in-kernel alongside
    the tap."""
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id t = (ky*kw + kx) * cb + cin_tile — unlike the
    # streaming kernel nothing is resolved by the index_map; tap AND cin
    # tile are dynamic slices into the resident block
    t = idx_ref[j, s]
    tap = t // cb
    ky = tap // kw
    kx = tap % kw
    ct = t % cb

    rlen = stride * (bh - 1) + 1
    clen = stride * (w_out - 1) + 1
    xt = xh_ref[0, pl.ds(ky * dilation, rlen),
                pl.ds(kx * dilation, clen), ct]  # (rlen, clen, vk)
    if stride > 1:
        xt = xt[::stride, ::stride]
    xs2 = xt.reshape(bh * w_out, xt.shape[-1])

    def _mac():
        acc_ref[...] += _mac_dot(xs2, w_ref[0, 0])

    if skip_zero_inputs:
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_scale:
            # exact multiply (po2 dequant scales) — FMA-contraction-proof
            acc = acc * scale_ref[0].astype(jnp.float32)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "groups", "dilation", "w_out", "bh",
        "skip_zero_inputs", "fuse_relu", "interpret", "out_dtype",
    ),
)
def vsconv_halo_pallas(
    xh: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Direct input xh (N, rows, bW, CB, vk) * sparse (kh*kw*CB*vk/groups,
    Cout) -> (N, Hout, w_out, Cout), Hout = (rows - ke_h) // stride + 1
    with ke_h = (kh-1)*dilation + 1.

    INT8: int8 ``xh`` + int8 ``vs.vals`` + ``scale`` (Cout,) — the combined
    per-cout dequant scale, applied at flush before the bias; each step's
    MAC accumulates in int32 on the MXU and the output defaults to f32.

    ``xh`` is `build_halo_input`'s SAME-padded raw input; Hout must be a
    multiple of ``bh`` (the `ops.vsconv` wrapper pads).  Each grid step sees
    an overlapping ``stride*(bh-1) + ke_h``-row halo block (`pl.Unblocked`
    element offsets) and slices its tap out in-kernel, so no tap-shifted
    copy of the input ever exists in HBM.  ``groups`` shards the cin-tile
    axis: output strip j belongs to group j // (NB/groups) and its stored
    K-tile ids index that group's CB/groups cin tiles only (the index_map
    adds the group's base tile).  ``bias`` (Cout,), ``residual``
    (N, Hout, w_out, Cout) and ``fuse_relu`` run the epilogue at flush
    time, identically to the stack kernel.
    """
    n, rows, bwp, cb, vk = xh.shape
    ke_h = (kh - 1) * dilation + 1
    assert (rows - ke_h) % stride == 0, (rows, kh, dilation, stride)
    h = (rows - ke_h) // stride + 1
    nb, s_steps, vk_w, vn = vs.vals.shape
    assert cb % groups == 0 and nb % groups == 0, (cb, nb, groups)
    cbg = cb // groups   # cin tiles reachable from one strip
    spg = nb // groups   # output strips per group
    assert vk_w == vk and vs.shape[0] == kh * kw * cbg * vk, (
        vs.shape, xh.shape, kh, kw, groups)
    assert h % bh == 0, (h, bh)
    hb = h // bh
    hh = stride * (bh - 1) + ke_h  # halo rows per output row-block
    out_dtype = out_dtype or (jnp.float32 if xh.dtype == jnp.int8
                              else xh.dtype)
    has_scale = scale is not None
    has_bias = bias is not None
    has_residual = residual is not None
    resident = use_resident_halo(h, groups)

    if resident:
        # tiny-feature-map layout: ONE block of all cb cin tiles, offsets a
        # function of the row-block only — with the (image, row-block) axis
        # outermost every strip and sparse step revisits it, so the input
        # is DMA'd exactly once per (image, row-block)
        in_specs = [
            pl.BlockSpec(
                (1, hh, bwp, cb, vk),
                resident_in_index_map(hb, stride, bh),
                indexing_mode=pl.Unblocked(),
            ),
            pl.BlockSpec((1, 1, vk, vn), conv_weight_index_map(resident=True)),
        ]
        out_map = conv_out_index_map(hb, resident=True)
        bias_map = conv_bias_index_map(resident=True)
        grid = (n * hb, nb, s_steps)
        kernel = functools.partial(
            _halo_resident_kernel, cb=cb, kw=kw, stride=stride,
            dilation=dilation, bh=bh, w_out=w_out, fuse_relu=fuse_relu,
            has_scale=has_scale, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        )
    else:
        in_specs = [
            # one image, one overlapping halo row window, full width, one
            # cin tile — element offsets (Unblocked): row-blocks overlap by
            # ke_h - stride rows, and the offsets are tap-independent so
            # consecutive sparse steps on one cin tile revisit the block
            # without a new DMA (cin-major tile order makes that the common
            # case).  A grouped strip's tile id is relative to its group,
            # so the group's base tile is added here.
            pl.BlockSpec(
                (1, hh, bwp, 1, vk),
                halo_in_index_map(hb, stride, bh, cbg, spg),
                indexing_mode=pl.Unblocked(),
            ),
            pl.BlockSpec((1, 1, vk, vn), conv_weight_index_map()),
        ]
        out_map = conv_out_index_map(hb)
        bias_map = conv_bias_index_map()
        grid = (nb, n * hb, s_steps)
        kernel = functools.partial(
            _halo_kernel, cb=cbg, kw=kw, stride=stride, dilation=dilation,
            bh=bh, w_out=w_out,
            fuse_relu=fuse_relu, has_scale=has_scale, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        )
    args = [vs.idx, xh, vs.vals]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, vn), bias_map))
        args.append(scale.reshape(nb, vn))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), bias_map))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vn), (
            residual.shape, (n, h, w_out, nb * vn))
        in_specs.append(pl.BlockSpec((1, bh, w_out, vn), out_map))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, w_out, vn), out_map),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=halo_kernel_cost(
            n=n, hop=h, w_out=w_out, kh=kh, stride=stride, bwp=bwp, bh=bh,
            nb=nb, s_steps=s_steps, cb=cbg, vk=vk, vn=vn, dilation=dilation,
            resident=resident,
            in_itemsize=xh.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)


# --------------------------------------------------------------------------
# Row-tap stack kernel (oracle + fallback)
# --------------------------------------------------------------------------

def _kernel(idx_ref, xt_ref, w_ref, *refs, cb: int, kw: int, stride: int,
            dilation: int, w_out: int, fuse_relu: bool, has_scale: bool,
            has_bias: bool, has_residual: bool, skip_zero_inputs: bool):
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id: t = (ky*kw + kx) * cb + cin_tile (cb per group
    # for a grouped conv).  ky and the width phase ((kx*dilation) % stride)
    # are already resolved by the index_map; only the in-plane column
    # offset (kx*dilation) // stride remains.
    t = idx_ref[j, s]
    kx = (t // cb) % kw

    xt = xt_ref[0, 0]  # (bh, bW, vk) — plane and cin-tile selected by index_map
    xs = jax.lax.dynamic_slice_in_dim(
        xt, (kx * dilation) // stride, w_out, axis=1)
    xs2 = xs.reshape(-1, xs.shape[-1])  # (bh*w_out, vk)

    def _mac():
        acc_ref[...] += _mac_dot(xs2, w_ref[0, 0])

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_scale:
            # exact multiply (po2 dequant scales) — FMA-contraction-proof
            acc = acc * scale_ref[0].astype(jnp.float32)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            # ResNet shortcut fused at flush: add before the ReLU so the
            # whole basic block retires with one on-chip epilogue
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "groups", "dilation", "w_out", "bh",
        "skip_zero_inputs", "fuse_relu", "interpret", "out_dtype",
    ),
)
def vsconv_pallas(
    xt: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Row-tap stack xt (N, kh*stride, H, bW, C) * sparse (kh*kw*C/groups,
    Cout) -> (N, H, w_out, Cout).

    The materialized-stack impl, kept as the oracle/fallback for
    `vsconv_halo_pallas`.  H (the stack's output-row count) must be a
    multiple of ``bh``; the `ops.vsconv` wrapper pads.  ``groups`` shards
    the cin-tile axis per group exactly as in the halo kernel; ``dilation``
    spaces the taps (the stack planes are built dilated, so only the
    in-plane column offset changes here).  ``bias`` (Cout,), ``residual``
    (N, H, w_out, Cout) — the ResNet shortcut, added before the ReLU — and
    ``fuse_relu`` run the epilogue inside the kernel at flush time.
    """
    n, planes, h, bw, c = xt.shape
    assert planes == kh * stride, (planes, kh, stride)
    nb, s_steps, vk, vn = vs.vals.shape
    assert c % vk == 0 and (c // vk) % groups == 0 and nb % groups == 0, (
        c, vk, nb, groups)
    cbg = (c // vk) // groups  # cin-tiles per tap reachable from one strip
    spg = nb // groups         # output strips per group
    assert vs.shape[0] == kh * kw * cbg * vk, (vs.shape, c, vk, groups)
    assert h % bh == 0, (h, bh)
    hb = h // bh
    out_dtype = out_dtype or (jnp.float32 if xt.dtype == jnp.int8
                              else xt.dtype)
    has_scale = scale is not None
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        # block: one image, one (ky, phase) plane, one row block, full width,
        # one cin tile — the plane id is the generalized tap select:
        #   plane = ky*stride + (kx*dilation) % stride,  tap = idx[j,s] // cbg
        # and a grouped strip's cin tile gets its group's base added.
        pl.BlockSpec(
            (1, 1, bh, bw, vk),
            stack_in_index_map(hb, cbg, spg, kw, stride, dilation),
        ),
        pl.BlockSpec((1, 1, vk, vn), conv_weight_index_map()),
    ]
    args = [vs.idx, xt, vs.vals]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, vn), conv_bias_index_map()))
        args.append(scale.reshape(nb, vn))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), conv_bias_index_map()))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vn), (
            residual.shape, (n, h, w_out, nb * vn))
        in_specs.append(pl.BlockSpec((1, bh, w_out, vn), conv_out_index_map(hb)))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, w_out, vn), conv_out_index_map(hb)),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, cb=cbg, kw=kw, stride=stride, dilation=dilation,
            w_out=w_out,
            fuse_relu=fuse_relu, has_scale=has_scale, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=stack_kernel_cost(
            n=n, hop=h, w_out=w_out, bw=bw, bh=bh, nb=nb, s_steps=s_steps,
            vk=vk, vn=vn, in_itemsize=xt.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)


# --------------------------------------------------------------------------
# Depthwise kernels (groups == Cin): per-channel tap vectors, VPU MACs
# --------------------------------------------------------------------------
#
# A depthwise conv (multiplier 1) has one kh x kw filter per channel — a
# full-cin K-tile would waste vk-1 lanes of every MXU issue.  Instead the
# weight is the (kh*kw, C) tap matrix encoded with vk == 1, vn == vc: output
# strips are vc-channel tiles and each stored vector is one tap's weights
# across the tile (idx[j, s] = the tap id).  The MAC is elementwise over the
# channel lane axis (VPU, not MXU); pruned (tap, channel-tile) vectors are
# structurally absent and an all-zero shifted input block is skipped with
# @pl.when — the same two-sided skip as the full kernels.


def _dw_flush(acc_ref, o_ref, scale_ref, bias_ref, res_ref, *, fuse_relu,
              has_scale, has_bias, has_residual):
    acc = acc_ref[...].reshape(o_ref.shape)
    if has_scale:
        # int8 dequant first (the elementwise int8 MAC is f32-exact, so the
        # accumulator already holds the exact integer sums)
        acc = acc * scale_ref[0].astype(jnp.float32)
    if has_bias:
        acc = acc + bias_ref[0].astype(jnp.float32)
    if has_residual:
        acc = acc + res_ref[...].astype(jnp.float32)
    if fuse_relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _dw_halo_kernel(idx_ref, xh_ref, w_ref, *refs, kw: int, stride: int,
                    dilation: int, bh: int, w_out: int, fuse_relu: bool,
                    has_scale: bool, has_bias: bool, has_residual: bool,
                    skip_zero_inputs: bool):
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # idx[j, s] IS the tap id — no cin tile to decode: the input block
    # depends only on (row-block, channel tile), so every sparse step
    # revisits it and the halo is fetched exactly once per (strip, block).
    t = idx_ref[j, s]
    ky = t // kw
    kx = t % kw
    rlen = stride * (bh - 1) + 1
    clen = stride * (w_out - 1) + 1
    xt = xh_ref[0, pl.ds(ky * dilation, rlen),
                pl.ds(kx * dilation, clen), 0]  # (rlen, clen, vc)
    if stride > 1:
        xt = xt[::stride, ::stride]
    xs2 = xt.reshape(bh * w_out, xt.shape[-1])

    def _mac():
        # elementwise per-channel MAC: one tap vector scales its channels
        # (f32-exact for int8 values too — every |v| <= 127 product is
        # exactly representable, so no separate int32 path is needed)
        acc_ref[...] += xs2.astype(jnp.float32) * w_ref[0, 0, 0].astype(
            jnp.float32)

    if skip_zero_inputs:
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        _dw_flush(acc_ref, o_ref, scale_ref, bias_ref, res_ref,
                  fuse_relu=fuse_relu, has_scale=has_scale,
                  has_bias=has_bias, has_residual=has_residual)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "dilation", "w_out", "bh", "skip_zero_inputs",
        "fuse_relu", "interpret", "out_dtype",
    ),
)
def vsconv_dw_halo_pallas(
    xh: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Depthwise halo kernel: direct input xh (N, rows, bW, CB, vc) * tap
    matrix (kh*kw, C) encoded vk=1/vn=vc -> (N, Hout, w_out, C).

    ``xh`` is `build_halo_input(x, vk=vc)`; the channel-tile axis CB = C/vc
    is the strip axis.  The halo block offset is tap-independent AND
    cin-tile-trivial (strip == channel tile), so the input is DMA'd once
    per (strip, row-block) regardless of tap order — the depthwise case is
    where the halo layout's fetch-once story is exact, not amortized.
    """
    n, rows, bwp, cb, vc = xh.shape
    ke_h = (kh - 1) * dilation + 1
    assert (rows - ke_h) % stride == 0, (rows, kh, dilation, stride)
    h = (rows - ke_h) // stride + 1
    nb, s_steps, vk_w, vn = vs.vals.shape
    assert vk_w == 1 and vn == vc and nb == cb, (vs.vals.shape, xh.shape)
    assert vs.shape == (kh * kw, cb * vc), (vs.shape, kh, kw, cb, vc)
    assert h % bh == 0, (h, bh)
    hb = h // bh
    hh = stride * (bh - 1) + ke_h
    out_dtype = out_dtype or (jnp.float32 if xh.dtype == jnp.int8
                              else xh.dtype)
    has_scale = scale is not None
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        pl.BlockSpec(
            (1, hh, bwp, 1, vc),
            dw_halo_in_index_map(hb, stride, bh),
            indexing_mode=pl.Unblocked(),
        ),
        pl.BlockSpec((1, 1, 1, vc), conv_weight_index_map()),
    ]
    args = [vs.idx, xh, vs.vals]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, vc), conv_bias_index_map()))
        args.append(scale.reshape(nb, vc))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vc), conv_bias_index_map()))
        args.append(bias.reshape(nb, vc))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vc), (
            residual.shape, (n, h, w_out, nb * vc))
        in_specs.append(pl.BlockSpec((1, bh, w_out, vc), conv_out_index_map(hb)))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, w_out, vc), conv_out_index_map(hb)),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vc), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _dw_halo_kernel, kw=kw, stride=stride, dilation=dilation, bh=bh,
            w_out=w_out, fuse_relu=fuse_relu, has_scale=has_scale,
            has_bias=has_bias, has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vc), out_dtype),
        interpret=interpret,
        cost_estimate=dw_halo_kernel_cost(
            n=n, hop=h, w_out=w_out, kh=kh, stride=stride, bwp=bwp, bh=bh,
            nb=nb, s_steps=s_steps, vc=vc, dilation=dilation,
            in_itemsize=xh.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)


def _dw_stack_kernel(idx_ref, xt_ref, w_ref, *refs, kw: int, stride: int,
                     dilation: int, w_out: int, fuse_relu: bool,
                     has_scale: bool, has_bias: bool, has_residual: bool,
                     skip_zero_inputs: bool):
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # idx[j, s] is the tap id; (ky, phase) resolved by the index_map, only
    # the in-plane column offset remains.
    t = idx_ref[j, s]
    kx = t % kw
    xt = xt_ref[0, 0]  # (bh, bW, vc)
    xs = jax.lax.dynamic_slice_in_dim(
        xt, (kx * dilation) // stride, w_out, axis=1)
    xs2 = xs.reshape(-1, xs.shape[-1])

    def _mac():
        acc_ref[...] += xs2.astype(jnp.float32) * w_ref[0, 0, 0].astype(
            jnp.float32)

    if skip_zero_inputs:
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        _dw_flush(acc_ref, o_ref, scale_ref, bias_ref, res_ref,
                  fuse_relu=fuse_relu, has_scale=has_scale,
                  has_bias=has_bias, has_residual=has_residual)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "dilation", "w_out", "bh", "skip_zero_inputs",
        "fuse_relu", "interpret", "out_dtype",
    ),
)
def vsconv_dw_stack_pallas(
    xt: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Depthwise row-tap-stack kernel: xt (N, kh*stride, H, bW, C) * tap
    matrix (kh*kw, C) encoded vk=1/vn=vc -> (N, H, w_out, C).

    The bandwidth-dumb oracle for `vsconv_dw_halo_pallas`: each sparse step
    selects a fresh (plane, channel-tile) block, so the input is re-DMA'd
    every step — S fetches where the halo layout needs one.
    """
    n, planes, h, bw, c = xt.shape
    assert planes == kh * stride, (planes, kh, stride)
    nb, s_steps, vk_w, vc = vs.vals.shape
    assert vk_w == 1 and c == nb * vc, (vs.vals.shape, c)
    assert vs.shape == (kh * kw, c), (vs.shape, kh, kw, c)
    assert h % bh == 0, (h, bh)
    hb = h // bh
    out_dtype = out_dtype or (jnp.float32 if xt.dtype == jnp.int8
                              else xt.dtype)
    has_scale = scale is not None
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        pl.BlockSpec(
            (1, 1, bh, bw, vc),
            dw_stack_in_index_map(hb, kw, stride, dilation),
        ),
        pl.BlockSpec((1, 1, 1, vc), conv_weight_index_map()),
    ]
    args = [vs.idx, xt, vs.vals]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, vc), conv_bias_index_map()))
        args.append(scale.reshape(nb, vc))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vc), conv_bias_index_map()))
        args.append(bias.reshape(nb, vc))
    if has_residual:
        assert residual.shape == (n, h, w_out, c), (
            residual.shape, (n, h, w_out, c))
        in_specs.append(pl.BlockSpec((1, bh, w_out, vc), conv_out_index_map(hb)))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, w_out, vc), conv_out_index_map(hb)),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vc), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _dw_stack_kernel, kw=kw, stride=stride, dilation=dilation,
            w_out=w_out, fuse_relu=fuse_relu, has_scale=has_scale,
            has_bias=has_bias, has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, c), out_dtype),
        interpret=interpret,
        cost_estimate=dw_stack_kernel_cost(
            n=n, hop=h, w_out=w_out, bw=bw, bh=bh, nb=nb, s_steps=s_steps,
            vc=vc, in_itemsize=xt.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)
