"""vsconv — direct KxK vector-sparse convolution Pallas TPU kernel.

The paper decomposes a conv into kernel *columns* (WA/WB/WC in Fig. 6) and
skips all-zero columns and all-zero input column vectors.  The TPU analogue
decomposes an arbitrary ``kh x kw`` / stride-``s`` conv into kernel *taps*
x input-channel tiles:

    conv(x, w)[i, j] = sum_{ky, kx} x[s*i + ky - pt, s*j + kx - pl] @ w[ky, kx]
                     = sum over K-tiles t = (ky*kw + kx, cin-tile) of
                       gather(x, t)[i, j] @ w_tile[t]          (kh*kw*CB matmuls)

A "weight vector" here is one (vk cin, vn cout) tile of one tap — pruned tiles
are structurally absent from the balanced block-CSR, so their matmuls never
enter the grid (the paper's weight-side skip).  An all-zero shifted-input row
block is skipped at runtime with ``@pl.when`` (the input-side skip).

Input layout — the generalized row-tap/phase stack built by
``build_row_tap_stack``:

  XT (N, kh*stride, Hout, bW, C)
  XT[:, ky*stride + phase, i, j'] = pad(x)[:, stride*i + ky, phase + stride*j']

Rows are pre-strided per tap row ``ky`` (so the ky shift *and* the row stride
become a unit-block index selectable from the scalar-prefetched tap id), and
the width axis is pre-split into its ``stride`` phases.  Writing
``kx = stride*(kx//stride) + (kx % stride)``, output column ``j`` at tap
``kx`` reads input column ``phase + stride*(j + kx//stride)`` with
``phase = kx % stride`` — i.e. plane ``ky*stride + phase`` at column
``j + kx//stride``.  So the whole tap select is BlockSpec index_map
arithmetic plus one contiguous sublane slice of length ``w_out`` starting at
``kx // stride`` inside the kernel (the paper's "broadcast the right input
column" realized as index arithmetic).  For stride 1 this degenerates to the
classic 3-plane row-tap stack; bW is Wout + (kw-1)//stride rounded up to the
sublane multiple.

Padding is XLA-"SAME" for the given stride (Hout = ceil(H/stride)); the
`ops.vsconv` wrapper computes it and pads Hout to a ``bh`` multiple.

Fused epilogue: optional per-cout ``bias`` add, optional ``residual``
(ResNet shortcut) add, and ReLU run inside the kernel at flush time
(f32 accumulator -> +bias -> +residual -> max(0) -> cast).  Fusing the ReLU
means the *next* layer's input zeros — the vectors its input-side skip
elides — are produced on-chip for free, exactly the paper's post-ReLU
input-zero-vector story; fusing the residual means a whole ResNet basic
block retires with a single extra VMEM read, no extra HBM round-trip.

Grid: ``(NB, N * HB, S)`` — cout strip j, (image, row-block) m, sparse step s.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.sparse_ops import same_pads
from repro.core.vector_sparse import VectorSparse

__all__ = ["vsconv_pallas", "build_row_tap_stack", "same_pads"]


def build_row_tap_stack(
    x: jax.Array,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    h_out: int | None = None,
    sublane: int = 8,
) -> jax.Array:
    """NHWC -> (N, kh*stride, Hout, bW, C) row-tap/phase stack (SAME padding).

    ``h_out`` lets the caller round Hout up to a row-block multiple (the
    extra rows read zero padding).  bW = Wout + (kw-1)//stride rounded up to
    ``sublane`` so the kernel's kx slice stays in-bounds and sublane-aligned.
    """
    n, h, w, c = x.shape
    ho, pt, _ = same_pads(h, kh, stride)
    wo, pl_, _ = same_pads(w, kw, stride)
    ho = h_out or ho
    bw = -(-(wo + (kw - 1) // stride) // sublane) * sublane
    rows_needed = stride * (ho - 1) + kh  # padded-row index ceiling
    cols_needed = stride * bw  # every phase plane must reach bw columns
    xp = jnp.pad(
        x,
        (
            (0, 0),
            (pt, max(rows_needed - h - pt, 0)),
            (pl_, max(cols_needed - w - pl_, 0)),
            (0, 0),
        ),
    )
    planes = [
        xp[:, ky : ky + stride * (ho - 1) + 1 : stride, phase :: stride][
            :, :, :bw
        ]
        for ky in range(kh)
        for phase in range(stride)
    ]
    return jnp.stack(planes, axis=1)


def _kernel(idx_ref, xt_ref, w_ref, *refs, cb: int, kw: int, stride: int,
            w_out: int, fuse_relu: bool, has_bias: bool, has_residual: bool,
            skip_zero_inputs: bool):
    it = iter(refs)
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    j = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the K-tile id: t = (ky*kw + kx) * CB + cin_tile.  ky and the
    # width phase (kx % stride) are already resolved by the index_map; only
    # the in-plane column offset kx // stride remains.
    t = idx_ref[j, s]
    kx = (t // cb) % kw

    xt = xt_ref[0, 0]  # (bh, bW, vk) — plane and cin-tile selected by index_map
    xs = jax.lax.dynamic_slice_in_dim(xt, kx // stride, w_out, axis=1)
    xs2 = xs.reshape(-1, xs.shape[-1])  # (bh*w_out, vk)

    def _mac():
        acc_ref[...] += jnp.dot(
            xs2, w_ref[0, 0], preferred_element_type=jnp.float32
        )

    if skip_zero_inputs:
        # paper's input zero-vector skip (post-ReLU activations)
        pl.when(jnp.any(xs2 != 0))(_mac)
    else:
        _mac()

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].reshape(o_ref.shape)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            # ResNet shortcut fused at flush: add before the ReLU so the
            # whole basic block retires with one on-chip epilogue
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "kh", "kw", "stride", "w_out", "bh", "skip_zero_inputs", "fuse_relu",
        "interpret", "out_dtype",
    ),
)
def vsconv_pallas(
    xt: jax.Array,
    vs: VectorSparse,
    *,
    w_out: int,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """Row-tap stack xt (N, kh*stride, H, bW, C) * sparse (kh*kw*C, Cout)
    -> (N, H, w_out, Cout).

    H (the stack's output-row count) must be a multiple of ``bh``; the
    `ops.vsconv` wrapper pads.  ``bias`` (Cout,), ``residual``
    (N, H, w_out, Cout) — the ResNet shortcut, added before the ReLU — and
    ``fuse_relu`` run the epilogue inside the kernel at flush time.
    """
    n, planes, h, bw, c = xt.shape
    assert planes == kh * stride, (planes, kh, stride)
    nb, s_steps, vk, vn = vs.vals.shape
    assert vs.shape[0] == kh * kw * c and c % vk == 0, (vs.shape, c, vk)
    assert h % bh == 0, (h, bh)
    cb = c // vk  # cin-tiles per tap
    hb = h // bh
    out_dtype = out_dtype or xt.dtype
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        # block: one image, one (ky, phase) plane, one row block, full width,
        # one cin tile — the plane id is the generalized tap select:
        #   plane = ky*stride + kx % stride,  tap = idx[j, s] // cb
        pl.BlockSpec(
            (1, 1, bh, bw, vk),
            lambda j, m, s, idx: (
                m // hb,                                      # image
                (idx[j, s] // cb // kw) * stride
                + ((idx[j, s] // cb) % kw) % stride,          # (ky, phase)
                m % hb,                                       # row block
                0,
                idx[j, s] % cb,                               # cin tile
            ),
        ),
        pl.BlockSpec((1, 1, vk, vn), lambda j, m, s, idx: (j, s, 0, 0)),
    ]
    args = [vs.idx, xt, vs.vals]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), lambda j, m, s, idx: (j, 0)))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (n, h, w_out, nb * vn), (
            residual.shape, (n, h, w_out, nb * vn))
        in_specs.append(pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, n * hb, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, bh, w_out, vn), lambda j, m, s, idx: (m // hb, m % hb, 0, j)
        ),
        scratch_shapes=[pltpu.VMEM((bh * w_out, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(
            _kernel, cb=cb, kw=kw, stride=stride, w_out=w_out,
            fuse_relu=fuse_relu, has_bias=has_bias,
            has_residual=has_residual,
            skip_zero_inputs=skip_zero_inputs,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, w_out, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=2 * n * h * w_out * nb * s_steps * vk * vn,
            bytes_accessed=(
                n * hb * nb * s_steps * bh * bw * vk * xt.dtype.itemsize
                + vs.vals.size * vs.vals.dtype.itemsize
                + n * h * w_out * nb * vn * jnp.dtype(out_dtype).itemsize
                + (residual.size * residual.dtype.itemsize
                   if has_residual else 0)
            ),
            transcendentals=0,
        ),
    )(*args)
