"""Public jit'd wrappers around the Pallas kernels.

Handle padding to kernel-friendly shapes, backend dispatch (interpret=True on
CPU so kernels validate everywhere, compiled on real TPU), and layout prep
(the vsconv row-tap stack).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vector_sparse import VectorSparse
from .vsmm import vsmm_pallas
from .vsconv import vsconv_pallas, build_row_tap_stack

__all__ = ["vsmm", "vsconv"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def vsmm(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bm: int = 256,
    skip_zero_inputs: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """x (M, K) @ vector-sparse W (K, N) -> (M, N); pads M to a bm multiple."""
    m, k = x.shape
    interpret = _interpret() if interpret is None else interpret
    bm = min(bm, _round_up(m, 8))
    mp = _round_up(m, bm)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    out = vsmm_pallas(
        x, vs, bm=bm, skip_zero_inputs=skip_zero_inputs, interpret=interpret
    )
    return out[:m] if mp != m else out


def vsconv(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """NHWC 3x3/s1/p1 conv with vector-sparse (9*Cin, Cout) weights."""
    n, h, w, c = x.shape
    interpret = _interpret() if interpret is None else interpret
    bh = min(bh, h)
    hp = _round_up(h, bh)
    if hp != h:
        x = jnp.pad(x, ((0, 0), (0, hp - h), (0, 0), (0, 0)))
    xt = build_row_tap_stack(x)
    out = vsconv_pallas(
        xt, vs, w_out=w, bh=bh, skip_zero_inputs=skip_zero_inputs,
        interpret=interpret,
    )
    return out[:, :h] if hp != h else out
