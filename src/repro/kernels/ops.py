"""Public jit'd wrappers around the Pallas kernels.

Handle padding to kernel-friendly shapes, backend dispatch (interpret=True on
CPU so kernels validate everywhere, compiled on real TPU), and layout prep
(the halo direct input / the row-tap stack).

`vsconv` covers the generalized kernel family:

  vsconv(x, vs, kh=3, kw=3, stride=1, groups=1, dilation=1, bias=None,
         fuse_relu=False, impl="halo")

  * arbitrary odd/even kh x kw taps, SAME padding for the given stride
    (Hout = ceil(H/stride)) — the weight matrix is (kh*kw*Cin/groups, Cout)
    with K ordered (ky, kx, cin), i.e. `core.sparse_ops.conv_weight_to_matrix`;
  * stride 1 and 2 (any stride the tap decomposition supports, in fact),
    dilated taps (effective extent (k-1)*dilation + 1), grouped convs
    (strips group-major, per-group cin tiles), and depthwise
    (groups == Cin) via the per-channel tap kernels;
  * ungrouped 1x1 convs route through `vsmm` over flattened pixels (a
    pointwise conv *is* the sparse matmul; stride subsamples first) —
    ResNet projections and MobileNet pointwise stages;
  * ``impl`` picks the input layout: ``"halo"`` (default) reads the raw
    SAME-padded input through overlapping halo blocks and resolves the tap
    in-kernel — ~1x-input HBM traffic; ``"stack"`` materializes the
    kh*stride-plane row-tap stack first — the bandwidth-dumb oracle and
    fallback layout;
  * ``bias``/``fuse_relu`` run the epilogue inside the kernel, so the
    post-ReLU zeros feeding the next layer's input-side skip are produced
    on-chip for free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vector_sparse import VectorSparse
from .vsmm import vsmm_pallas
from .vsconv import (
    vsconv_pallas, vsconv_halo_pallas, vsconv_dw_halo_pallas,
    vsconv_dw_stack_pallas, build_row_tap_stack, build_halo_input,
    same_pads,
)

__all__ = ["vsmm", "vsconv"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def vsmm(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bm: int = 256,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool | None = None,
) -> jax.Array:
    """x (M, K) @ vector-sparse W (K, N) -> (M, N); pads M to a bm multiple.

    Optional fused epilogue: ``scale`` (N,) int8 dequant multiply + ``bias``
    (N,) add + ``residual`` (M, N) add (before the ReLU — the ResNet
    shortcut) + ``fuse_relu`` inside the kernel (f32 accumulator, one cast
    at flush).
    """
    m, k = x.shape
    interpret = _interpret() if interpret is None else interpret
    bm = min(bm, _round_up(m, 8))
    mp = _round_up(m, bm)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
        if residual is not None:
            residual = jnp.pad(residual, ((0, mp - m), (0, 0)))
    out = vsmm_pallas(
        x, vs, bm=bm, bias=bias, residual=residual, scale=scale,
        skip_zero_inputs=skip_zero_inputs,
        fuse_relu=fuse_relu, interpret=interpret
    )
    return out[:m] if mp != m else out


def vsconv(
    x: jax.Array,
    vs: VectorSparse,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    bh: int = 8,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    impl: str = "halo",
    interpret: bool | None = None,
) -> jax.Array:
    """NHWC kh x kw / stride / dilation / SAME (grouped) conv with
    vector-sparse (kh*kw*Cin/groups, Cout) weights
    -> (N, ceil(H/stride), ceil(W/stride), Cout).

    Ungrouped 1x1 convs dispatch to the sparse matmul over flattened pixels
    (stride subsamples first); depthwise convs (groups == Cin, multiplier
    1, weight matrix (kh*kw, C) encoded vk=1) run the per-channel tap
    kernels; everything else runs one of the two direct tap-decomposed
    Pallas kernels — grouped convs shard the cin-tile axis per group.
    ``impl="halo"`` (default — raw input, halo-blocked, tap resolved
    in-kernel) or ``impl="stack"`` (the materialized row-tap/phase stack,
    kept as oracle and fallback) selects the input layout for all of them.
    ``bias`` (Cout,), ``residual`` (the output-shaped ResNet shortcut,
    added before the ReLU) and ``fuse_relu`` fuse the epilogue in-kernel.
    """
    n, h, w, c = x.shape
    interpret = _interpret() if interpret is None else interpret
    if impl not in ("halo", "stack"):
        raise ValueError(f"vsconv impl must be 'halo' or 'stack', got {impl!r}")
    assert c % groups == 0, (c, groups)
    # multiplier-1 depthwise only; a channel-multiplier conv (cout > cin)
    # still runs the general grouped kernels with vk == cin/groups == 1
    depthwise = groups > 1 and groups == c and vs.shape == (kh * kw, c)
    if kh == 1 and kw == 1 and groups == 1:
        if stride != 1:
            x = x[:, ::stride, ::stride]
        _, ho, wo, _ = x.shape
        res2 = (residual.reshape(n * ho * wo, -1)
                if residual is not None else None)
        out = vsmm(
            x.reshape(-1, c), vs, bias=bias, residual=res2, scale=scale,
            skip_zero_inputs=skip_zero_inputs, fuse_relu=fuse_relu,
            interpret=interpret,
        )
        return out.reshape(n, ho, wo, -1)
    ho, _, _ = same_pads(h, kh, stride, dilation)
    wo, _, _ = same_pads(w, kw, stride, dilation)
    bh = min(bh, ho)
    hop = _round_up(ho, bh)
    if residual is not None and hop != ho:
        residual = jnp.pad(residual, ((0, 0), (0, hop - ho), (0, 0), (0, 0)))
    common = dict(
        w_out=wo, kh=kh, kw=kw, stride=stride, dilation=dilation, bias=bias,
        residual=residual, scale=scale, bh=bh,
        skip_zero_inputs=skip_zero_inputs,
        fuse_relu=fuse_relu, interpret=interpret,
    )
    if depthwise:
        # per-channel tap kernels: strips are vn-channel tiles (vk == 1)
        assert vs.vk == 1 and vs.shape == (kh * kw, c), (vs.shape, kh, kw, c)
        if impl == "halo":
            xh = build_halo_input(x, kh=kh, kw=kw, stride=stride,
                                  dilation=dilation, vk=vs.vn, h_out=hop)
            out = vsconv_dw_halo_pallas(xh, vs, **common)
        else:
            xt = build_row_tap_stack(x, kh=kh, kw=kw, stride=stride,
                                     dilation=dilation, h_out=hop)
            out = vsconv_dw_stack_pallas(xt, vs, **common)
    elif impl == "halo":
        xh = build_halo_input(x, kh=kh, kw=kw, stride=stride,
                              dilation=dilation, vk=vs.vk, h_out=hop)
        out = vsconv_halo_pallas(xh, vs, groups=groups, **common)
    else:
        xt = build_row_tap_stack(x, kh=kh, kw=kw, stride=stride,
                                 dilation=dilation, h_out=hop)
        out = vsconv_pallas(xt, vs, groups=groups, **common)
    return out[:, :ho] if hop != ho else out
