"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here computing the *same
mathematical function* with plain jnp ops (densify + dense compute).  Tests
sweep shapes/dtypes/geometries and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vector_sparse import VectorSparse, decode

__all__ = ["vsmm_ref", "vsconv_ref", "conv_ref", "conv3x3_ref"]


def vsmm_ref(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    fuse_relu: bool = False,
) -> jax.Array:
    """x (M, K) @ densify(vs) (K, N) -> (M, N), f32 accumulation.

    ``bias``/``residual``/``fuse_relu`` mirror the kernel's fused epilogue
    (applied in f32, residual before ReLU, before the output cast).
    """
    w = decode(vs)
    y = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def conv_ref(x: jax.Array, w: jax.Array, *, stride: int = 1, groups: int = 1,
             dilation: int = 1) -> jax.Array:
    """Dense kh x kw / stride / dilation / SAME conv oracle.  x NHWC,
    w (kh, kw, Cin/groups, Cout) — XLA's grouped HWIO layout."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="SAME",
        rhs_dilation=(dilation, dilation),
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def conv3x3_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Dense 3x3/s1/p1 conv oracle (back-compat alias)."""
    return conv_ref(x, w, stride=1)


def vsconv_ref(
    x: jax.Array,
    w_vs: VectorSparse,
    *,
    kh: int = 3,
    kw: int = 3,
    stride: int = 1,
    groups: int = 1,
    dilation: int = 1,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    fuse_relu: bool = False,
) -> jax.Array:
    """kh x kw / stride / dilation / SAME (grouped) conv against the
    densified vector-sparse weight.

    w_vs shape is (kh*kw*Cin/groups, Cout) with K ordered (ky, kx,
    cin-within-group) and output strips group-major — the layout produced by
    `core.sparse_ops.conv_weight_to_matrix` on XLA's grouped HWIO weight.
    Depthwise (groups == Cin) is the (kh*kw, C) degenerate case.  ``bias``,
    ``residual`` (output-shaped shortcut added before the ReLU) and
    ``fuse_relu`` mirror the kernel's fused epilogue.
    """
    n, h, wdt, c = x.shape
    k, cout = w_vs.shape
    assert k == kh * kw * (c // groups), (k, kh, kw, c, groups)
    w = decode(w_vs).reshape(kh, kw, c // groups, cout)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="SAME",
        rhs_dilation=(dilation, dilation),
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    if residual is not None:
        y = y + residual.astype(jnp.float32)
    if fuse_relu:
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)
