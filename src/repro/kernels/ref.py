"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference here computing the *same
mathematical function* with plain jnp ops (densify + dense compute).  Tests
sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.vector_sparse import VectorSparse, decode

__all__ = ["vsmm_ref", "vsconv_ref", "conv3x3_ref"]


def vsmm_ref(x: jax.Array, vs: VectorSparse) -> jax.Array:
    """x (M, K) @ densify(vs) (K, N) -> (M, N), f32 accumulation."""
    w = decode(vs)
    return jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(x.dtype)


def conv3x3_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Dense 3x3/s1/p1 conv oracle. x NHWC, w (3,3,Cin,Cout)."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ).astype(x.dtype)


def vsconv_ref(x: jax.Array, w_vs: VectorSparse) -> jax.Array:
    """3x3 conv against the densified vector-sparse weight.

    w_vs shape is (9*Cin, Cout) with K ordered (ky, kx, cin) — the layout
    produced by `core.sparse_ops.conv_weight_to_matrix`.
    """
    n, h, wdt, c = x.shape
    k, cout = w_vs.shape
    assert k == 9 * c, (k, c)
    w = decode(w_vs).reshape(3, 3, c, cout)
    return conv3x3_ref(x, w)
