"""vsmm — vector-sparse matmul Pallas TPU kernel (the paper's PE array on MXU).

Maps VSCNN's dataflow onto the TPU memory hierarchy:

  paper (ASIC)                          this kernel (TPU)
  ------------------------------------  -----------------------------------
  nonzero 1-D weight vectors in SRAM    nonzero (vk, vn) weight tiles in a
                                        balanced block-CSR; only those tiles
                                        are DMA'd HBM->VMEM by the grid
                                        pipeline (static skip: the zero
                                        tiles never cost cycles *or* FLOPs)
  per-vector index -> accumulator       scalar-prefetch ``idx`` in SMEM
                                        drives BlockSpec.index_map: the s-th
                                        issued vector of output strip j
                                        gathers activation K-tile idx[j,s]
  zero input vectors absent from SRAM   ``@pl.when(any(x!=0))`` runtime
                                        guard: an all-zero activation tile
                                        issues no MXU op (the TPU analogue
                                        of a skipped cycle; the DMA itself
                                        is pipelined and hidden)
  diagonal partial-sum accumulation     f32 VMEM accumulator revisited
                                        across the innermost sparse-K grid
                                        dimension (stays on-chip, one
                                        HBM write at s == S-1)
  dense/sparse in one datapath          the dense path is S == KB with
                                        idx[j, s] = s — same kernel

Grid: ``(NB, MB, S)`` — output strip j, activation row-block m, sparse step s
(innermost, so the output tile is revisited and accumulated in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.vector_sparse import VectorSparse

__all__ = [
    "vsmm_pallas", "vsmm_kernel_cost", "vsmm_x_index_map", "vsmm_w_index_map",
    "vsmm_out_index_map", "vsmm_bias_index_map",
]


def _mac_dot(x: jax.Array, w: jax.Array) -> jax.Array:
    """One sparse-step MAC on the MXU.

    int8 inputs multiply-accumulate exactly in int32 (the MXU-native int8
    path; one step is at most 127*127*vk < 2^24, so the cast of the partial
    into the shared f32 accumulator is also exact); float inputs accumulate
    in f32 directly.
    """
    if x.dtype == jnp.int8:
        return jnp.dot(x, w, preferred_element_type=jnp.int32).astype(
            jnp.float32)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def vsmm_kernel_cost(
    *, m: int, nb: int, s_steps: int, vk: int, vn: int, in_itemsize: int = 4,
    w_itemsize: int = 4, out_itemsize: int = 4, residual_bytes: int = 0,
) -> pl.CostEstimate:
    """Kernel-side cost of the sparse matmul: every sparse step gathers a
    fresh (bm, vk) activation K-tile, the stored weight tiles stream once,
    the output strip is written once.  ``m`` is the kernel's (padded) row
    count — `core.accel_model.conv_layer_traffic` quotes the same formulas
    at the unpadded row count for the 1x1-conv route."""
    return pl.CostEstimate(
        flops=2 * m * nb * s_steps * vk * vn,
        bytes_accessed=(
            m * nb * s_steps * vk * in_itemsize
            + nb * s_steps * vk * vn * w_itemsize
            + m * nb * vn * out_itemsize
            + residual_bytes
        ),
        transcendentals=0,
    )


# --------------------------------------------------------------------------
# BlockSpec index maps (named factories — shared with `repro.analysis`).
# Grid order (j, mi, s) = (output strip, activation row-block, sparse step).
# --------------------------------------------------------------------------

def vsmm_x_index_map():
    """Activation K-tile gather: the paper's index system — the s-th issued
    vector of strip j reads activation K-tile idx[j, s]."""
    def index_map(j, mi, s, idx):
        return (mi, idx[j, s])
    return index_map


def vsmm_w_index_map():
    """The s-th stored weight vector of strip j."""
    def index_map(j, mi, s, idx):
        return (j, s, 0, 0)
    return index_map


def vsmm_out_index_map():
    """Output/residual (row-block, strip) tile."""
    def index_map(j, mi, s, idx):
        return (mi, j)
    return index_map


def vsmm_bias_index_map():
    """Strip j's bias tile (excluded from the byte contract)."""
    def index_map(j, mi, s, idx):
        return (j, 0)
    return index_map


def _kernel(idx_ref, x_ref, w_ref, *refs, fuse_relu: bool, has_scale: bool,
            has_bias: bool, has_residual: bool, skip_zero_inputs: bool):
    it = iter(refs)
    scale_ref = next(it) if has_scale else None
    bias_ref = next(it) if has_bias else None
    res_ref = next(it) if has_residual else None
    o_ref = next(it)
    acc_ref = next(it)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    if skip_zero_inputs:
        # Paper's input-side zero-vector skip: an all-zero activation tile
        # (e.g. post-ReLU) issues no MXU work.  On the ASIC the vector is not
        # in SRAM at all; on TPU the DMA is pipelined/hidden and we predicate
        # off the compute, which is what costs cycles on the MXU.
        nonzero = jnp.any(x != 0)

        @pl.when(nonzero)
        def _mac():
            acc_ref[...] += _mac_dot(x, w_ref[0, 0])
    else:
        acc_ref[...] += _mac_dot(x, w_ref[0, 0])

    @pl.when(s == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...]
        # fused epilogue: the ReLU zeros produced here are exactly the input
        # vectors the *next* layer's input-side skip elides.  The residual
        # (ResNet shortcut) is added before the ReLU, so a whole basic block
        # retires in-kernel with one HBM write.  Dequant (int8) comes first:
        # acc -> *scale -> +bias -> +residual -> max(0) -> cast.
        if has_scale:
            # exact multiply: dequant scales are powers of two, so FMA
            # contraction with the bias add cannot change the result —
            # parity with the structural jnp path is compiler-proof
            acc = acc * scale_ref[0].astype(jnp.float32)
        if has_bias:
            acc = acc + bias_ref[0].astype(jnp.float32)
        if has_residual:
            acc = acc + res_ref[...].astype(jnp.float32)
        if fuse_relu:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "skip_zero_inputs", "fuse_relu", "interpret",
                     "out_dtype"),
)
def vsmm_pallas(
    x: jax.Array,
    vs: VectorSparse,
    *,
    bm: int = 256,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    scale: jax.Array | None = None,
    skip_zero_inputs: bool = True,
    fuse_relu: bool = False,
    interpret: bool = False,
    out_dtype=None,
) -> jax.Array:
    """x (M, K) @ vector-sparse W (K, N) -> (M, N).

    M must be a multiple of ``bm`` and K of ``vs.vk`` (the `ops.vsmm` wrapper
    pads).  FLOPs scale with vs.density — the zero weight vectors are
    structurally absent from the grid.  ``bias`` (N,), ``residual`` (M, N)
    and ``fuse_relu`` run the epilogue inside the kernel at flush time
    (f32 accumulator -> *scale -> +bias -> +residual -> max(0) -> cast).

    INT8: pass int8 ``x`` + int8 ``vs.vals`` + ``scale`` (N,) — the combined
    per-cout dequant scale (activation scale x weight scale).  Each step
    multiply-accumulates in int32 on the MXU and the f32 output materializes
    only at flush; the residual stays f32.
    """
    m, k = x.shape
    nb, s_steps, vk, vn = vs.vals.shape
    assert k == vs.shape[0] and k % vk == 0, (x.shape, vs.shape, vk)
    assert m % bm == 0, (m, bm)
    out_dtype = out_dtype or (jnp.float32 if x.dtype == jnp.int8 else x.dtype)
    has_scale = scale is not None
    has_bias = bias is not None
    has_residual = residual is not None

    in_specs = [
        pl.BlockSpec((bm, vk), vsmm_x_index_map()),
        pl.BlockSpec((1, 1, vk, vn), vsmm_w_index_map()),
    ]
    args = [vs.idx, x, vs.vals]
    if has_scale:
        in_specs.append(pl.BlockSpec((1, vn), vsmm_bias_index_map()))
        args.append(scale.reshape(nb, vn))
    if has_bias:
        in_specs.append(pl.BlockSpec((1, vn), vsmm_bias_index_map()))
        args.append(bias.reshape(nb, vn))
    if has_residual:
        assert residual.shape == (m, nb * vn), (residual.shape, m, nb * vn)
        in_specs.append(pl.BlockSpec((bm, vn), vsmm_out_index_map()))
        args.append(residual)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, m // bm, s_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, vn), vsmm_out_index_map()),
        scratch_shapes=[pltpu.VMEM((bm, vn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, fuse_relu=fuse_relu, has_scale=has_scale,
                          has_bias=has_bias, has_residual=has_residual,
                          skip_zero_inputs=skip_zero_inputs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, nb * vn), out_dtype),
        interpret=interpret,
        cost_estimate=vsmm_kernel_cost(
            m=m, nb=nb, s_steps=s_steps, vk=vk, vn=vn,
            in_itemsize=x.dtype.itemsize,
            w_itemsize=vs.vals.dtype.itemsize,
            out_itemsize=jnp.dtype(out_dtype).itemsize,
            residual_bytes=(residual.size * residual.dtype.itemsize
                            if has_residual else 0),
        ),
    )(*args)
