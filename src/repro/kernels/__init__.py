"""Pallas TPU kernels for the paper's compute hot-spot: sparse conv/matmul.

- `vsmm`   -- vector-sparse matmul (scalar-prefetch block-CSR, the paper's
             index system as BlockSpec.index_map, runtime input-vector skip)
- `vsconv` -- direct 3x3 vector-sparse convolution (tap-granular weight skip)
- `flash`  -- flash-attention forward (VMEM-resident online softmax; the
             dominant HBM term of every train/prefill roofline cell)
- `ref`    -- pure-jnp oracles
- `ops`    -- jit'd public wrappers (padding, backend dispatch)

Validated with interpret=True on CPU; compiled paths target TPU v5e.
"""
from .ops import vsmm, vsconv
from .flash import flash_fwd_pallas
from . import ref
